//! End-to-end benches: one per paper table family (DESIGN.md §4).
//!
//! Each case decodes a real eval prompt through the full stack (PJRT
//! artifacts + offload policy + simulated memory hierarchy) and reports
//! *wallclock* per decoded request — the L3 perf metric (the paper-scale
//! throughput numbers come from the simulated clock and are produced by
//! `melinoe repro <id>`, not here).
//!
//! Skips cleanly when artifacts are not built.

use melinoe::clock::GpuSpec;
use melinoe::cluster::workload::{OutputLen, PriorityMix};
use melinoe::cluster::{self, ClusterConfig};
use melinoe::coordinator::workload::Arrival;
use melinoe::coordinator::{PreemptPolicy, SchedulerMode};
use melinoe::policies::PolicyConfig;
use melinoe::repro::Ctx;
use melinoe::util::bench::Bench;

fn main() {
    // ---- cluster serving loop (artifact-free: cost model + synthetic traces)
    let mut b = Bench::new("cluster");
    let cfg = {
        let mut c = ClusterConfig::synthetic(4, 16, 4, GpuSpec::h100(), 42)
            .with_arrival(Arrival::Burst);
        c.workload.prompt_tokens = 4;
        c.workload.output = OutputLen::Fixed(8);
        c
    };
    for name in cluster::BALANCERS {
        b.bench(&format!("cluster 4r/16req [{name}]"), || {
            let mut bal = cluster::balancer::by_name(name).unwrap();
            std::hint::black_box(cluster::run_cluster(&cfg, bal.as_mut()).unwrap());
        });
    }
    b.finish();

    // ---- scheduler modes under skewed output lengths (the tentpole's
    // static-vs-continuous comparison, wallclock cost of the sim itself)
    let mut b = Bench::new("scheduler");
    let skew = cfg
        .clone()
        .with_output(OutputLen::Bimodal { short: 4, long: 32, long_frac: 0.25 });
    for mode in [SchedulerMode::Static, SchedulerMode::Continuous] {
        let mcfg = skew.clone().with_scheduler(mode);
        b.bench(&format!("cluster 4r/16req skewed [{mode:?}]"), || {
            let mut bal = cluster::balancer::by_name("expert-affinity").unwrap();
            std::hint::black_box(cluster::run_cluster(&mcfg, bal.as_mut()).unwrap());
        });
    }
    b.finish();

    // ---- chunked prefill over long prompts (wallclock cost of the sim
    // loop at each chunk setting; the sim-time TTFT numbers come from
    // `melinoe repro ext_prefill`)
    let mut b = Bench::new("prefill");
    let long_prompt = {
        let mut c = cfg.clone();
        c.workload.prompt_tokens = 64;
        c.workload.output = OutputLen::Fixed(8);
        c
    };
    for chunk in [1usize, 8, 32] {
        let pcfg = long_prompt.clone().with_prefill_chunk(chunk);
        b.bench(&format!("cluster 4r/16req 64-tok prompts [chunk={chunk}]"), || {
            let mut bal = cluster::balancer::by_name("expert-affinity").unwrap();
            std::hint::black_box(cluster::run_cluster(&pcfg, bal.as_mut()).unwrap());
        });
    }
    b.finish();

    // ---- layer-ahead transfer overlap (wallclock cost of the pipelined
    // sim loop at each lookahead depth; the sim-time stall/overlap
    // numbers come from `melinoe repro ext_overlap`)
    let mut b = Bench::new("overlap");
    let pressure = {
        let mut c = cfg.clone();
        // capacity below the hot-set size so the pipeline actually fires
        c.spec.capacity = (c.spec.capacity / 2).max(1);
        c
    };
    for depth in [0usize, 1, 2] {
        let ocfg = pressure.clone().with_lookahead(depth);
        b.bench(&format!("cluster 4r/16req tight cache [lookahead={depth}]"), || {
            let mut bal = cluster::balancer::by_name("expert-affinity").unwrap();
            std::hint::black_box(cluster::run_cluster(&ocfg, bal.as_mut()).unwrap());
        });
    }
    b.finish();

    // ---- priority preemption (wallclock cost of the suspend/resume
    // machinery in the sim loop; the sim-time TTFT/latency numbers come
    // from `melinoe repro ext_preempt`)
    let mut b = Bench::new("preempt");
    let skewed_prio = cfg
        .clone()
        .with_output(OutputLen::Fixed(16))
        .with_priority_mix(PriorityMix { high: 0.2, low: 0.8 });
    let thresh = skewed_prio.spec.est_service_seconds(4, 16) / 20.0;
    for (label, policy) in [("off", PreemptPolicy::Off), ("on", PreemptPolicy::After(thresh))] {
        let pcfg = skewed_prio.clone().with_preempt(policy);
        b.bench(&format!("cluster 4r/16req 20% high [preempt={label}]"), || {
            let mut bal = cluster::balancer::by_name("expert-affinity").unwrap();
            std::hint::black_box(cluster::run_cluster(&pcfg, bal.as_mut()).unwrap());
        });
    }
    b.finish();

    let dir = melinoe::artifacts_dir();
    let Some(ctx) = ["olmoe-micro", "phi-micro", "mixtral-micro"]
        .iter()
        .find_map(|p| Ctx::load(&dir, p).ok())
    else {
        eprintln!("SKIP e2e bench: no artifacts (run `make artifacts`)");
        return;
    };
    println!("e2e bench preset: {}", ctx.preset);
    let eval = ctx.eval_set("dolly").expect("eval set");
    let prompt = eval.samples[0].prompt.clone();
    let cap = ctx.cfg.cache_capacity;

    // ---- per-policy end-to-end decode (Table 1 / Fig. 3 machinery)
    let mut b = Bench::new("decode_policies");
    let ft = if ctx.cfg.variants.iter().any(|v| v == "ft_dolly") { "ft_dolly" } else { "base" };
    let policies = vec![
        PolicyConfig::base_offload(cap),
        PolicyConfig::melinoe_no_prefetch(ft, cap),
        PolicyConfig::deepspeed_moe(ctx.cfg.top_k),
        PolicyConfig::fiddler(cap),
    ];
    for pol in policies {
        let parts = ctx.parts(&pol, "dolly").expect("parts");
        let engine = parts.engine(&ctx, GpuSpec::h100());
        b.bench(&format!("decode 8 tok [{}]", pol.name), || {
            std::hint::black_box(engine.decode(&prompt, 8).unwrap());
        });
    }
    b.finish();

    // ---- dispatch-level: single PJRT calls (L3 hot path, §Perf)
    let mut b = Bench::new("pjrt_dispatch");
    let pol = PolicyConfig::base_offload(ctx.cfg.n_experts);
    let parts = ctx.parts(&pol, "dolly").expect("parts");
    let store = &parts.store;
    let (kc, vc) = ctx.rt.init_kv(&ctx.cfg).unwrap();
    let x = vec![0.05f32; ctx.cfg.d_model];
    b.bench("layer_step call", || {
        std::hint::black_box(ctx.rt.layer_step(&x, &store.layers[0], &kc, &vc, 0).unwrap());
    });
    let selected: Vec<usize> = (0..ctx.cfg.top_k).collect();
    let stw = store.stack_experts(0, &selected, ctx.cfg.d_model, ctx.cfg.d_ff).unwrap();
    let out = ctx.rt.layer_step(&x, &store.layers[0], &kc, &vc, 0).unwrap();
    let gates = vec![1.0 / ctx.cfg.top_k as f32; ctx.cfg.top_k];
    b.bench("expert_group call (K experts)", || {
        std::hint::black_box(ctx.rt.expert_group(&gates, &out.h2, &stw.wg, &stw.wu, &stw.wd).unwrap());
    });
    b.bench("stack_experts (host gather)", || {
        std::hint::black_box(
            store.stack_experts(0, &selected, ctx.cfg.d_model, ctx.cfg.d_ff).unwrap(),
        );
    });
    b.bench("lm_head call", || {
        std::hint::black_box(ctx.rt.lm_head(&x, &store.lnf_lit, &store.embed_lit).unwrap());
    });
    b.finish();

    // ---- batched serving step (Fig. 5 machinery)
    let mut b = Bench::new("batch_decode");
    let parts = ctx.parts(&PolicyConfig::base_offload(cap), "dolly").expect("parts");
    let engine = parts.engine(&ctx, GpuSpec::h100());
    for bs in [1usize, 2, 4] {
        let prompts: Vec<Vec<usize>> =
            eval.samples.iter().take(bs).map(|s| s.prompt.clone()).collect();
        b.bench(&format!("decode_batch bs={bs}, 4 tok"), || {
            std::hint::black_box(engine.decode_batch(&prompts, 4).unwrap());
        });
    }
    b.finish();
}
