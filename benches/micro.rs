//! Component micro-benchmarks: the host-side pieces of the request path.
//!
//! These are the L3 hot-path candidates identified in DESIGN.md §6 —
//! cache ops, quantization, expert-weight stacking, JSON, ROUGE-L — and
//! feed the §Perf iteration log in EXPERIMENTS.md.

use melinoe::cache::{EvictionKind, LayerCache};
use melinoe::eval::rouge_l;
use melinoe::quant::{dequantize, quantize, QuantMode};
use melinoe::tensor::HostTensor;
use melinoe::util::bench::Bench;
use melinoe::util::json::Json;
use melinoe::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);

    let mut b = Bench::new("cache");
    let trace: Vec<usize> = (0..4096).map(|_| rng.below(64)).collect();
    for kind in [EvictionKind::Lru, EvictionKind::Lfu, EvictionKind::Gamma(0.9)] {
        let mut c = LayerCache::new(64, 16, kind);
        let mut i = 0;
        b.bench(&format!("{kind:?}: request+insert"), || {
            let e = trace[i % trace.len()];
            i += 1;
            if i % 8 == 0 {
                c.token_tick();
            }
            if !c.request(e) {
                c.insert(e, &[e]);
            }
        });
    }
    b.finish();

    let mut b = Bench::new("quant");
    let data: Vec<f32> = (0..3 * 64 * 32).map(|_| rng.normal() as f32).collect();
    b.bench("quantize int4 (one expert)", || {
        std::hint::black_box(quantize(&data, QuantMode::Int4));
    });
    let blob = quantize(&data, QuantMode::Int4);
    b.bench("dequantize int4 (one expert)", || {
        std::hint::black_box(dequantize(&blob));
    });
    b.finish();

    let mut b = Bench::new("host_tensor");
    let probs = HostTensor::new(vec![64], (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect())
        .unwrap();
    b.bench("topk(8) of 64 probs", || {
        std::hint::black_box(probs.topk(8));
    });
    let logits =
        HostTensor::new(vec![512], (0..512).map(|i| ((i * 131) % 512) as f32).collect()).unwrap();
    b.bench("argmax of 512 logits", || {
        std::hint::black_box(logits.argmax());
    });
    let (a, c): (Vec<f32>, Vec<f32>) = ((0..32).map(|i| i as f32).collect(), (0..32).map(|i| i as f32).collect());
    b.bench("residual add d=32", || {
        std::hint::black_box(melinoe::tensor::add(&a, &c));
    });
    b.finish();

    let mut b = Bench::new("eval");
    let x: Vec<usize> = (0..64).map(|_| rng.below(100)).collect();
    let y: Vec<usize> = (0..64).map(|_| rng.below(100)).collect();
    b.bench("rouge_l 64x64", || {
        std::hint::black_box(rouge_l(&x, &y));
    });
    b.finish();

    let mut b = Bench::new("json");
    let doc = format!(
        "{{\"samples\": [{}]}}",
        (0..64)
            .map(|i| format!("{{\"prompt\": [1,2,{i}], \"answer\": \"x\"}}"))
            .collect::<Vec<_>>()
            .join(",")
    );
    b.bench("parse 64-sample eval set", || {
        std::hint::black_box(Json::parse(&doc).unwrap());
    });
    b.finish();
}
