"""AOT build pipeline: pretrain → fine-tune variants → predictors → HLO.

Emits, per model preset, everything the Rust request path consumes:

    artifacts/<preset>/
      config.json                 model dims + cost model + variant index
      hlo/layer_step.hlo.txt      per-layer pre-expert decode step
      hlo/expert_group.hlo.txt    Pallas grouped expert FFN
      hlo/lm_head.hlo.txt         final norm + tied LM head
      hlo/predictor.hlo.txt       activation-predictor MLP
      weights/base.npz            pretrained micro backbone
      weights/<variant>.npz       MELINOE fine-tuned checkpoints
      weights/predictor_<variant>_<ds>.npz
      weights/profile_<variant>_<ds>.npz   router frequency profiles
      eval/eval_<ds>.json         held-out prompts + references
      eval/goldens.json           python-decoded outputs (rust integration)
      logs/*.json                 training curves (EXPERIMENTS.md)

HLO is emitted as *text* — the image's xla_extension 0.5.1 rejects jax≥0.5
serialized protos (64-bit instruction ids); the text parser reassigns ids
(see /opt/xla-example/README.md).  Every stage is resumable: existing
outputs are skipped, so `make artifacts` is cheap when up to date.
"""

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, finetune, predictor, pretrain
from .configs import (
    PRESETS,
    FinetuneConfig,
    ModelConfig,
    PredictorConfig,
    PretrainConfig,
    finetune_plan,
)
from .model import (
    decode_greedy,
    decode_layer_step,
    expert_group,
    forward,
    lm_head_fn,
    topk_mask,
)
from .predictor import predictor_forward


# ----------------------------------------------------------------- lowering
def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_hlo(cfg: ModelConfig, outdir: str, pcfg: PredictorConfig) -> None:
    hlodir = os.path.join(outdir, "hlo")
    os.makedirs(hlodir, exist_ok=True)
    d, e, k, dff, v = cfg.d_model, cfg.n_experts, cfg.top_k, cfg.d_ff, cfg.vocab_size
    kv = f32(cfg.n_heads, cfg.max_seq, cfg.head_dim)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)

    def layer_fn(x, ln1, wq, wk, wv, wo, ln2, router_w, kc, vc, pos):
        return decode_layer_step(
            x, ln1, wq, wk, wv, wo, ln2, router_w, kc, vc, pos, cfg=cfg, use_pallas=True
        )

    jobs = {
        "layer_step": (
            layer_fn,
            (f32(d), f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d),
             f32(d), f32(e, d), kv, kv, i32),
        ),
        "expert_group": (
            lambda gates, h2, wg, wu, wd: expert_group(gates, h2, wg, wu, wd, use_pallas=True),
            (f32(k), f32(d), f32(k, dff, d), f32(k, dff, d), f32(k, d, dff)),
        ),
        "lm_head": (
            lambda h, lnf, emb: lm_head_fn(h, lnf, emb, cfg=cfg),
            (f32(d), f32(d), f32(v, d)),
        ),
        "predictor": (
            lambda x, w1, b1, w2, b2: predictor_forward(
                {"w1": w1, "b1": b1, "w2": w2, "b2": b2}, x, cfg.n_layers, cfg.n_experts
            ),
            (f32(d), f32(pcfg.hidden_dim, d), f32(pcfg.hidden_dim),
             f32(cfg.n_layers * e, pcfg.hidden_dim), f32(cfg.n_layers * e)),
        ),
    }
    for name, (fn, specs) in jobs.items():
        path = os.path.join(hlodir, f"{name}.hlo.txt")
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        with open(path, "w") as f:
            f.write(text)
        print(f"  [hlo {cfg.name}] {name}: {len(text)} chars", flush=True)


# ------------------------------------------------------------------- saving
def save_npz(path: str, arrays) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in arrays.items()})


def load_npz(path: str):
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


def save_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)


# ----------------------------------------------------------------- profiles
def routing_profile(params, cfg: ModelConfig, dataset: str, n_batches: int = 8):
    """Average request frequency per (layer, expert) over training-split
    batches — the MoE-Infinity-style activation profile."""
    rng = np.random.RandomState(17)
    acc = np.zeros((cfg.n_layers, cfg.n_experts), np.float64)
    tot = 0.0
    fwd = jax.jit(lambda p, t: forward(p, t, cfg))
    for _ in range(n_batches):
        seeds = rng.randint(0, data.EVAL_SEED_OFFSET, size=4)
        toks, mask = data.pack_batch(dataset, seeds, 48)
        _, probs = fwd(params, jnp.asarray(toks))
        req, _, _ = topk_mask(probs, cfg.top_k)
        w = jnp.asarray(mask)[None, :, :, None]
        acc += np.asarray(jnp.sum(req * w, axis=(1, 2)))
        tot += float(mask.sum())
    return acc / max(tot, 1.0)


# ------------------------------------------------------------------ goldens
def build_goldens(weights_by_variant, cfg: ModelConfig, n_prompts: int = 3, n_gen: int = 12):
    """Python-decoded outputs through the *pallas* path; the Rust engine
    must reproduce these token-for-token (integration test)."""
    out = {}
    for variant, params in weights_by_variant.items():
        recs = []
        for ds in ("dolly-syn", "gsm-syn"):
            for s in data.eval_samples(ds, n_prompts, seed=3):
                prompt = s.tokens[: s.prompt_len]
                gen, _ = decode_greedy(params, prompt, n_gen, cfg, use_pallas=True)
                recs.append({"dataset": ds, "prompt": prompt, "expected": gen})
        out[variant] = recs
    return out


# -------------------------------------------------------------------- build
def build_preset(cfg: ModelConfig, outdir: str, fast: bool, stages) -> None:
    os.makedirs(outdir, exist_ok=True)
    for sub in ("hlo", "weights", "eval", "logs"):
        os.makedirs(os.path.join(outdir, sub), exist_ok=True)
    wdir = os.path.join(outdir, "weights")
    ldir = os.path.join(outdir, "logs")

    shrink = (lambda s: max(s // 10, 3)) if fast else (lambda s: s)
    pcfg = PretrainConfig()
    if cfg.name != "olmoe-micro":
        # the coarse-expert presets learn the (easier, lower-E) routing
        # task faster; fewer steps keeps the single-core build tractable
        pcfg = dataclasses.replace(pcfg, steps=350)
    pcfg = dataclasses.replace(pcfg, steps=shrink(pcfg.steps))
    predcfg = PredictorConfig()
    if cfg.name != "olmoe-micro":
        predcfg = dataclasses.replace(predcfg, n_prompts=32, epochs=15)
    if fast:
        predcfg = dataclasses.replace(predcfg, n_prompts=12, epochs=5, gen_tokens=8)

    # 1. pretrain --------------------------------------------------------
    base_path = os.path.join(wdir, "base.npz")
    if "train" in stages:
        if not os.path.exists(base_path):
            t0 = time.time()
            params, log = pretrain.pretrain(cfg, pcfg)
            save_npz(base_path, params)
            save_json(os.path.join(ldir, "pretrain.json"), log)
            print(f"  [pretrain {cfg.name}] done in {time.time()-t0:.0f}s", flush=True)
        base = load_npz(base_path)

        # 2. fine-tune variants ----------------------------------------
        for fcfg in finetune_plan(cfg):
            path = os.path.join(wdir, f"{fcfg.variant}.npz")
            if os.path.exists(path):
                continue
            fcfg = dataclasses.replace(fcfg, steps=shrink(fcfg.steps))
            t0 = time.time()
            merged, log = finetune.finetune(base, cfg, fcfg)
            save_npz(path, merged)
            save_json(os.path.join(ldir, f"{fcfg.variant}.json"), log)
            print(f"  [ft {cfg.name}/{fcfg.variant}] done in {time.time()-t0:.0f}s", flush=True)

    # 3. predictors + profiles ------------------------------------------
    if "predict" in stages:
        base = load_npz(base_path)
        main_variants = {"base": base}
        for short, ds in (("dolly", "dolly-syn"), ("gsm", "gsm-syn")):
            vpath = os.path.join(wdir, f"ft_{short}.npz")
            if os.path.exists(vpath):
                main_variants[f"ft_{short}"] = load_npz(vpath)
        for variant, params in main_variants.items():
            for short, ds in (("dolly", "dolly-syn"), ("gsm", "gsm-syn")):
                prof_path = os.path.join(wdir, f"profile_{variant}_{short}.npz")
                if not os.path.exists(prof_path):
                    save_npz(prof_path, {"freq": routing_profile(params, cfg, ds)})
                # predictors only for the checkpoints that serve that dataset
                if variant != "base" and variant != f"ft_{short}":
                    continue
                pred_path = os.path.join(wdir, f"predictor_{variant}_{short}.npz")
                if os.path.exists(pred_path):
                    continue
                x, y = predictor.build_dataset(params, cfg, ds, predcfg)
                mlp, log = predictor.train_predictor(x, y, cfg, predcfg)
                hit = predictor.topc_hit_rate(mlp, x, y, cfg, cfg.cache_capacity)
                print(f"  [predictor {cfg.name}/{variant}/{short}] top-C hit {hit:.2f}", flush=True)
                save_npz(pred_path, mlp)
                save_json(os.path.join(ldir, f"predictor_{variant}_{short}.json"),
                          {"log": log, "topc_hit": hit})

    # 4. eval sets + goldens --------------------------------------------
    if "eval" in stages:
        for short, ds in (("dolly", "dolly-syn"), ("gsm", "gsm-syn")):
            path = os.path.join(outdir, "eval", f"eval_{short}.json")
            if not os.path.exists(path):
                save_json(path, data.export_eval_set(ds, 64, cfg.max_seq // 4, cfg.max_seq - 8))
        gpath = os.path.join(outdir, "eval", "goldens.json")
        if not os.path.exists(gpath):
            wv = {"base": load_npz(base_path)}
            ft_path = os.path.join(wdir, "ft_dolly.npz")
            if os.path.exists(ft_path):
                wv["ft_dolly"] = load_npz(ft_path)
            save_json(gpath, build_goldens(wv, cfg))

    # 5. HLO + config -----------------------------------------------------
    if "hlo" in stages:
        lower_hlo(cfg, outdir, predcfg)
        variants = ["base"] + [f.variant for f in finetune_plan(cfg)]
        conf = cfg.to_json_dict()
        conf["variants"] = variants
        conf["predictor_hidden"] = predcfg.hidden_dim
        conf["finetune"] = [dataclasses.asdict(f) for f in finetune_plan(cfg)]
        save_json(os.path.join(outdir, "config.json"), conf)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="all", choices=["all", *PRESETS])
    ap.add_argument("--stages", default="train,predict,eval,hlo")
    ap.add_argument("--fast", action="store_true", help="smoke-test build (tiny step counts)")
    args = ap.parse_args()
    stages = set(args.stages.split(","))
    names = list(PRESETS) if args.preset == "all" else [args.preset]
    for name in names:
        cfg = PRESETS[name]
        print(f"[aot] building {name} → {args.out_dir}/{name}", flush=True)
        build_preset(cfg, os.path.join(args.out_dir, name), args.fast, stages)
    print("[aot] complete", flush=True)


if __name__ == "__main__":
    main()
