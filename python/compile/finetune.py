"""MELINOE fine-tuning (paper §3.1.1).

Trainable parameters, per the paper: the router weights and the expert
*gate* projections (full-rank), plus LoRA adapters on the expert up and
down projections.  Everything else (embeddings, attention, norms) stays at
the pretrained values.

Each step runs two forwards: the trainable model (base ⊕ trainable subset ⊕
LoRA) and the *frozen base* model, whose router distributions feed the
rank-matching loss L_rm (the fine-tuned router must preserve the base
router's expert ordering up to margin ρ — the anti-collapse term).
"""

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .configs import FinetuneConfig, ModelConfig
from .losses import melinoe_objective
from .model import Params, forward, init_lora, merge_lora
from .optim import adamw_init, adamw_update, linear_schedule


def split_trainable(base: Params, cfg: ModelConfig) -> Tuple[Params, Params]:
    """(trainable, frozen): router + gate projections train full-rank."""
    train_keys = set()
    for l in range(cfg.n_layers):
        train_keys.add(f"l{l}.router")
        train_keys.add(f"l{l}.wg")
    trainable = {k: v for k, v in base.items() if k in train_keys}
    frozen = {k: v for k, v in base.items() if k not in train_keys}
    return trainable, frozen


def finetune(
    base_params: Params, cfg: ModelConfig, fcfg: FinetuneConfig, log_every: int = 25
) -> Tuple[Params, List[Dict]]:
    """Returns (merged fine-tuned params, training log)."""
    trainable, frozen = split_trainable(base_params, cfg)
    lora = init_lora(cfg, fcfg.lora_rank, fcfg.seed)
    tstate = {"w": trainable, "lora": lora}
    opt = adamw_init(tstate)

    def loss_fn(ts, toks, mask):
        p = {**frozen, **ts["w"]}
        logits, probs_f = forward(
            p, toks, cfg, lora=ts["lora"], lora_alpha=fcfg.lora_alpha, lora_rank=fcfg.lora_rank
        )
        _, probs_b = forward(base_params, toks, cfg)
        # routing locality is shaped over the *whole* sequence (prompt +
        # completion); NLL stays masked to the completion.
        valid = (toks != 0).astype(logits.dtype)
        total, parts = melinoe_objective(
            logits, probs_f, probs_b, toks, mask,
            lambda_cs=fcfg.lambda_cs, lambda_rm=fcfg.lambda_rm,
            gamma=fcfg.gamma, capacity=float(fcfg.cache_capacity),
            top_k=cfg.top_k, rho=fcfg.rho, aux_mask=valid,
        )
        return total, parts

    @jax.jit
    def step_fn(ts, opt_state, step, toks, mask):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(ts, toks, mask)
        lr = linear_schedule(step, fcfg.steps, fcfg.lr, fcfg.warmup_ratio)
        ts, opt_state = adamw_update(ts, grads, opt_state, lr, weight_decay=fcfg.weight_decay)
        return ts, opt_state, parts

    rng = np.random.RandomState(fcfg.seed + 2)
    log: List[Dict] = []
    t0 = time.time()
    for i in range(fcfg.steps):
        seeds = rng.randint(0, data.EVAL_SEED_OFFSET, size=fcfg.batch_size)
        toks, mask = data.pack_batch(fcfg.dataset, seeds, fcfg.seq_len)
        tstate, opt, parts = step_fn(
            tstate, opt, jnp.int32(i), jnp.asarray(toks), jnp.asarray(mask)
        )
        if i % log_every == 0 or i == fcfg.steps - 1:
            rec = {"step": i, "sec": time.time() - t0}
            rec.update({k: float(v) for k, v in parts.items()})
            log.append(rec)
            print(
                f"  [ft {cfg.name}/{fcfg.variant}] step {i} "
                f"nll={rec['nll']:.3f} cs={rec['cs']:.3f} rm={rec['rm']:.4f}",
                flush=True,
            )
    merged = merge_lora(
        {**frozen, **tstate["w"]}, tstate["lora"], cfg, fcfg.lora_alpha, fcfg.lora_rank
    )
    return merged, log
