"""Pallas kernel: single-query decode attention over a KV cache.

Decode attention for one token: q attends over all cached positions.  The
grid iterates over heads; each step stages one head's K/V cache stripes
HBM→VMEM and computes a masked softmax-weighted sum.  RoPE is applied by
the surrounding L2 function (model.decode_layer_step), keeping the kernel a
pure attention primitive.

The additive mask (0 valid / -1e9 invalid) is computed by the caller from
the scalar position, which keeps the kernel free of dynamic control flow —
the TPU-friendly formulation of the causal constraint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    q = q_ref[0]  # [hd]
    k = k_ref[0]  # [T, hd]
    v = v_ref[0]  # [T, hd]
    hd = q.shape[-1]
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(hd)
    )
    scores = scores + mask_ref[...]
    # numerically stable softmax in-kernel
    m = jnp.max(scores)
    e = jnp.exp(scores - m)
    w = e / jnp.sum(e)
    o_ref[0] = jnp.dot(w, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k_cache, v_cache, mask, *, interpret: bool = True):
    """q: [H, hd]; k_cache, v_cache: [H, T, hd]; mask: [T] -> [H, hd].

    Matches kernels.ref.ref_decode_attention.
    """
    h, hd = q.shape
    t = k_cache.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, hd), lambda i: (i, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, hd), jnp.float32),
        interpret=interpret,
    )(q, k_cache, v_cache, mask)


def position_mask(t_max: int, pos) -> jnp.ndarray:
    """Additive mask admitting cache slots 0..pos inclusive."""
    idx = jnp.arange(t_max)
    return jnp.where(idx <= pos, 0.0, NEG_INF).astype(jnp.float32)
