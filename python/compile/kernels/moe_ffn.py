"""Pallas kernel: grouped top-K expert FFN (the MELINOE compute hot-spot).

The paper's hot path executes, for each token, the K routed experts'
SwiGLU FFNs and combines them with the router probabilities (Eqs. 1–2).
On GPU this is a batch of per-expert GEMVs with weights streamed from HBM.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the grid iterates over the
K selected experts; each grid step's BlockSpec stages exactly one expert's
(gate, up, down) tiles HBM→VMEM while the MXU computes
``wd @ (silu(wg @ h) * (wu @ h))``.  The probability-weighted K-expert
reduction is a sequential grid accumulation into the output block — the
idiomatic TPU replacement for the GPU's atomics / second kernel.  dff is
additionally tiled so that one (expert, dff-tile) working set stays well
under VMEM; the f-axis partial products accumulate into the same output
block.

Lowered with ``interpret=True``: CPU PJRT cannot execute Mosaic
custom-calls, so the kernel runs as plain HLO with identical semantics; the
grid/BlockSpec structure (and the VMEM/MXU estimates in EXPERIMENTS.md
§Perf) is what carries to real TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(gates_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    k = pl.program_id(0)
    f = pl.program_id(1)
    x = x_ref[...]  # [d]
    g = jnp.dot(wg_ref[0], x, preferred_element_type=jnp.float32)  # [tf]
    u = jnp.dot(wu_ref[0], x, preferred_element_type=jnp.float32)  # [tf]
    a = jax.nn.silu(g) * u
    y = jnp.dot(wd_ref[0], a, preferred_element_type=jnp.float32)  # [d]
    y = y * gates_ref[0]

    @pl.when(jnp.logical_and(k == 0, f == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += y


def _pick_tile(dff: int, max_tile: int = 128) -> int:
    """Largest divisor of dff that is <= max_tile (VMEM budget knob)."""
    t = min(dff, max_tile)
    while dff % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tile_f", "interpret"))
def moe_ffn(gates, x, wg, wu, wd, *, tile_f: int = 0, interpret: bool = True):
    """Grouped K-expert FFN.

    gates: [K]; x: [d]; wg, wu: [K, dff, d]; wd: [K, d, dff] -> [d]
    Matches kernels.ref.ref_moe_ffn.
    """
    k_sel, dff, d = wg.shape
    tf = tile_f or _pick_tile(dff)
    assert dff % tf == 0, (dff, tf)
    grid = (k_sel, dff // tf)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda k, f: (k,)),  # gates
            pl.BlockSpec((d,), lambda k, f: (0,)),  # x (resident)
            pl.BlockSpec((1, tf, d), lambda k, f: (k, f, 0)),  # wg tile
            pl.BlockSpec((1, tf, d), lambda k, f: (k, f, 0)),  # wu tile
            pl.BlockSpec((1, d, tf), lambda k, f: (k, 0, f)),  # wd tile
        ],
        out_specs=pl.BlockSpec((d,), lambda k, f: (0,)),  # accumulated
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(gates, x, wg, wu, wd)


def vmem_bytes(d: int, dff: int, tile_f: int = 0, bytes_per_el: int = 4) -> int:
    """Per-grid-step VMEM working set (weights tiles + activations)."""
    tf = tile_f or _pick_tile(dff)
    weights = 2 * tf * d + d * tf  # wg, wu, wd tiles
    acts = d + 3 * tf + d  # x, g/u/a, y/out
    return (weights + acts) * bytes_per_el
