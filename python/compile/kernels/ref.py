"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: ``pytest python/tests`` asserts the
Pallas kernels (interpret mode) match these references across
hypothesis-swept shapes, and the L2 training path uses them directly (the
Pallas kernels are reserved for the AOT decode artifacts).
"""

import jax
import jax.numpy as jnp


def ref_expert_ffn(wg, wu, wd, x):
    """One expert's SwiGLU FFN (paper Eq. 2).

    wg, wu: [dff, d]; wd: [d, dff]; x: [d] -> [d]
    """
    return wd @ (jax.nn.silu(wg @ x) * (wu @ x))


def ref_moe_ffn(gates, x, wg, wu, wd):
    """Grouped K-expert FFN with probability-weighted combine (paper Eq. 1).

    gates: [K]; x: [d]; wg, wu: [K, dff, d]; wd: [K, d, dff] -> [d]
    """
    g = jnp.einsum("kfd,d->kf", wg, x)
    u = jnp.einsum("kfd,d->kf", wu, x)
    a = jax.nn.silu(g) * u
    y = jnp.einsum("kdf,kf->kd", wd, a)
    return jnp.einsum("k,kd->d", gates, y)


def ref_decode_attention(q, k_cache, v_cache, mask):
    """Single-query multi-head attention over a KV cache.

    q: [H, hd]; k_cache, v_cache: [H, T, hd]; mask: [T] additive
    (0 for valid positions, large negative for invalid) -> [H, hd]
    """
    hd = q.shape[-1]
    scores = jnp.einsum("hd,htd->ht", q, k_cache) / jnp.sqrt(jnp.float32(hd))
    w = jax.nn.softmax(scores + mask[None, :], axis=-1)
    return jnp.einsum("ht,htd->hd", w, v_cache)
