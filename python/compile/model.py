"""L2: the MoE transformer (JAX), shared between training and AOT export.

Architecture (per layer): RMSNorm → multi-head attention with RoPE →
residual → RMSNorm → router (softmax over E experts, paper Eq. 1) → top-K
SwiGLU experts (paper Eq. 2) → probability-weighted combine → residual.
The LM head is tied to the token embedding.

Two execution paths share the same parameters:

* ``forward``       — batched teacher-forced training forward returning
                      logits and the per-layer router distributions the
                      MELINOE losses need.  Expert compute is gather-based
                      (only the K routed experts per token), with
                      ``jax.checkpoint`` per layer so the gathered weight
                      tensors are recomputed rather than stored for the
                      backward pass.
* ``decode_layer_step`` / ``expert_group`` / ``lm_head_fn`` — the unbatched
  decode-step functions that ``aot.py`` lowers to HLO artifacts.  Expert
  weights are *inputs* of ``expert_group``: the Rust coordinator owns
  residency and must produce the routed experts' weights for every call —
  a cache miss is literally a weight fetch.

Parameters live in a flat ``{name: array}`` dict (a valid pytree) so they
round-trip through ``.npz`` untouched.
"""

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import ref
from .kernels.attention import decode_attention, position_mask
from .kernels.moe_ffn import moe_ffn

Params = Dict[str, jnp.ndarray]


# ------------------------------------------------------------------- params
def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    rng = np.random.RandomState(seed)

    def dense(*shape):
        scale = 1.0 / np.sqrt(shape[-1])
        return jnp.asarray(rng.randn(*shape).astype(np.float32) * scale)

    p: Params = {"embed": dense(cfg.vocab_size, cfg.d_model), "lnf": jnp.ones(cfg.d_model)}
    d, dff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    for l in range(cfg.n_layers):
        p[f"l{l}.ln1"] = jnp.ones(d)
        p[f"l{l}.ln2"] = jnp.ones(d)
        for w in ("wq", "wk", "wv", "wo"):
            p[f"l{l}.{w}"] = dense(d, d)
        p[f"l{l}.router"] = dense(e, d)
        p[f"l{l}.wg"] = dense(e, dff, d)
        p[f"l{l}.wu"] = dense(e, dff, d)
        p[f"l{l}.wd"] = dense(e, d, dff)
    return p


def init_lora(cfg: ModelConfig, rank: int, seed: int = 0) -> Params:
    """LoRA adapters on the expert up & down projections (paper §3.1.1)."""
    rng = np.random.RandomState(seed + 99)
    e, d, dff = cfg.n_experts, cfg.d_model, cfg.d_ff
    p: Params = {}
    for l in range(cfg.n_layers):
        # A ~ N(0, 1/r), B = 0 → identity at init.
        p[f"l{l}.wu_a"] = jnp.asarray(
            rng.randn(e, rank, d).astype(np.float32) / np.sqrt(rank)
        )
        p[f"l{l}.wu_b"] = jnp.zeros((e, dff, rank), jnp.float32)
        p[f"l{l}.wd_a"] = jnp.asarray(
            rng.randn(e, rank, dff).astype(np.float32) / np.sqrt(rank)
        )
        p[f"l{l}.wd_b"] = jnp.zeros((e, d, rank), jnp.float32)
    return p


def merge_lora(params: Params, lora: Params, cfg: ModelConfig, alpha: float, rank: int) -> Params:
    """Fold LoRA adapters into dense expert weights (done once at export)."""
    out = dict(params)
    scale = alpha / rank
    for l in range(cfg.n_layers):
        out[f"l{l}.wu"] = params[f"l{l}.wu"] + scale * jnp.einsum(
            "efr,erd->efd", lora[f"l{l}.wu_b"], lora[f"l{l}.wu_a"]
        )
        out[f"l{l}.wd"] = params[f"l{l}.wd"] + scale * jnp.einsum(
            "edr,erf->edf", lora[f"l{l}.wd_b"], lora[f"l{l}.wd_a"]
        )
    return out


# --------------------------------------------------------------------- ops
def rmsnorm(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope_angles(positions, head_dim: int, theta: float):
    """positions [...], returns cos/sin of shape [..., head_dim//2]."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., head_dim] with positions broadcastable to x.shape[:-1]."""
    half = x.shape[-1] // 2
    cos, sin = _rope_angles(positions, x.shape[-1], theta)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def router_probs(h2, router_w):
    """softmax(W_r x) — paper Eq. 1. h2: [..., d], router_w: [E, d]."""
    return jax.nn.softmax(h2 @ router_w.T, axis=-1)


def topk_mask(p, k: int):
    """Binary request vector r (‖r‖₁ = K) plus the top-k values/indices."""
    topv, topi = jax.lax.top_k(p, k)
    mask = jnp.sum(jax.nn.one_hot(topi, p.shape[-1], dtype=p.dtype), axis=-2)
    return mask, topv, topi


def ste_request(p, mask):
    """Straight-through request vector: forward = binary mask, backward =
    gradient through the routing probabilities on the selected entries.
    (The paper's r is binary; this is the standard differentiable proxy.)"""
    sel = p * mask
    return jax.lax.stop_gradient(mask - sel) + sel


# --------------------------------------------------------- training forward
def _attention_train(x, wq, wk, wv, wo, cfg: ModelConfig):
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ wq.T).reshape(b, t, h, hd)
    k = (x @ wk.T).reshape(b, t, h, hd)
    v = (x @ wv.T).reshape(b, t, h, hd)
    pos = jnp.arange(t)
    q = apply_rope(q, pos[None, :, None], cfg.rope_theta)
    k = apply_rope(k, pos[None, :, None], cfg.rope_theta)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal[None, None], scores, -1e9)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, t, d)
    return out @ wo.T


def _moe_block_train(h2, layer_w, cfg: ModelConfig):
    """Gather-based top-K expert execution.  Returns (y, probs)."""
    p = router_probs(h2, layer_w["router"])  # [B,T,E]
    _, topv, topi = topk_mask(p, cfg.top_k)

    def per_sample(args):
        h2_b, topi_b, topv_b = args  # [T,d], [T,K], [T,K]
        wg = layer_w["wg"][topi_b]  # [T,K,dff,d]
        wu = layer_w["wu"][topi_b]
        wd = layer_w["wd"][topi_b]
        g = jnp.einsum("tkfd,td->tkf", wg, h2_b)
        u = jnp.einsum("tkfd,td->tkf", wu, h2_b)
        a = jax.nn.silu(g) * u
        y = jnp.einsum("tkdf,tkf->tkd", wd, a)
        return jnp.einsum("tk,tkd->td", topv_b, y)

    y = jax.lax.map(per_sample, (h2, topi, topv))
    return y, p


def _layer_train(x, layer_w, cfg: ModelConfig):
    h = rmsnorm(x, layer_w["ln1"], cfg.rms_eps)
    x = x + _attention_train(h, layer_w["wq"], layer_w["wk"], layer_w["wv"], layer_w["wo"], cfg)
    h2 = rmsnorm(x, layer_w["ln2"], cfg.rms_eps)
    y, p = _moe_block_train(h2, layer_w, cfg)
    return x + y, p


def layer_weights(params: Params, l: int) -> Dict[str, jnp.ndarray]:
    names = ("ln1", "wq", "wk", "wv", "wo", "ln2", "router", "wg", "wu", "wd")
    return {n: params[f"l{l}.{n}"] for n in names}


def forward(
    params: Params, tokens, cfg: ModelConfig, lora: Params = None,
    lora_alpha: float = 16.0, lora_rank: int = 8,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced forward.

    tokens: [B, T] int32.
    Returns (logits [B,T,V], probs [L,B,T,E]).
    """
    if lora is not None:
        params = merge_lora(params, lora, cfg, lora_alpha, lora_rank)
    x = params["embed"][tokens]
    probs = []
    step = jax.checkpoint(functools.partial(_layer_train, cfg=cfg))
    for l in range(cfg.n_layers):
        x, p = step(x, layer_weights(params, l))
        probs.append(p)
    x = rmsnorm(x, params["lnf"], cfg.rms_eps)
    logits = x @ params["embed"].T
    return logits, jnp.stack(probs)


# ----------------------------------------------------- decode-step functions
def decode_layer_step(
    x, ln1, wq, wk, wv, wo, ln2, router_w, k_cache, v_cache, pos,
    *, cfg: ModelConfig, use_pallas: bool = True,
):
    """One layer's pre-expert decode step (lowered to layer_step.hlo.txt).

    x: [d]; k_cache, v_cache: [H, T_max, hd]; pos: scalar int32.
    Returns (probs [E], h_res [d], h2 [d], new_k_cache, new_v_cache).
    The expert contribution is applied by the caller (Rust) as
    ``x_next = h_res + expert_group(...)``.
    """
    h_dim, hd = cfg.n_heads, cfg.head_dim
    h = rmsnorm(x, ln1, cfg.rms_eps)
    q = (wq @ h).reshape(h_dim, hd)
    k = (wk @ h).reshape(h_dim, hd)
    v = (wv @ h).reshape(h_dim, hd)
    q = apply_rope(q, jnp.full((h_dim,), pos), cfg.rope_theta)
    k = apply_rope(k, jnp.full((h_dim,), pos), cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k[:, None, :], (0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v[:, None, :], (0, pos, 0))
    mask = position_mask(k_cache.shape[1], pos)
    if use_pallas:
        attn = decode_attention(q, k_cache, v_cache, mask)
    else:
        attn = ref.ref_decode_attention(q, k_cache, v_cache, mask)
    h_res = x + wo @ attn.reshape(-1)
    h2 = rmsnorm(h_res, ln2, cfg.rms_eps)
    probs = jax.nn.softmax(router_w @ h2)
    return probs, h_res, h2, k_cache, v_cache


def expert_group(gates, h2, wg, wu, wd, *, use_pallas: bool = True):
    """Grouped routed-expert FFN (lowered to expert_group.hlo.txt).

    gates: [K]; h2: [d]; wg/wu: [K,dff,d]; wd: [K,d,dff] → y [d].
    """
    if use_pallas:
        return moe_ffn(gates, h2, wg, wu, wd)
    return ref.ref_moe_ffn(gates, h2, wg, wu, wd)


def lm_head_fn(h, lnf, embed, *, cfg: ModelConfig):
    """Final norm + tied LM head (lowered to lm_head.hlo.txt)."""
    return embed @ rmsnorm(h, lnf, cfg.rms_eps)


# --------------------------------------------------- python-side decoding
def init_kv(cfg: ModelConfig):
    shape = (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"))
def decode_token(params: Params, tok, pos, k_caches, v_caches, cfg: ModelConfig, use_pallas: bool = False):
    """Run one full decode step in python (predictor data / goldens).

    Returns (next_token, probs [L,E], new caches).
    Mirrors exactly what the Rust engine does with the HLO artifacts.
    """
    x = params["embed"][tok]
    probs_all = []
    new_k, new_v = [], []
    for l in range(cfg.n_layers):
        w = layer_weights(params, l)
        probs, h_res, h2, kc, vc = decode_layer_step(
            x, w["ln1"], w["wq"], w["wk"], w["wv"], w["wo"], w["ln2"],
            w["router"], k_caches[l], v_caches[l], pos,
            cfg=cfg, use_pallas=use_pallas,
        )
        _, topv, topi = topk_mask(probs, cfg.top_k)
        y = expert_group(
            topv, h2, w["wg"][topi], w["wu"][topi], w["wd"][topi],
            use_pallas=use_pallas,
        )
        x = h_res + y
        probs_all.append(probs)
        new_k.append(kc)
        new_v.append(vc)
    logits = lm_head_fn(x, params["lnf"], params["embed"], cfg=cfg)
    return jnp.argmax(logits), jnp.stack(probs_all), jnp.stack(new_k), jnp.stack(new_v)


def decode_greedy(params: Params, prompt, n_gen: int, cfg: ModelConfig, use_pallas: bool = False):
    """Greedy decode; returns (generated tokens, probs [steps, L, E]).

    probs covers every decode step (prompt prefill + generation), matching
    the router-statistics collection the predictor trains on (§3.1.2).
    """
    k_caches, v_caches = init_kv(cfg)
    probs_hist = []
    tok = None
    gen = []
    for i, t in enumerate(list(prompt)):
        tok, probs, k_caches, v_caches = decode_token(
            params, jnp.int32(t), jnp.int32(i), k_caches, v_caches, cfg, use_pallas
        )
        probs_hist.append(probs)
    pos = len(prompt)
    for _ in range(n_gen):
        gen.append(int(tok))
        if gen[-1] == 2:  # EOS
            break
        tok, probs, k_caches, v_caches = decode_token(
            params, jnp.int32(tok), jnp.int32(pos), k_caches, v_caches, cfg, use_pallas
        )
        probs_hist.append(probs)
        pos += 1
    return gen, jnp.stack(probs_hist)
