"""Hand-rolled AdamW + linear-warmup/linear-decay schedule.

(optax is unavailable in the offline image; this is the standard textbook
AdamW over flat ``{name: array}`` pytrees, with decoupled weight decay and
bias correction, matching the paper's optimizer settings in Table 7.)
"""

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def adamw_init(params: Params) -> Dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(
    params: Params,
    grads: Params,
    state: Dict,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Tuple[Params, Dict]:
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - lr * (step + weight_decay * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def linear_schedule(step, total_steps: int, peak_lr: float, warmup_ratio: float):
    """Linear warmup to peak then linear decay to 0 (paper Table 7)."""
    warm = max(int(total_steps * warmup_ratio), 1)
    s = step.astype(jnp.float32)
    up = peak_lr * s / warm
    down = peak_lr * jnp.maximum(total_steps - s, 0.0) / max(total_steps - warm, 1)
    return jnp.where(s < warm, up, down)
