"""Procedural corpora standing in for Dolly15K and GSM8K.

The paper fine-tunes on an instruction-following set (Dolly15K) and a math
set with longer generations (GSM8K).  Neither is available offline, so we
build procedural equivalents over a 512-token vocabulary:

* ``dolly-syn`` — instruction templates (copy / reverse / sort / last) over
  items drawn from one of eight latent *domains* (disjoint token blocks).
  A sequence stays inside its domain, giving the router natural
  sequence-level expert preferences — exactly the "weak specialization" the
  paper exploits (§2, Expert Specialization).  Quality metric: ROUGE-L of
  the generated completion against the reference (mirrors Table 2 left).

* ``gsm-syn`` — small arithmetic chains ``a ± b ± c`` with the result spelt
  out in digit tokens after an ``ANS`` marker, prefixed by domain "subject"
  filler.  Quality metric: exact-match of the answer digits (mirrors
  Table 2 right).

Both generators are pure functions of a seed; train and eval splits use
disjoint seed ranges.
"""

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

# ---------------------------------------------------------------- vocabulary
PAD, BOS, EOS, SEP = 0, 1, 2, 3
CMD_COPY, CMD_REV, CMD_SORT, CMD_LAST = 4, 5, 6, 7
DIG0 = 10  # digits 0..9 -> tokens 10..19
Q_TOK, PLUS, MINUS, EQ, ANS = 20, 21, 22, 24, 25

N_DOMAINS = 8
DOMAIN_BLOCK = 16
DOMAIN_BASE = 32  # domain d owns tokens [32 + 48*d, 32 + 48*(d+1))
VOCAB_SIZE = 512

EVAL_SEED_OFFSET = 1_000_000


def domain_tokens(domain: int) -> np.ndarray:
    lo = DOMAIN_BASE + DOMAIN_BLOCK * domain
    return np.arange(lo, lo + DOMAIN_BLOCK)


def digits_of(n: int) -> List[int]:
    return [DIG0 + int(c) for c in str(n)]


@dataclass
class Sample:
    tokens: List[int]  # BOS ... EOS
    prompt_len: int  # prompt = tokens[:prompt_len] (ends with SEP)
    domain: int
    answer: str = ""  # gsm only: decimal string


# ---------------------------------------------------------------- dolly-syn
def make_dolly(seed: int) -> Sample:
    rng = np.random.RandomState(seed)
    domain = int(rng.randint(N_DOMAINS))
    cmd = int(rng.choice([CMD_COPY, CMD_REV, CMD_SORT, CMD_LAST]))
    n_items = int(rng.randint(4, 10))
    items = rng.choice(domain_tokens(domain), size=n_items, replace=True)
    if cmd == CMD_COPY:
        out = list(items)
    elif cmd == CMD_REV:
        out = list(items[::-1])
    elif cmd == CMD_SORT:
        out = sorted(items.tolist())
    else:  # CMD_LAST: echo the final three items
        out = list(items[-3:])
    prompt = [BOS, cmd] + [int(t) for t in items] + [SEP]
    tokens = prompt + [int(t) for t in out] + [EOS]
    return Sample(tokens=tokens, prompt_len=len(prompt), domain=domain)


# ------------------------------------------------------------------ gsm-syn
def make_gsm(seed: int) -> Sample:
    rng = np.random.RandomState(seed)
    domain = int(rng.randint(N_DOMAINS))
    subject = rng.choice(domain_tokens(domain), size=4, replace=True)
    n_terms = int(rng.randint(2, 4))
    vals = [int(rng.randint(1, 10)) for _ in range(n_terms)]
    ops = [int(rng.choice([PLUS, MINUS])) for _ in range(n_terms - 1)]
    acc = vals[0]
    body: List[int] = [DIG0 + vals[0]]
    for op, v in zip(ops, vals[1:]):
        body += [op, DIG0 + v]
        acc = acc + v if op == PLUS else acc - v
    acc = abs(acc)
    prompt = [BOS] + [int(t) for t in subject] + [Q_TOK] + body + [EQ, SEP]
    tokens = prompt + [ANS] + digits_of(acc) + [EOS]
    return Sample(tokens=tokens, prompt_len=len(prompt), domain=domain, answer=str(acc))


MAKERS = {"dolly-syn": make_dolly, "gsm-syn": make_gsm}


def make_sample(dataset: str, seed: int) -> Sample:
    return MAKERS[dataset](seed)


# ----------------------------------------------------------------- batching
def pack_batch(
    dataset: str, seeds: np.ndarray, seq_len: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Right-padded token batch plus an NLL mask.

    The mask is 1 on positions whose *next-token* prediction is scored —
    completion tokens only, matching instruction-tuning practice (prompt
    tokens condition but are not scored).
    """
    bsz = len(seeds)
    toks = np.full((bsz, seq_len), PAD, dtype=np.int32)
    mask = np.zeros((bsz, seq_len), dtype=np.float32)
    for b, seed in enumerate(seeds):
        s = make_sample(dataset, int(seed))
        t = s.tokens[:seq_len]
        toks[b, : len(t)] = t
        # position i predicts token i+1; score predictions of completion.
        lo = max(s.prompt_len - 1, 0)
        hi = max(len(t) - 1, lo)
        mask[b, lo:hi] = 1.0
    return toks, mask


def train_batches(dataset: str, steps: int, batch_size: int, seq_len: int, seed: int):
    """Deterministic stream of (tokens, mask) train batches."""
    rng = np.random.RandomState(seed)
    for _ in range(steps):
        seeds = rng.randint(0, EVAL_SEED_OFFSET, size=batch_size)
        yield pack_batch(dataset, seeds, seq_len)


def eval_samples(dataset: str, n: int, seed: int = 0) -> List[Sample]:
    """Held-out samples (seed range disjoint from training)."""
    rng = np.random.RandomState(seed + 7)
    seeds = EVAL_SEED_OFFSET + rng.randint(0, 1_000_000, size=n)
    return [make_sample(dataset, int(s)) for s in seeds]


def eval_batch(dataset: str, n: int, seq_len: int, seed: int = 0):
    rng = np.random.RandomState(seed + 7)
    seeds = EVAL_SEED_OFFSET + rng.randint(0, 1_000_000, size=n)
    return pack_batch(dataset, seeds, seq_len)


def export_eval_set(dataset: str, n: int, max_prompt: int, max_total: int) -> Dict:
    """JSON-serializable eval set consumed by the Rust harness."""
    out = []
    for s in eval_samples(dataset, n):
        if s.prompt_len > max_prompt or len(s.tokens) > max_total:
            continue
        out.append(
            {
                "prompt": s.tokens[: s.prompt_len],
                "reference": s.tokens[s.prompt_len :],
                "domain": s.domain,
                "answer": s.answer,
            }
        )
    return {"dataset": dataset, "samples": out}
