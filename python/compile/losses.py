"""MELINOE fine-tuning objectives (paper §3.1.1, Appendix C).

* ``cache_sim_loss``  — L_cs: soft-cache simulation loss.  The soft cache
  state follows the normalized recursion of Proposition C.3 exactly:

      c^{t+1} = (γ Z^t c^t + r^t) / Z^{t+1},   Z^{t+1} = γ Z^t + K/C

  with uniform initialization ‖c^1‖₁ = C, Z^1 = 1 (the paper's alternative
  to the cache-fill phase).  The request vector r is the straight-through
  relaxation of the binary top-K mask (model.ste_request).

* ``rank_match_loss`` — L_rm: margin rank loss (Eq. 12), a differentiable
  upper bound on ρ·Inv(p_f, p_b) (Lemma C.8).

* ``nll_loss``        — masked next-token NLL.
* ``load_balance_loss`` — Switch-style auxiliary used only for *pretraining*
  the base models, giving them the paper's "broad utilization" pathology.
"""

from typing import Tuple

import jax
import jax.numpy as jnp

from .model import ste_request, topk_mask


def soft_cache_scan(r_seq, gamma: float, capacity: float, top_k: int):
    """Run the Prop. C.3 soft-cache recursion over a request sequence.

    r_seq: [T, E] request vectors (rows sum to K).
    Returns c_seq [T, E]: the cache state *seen by* token t (i.e. built from
    requests 1..t-1), with uniform init c^1 = C/E · 1, ‖c^t‖₁ = C ∀t.
    """
    t_len, e = r_seq.shape
    c0 = jnp.full((e,), capacity / e, r_seq.dtype)
    z0 = jnp.asarray(1.0, r_seq.dtype)

    def step(carry, r_t):
        c, z = carry
        z_next = gamma * z + top_k / capacity
        c_next = (gamma * z * c + r_t) / z_next
        return (c_next, z_next), c

    (_, _), c_seq = jax.lax.scan(step, (c0, z0), r_seq)
    return c_seq


def cache_sim_loss(probs, gamma: float, capacity: float, top_k: int, token_mask=None):
    """L_cs = (1/LT) Σ_{ℓ,t} Σ_i r_i (1 − c_i)   (paper Eq. 4).

    probs: [L, B, T, E] router distributions.
    token_mask: optional [B, T] (1 = real token); padded positions
    contribute no requests and are excluded from the average.
    """
    l, b, t, e = probs.shape
    mask, _, _ = topk_mask(probs, top_k)
    # Cache history evolves from the *hard* requests (stop-grad: the cache
    # state is environment, not a control knob), while the miss penalty is
    # charged against the *soft* request K·p — the dense differentiable
    # relaxation of the binary r whose gradient moves probability mass
    # toward cache-resident experts at every position.  (With the paper's
    # multi-epoch budget the straight-through form works too; the dense
    # form reaches the same routing-locality fixed point in far fewer
    # steps — see DESIGN.md §2.)
    r_hard = jax.lax.stop_gradient(mask)
    r_soft = top_k * probs
    if token_mask is not None:
        r_hard = r_hard * token_mask[None, :, :, None]
        r_soft = r_soft * token_mask[None, :, :, None]

    def per_seq(args):  # ([T,E], [T,E])
        r_seq, s_seq = args
        c_seq = jax.lax.stop_gradient(soft_cache_scan(r_seq, gamma, capacity, top_k))
        # clamp: with uniform init the normalized state stays ≤ C but
        # individual entries can exceed 1; the miss proxy floors at 0.
        miss = s_seq * jnp.clip(1.0 - c_seq, 0.0, None)
        return jnp.sum(miss, axis=-1)  # [T]

    flat_h = r_hard.reshape(l * b, t, e)
    flat_s = r_soft.reshape(l * b, t, e)
    miss = jax.vmap(per_seq)((flat_h, flat_s))  # [L*B, T]
    if token_mask is not None:
        denom = l * jnp.maximum(jnp.sum(token_mask), 1.0)
    else:
        denom = l * b * t
    return jnp.sum(miss) / denom


def rank_match_loss(probs_f, probs_b, rho: float, token_mask=None):
    """L_rm = (1/LT) Σ_{ℓ,t} Σ_{i,j} 1{p_b,i > p_b,j}[ρ − (p_f,i − p_f,j)]₊.

    probs_f, probs_b: [L, B, T, E].
    """
    l, b, t, e = probs_f.shape
    gt = (probs_b[..., :, None] > probs_b[..., None, :]).astype(probs_f.dtype)
    diff = probs_f[..., :, None] - probs_f[..., None, :]
    # normalized by the number of ordered pairs so the loss scale (and the
    # meaning of lambda_rm) is comparable across expert counts E
    m = jnp.mean(gt * jax.nn.relu(rho - diff), axis=(-1, -2))  # [L,B,T]
    if token_mask is not None:
        m = m * token_mask[None]
        denom = l * jnp.maximum(jnp.sum(token_mask), 1.0)
    else:
        denom = l * b * t
    return jnp.sum(m) / denom


def nll_loss(logits, tokens, mask):
    """Masked next-token NLL.  logits [B,T,V], tokens [B,T], mask [B,T]
    (mask[i] scores the prediction of tokens[i+1])."""
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, :-1]
    return -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)


def perplexity(logits, tokens, mask) -> jnp.ndarray:
    return jnp.exp(nll_loss(logits, tokens, mask))


def load_balance_loss(probs, top_k: int, token_mask=None):
    """Switch-transformer auxiliary: E · Σ_i f_i · P_i per layer, averaged.

    f_i = fraction of routed (token, slot) assignments to expert i;
    P_i = mean router probability of expert i.
    """
    l, b, t, e = probs.shape
    mask, _, _ = topk_mask(probs, top_k)  # [L,B,T,E]
    if token_mask is not None:
        w = token_mask[None, :, :, None]
        denom = jnp.maximum(jnp.sum(token_mask), 1.0)
        f = jnp.sum(mask * w, axis=(1, 2)) / (denom * top_k)  # [L,E]
        p = jnp.sum(probs * w, axis=(1, 2)) / denom
    else:
        f = jnp.mean(mask, axis=(1, 2)) / top_k
        p = jnp.mean(probs, axis=(1, 2))
    return e * jnp.mean(jnp.sum(f * p, axis=-1))


def melinoe_objective(
    logits, probs_f, probs_b, tokens, mask,
    *, lambda_cs: float, lambda_rm: float, gamma: float, capacity: float,
    top_k: int, rho: float, aux_mask=None,
) -> Tuple[jnp.ndarray, dict]:
    """Full fine-tuning loss L = L_nll + λ_cs L_cs + λ_rm L_rm (Eq. 6).

    ``mask`` scores the NLL (completion tokens); ``aux_mask`` (default:
    same) covers the positions whose *routing* the auxiliary losses see —
    the paper computes L_cs/L_rm over the whole sequence, so fine-tuning
    passes the full validity mask here.
    """
    if aux_mask is None:
        aux_mask = mask
    l_nll = nll_loss(logits, tokens, mask)
    l_cs = cache_sim_loss(probs_f, gamma, capacity, top_k, token_mask=aux_mask)
    l_rm = rank_match_loss(probs_f, jax.lax.stop_gradient(probs_b), rho, token_mask=aux_mask)
    total = l_nll + lambda_cs * l_cs + lambda_rm * l_rm
    return total, {"nll": l_nll, "cs": l_cs, "rm": l_rm, "total": total}
