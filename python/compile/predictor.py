"""Expert-activation predictor Ψ (paper §3.1.2).

Dataset: for each training prompt q we record the per-layer *average router
probability* over a greedy generation, Y(q) ∈ R^{L×E} — exactly the
supervised target of the paper.  The prompt representation Ψ_EMB(q) is the
mean-pooled (frozen) MoE token embedding of the prompt (the offline
substitute for BGE-Base; DESIGN.md §2.4).

Model: a two-layer MLP trained with row-wise-softmax KL divergence against
the row-normalized targets, SGD + momentum (paper Table 8).
"""

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .configs import ModelConfig, PredictorConfig
from .model import Params, decode_greedy


def build_dataset(
    params: Params, cfg: ModelConfig, dataset: str, pcfg: PredictorConfig
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X [N, d], Y [N, L, E])."""
    rng = np.random.RandomState(pcfg.seed + 11)
    embed = np.asarray(params["embed"])
    xs, ys = [], []
    for n in range(pcfg.n_prompts):
        seed = int(rng.randint(0, data.EVAL_SEED_OFFSET))
        s = data.make_sample(dataset, seed)
        prompt = s.tokens[: s.prompt_len]
        _, probs_hist = decode_greedy(params, prompt, pcfg.gen_tokens, cfg)
        xs.append(embed[prompt].mean(axis=0))
        ys.append(np.asarray(probs_hist.mean(axis=0)))  # [L,E]
    return np.stack(xs), np.stack(ys)


def init_predictor(cfg: ModelConfig, pcfg: PredictorConfig, seed: int = 0) -> Dict:
    rng = np.random.RandomState(seed + 12)
    d, h = cfg.d_model, pcfg.hidden_dim
    out = cfg.n_layers * cfg.n_experts
    return {
        "w1": jnp.asarray(rng.randn(h, d).astype(np.float32) / np.sqrt(d)),
        "b1": jnp.zeros(h, jnp.float32),
        "w2": jnp.asarray(rng.randn(out, h).astype(np.float32) / np.sqrt(h)),
        "b2": jnp.zeros(out, jnp.float32),
    }


def predictor_forward(p: Dict, x, n_layers: int, n_experts: int):
    """x: [..., d] → scores [..., L, E].  Must match rust predictor/mlp.rs
    and the lowered predictor.hlo.txt bit-for-bit in structure."""
    h = jax.nn.relu(x @ p["w1"].T + p["b1"])
    out = h @ p["w2"].T + p["b2"]
    return out.reshape(*x.shape[:-1], n_layers, n_experts)


def kl_loss(p: Dict, x, y, n_layers: int, n_experts: int):
    """Row-wise KL(target ‖ softmax(pred)) (paper §3.1.2)."""
    scores = predictor_forward(p, x, n_layers, n_experts)
    logq = jax.nn.log_softmax(scores, axis=-1)
    tgt = y / jnp.clip(jnp.sum(y, axis=-1, keepdims=True), 1e-9)
    ent = jnp.sum(tgt * jnp.log(jnp.clip(tgt, 1e-9)), axis=-1)
    return jnp.mean(ent - jnp.sum(tgt * logq, axis=-1))


def train_predictor(
    x: np.ndarray, y: np.ndarray, cfg: ModelConfig, pcfg: PredictorConfig
) -> Tuple[Dict, List[Dict]]:
    params = init_predictor(cfg, pcfg, pcfg.seed)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)

    @jax.jit
    def step_fn(p, v, xb, yb):
        loss, g = jax.value_and_grad(kl_loss)(p, xb, yb, cfg.n_layers, cfg.n_experts)
        v = jax.tree_util.tree_map(lambda v_, g_: pcfg.momentum * v_ + g_, v, g)
        p = jax.tree_util.tree_map(lambda p_, v_: p_ - pcfg.lr * v_, p, v)
        return p, v, loss

    n = x.shape[0]
    rng = np.random.RandomState(pcfg.seed + 13)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    log: List[Dict] = []
    t0 = time.time()
    for ep in range(pcfg.epochs):
        order = rng.permutation(n)
        losses = []
        for lo in range(0, n, pcfg.batch_size):
            idx = order[lo : lo + pcfg.batch_size]
            params, vel, loss = step_fn(params, vel, xj[idx], yj[idx])
            losses.append(float(loss))
        if ep % 10 == 0 or ep == pcfg.epochs - 1:
            rec = {"epoch": ep, "kl": float(np.mean(losses)), "sec": time.time() - t0}
            log.append(rec)
            print(f"  [predictor {cfg.name}] epoch {ep} kl={rec['kl']:.4f}", flush=True)
    return params, log


def topc_hit_rate(p: Dict, x, y, cfg: ModelConfig, capacity: int) -> float:
    """Eval: fraction of true top-C experts recovered in the predicted
    top-C prefetch set, averaged over layers/prompts."""
    scores = np.asarray(predictor_forward(p, jnp.asarray(x), cfg.n_layers, cfg.n_experts))
    hits = []
    for n in range(x.shape[0]):
        for l in range(cfg.n_layers):
            pred = set(np.argsort(-scores[n, l])[:capacity].tolist())
            true = set(np.argsort(-y[n, l])[:capacity].tolist())
            hits.append(len(pred & true) / capacity)
    return float(np.mean(hits))
