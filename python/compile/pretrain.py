"""From-scratch pretraining of the micro MoE backbones.

The paper starts from pretrained checkpoints (OLMoE / Phi-3.5-MoE /
Mixtral-8x7B) whose routers were trained with *load-balancing* objectives —
the very objective that causes broad expert utilization and heavy cache
churn (§2).  To reproduce that starting point we pretrain each micro model
on a 50/50 mix of the two synthetic corpora with NLL + a Switch-style
load-balance auxiliary, so the base router exhibits the paper's "weak
sequence-level specialization, broad global utilization" pathology before
MELINOE fine-tuning is applied.
"""

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .configs import ModelConfig, PretrainConfig
from .losses import load_balance_loss, nll_loss
from .model import Params, forward, init_params
from .optim import adamw_init, adamw_update, linear_schedule


def pretrain(cfg: ModelConfig, pcfg: PretrainConfig, log_every: int = 50) -> Tuple[Params, List[Dict]]:
    params = init_params(cfg, pcfg.seed)
    opt = adamw_init(params)

    def loss_fn(p, toks, mask):
        logits, probs = forward(p, toks, cfg)
        l_nll = nll_loss(logits, toks, mask)
        l_lb = load_balance_loss(probs, cfg.top_k, token_mask=mask)
        return l_nll + pcfg.load_balance_coef * l_lb, (l_nll, l_lb)

    @jax.jit
    def step_fn(p, opt_state, step, toks, mask):
        (loss, (l_nll, l_lb)), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, toks, mask)
        lr = linear_schedule(step, pcfg.steps, pcfg.lr, pcfg.warmup_ratio)
        p, opt_state = adamw_update(p, grads, opt_state, lr, weight_decay=pcfg.weight_decay)
        return p, opt_state, loss, l_nll, l_lb

    rng = np.random.RandomState(pcfg.seed + 1)
    log: List[Dict] = []
    t0 = time.time()
    for i in range(pcfg.steps):
        ds = "dolly-syn" if i % 2 == 0 else "gsm-syn"
        seeds = rng.randint(0, data.EVAL_SEED_OFFSET, size=pcfg.batch_size)
        toks, mask = data.pack_batch(ds, seeds, pcfg.seq_len)
        params, opt, loss, l_nll, l_lb = step_fn(
            params, opt, jnp.int32(i), jnp.asarray(toks), jnp.asarray(mask)
        )
        if i % log_every == 0 or i == pcfg.steps - 1:
            rec = {
                "step": i,
                "loss": float(loss),
                "nll": float(l_nll),
                "lb": float(l_lb),
                "sec": time.time() - t0,
            }
            log.append(rec)
            print(f"  [pretrain {cfg.name}] step {i} nll={rec['nll']:.3f} lb={rec['lb']:.3f}", flush=True)
    return params, log
