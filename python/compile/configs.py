"""Model presets for the MELINOE reproduction.

Each preset pairs a *micro* configuration (what actually runs — numerics,
routing, fine-tuning) with the *paper-scale* cost-model configuration of the
backbone it mirrors (Table 6 of the paper).  The micro model keeps the axes
MELINOE's mechanism depends on — expert count E, top-K, cache capacity C,
and expert granularity — and shrinks only the hidden dimensions.  The Rust
coordinator uses the paper-scale dims to drive the simulated clock (GPU
roofline + PCIe transfer model, paper Eq. 3 / Table 9).
"""

from dataclasses import dataclass, field, asdict
from typing import Dict, List


@dataclass(frozen=True)
class CostDims:
    """Paper-scale dimensions (Table 6) used only by the L3 cost model."""

    n_layers: int
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    total_params_b: float
    active_params_b: float

    def expert_bytes_fp16(self) -> int:
        """Bytes of one expert's (gate, up, down) projections in fp16."""
        return 2 * 3 * self.d_model * self.d_ff


@dataclass(frozen=True)
class ModelConfig:
    """Micro-model configuration (what is pretrained / fine-tuned / served)."""

    name: str
    mirrors: str
    n_layers: int
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    n_heads: int
    vocab_size: int
    max_seq: int
    # Evaluation cache capacity (GPU-resident experts per layer, paper
    # Table 10: OLMoE 16, Phi-3.5-MoE 8, Mixtral-8x7B 5).
    cache_capacity: int
    cost: CostDims = field(default=None)
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_json_dict(self) -> Dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["cost"]["expert_bytes_fp16"] = self.cost.expert_bytes_fp16()
        return d


OLMOE_MICRO = ModelConfig(
    name="olmoe-micro",
    mirrors="OLMoE",
    n_layers=8,
    n_experts=64,
    top_k=8,
    d_model=32,
    d_ff=64,
    n_heads=4,
    vocab_size=512,
    max_seq=288,
    cache_capacity=16,
    cost=CostDims(
        n_layers=16,
        n_experts=64,
        top_k=8,
        d_model=2048,
        d_ff=1024,
        total_params_b=6.9,
        active_params_b=1.3,
    ),
)

PHI_MICRO = ModelConfig(
    name="phi-micro",
    mirrors="Phi-3.5-MoE",
    n_layers=8,
    n_experts=16,
    top_k=2,
    d_model=32,
    d_ff=128,
    n_heads=4,
    vocab_size=512,
    max_seq=288,
    cache_capacity=8,
    cost=CostDims(
        n_layers=32,
        n_experts=16,
        top_k=2,
        d_model=4096,
        d_ff=6400,
        total_params_b=42.0,
        active_params_b=6.6,
    ),
)

MIXTRAL_MICRO = ModelConfig(
    name="mixtral-micro",
    mirrors="Mixtral-8x7B",
    n_layers=8,
    n_experts=8,
    top_k=2,
    d_model=32,
    d_ff=192,
    n_heads=4,
    vocab_size=512,
    max_seq=288,
    cache_capacity=5,
    cost=CostDims(
        n_layers=32,
        n_experts=8,
        top_k=2,
        d_model=4096,
        d_ff=14336,
        total_params_b=46.7,
        active_params_b=12.9,
    ),
)

PRESETS: Dict[str, ModelConfig] = {
    c.name: c for c in (OLMOE_MICRO, PHI_MICRO, MIXTRAL_MICRO)
}


@dataclass(frozen=True)
class FinetuneConfig:
    """MELINOE fine-tuning hyperparameters (paper Table 7, scaled)."""

    variant: str  # artifact name, e.g. "ft_dolly"
    dataset: str  # "dolly-syn" | "gsm-syn"
    lambda_cs: float
    lambda_rm: float
    gamma: float = 0.9
    rho: float = 0.1
    cache_capacity: int = 16  # C used *inside* the loss (soft cache)
    steps: int = 80
    batch_size: int = 4
    seq_len: int = 48
    lr: float = 3e-3
    warmup_ratio: float = 0.03
    weight_decay: float = 0.01
    lora_rank: int = 8
    lora_alpha: float = 16.0
    seed: int = 0


def default_ft(preset: ModelConfig, dataset: str, **kw) -> FinetuneConfig:
    """Paper defaults: Dolly15K uses (λcs, λrm) = (0.5, 0.1); GSM8K uses
    (0.05, 0.01); C = E/4 during fine-tuning (Table 7)."""
    short = "dolly" if dataset == "dolly-syn" else "gsm"
    # Paper Table 7: (0.5, 0.1) dolly / (0.05, 0.01) gsm over 3-5 epochs of
    # ~15k samples.  Our budget is ~10^2 steps, so coefficients scale 4x
    # (ratio preserved) to reach the same routing-locality fixed point.
    lam_cs, lam_rm = (2.0, 0.5) if dataset == "dolly-syn" else (0.2, 0.05)
    base = dict(
        variant=f"ft_{short}",
        dataset=dataset,
        lambda_cs=lam_cs,
        lambda_rm=lam_rm,
        cache_capacity=max(preset.n_experts // 4, 2),
    )
    base.update(kw)
    return FinetuneConfig(**base)


def finetune_plan(preset: ModelConfig) -> List[FinetuneConfig]:
    """All fine-tuned variants built for a preset.

    olmoe-micro carries the full ablation grid (γ sweep for Fig. 13 /
    Table 13, C_loss sweep for Fig. 12, λ sweeps for Fig. 4); the larger
    presets only build the two main-results checkpoints.
    """
    short_steps = 80 if preset.name == "olmoe-micro" else 60
    plan = [
        default_ft(preset, "dolly-syn", steps=short_steps),
        default_ft(preset, "gsm-syn", steps=short_steps),
    ]
    if preset.name != "olmoe-micro":
        return plan
    for g in (0.1, 0.3, 0.5, 0.7):
        plan.append(
            default_ft(preset, "dolly-syn", variant=f"ft_dolly_g{int(g*10):02d}", gamma=g, steps=50)
        )
    for c in (8, 32):
        plan.append(
            default_ft(preset, "dolly-syn", variant=f"ft_dolly_c{c}", cache_capacity=c, steps=50)
        )
    for lcs in (0.1, 2.0, 10.0):
        tag = str(lcs).replace(".", "p")
        plan.append(
            default_ft(preset, "dolly-syn", variant=f"ft_dolly_lcs{tag}", lambda_cs=lcs, steps=50)
        )
    for lrm in (0.01, 1.0):
        tag = str(lrm).replace(".", "p")
        plan.append(
            default_ft(preset, "dolly-syn", variant=f"ft_dolly_lrm{tag}", lambda_rm=lrm, steps=50)
        )
    return plan


@dataclass(frozen=True)
class PretrainConfig:
    steps: int = 800
    batch_size: int = 6
    seq_len: int = 48
    lr: float = 3e-3
    warmup_ratio: float = 0.05
    weight_decay: float = 0.01
    load_balance_coef: float = 0.01
    seed: int = 0


@dataclass(frozen=True)
class PredictorConfig:
    """Activation-predictor MLP (paper Table 8, embedder substituted with
    mean-pooled MoE token embeddings, see DESIGN.md §2.4)."""

    hidden_dim: int = 128
    n_prompts: int = 64
    gen_tokens: int = 16
    epochs: int = 25
    lr: float = 0.2
    momentum: float = 0.9
    batch_size: int = 16
    seed: int = 0
