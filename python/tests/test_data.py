"""Synthetic corpora: determinism, structure, masks."""

import numpy as np

from compile import data


def test_determinism():
    for ds in ("dolly-syn", "gsm-syn"):
        a = data.make_sample(ds, 42)
        b = data.make_sample(ds, 42)
        assert a.tokens == b.tokens and a.prompt_len == b.prompt_len


def test_dolly_structure():
    for seed in range(50):
        s = data.make_sample("dolly-syn", seed)
        assert s.tokens[0] == data.BOS
        assert s.tokens[-1] == data.EOS
        assert s.tokens[s.prompt_len - 1] == data.SEP
        items = s.tokens[2 : s.prompt_len - 1]
        dom = data.domain_tokens(s.domain)
        assert all(dom[0] <= t <= dom[-1] for t in items)


def test_dolly_commands_correct():
    for seed in range(80):
        s = data.make_sample("dolly-syn", seed)
        cmd = s.tokens[1]
        items = s.tokens[2 : s.prompt_len - 1]
        out = s.tokens[s.prompt_len : -1]
        if cmd == data.CMD_COPY:
            assert out == items
        elif cmd == data.CMD_REV:
            assert out == items[::-1]
        elif cmd == data.CMD_SORT:
            assert out == sorted(items)
        elif cmd == data.CMD_LAST:
            assert out == items[-3:]


def test_gsm_answer_correct():
    for seed in range(80):
        s = data.make_sample("gsm-syn", seed)
        # re-evaluate the arithmetic from the prompt tokens
        body = s.tokens[6 : s.prompt_len - 2]  # after BOS + 4 subject + Q
        acc = body[0] - data.DIG0
        i = 1
        while i < len(body):
            op, v = body[i], body[i + 1] - data.DIG0
            acc = acc + v if op == data.PLUS else acc - v
            i += 2
        assert str(abs(acc)) == s.answer
        # answer digits encoded after ANS
        ans_toks = s.tokens[s.prompt_len + 1 : -1]
        assert "".join(str(t - data.DIG0) for t in ans_toks) == s.answer


def test_domains_disjoint():
    blocks = [set(data.domain_tokens(d).tolist()) for d in range(data.N_DOMAINS)]
    for i in range(len(blocks)):
        for j in range(i + 1, len(blocks)):
            assert not blocks[i] & blocks[j]
    assert max(max(b) for b in blocks) < data.VOCAB_SIZE


def test_pack_batch_mask_semantics():
    toks, mask = data.pack_batch("dolly-syn", np.arange(4), 48)
    assert toks.shape == (4, 48) and mask.shape == (4, 48)
    for b in range(4):
        s = data.make_sample("dolly-syn", b)
        t = s.tokens[:48]
        # mask scores exactly the completion predictions
        lo, hi = s.prompt_len - 1, len(t) - 1
        assert mask[b, :lo].sum() == 0
        assert mask[b, lo:hi].all()
        assert mask[b, hi:].sum() == 0


def test_eval_split_disjoint_from_train():
    train_seeds = set(range(1000))
    ev = data.eval_samples("dolly-syn", 20)
    # eval sampling uses seeds >= EVAL_SEED_OFFSET; spot-check outputs differ
    tr = [data.make_sample("dolly-syn", s) for s in list(train_seeds)[:20]]
    assert any(e.tokens != t.tokens for e, t in zip(ev, tr))


def test_export_eval_set_shape():
    out = data.export_eval_set("gsm-syn", 16, 40, 100)
    assert out["dataset"] == "gsm-syn"
    for s in out["samples"]:
        assert s["prompt"][-1] == data.SEP
        assert s["answer"]
        assert len(s["prompt"]) <= 40
