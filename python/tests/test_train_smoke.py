"""End-to-end smoke of the pre-deployment stage on a tiny config.

Checks the *direction* of each training effect: pretraining lowers NLL,
MELINOE fine-tuning lowers the cache-simulation loss (routing locality up)
without NLL blow-up, and the predictor's KL decreases.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, data, finetune, losses, model, optim, predictor, pretrain

TINY = dataclasses.replace(
    configs.OLMOE_MICRO, name="tiny-train", n_layers=2, n_experts=16, top_k=4,
    d_model=16, d_ff=32, n_heads=2, vocab_size=512, max_seq=64,
    cache_capacity=4, cost=configs.OLMOE_MICRO.cost,
)


@pytest.fixture(scope="module")
def pretrained():
    pcfg = configs.PretrainConfig(steps=30, batch_size=4, seq_len=32)
    params, log = pretrain.pretrain(TINY, pcfg, log_every=29)
    return params, log


def test_pretrain_reduces_nll(pretrained):
    _, log = pretrained
    assert log[-1]["nll"] < log[0]["nll"]


def test_finetune_improves_routing_locality(pretrained):
    params, _ = pretrained
    fcfg = configs.FinetuneConfig(
        variant="t", dataset="dolly-syn", lambda_cs=1.0, lambda_rm=0.1,
        cache_capacity=4, steps=40, batch_size=4, seq_len=32, lr=5e-3,
    )
    merged, log = finetune.finetune(params, TINY, fcfg, log_every=39)
    assert log[-1]["cs"] < log[0]["cs"], "cache-sim loss should fall"

    # the operational target: fewer misses under an LFU expert cache
    toks, mask = data.pack_batch("dolly-syn", np.arange(4) + 500, 32)

    def lfu_misses(p_, capacity=4):
        _, probs = model.forward(p_, jnp.asarray(toks), TINY)
        req, _, _ = model.topk_mask(probs, TINY.top_k)
        req = np.asarray(req * jnp.asarray(mask)[None, :, :, None])  # [L,B,T,E]
        misses = 0
        for l in range(req.shape[0]):
            for b in range(req.shape[1]):
                freq = np.zeros(TINY.n_experts)
                resident: set = set()
                for t in range(req.shape[2]):
                    sel = np.where(req[l, b, t] > 0)[0]
                    for e in sel:
                        freq[e] += 1
                        if e not in resident:
                            misses += 1
                            if len(resident) >= capacity:
                                victim = min(resident, key=lambda x: freq[x])
                                resident.discard(victim)
                            resident.add(e)
        return misses

    assert lfu_misses(merged) <= lfu_misses(params) + 2


def test_finetune_only_touches_allowed_params(pretrained):
    params, _ = pretrained
    fcfg = configs.FinetuneConfig(
        variant="t2", dataset="gsm-syn", lambda_cs=0.5, lambda_rm=0.1,
        cache_capacity=4, steps=3, batch_size=2, seq_len=32,
    )
    merged, _ = finetune.finetune(params, TINY, fcfg)
    for k in params:
        frozen = not any(s in k for s in (".router", ".wg", ".wu", ".wd"))
        same = bool(jnp.all(merged[k] == params[k]))
        assert same == frozen, f"{k}: frozen={frozen} but same={same}"


def test_predictor_learns(pretrained):
    params, _ = pretrained
    pcfg = configs.PredictorConfig(n_prompts=8, gen_tokens=6, epochs=10, batch_size=4)
    x, y = predictor.build_dataset(params, TINY, "dolly-syn", pcfg)
    assert x.shape == (8, TINY.d_model) and y.shape == (8, TINY.n_layers, TINY.n_experts)
    mlp, log = predictor.train_predictor(x, y, TINY, pcfg)
    assert log[-1]["kl"] < log[0]["kl"]
    hit = predictor.topc_hit_rate(mlp, x, y, TINY, TINY.cache_capacity)
    assert hit > TINY.cache_capacity / TINY.n_experts  # beats random


def test_adamw_converges_quadratic():
    p = {"x": jnp.asarray([5.0, -3.0])}
    st = optim.adamw_init(p)
    import jax

    g = jax.grad(lambda q: jnp.sum(q["x"] ** 2))
    for i in range(300):
        p, st = optim.adamw_update(p, g(p), st, 0.1)
    assert float(jnp.max(jnp.abs(p["x"]))) < 0.05


def test_linear_schedule_shape():
    lr0 = float(optim.linear_schedule(jnp.int32(0), 100, 1.0, 0.1))
    lr_peak = float(optim.linear_schedule(jnp.int32(10), 100, 1.0, 0.1))
    lr_end = float(optim.linear_schedule(jnp.int32(100), 100, 1.0, 0.1))
    assert lr0 == 0.0 and abs(lr_peak - 1.0) < 1e-6 and lr_end == 0.0
