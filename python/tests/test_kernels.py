"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis-swept)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention, position_mask
from compile.kernels.moe_ffn import _pick_tile, moe_ffn, vmem_bytes


def rand(rng, *shape, scale=0.5):
    return jnp.asarray(rng.randn(*shape).astype(np.float32) * scale)


# ------------------------------------------------------------------ moe_ffn
@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([1, 2, 4, 8]),
    dff=st.sampled_from([16, 64, 128, 192]),
    d=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_moe_ffn_matches_ref(k, dff, d, seed):
    rng = np.random.RandomState(seed)
    gates = jnp.asarray(np.abs(rng.randn(k)).astype(np.float32))
    x = rand(rng, d)
    wg, wu = rand(rng, k, dff, d), rand(rng, k, dff, d)
    wd = rand(rng, k, d, dff)
    got = moe_ffn(gates, x, wg, wu, wd)
    want = ref.ref_moe_ffn(gates, x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("tile", [16, 32, 64])
def test_moe_ffn_tile_invariant(tile):
    """Output must not depend on the dff tiling (pure perf knob)."""
    rng = np.random.RandomState(0)
    k, dff, d = 4, 64, 32
    gates = jnp.asarray(np.abs(rng.randn(k)).astype(np.float32))
    x, wg, wu, wd = rand(rng, d), rand(rng, k, dff, d), rand(rng, k, dff, d), rand(rng, k, d, dff)
    full = moe_ffn(gates, x, wg, wu, wd, tile_f=dff)
    tiled = moe_ffn(gates, x, wg, wu, wd, tile_f=tile)
    np.testing.assert_allclose(full, tiled, rtol=1e-4, atol=1e-5)


def test_moe_ffn_zero_gates():
    rng = np.random.RandomState(1)
    k, dff, d = 2, 16, 8
    out = moe_ffn(
        jnp.zeros(k), rand(rng, d), rand(rng, k, dff, d), rand(rng, k, dff, d), rand(rng, k, d, dff)
    )
    np.testing.assert_allclose(out, np.zeros(d), atol=1e-7)


def test_moe_ffn_single_expert_equals_expert_ffn():
    rng = np.random.RandomState(2)
    dff, d = 32, 16
    x, wg, wu, wd = rand(rng, d), rand(rng, 1, dff, d), rand(rng, 1, dff, d), rand(rng, 1, d, dff)
    got = moe_ffn(jnp.ones(1), x, wg, wu, wd)
    want = ref.ref_expert_ffn(wg[0], wu[0], wd[0], x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_moe_ffn_linear_in_gates():
    """y(α·gates) = α·y(gates): the combine is linear in the router probs."""
    rng = np.random.RandomState(3)
    k, dff, d = 4, 32, 16
    gates = jnp.asarray(np.abs(rng.randn(k)).astype(np.float32))
    args = (rand(rng, d), rand(rng, k, dff, d), rand(rng, k, dff, d), rand(rng, k, d, dff))
    np.testing.assert_allclose(
        moe_ffn(2.5 * gates, *args), 2.5 * moe_ffn(gates, *args), rtol=1e-4, atol=1e-5
    )


def test_pick_tile_divides():
    for dff in (16, 48, 64, 100, 128, 192, 384):
        t = _pick_tile(dff)
        assert dff % t == 0 and 1 <= t <= 128


def test_vmem_budget():
    """Structural perf check: per-step working set must fit 16 MB VMEM
    with generous margin for every preset's (d, dff)."""
    for d, dff in ((32, 64), (32, 128), (32, 192), (2048, 1024)):
        assert vmem_bytes(d, dff) < 4 * 2**20


# --------------------------------------------------------- decode attention
@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([4, 16, 64, 288]),
    hd=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(h, t, hd, seed):
    rng = np.random.RandomState(seed)
    pos = int(rng.randint(t))
    q, kc, vc = rand(rng, h, hd), rand(rng, h, t, hd), rand(rng, h, t, hd)
    mask = position_mask(t, pos)
    got = decode_attention(q, kc, vc, mask)
    want = ref.ref_decode_attention(q, kc, vc, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_pos0_reads_only_slot0():
    """With pos=0 the output must be exactly v_cache[:, 0]."""
    rng = np.random.RandomState(5)
    h, t, hd = 2, 8, 4
    q, kc, vc = rand(rng, h, hd), rand(rng, h, t, hd), rand(rng, h, t, hd)
    out = decode_attention(q, kc, vc, position_mask(t, 0))
    np.testing.assert_allclose(out, vc[:, 0], rtol=1e-5, atol=1e-6)


def test_attention_ignores_future_garbage():
    """Entries beyond pos must not affect the result (causal correctness)."""
    rng = np.random.RandomState(6)
    h, t, hd, pos = 2, 16, 8, 5
    q, kc, vc = rand(rng, h, hd), rand(rng, h, t, hd), rand(rng, h, t, hd)
    mask = position_mask(t, pos)
    base = decode_attention(q, kc, vc, mask)
    kc2 = kc.at[:, pos + 1 :].set(999.0)
    vc2 = vc.at[:, pos + 1 :].set(-999.0)
    np.testing.assert_allclose(decode_attention(q, kc2, vc2, mask), base, rtol=1e-5, atol=1e-6)


def test_position_mask_values():
    m = np.asarray(position_mask(6, 2))
    assert (m[:3] == 0).all() and (m[3:] < -1e8).all()
