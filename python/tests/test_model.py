"""L2 model: shapes, RoPE, routing, and train-vs-decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, data, model

TINY = dataclasses.replace(
    configs.OLMOE_MICRO, name="tiny", n_layers=2, n_experts=8, top_k=2,
    d_model=16, d_ff=32, n_heads=2, vocab_size=64, max_seq=32,
    cost=configs.OLMOE_MICRO.cost,
)


@pytest.fixture(scope="module")
def params():
    return model.init_params(TINY, 0)


def test_param_shapes(params):
    assert params["embed"].shape == (64, 16)
    assert params["l0.router"].shape == (8, 16)
    assert params["l0.wg"].shape == (8, 32, 16)
    assert params["l1.wd"].shape == (8, 16, 32)


def test_forward_shapes(params):
    toks = jnp.asarray(np.random.RandomState(0).randint(0, 64, (3, 12)), jnp.int32)
    logits, probs = model.forward(params, toks, TINY)
    assert logits.shape == (3, 12, 64)
    assert probs.shape == (2, 3, 12, 8)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, rtol=1e-4)


def test_rope_preserves_norm():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(5, 8).astype(np.float32))
    y = model.apply_rope(x, jnp.arange(5))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_is_identity():
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(3, 8).astype(np.float32))
    np.testing.assert_allclose(model.apply_rope(x, jnp.zeros(3)), x, atol=1e-6)


def test_rope_relative_property():
    """RoPE inner products depend only on relative offset."""
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(8).astype(np.float32))
    k = jnp.asarray(rng.randn(8).astype(np.float32))

    def dot_at(pq, pk):
        return float(
            model.apply_rope(q[None], jnp.asarray([pq]))[0]
            @ model.apply_rope(k[None], jnp.asarray([pk]))[0]
        )

    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)


def test_topk_mask_properties():
    rng = np.random.RandomState(4)
    p = jax.nn.softmax(jnp.asarray(rng.randn(6, 8).astype(np.float32)), -1)
    mask, topv, topi = model.topk_mask(p, 3)
    assert np.asarray(mask).sum(-1).tolist() == [3] * 6
    # mask marks exactly the top-3 entries
    for r in range(6):
        sel = set(np.where(np.asarray(mask[r]) > 0)[0].tolist())
        assert sel == set(np.asarray(topi[r]).tolist())


def test_merge_lora_identity_at_init(params):
    """B = 0 at init ⇒ merged weights equal the base weights."""
    lora = model.init_lora(TINY, 4, 0)
    merged = model.merge_lora(params, lora, TINY, 16.0, 4)
    np.testing.assert_allclose(merged["l0.wu"], params["l0.wu"], atol=1e-7)
    np.testing.assert_allclose(merged["l1.wd"], params["l1.wd"], atol=1e-7)


def test_merge_lora_changes_weights(params):
    lora = model.init_lora(TINY, 4, 0)
    lora = {k: (v + 0.1 if "_b" in k else v) for k, v in lora.items()}
    merged = model.merge_lora(params, lora, TINY, 16.0, 4)
    assert float(jnp.max(jnp.abs(merged["l0.wu"] - params["l0.wu"]))) > 1e-3


def test_decode_matches_teacher_forced(params):
    """The incremental KV-cache decode path must produce the same router
    distributions as the batched training forward — this pins the AOT
    decode artifacts to the training semantics."""
    rng = np.random.RandomState(5)
    toks = rng.randint(4, 64, size=10).tolist()
    _, probs_train = model.forward(params, jnp.asarray([toks], jnp.int32), TINY)
    k_caches, v_caches = model.init_kv(TINY)
    for i, t in enumerate(toks):
        _, probs_step, k_caches, v_caches = model.decode_token(
            params, jnp.int32(t), jnp.int32(i), k_caches, v_caches, TINY, False
        )
        np.testing.assert_allclose(
            np.asarray(probs_step), np.asarray(probs_train[:, 0, i]), rtol=2e-3, atol=2e-4
        )


def test_decode_pallas_matches_ref_path(params):
    toks = [5, 9, 17, 33]
    kr, vr = model.init_kv(TINY)
    kp, vp = model.init_kv(TINY)
    for i, t in enumerate(toks):
        tr, pr, kr, vr = model.decode_token(params, jnp.int32(t), jnp.int32(i), kr, vr, TINY, False)
        tp, pp, kp, vp = model.decode_token(params, jnp.int32(t), jnp.int32(i), kp, vp, TINY, True)
        assert int(tr) == int(tp)
        np.testing.assert_allclose(np.asarray(pr), np.asarray(pp), rtol=1e-4, atol=1e-5)


def test_decode_greedy_stops_at_eos(params):
    gen, probs = model.decode_greedy(params, [1, 5, 9], 8, TINY)
    assert len(gen) <= 8
    assert probs.shape[1:] == (2, 8)
