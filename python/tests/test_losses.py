"""MELINOE loss functions vs the paper's Appendix C identities."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import losses
from compile.model import topk_mask, ste_request


def rand_probs(rng, *shape):
    z = rng.randn(*shape).astype(np.float32) * 2
    return jnp.asarray(jax.nn.softmax(jnp.asarray(z), axis=-1))


# ------------------------------------------------------------- soft cache
def unrolled_cache(r_seq, gamma, capacity, top_k):
    """Direct (non-recursive) form of Prop. C.3:
    c^t = Count^t / ||Count^t||_1 * C with Count unrolled explicitly."""
    t_len, e = r_seq.shape
    count = np.full(e, capacity / e)  # uniform init, ||.||_1 = C
    states = []
    for t in range(t_len):
        states.append(count / count.sum() * capacity)
        count = gamma * count + np.asarray(r_seq[t])
    return np.stack(states)


@settings(max_examples=15, deadline=None)
@given(
    e=st.sampled_from([4, 8, 16]),
    t=st.sampled_from([3, 8, 20]),
    gamma=st.sampled_from([0.0, 0.3, 0.9, 1.0]),
    seed=st.integers(0, 2**16),
)
def test_soft_cache_recursion_matches_unrolled(e, t, gamma, seed):
    rng = np.random.RandomState(seed)
    k = 2
    p = rand_probs(rng, t, e)
    mask, _, _ = topk_mask(p, k)
    c_rec = np.asarray(losses.soft_cache_scan(mask, gamma, float(e // 2), k))
    c_unr = unrolled_cache(mask, gamma, float(e // 2), k)
    np.testing.assert_allclose(c_rec, c_unr, rtol=1e-4, atol=1e-5)


def test_soft_cache_l1_norm_preserved():
    """‖c^t‖₁ = C for all t (Prop. C.3 normalization)."""
    rng = np.random.RandomState(0)
    p = rand_probs(rng, 16, 8)
    mask, _, _ = topk_mask(p, 2)
    c = np.asarray(losses.soft_cache_scan(mask, 0.9, 4.0, 2))
    np.testing.assert_allclose(c.sum(axis=-1), 4.0, rtol=1e-5)


def test_cache_loss_prefers_repeat_routing():
    """A sequence that reuses the same experts must score lower than one
    that touches disjoint experts each token (the whole point of L_cs)."""
    e, t, k = 8, 8, 2
    same = np.zeros((1, 1, t, e), np.float32)
    same[..., :, :k] = 1.0 / k  # always experts {0,1}, prob mass on them
    roam = np.zeros((1, 1, t, e), np.float32)
    for i in range(t):
        roam[0, 0, i, (2 * i) % e] = 0.5
        roam[0, 0, i, (2 * i + 1) % e] = 0.5
    l_same = float(losses.cache_sim_loss(jnp.asarray(same), 0.9, 2.0, k))
    l_roam = float(losses.cache_sim_loss(jnp.asarray(roam), 0.9, 2.0, k))
    assert l_same < l_roam


def test_cache_loss_bounded_by_k():
    rng = np.random.RandomState(1)
    probs = rand_probs(rng, 2, 3, 12, 16)
    l = float(losses.cache_sim_loss(probs, 0.9, 4.0, 4))
    assert 0.0 <= l <= 4.0


def test_cache_loss_has_router_gradient():
    """The STE relaxation must give non-zero gradient w.r.t. the probs."""
    rng = np.random.RandomState(2)
    z = jnp.asarray(rng.randn(1, 1, 6, 8).astype(np.float32))

    def f(z):
        return losses.cache_sim_loss(jax.nn.softmax(z, -1), 0.9, 2.0, 2)

    g = jax.grad(f)(z)
    assert float(jnp.max(jnp.abs(g))) > 0.0


# ------------------------------------------------------------ rank matching
def inversion_count(pf, pb):
    e = pf.shape[-1]
    inv = 0
    for i in range(e):
        for j in range(e):
            if pb[i] > pb[j] and pf[i] < pf[j]:
                inv += 1
    return inv


@settings(max_examples=15, deadline=None)
@given(e=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
def test_rank_loss_bounds_inversions(e, seed):
    """Lemma C.8: the raw margin sum bounds ρ·Inv(p_f, p_b).  Our
    implementation normalizes by the E² pair count (DESIGN.md §2.7), so
    the bound reads m ≥ ρ · Inv / E²."""
    rng = np.random.RandomState(seed)
    rho = 0.1
    pf = np.asarray(rand_probs(rng, e))
    pb = np.asarray(rand_probs(rng, e))
    m = float(
        losses.rank_match_loss(
            jnp.asarray(pf)[None, None, None], jnp.asarray(pb)[None, None, None], rho
        )
    )
    assert m >= rho * inversion_count(pf, pb) / (e * e) - 1e-6


def test_rank_loss_zero_when_separated():
    """If p_f preserves p_b's order with margins ≥ ρ everywhere, L_rm = 0."""
    p = jnp.asarray([[0.5, 0.3, 0.15, 0.05]], jnp.float32)[None, None]
    assert float(losses.rank_match_loss(p, p, 0.05)) == 0.0


def test_rank_loss_penalizes_flip():
    pb = jnp.asarray([0.6, 0.3, 0.1], jnp.float32)[None, None, None]
    pf_ok = jnp.asarray([0.55, 0.35, 0.10], jnp.float32)[None, None, None]
    pf_flip = jnp.asarray([0.10, 0.35, 0.55], jnp.float32)[None, None, None]
    assert float(losses.rank_match_loss(pf_flip, pb, 0.1)) > float(
        losses.rank_match_loss(pf_ok, pb, 0.1)
    )


# ------------------------------------------------------------------- others
def test_nll_matches_manual():
    rng = np.random.RandomState(3)
    logits = jnp.asarray(rng.randn(1, 4, 6).astype(np.float32))
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    mask = jnp.asarray([[1.0, 1.0, 1.0, 0.0]], jnp.float32)
    logp = np.asarray(jax.nn.log_softmax(logits, -1))
    want = -(logp[0, 0, 2] + logp[0, 1, 3] + logp[0, 2, 4]) / 3
    got = float(losses.nll_loss(logits, toks, mask))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_load_balance_uniform_is_one():
    """Perfectly balanced routing gives E·Σ f·P = E·E·(1/E·1/E) = 1."""
    e, k, t = 8, 2, 64
    # cyclic routing: uniform f; probs uniform.
    p = jnp.full((1, 1, t, e), 1.0 / e, jnp.float32)
    # ties in top_k pick the first k — perturb cyclically for uniform f
    z = np.full((1, 1, t, e), 1.0 / e, np.float32)
    for i in range(t):
        z[0, 0, i, (i * k) % e] += 1e-4
        z[0, 0, i, (i * k + 1) % e] += 1e-4
    val = float(losses.load_balance_loss(jnp.asarray(z), k))
    np.testing.assert_allclose(val, 1.0, rtol=0.05)


def test_ste_request_forward_is_binary():
    rng = np.random.RandomState(4)
    p = rand_probs(rng, 5, 8)
    mask, _, _ = topk_mask(p, 3)
    r = ste_request(p, mask)
    np.testing.assert_allclose(np.asarray(r), np.asarray(mask), atol=1e-7)
    assert np.allclose(np.asarray(r).sum(-1), 3)


def test_melinoe_objective_composition():
    rng = np.random.RandomState(5)
    logits = jnp.asarray(rng.randn(2, 8, 16).astype(np.float32))
    probs = rand_probs(rng, 3, 2, 8, 8)
    toks = jnp.asarray(rng.randint(0, 16, (2, 8)), jnp.int32)
    mask = jnp.ones((2, 8), jnp.float32)
    total, parts = losses.melinoe_objective(
        logits, probs, probs, toks, mask,
        lambda_cs=0.5, lambda_rm=0.1, gamma=0.9, capacity=2.0, top_k=2, rho=0.1,
    )
    np.testing.assert_allclose(
        float(total),
        float(parts["nll"]) + 0.5 * float(parts["cs"]) + 0.1 * float(parts["rm"]),
        rtol=1e-5,
    )
