//! End-to-end serving driver (the DESIGN.md validation workload): load a
//! micro MoE, serve a request stream through the coordinator's step-level
//! scheduler, and report latency/throughput — real tokens through real
//! PJRT executables, offloading simulated at paper scale.
//!
//! ```bash
//! cargo run --release --example serve_offloaded -- \
//!     --preset olmoe-micro --policy melinoe --requests 16 --batch 4 \
//!     --scheduler continuous
//! ```

use std::time::Duration;

use melinoe::clock::GpuSpec;
use melinoe::coordinator::{Decoder, PreemptPolicy, SchedulerMode, SeqFinish, Server, ServerConfig};
use melinoe::engine::{DecodeSession, Engine};
use melinoe::metrics::{fmt2, Table};
use melinoe::policies::PolicyConfig;
use melinoe::repro::{Ctx, EngineParts};
use melinoe::util::cli::Args;

/// Owns the model plus a persistent decode session; the borrowing
/// `Engine` view is rebuilt per step call (PJRT handles are not Send, so
/// everything lives inside the runner thread).
struct OwnedEngine {
    ctx: Ctx,
    parts: EngineParts,
    gpu: GpuSpec,
    sess: DecodeSession,
}

impl OwnedEngine {
    fn new(ctx: Ctx, parts: EngineParts, gpu: GpuSpec) -> OwnedEngine {
        let sess = parts.engine(&ctx, gpu.clone()).session();
        OwnedEngine { ctx, parts, gpu, sess }
    }
}

impl Decoder for OwnedEngine {
    fn admit(&mut self, prompt: &[usize], max_output: usize) -> anyhow::Result<u64> {
        let engine: Engine = self.parts.engine(&self.ctx, self.gpu.clone());
        engine.admit(&mut self.sess, prompt, max_output)
    }

    fn step(&mut self) -> anyhow::Result<Vec<SeqFinish>> {
        let engine: Engine = self.parts.engine(&self.ctx, self.gpu.clone());
        engine.step(&mut self.sess)
    }

    fn active(&self) -> usize {
        self.sess.active()
    }

    fn now(&self) -> f64 {
        self.sess.now()
    }

    fn set_prefill_chunk(&mut self, chunk: usize) {
        self.sess.set_prefill_chunk(chunk);
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let preset = args.get_or("preset", "olmoe-micro").to_string();
    let policy_name = args.get_or("policy", "melinoe").to_string();
    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 16)?;
    let max_output = args.get_usize("tokens", 24)?;
    let max_batch = args.get_usize("batch", 4)?;
    let scheduler = SchedulerMode::parse(args.get_or("scheduler", "continuous"))?;
    let prefill_chunk = args.get_usize("prefill-chunk", 1)?.max(1);

    // workload: held-out dolly-syn prompts
    let ctx0 = Ctx::load(&melinoe::artifacts_dir(), &preset)?;
    let eval = ctx0.eval_set("dolly")?;
    let prompts: Vec<Vec<usize>> =
        eval.samples.iter().cycle().take(n_requests).map(|s| s.prompt.clone()).collect();
    let capacity = ctx0.cfg.cache_capacity;
    let top_k = ctx0.cfg.top_k;
    drop(ctx0);

    let preset2 = preset.clone();
    let policy = match policy_name.as_str() {
        "melinoe" => PolicyConfig::melinoe("ft_dolly", capacity),
        "fiddler" => PolicyConfig::fiddler(capacity),
        "mixtral-offloading" => PolicyConfig::mixtral_offloading(capacity),
        "deepspeed-moe" => PolicyConfig::deepspeed_moe(top_k),
        "floe" => PolicyConfig::floe(capacity),
        "moe-infinity" => PolicyConfig::moe_infinity(capacity),
        _ => PolicyConfig::base_offload(capacity),
    };
    println!(
        "serving {preset} with policy {} (variant {}), {scheduler:?} scheduler",
        policy.name, policy.variant
    );

    let gpu2 = gpu.clone();
    let server = Server::start(
        move || {
            let ctx = Ctx::load(&melinoe::artifacts_dir(), &preset2)?;
            let parts = ctx.parts(&policy, "dolly")?;
            Ok(OwnedEngine::new(ctx, parts, gpu2))
        },
        ServerConfig {
            max_batch,
            batch_wait: Duration::from_millis(5),
            max_output,
            scheduler,
            prefill_chunk,
            preempt: PreemptPolicy::Off,
        },
    );

    // arrival process: burst (default) or open-loop poisson:<rate>
    use melinoe::coordinator::workload::{schedule, Arrival};
    let arrival = match args.get("arrival") {
        Some(s) if s.starts_with("poisson:") => {
            Arrival::Poisson(s.trim_start_matches("poisson:").parse()?)
        }
        Some(s) if s.starts_with("uniform:") => {
            Arrival::Uniform(s.trim_start_matches("uniform:").parse()?)
        }
        _ => Arrival::Burst,
    };
    let sched = schedule(prompts.len(), prompts.len(), arrival, 42);

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = prompts
        .iter()
        .zip(&sched)
        .map(|(p, s)| {
            let due = std::time::Duration::from_secs_f64(s.at);
            if let Some(wait) = due.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            server.submit(p.clone(), max_output)
        })
        .collect();
    let mut tokens = 0usize;
    for rx in rxs {
        tokens += rx.recv()?.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["requests".into(), stats.requests.to_string()]);
    t.row(vec![
        "token steps / mean occupancy".into(),
        format!("{} / {:.2}", stats.steps, stats.mean_batch_size),
    ]);
    t.row(vec!["output tokens".into(), tokens.to_string()]);
    t.row(vec![
        "sim throughput (tok/s)".into(),
        fmt2(tokens as f64 / stats.total_sim_seconds.max(1e-9)),
    ]);
    t.row(vec!["ttft p50/p95/p99 (s)".into(), stats.ttft.cell(1.0)]);
    t.row(vec!["tpot p50/p95/p99 (ms)".into(), stats.tpot.cell(1e3)]);
    t.row(vec!["sim latency p50/p95/p99 (s)".into(), stats.sim_latency.cell(1.0)]);
    t.row(vec!["queue wait p50/p95/p99 (ms)".into(), stats.queue_wait.cell(1e3)]);
    t.row(vec!["wallclock total (s)".into(), fmt2(wall)]);
    t.row(vec![
        "wallclock per request (s)".into(),
        fmt2(wall / stats.requests.max(1) as f64),
    ]);
    println!("{}", t.render());
    Ok(())
}
