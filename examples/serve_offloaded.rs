//! End-to-end serving driver (the DESIGN.md validation workload): load a
//! micro MoE, serve a stream of batched requests through the coordinator,
//! and report latency/throughput — real tokens through real PJRT
//! executables, offloading simulated at paper scale.
//!
//! ```bash
//! cargo run --release --example serve_offloaded -- \
//!     --preset olmoe-micro --policy melinoe --requests 16 --batch 4
//! ```

use std::time::Duration;

use melinoe::clock::GpuSpec;
use melinoe::coordinator::{Decoder, Server, ServerConfig};
use melinoe::metrics::{fmt2, Report, Table};
use melinoe::policies::PolicyConfig;
use melinoe::repro::{Ctx, EngineParts};
use melinoe::util::cli::Args;

struct OwnedEngine {
    ctx: Ctx,
    parts: EngineParts,
    gpu: GpuSpec,
}

impl Decoder for OwnedEngine {
    fn decode_batch(
        &mut self,
        prompts: &[Vec<usize>],
        max_output: usize,
    ) -> anyhow::Result<(Vec<Vec<usize>>, Report)> {
        self.parts.engine(&self.ctx, self.gpu.clone()).decode_batch(prompts, max_output)
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let preset = args.get_or("preset", "olmoe-micro").to_string();
    let policy_name = args.get_or("policy", "melinoe").to_string();
    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 16)?;
    let max_output = args.get_usize("tokens", 24)?;
    let max_batch = args.get_usize("batch", 4)?;

    // workload: held-out dolly-syn prompts
    let ctx0 = Ctx::load(&melinoe::artifacts_dir(), &preset)?;
    let eval = ctx0.eval_set("dolly")?;
    let prompts: Vec<Vec<usize>> =
        eval.samples.iter().cycle().take(n_requests).map(|s| s.prompt.clone()).collect();
    let capacity = ctx0.cfg.cache_capacity;
    let top_k = ctx0.cfg.top_k;
    drop(ctx0);

    let preset2 = preset.clone();
    let policy = match policy_name.as_str() {
        "melinoe" => PolicyConfig::melinoe("ft_dolly", capacity),
        "fiddler" => PolicyConfig::fiddler(capacity),
        "mixtral-offloading" => PolicyConfig::mixtral_offloading(capacity),
        "deepspeed-moe" => PolicyConfig::deepspeed_moe(top_k),
        "floe" => PolicyConfig::floe(capacity),
        "moe-infinity" => PolicyConfig::moe_infinity(capacity),
        _ => PolicyConfig::base_offload(capacity),
    };
    println!("serving {preset} with policy {} (variant {})", policy.name, policy.variant);

    let gpu2 = gpu.clone();
    let server = Server::start(
        move || {
            let ctx = Ctx::load(&melinoe::artifacts_dir(), &preset2)?;
            let parts = ctx.parts(&policy, "dolly")?;
            Ok(OwnedEngine { ctx, parts, gpu: gpu2 })
        },
        ServerConfig { max_batch, batch_wait: Duration::from_millis(5), max_output },
    );

    // arrival process: burst (default) or open-loop poisson:<rate>
    use melinoe::coordinator::workload::{schedule, Arrival};
    let arrival = match args.get("arrival") {
        Some(s) if s.starts_with("poisson:") => {
            Arrival::Poisson(s.trim_start_matches("poisson:").parse()?)
        }
        Some(s) if s.starts_with("uniform:") => {
            Arrival::Uniform(s.trim_start_matches("uniform:").parse()?)
        }
        _ => Arrival::Burst,
    };
    let sched = schedule(prompts.len(), prompts.len(), arrival, 42);

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = prompts
        .iter()
        .zip(&sched)
        .map(|(p, s)| {
            let due = std::time::Duration::from_secs_f64(s.at);
            if let Some(wait) = due.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            server.submit(p.clone(), max_output)
        })
        .collect();
    let mut tokens = 0usize;
    let mut sims = Vec::new();
    let mut waits = Vec::new();
    let mut batch_sizes = Vec::new();
    for rx in rxs {
        let r = rx.recv()?;
        tokens += r.tokens.len();
        sims.push(r.sim_seconds);
        waits.push(r.queue_wait * 1e3);
        batch_sizes.push(r.batch_size);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;

    sims.sort_by(|a, b| a.partial_cmp(b).unwrap());
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |v: &[f64], p: f64| v[((p / 100.0 * (v.len() - 1) as f64) as usize).min(v.len() - 1)];

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["requests".into(), stats.requests.to_string()]);
    t.row(vec!["batches / mean size".into(), format!("{} / {:.2}", stats.batches, stats.mean_batch_size)]);
    t.row(vec!["output tokens".into(), tokens.to_string()]);
    t.row(vec![
        "sim throughput (tok/s)".into(),
        fmt2(tokens as f64 / stats.total_sim_seconds.max(1e-9)),
    ]);
    t.row(vec!["sim latency p50 (s)".into(), fmt2(pct(&sims, 50.0))]);
    t.row(vec!["sim latency p95 (s)".into(), fmt2(pct(&sims, 95.0))]);
    t.row(vec!["queue wait p50 (ms)".into(), fmt2(pct(&waits, 50.0))]);
    t.row(vec!["wallclock total (s)".into(), fmt2(wall)]);
    t.row(vec![
        "wallclock per request (s)".into(),
        fmt2(wall / stats.requests.max(1) as f64),
    ]);
    println!("{}", t.render());
    Ok(())
}
