//! Cluster affinity demo: the same heterogeneous workload dispatched by
//! round-robin, least-loaded, and expert-affinity balancers.
//!
//! No artifacts required — the fleet runs on the paper-scale cost model
//! with synthetic per-task routing profiles (docs/CLUSTER.md).  Expected
//! shape: expert-affinity converges each task's traffic onto a stable
//! subset of replicas, so its fleet cache hit-rate approaches the task
//! concentration (~0.92) while round-robin thrashes every cache.
//!
//! ```bash
//! cargo run --release --example cluster_affinity -- --replicas 4 --requests 64
//! ```

use melinoe::clock::GpuSpec;
use melinoe::cluster::{self, ClusterConfig};
use melinoe::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let replicas = args.get_usize("replicas", 4)?;
    let requests = args.get_usize("requests", 64)?;
    let tasks = args.get_usize("tasks", 4)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;

    let cfg = ClusterConfig::synthetic(replicas, requests, tasks, gpu, seed);
    println!(
        "{} replicas, {} requests over {} tasks, C={} experts/layer (top-{} routing)\n",
        cfg.replicas, requests, tasks, cfg.spec.capacity, cfg.spec.top_k
    );

    let reports = cluster::compare(&cfg, cluster::BALANCERS)?;
    println!("{}", cluster::comparison_table(&reports).render());

    // per-replica view of the affinity run: each replica should end up
    // serving a stable subset of tasks
    let affinity = reports.last().expect("three reports");
    println!("expert-affinity per-replica breakdown:");
    for r in &affinity.replicas {
        println!(
            "  replica {}: {:>3} requests, hit rate {:.3}, {:>6.2} GB PCIe, busy {:.2}s",
            r.id, r.requests, r.hit_rate, r.pcie_gb, r.busy_seconds
        );
    }
    let rr = &reports[0];
    println!(
        "\nfleet hit rate: affinity {:.3} vs round-robin {:.3} ({:.1}% fewer H2D bytes)",
        affinity.hit_rate,
        rr.hit_rate,
        (1.0 - affinity.pcie_gb / rr.pcie_gb.max(1e-12)) * 100.0
    );
    Ok(())
}
