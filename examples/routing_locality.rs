//! Visualize what MELINOE fine-tuning does to routing: per-layer expert
//! activation histograms and concentration curves, base vs fine-tuned —
//! an ASCII rendition of the paper's Figs. 1b and 7–10.
//!
//! ```bash
//! cargo run --release --example routing_locality -- --preset olmoe-micro
//! ```

use melinoe::clock::GpuSpec;
use melinoe::policies::PolicyConfig;
use melinoe::repro::Ctx;
use melinoe::util::cli::Args;

fn bar(frac: f64, width: usize) -> String {
    let n = (frac * width as f64).round() as usize;
    "#".repeat(n.min(width))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let preset = args.get_or("preset", "olmoe-micro");
    let tokens = args.get_usize("tokens", 48)?;
    let ctx = Ctx::load(&melinoe::artifacts_dir(), preset)?;
    let eval = ctx.eval_set("dolly")?;
    let sample = &eval.samples[0];

    for variant in ["base", "ft_dolly"] {
        if !ctx.cfg.variants.iter().any(|v| v == variant) {
            continue;
        }
        let pol = PolicyConfig::base_offload(ctx.cfg.n_experts).with_variant(variant);
        let parts = ctx.parts(&pol, "dolly")?;
        let engine = parts.engine(&ctx, GpuSpec::h100());
        let out = engine.decode(&sample.prompt, tokens)?;

        println!("\n===== {variant} =====");
        println!(
            "top-{} share (mean over layers): {:.3}",
            ctx.cfg.cache_capacity,
            out.trace.mean_topc_share(ctx.cfg.cache_capacity)
        );
        // sorted activation-share curve for layer 0 (paper Fig. 1b)
        let curve = out.trace.share_curve(0);
        println!("layer-0 sorted activation share:");
        let mut cum = 0.0;
        for (rank, share) in curve.iter().take(16).enumerate() {
            cum += share;
            println!(
                "  expert #{:<3} {:>6.3}  cum {:>6.3} |{}",
                rank + 1,
                share,
                cum,
                bar(*share * 4.0, 40)
            );
        }
        // distinct experts touched per layer (Figs. 7-10 summary)
        print!("distinct experts touched per layer: ");
        for l in 0..ctx.cfg.n_layers {
            print!("{} ", out.trace.counts[l].iter().filter(|&&c| c > 0).count());
        }
        println!();
    }
    println!(
        "\n(fine-tuning should steepen the curve: more mass on fewer experts,\n\
         while different prompts still prefer different experts — paper Figs. 1b/10)"
    );
    Ok(())
}
