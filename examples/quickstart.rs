//! Quickstart: load a built preset, decode one prompt under MELINOE's
//! offload policy, and print what happened.
//!
//! ```bash
//! make artifacts                      # once (python build layer)
//! cargo run --release --example quickstart [-- --preset olmoe-micro]
//! ```

use melinoe::clock::GpuSpec;
use melinoe::policies::PolicyConfig;
use melinoe::repro::Ctx;
use melinoe::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let preset = args.get_or("preset", "olmoe-micro");

    // 1. Load the AOT artifacts (HLO executables + weights + eval set).
    let ctx = Ctx::load(&melinoe::artifacts_dir(), preset)?;
    println!(
        "loaded {}: {} layers × {} experts (top-{}), cache capacity {}",
        ctx.cfg.name, ctx.cfg.n_layers, ctx.cfg.n_experts, ctx.cfg.top_k, ctx.cfg.cache_capacity
    );

    // 2. Pick the MELINOE policy: fine-tuned checkpoint + predictor
    //    prefetch + LFU cache + INT4 residency (paper §3.2).
    let policy = PolicyConfig::melinoe("ft_dolly", ctx.cfg.cache_capacity);
    let parts = ctx.parts(&policy, "dolly")?;
    let engine = parts.engine(&ctx, GpuSpec::h100());

    // 3. Decode a held-out prompt.
    let eval = ctx.eval_set("dolly")?;
    let sample = &eval.samples[0];
    let out = engine.decode(&sample.prompt, 32)?;

    println!("\nprompt    : {:?}", sample.prompt);
    println!("generated : {:?}", out.tokens);
    println!("reference : {:?}", sample.reference);
    println!("ROUGE-L   : {:.4}", melinoe::eval::rouge_l(&out.tokens, &sample.reference));
    println!("\n-- offloading behaviour --");
    println!("simulated time   : {:.3}s  ({:.2} tok/s at paper scale on H100)",
        out.metrics.sim_seconds, out.metrics.tokens_per_sec());
    println!("H2D transfers    : {}", out.report.transfers.h2d_count);
    println!("transfers/layer  : {:.1}", out.report.misses_per_layer);
    println!("cache hit rate   : {:.3}", out.report.cache.hit_rate());
    println!("top-C share      : {:.3} (routing locality after fine-tuning)",
        out.trace.mean_topc_share(ctx.cfg.cache_capacity));
    Ok(())
}
