//! Compare all six offloading systems on one workload — a miniature of
//! the paper's Fig. 3 grid, runnable on any single (preset, GPU) pair.
//!
//! ```bash
//! cargo run --release --example compare_offloading -- \
//!     --preset olmoe-micro --gpu h100 --prompts 4 --tokens 24
//! ```

use melinoe::clock::GpuSpec;
use melinoe::metrics::{fmt2, fmt4, Table};
use melinoe::policies::PolicyConfig;
use melinoe::repro::{run_eval, Ctx, Workload};
use melinoe::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let preset = args.get_or("preset", "olmoe-micro");
    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 4)?,
        max_output: args.get_usize("tokens", 24)?,
        ignore_eos: true,
    };
    let ds = args.get_or("dataset", "dolly");
    let ft = if ds == "dolly" { "ft_dolly" } else { "ft_gsm" };

    let ctx = Ctx::load(&melinoe::artifacts_dir(), preset)?;
    let eval = ctx.eval_set(ds)?;
    println!(
        "{} on {} ({} prompts × ≤{} tokens, C={} experts/layer)\n",
        preset, gpu.name, wl.n_prompts, wl.max_output, ctx.cfg.cache_capacity
    );

    let mut t = Table::new(&[
        "policy", "tok/s (sim)", "tx/layer", "hit rate", "ROUGE-L", "cpu execs", "wall s",
    ]);
    for pol in PolicyConfig::all_baselines(ctx.cfg.cache_capacity, ctx.cfg.top_k, ft) {
        let parts = ctx.parts(&pol, ds)?;
        let engine = parts.engine(&ctx, gpu.clone());
        let r = run_eval(&engine, &eval, wl, ctx.cfg.cache_capacity)?;
        t.row(vec![
            pol.name.clone(),
            fmt2(r.tokens_per_sec),
            fmt2(r.tx_per_layer),
            fmt4(r.hit_rate),
            fmt4(r.rouge_l),
            r.cpu_execs.to_string(),
            fmt2(r.wall_seconds),
        ]);
    }
    println!("{}", t.render());
    println!("(tok/s is the simulated-clock throughput at paper scale; see DESIGN.md §2.2)");
    Ok(())
}
