#!/usr/bin/env python3
"""Emit a perf-trajectory snapshot (`BENCH_<n>.json`) from repro JSONs.

The nightly workflow runs the artifact-free extension experiments
(`melinoe repro ext_*`), which write `results/<id>.json`; this script
distills every row of every ext_* result into a compact per-arm record —
tok/s, p95 latency, cache hit-rate, PCIe overlap fraction — and writes
one snapshot file at the repo root.  Committing or archiving successive
snapshots gives a perf trajectory across nightly runs without diffing
full result JSONs.

Snapshot shape:

    {
      "schema": 1,
      "generated_unix": 1754524800,
      "git": "20f8e15",
      "experiments": {
        "ext_fault": [
          {"label": "crash-storm retry=on", "tok_s": ..,
           "latency_p95_s": .., "hit_rate": .., "overlap_fraction": ..},
          ...
        ], ...
      }
    }

Metrics absent from a row (not every experiment reports every quantity)
are recorded as null rather than dropped, so the per-arm schema is
stable across experiments.  Stdlib only — no third-party imports.

Usage: bench_snapshot.py [results_dir] [out.json]
  results_dir  default: results
  out.json     default: BENCH_<n>.json at the repo root, n = 1 + the
               highest existing snapshot index
"""

import json
import os
import re
import subprocess
import sys
import time

# keys that distinguish arms within one experiment, in label order
LABEL_KEYS = [
    "arm", "balancer", "scheduler", "dims", "model", "quant", "replicas",
    "capacity", "fp16_eq_capacity", "prefill_chunk", "lookahead", "preempt_on",
    "admission", "retry", "steal",
]

# first match wins: the row's headline p95 latency
P95_KEYS = ["latency_p95_s", "high_latency_p95_s", "ttft_p95_s", "recovery_wait_p95"]


def short(v):
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return str(v)


def label_of(row):
    parts = []
    for k in LABEL_KEYS:
        if k in row:
            parts.append(short(row[k]) if k == "arm" else f"{k}={short(row[k])}")
    return " ".join(parts) or "default"


def num_or_none(v):
    return v if isinstance(v, (int, float)) and not isinstance(v, bool) else None


def distill(row):
    rec = {
        "label": label_of(row),
        "tok_s": num_or_none(row.get("tok_s")),
        "latency_p95_s": None,
        "hit_rate": num_or_none(row.get("hit_rate")),
        "overlap_fraction": num_or_none(row.get("overlap_fraction")),
    }
    for k in P95_KEYS:
        if num_or_none(row.get(k)) is not None:
            rec["latency_p95_s"] = row[k]
            break
    return rec


def git_rev(repo_root):
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=repo_root, capture_output=True, text=True, timeout=10,
        )
        return out.stdout.strip() or None
    except OSError:
        return None


def next_snapshot_path(repo_root):
    top = 0
    for f in os.listdir(repo_root):
        m = re.fullmatch(r"BENCH_(\d+)\.json", f)
        if m:
            top = max(top, int(m.group(1)))
    return os.path.join(repo_root, f"BENCH_{top + 1}.json")


def main():
    results_dir = sys.argv[1] if len(sys.argv) > 1 else "results"
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out_path = sys.argv[2] if len(sys.argv) > 2 else next_snapshot_path(repo_root)

    experiments = {}
    if not os.path.isdir(results_dir):
        print(f"bench_snapshot: no results dir {results_dir!r}", file=sys.stderr)
        sys.exit(1)
    for f in sorted(os.listdir(results_dir)):
        if not (f.startswith("ext_") and f.endswith(".json")):
            continue
        name = f[: -len(".json")]
        if name.endswith("_trace"):
            continue  # Chrome-trace exports, not result rows
        with open(os.path.join(results_dir, f)) as fh:
            try:
                rows = json.load(fh)
            except ValueError as e:
                print(f"bench_snapshot: skipping unparseable {f}: {e}", file=sys.stderr)
                continue
        if isinstance(rows, list) and rows:
            experiments[name] = [distill(r) for r in rows if isinstance(r, dict)]

    if not experiments:
        print(f"bench_snapshot: no ext_* results under {results_dir!r}", file=sys.stderr)
        sys.exit(1)

    snapshot = {
        "schema": 1,
        "generated_unix": int(time.time()),
        "git": git_rev(repo_root),
        "experiments": experiments,
    }
    with open(out_path, "w") as fh:
        json.dump(snapshot, fh, indent=1, sort_keys=True)
        fh.write("\n")
    arms = sum(len(v) for v in experiments.values())
    print(f"bench_snapshot: {len(experiments)} experiments, {arms} arms -> {out_path}")


if __name__ == "__main__":
    main()
