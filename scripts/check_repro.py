#!/usr/bin/env python3
"""CI invariant gate over the repro smoke JSONs.

The workflow runs the artifact-free extension experiments
(`melinoe repro ext_*`), which write `results/<id>.json`; this script
parses them and FAILS the build if a headline invariant regresses:

  ext_cluster     expert-affinity hit-rate >= round-robin, per fleet size
  ext_continuous  continuous p95 latency <= static p95 latency
  ext_prefill     chunked prefill p95 TTFT <= token-at-a-time p95 TTFT
  ext_overlap     best lookahead stall < depth-0 stall, per (dims, C)
  ext_preempt     preempt-on High p95 TTFT <= off, tok/s within 5%,
                  hit-rate within 0.05, per capacity
  ext_quant       int4 + little-fallback stall < fp16 stall and tok/s
                  above fp16 at equal VRAM bytes; degraded_token_frac
                  finite in [0,1], and exactly 0 with the fallback off
  ext_stream      SLO-aware admission lifts goodput on the deadline-
                  heavy burst arm with raw tok/s within 5% of the
                  no-admission baseline; the cancel-storm arm leaks
                  nothing (pins_set == pins_released in the trace
                  counters) and every request reaches a terminal
                  outcome (completed + cancelled + rejected == n)
  ext_fault       the crash-storm arm really injects faults and fails
                  requests with retries off; retry-on strictly lifts the
                  completed fraction (to >= 99% of the workload) at
                  tok/s within 10% of fault-free; recovery conservation
                  (injected == recovered + failed) holds exactly on
                  every arm, terminal outcomes partition the workload,
                  and Completed tokens are bit-identical to fault-free
                  (the repro asserts it in-process and exports
                  bit_identical per row)
  ext_steal       per fleet size, steal-on fires steals (and off fires
                  none), strictly cuts p95 latency vs steal-off under
                  the same Zipf-imbalanced workload, at tok/s >= 98% of
                  off and hit-rate within 0.02 (runs untraced at ~10^5
                  requests, so the metrics-snapshot gate is skipped)

Every ext_* row also embeds a `metrics` snapshot from the run's merged
structured trace (docs/OBSERVABILITY.md); the gate rejects NaN /
negative counters and any trace-vs-TransferStats drift beyond 1e-6 —
the conservation audit over the prefetch/stall accounting.  When the
smoke step exported `results/ext_overlap_trace.json` (via `--trace`),
its Chrome-trace shape is sanity-checked too.

It also writes a $GITHUB_STEP_SUMMARY table of tok/s, hit-rate and
overlap fraction per experiment, so every CI run leaves a perf snapshot
in the job summary.  Stdlib only — no third-party imports.

Usage: check_repro.py [results_dir]   (default: results)
"""

import json
import math
import os
import sys

REQUIRED = [
    "ext_cluster", "ext_continuous", "ext_prefill", "ext_overlap", "ext_preempt",
    "ext_quant", "ext_stream", "ext_fault", "ext_steal",
]

# runs untraced (10^5-request fleets would swamp the recorder), so it
# exports no per-row metrics snapshot for check_metrics to validate
UNTRACED = {"ext_steal"}

# trace-derived PCIe totals must match TransferStats to this tolerance
TRACE_TOL = 1e-6

failures = []
summary_rows = []  # (experiment, headline, tok/s, hit-rate, overlap frac)


def load(results_dir, name):
    path = os.path.join(results_dir, f"{name}.json")
    if not os.path.exists(path):
        failures.append(f"{name}: missing {path} (did the smoke step run?)")
        return None
    with open(path) as f:
        return json.load(f)


class GateError(Exception):
    """A results row is structurally unusable (missing key / wrong shape).

    Raised instead of letting a bare KeyError escape, so the gate names
    the experiment, row, and key rather than dying with a stack trace or
    a generic "malformed JSON"."""


def require(row, key, ctx):
    """Fetch `row[key]` or fail loudly, naming the row and key."""
    if not isinstance(row, dict):
        raise GateError(f"{ctx}: expected an object row, got {type(row).__name__}")
    if key not in row:
        have = ", ".join(sorted(row)) or "<empty>"
        raise GateError(f"{ctx}: missing key {key!r} (row has: {have})")
    return row[key]


def check(name, ok, detail):
    status = "ok  " if ok else "FAIL"
    print(f"[{status}] {name}: {detail}")
    if not ok:
        failures.append(f"{name}: {detail}")


def fmt(x):
    return f"{x:.3f}" if isinstance(x, (int, float)) else str(x)


def check_cluster(rows):
    by_fleet = {}
    for r in rows:
        by_fleet.setdefault(r["replicas"], {})[r["balancer"]] = r
    for replicas, bals in sorted(by_fleet.items()):
        aff, rr = bals.get("expert-affinity"), bals.get("round-robin")
        if not aff or not rr:
            check("ext_cluster", False, f"{replicas} replicas: missing balancer rows")
            continue
        check(
            "ext_cluster",
            aff["hit_rate"] >= rr["hit_rate"] - 1e-9,
            f"{replicas} replicas: affinity hit-rate {fmt(aff['hit_rate'])} "
            f"vs round-robin {fmt(rr['hit_rate'])}",
        )
    top = max(by_fleet)
    aff = by_fleet[top].get("expert-affinity")
    if aff:
        summary_rows.append(
            ("ext_cluster", f"affinity @ {top} replicas", aff["tok_s"], aff["hit_rate"], None)
        )


def check_continuous(rows):
    by_sched = {r["scheduler"]: r for r in rows}
    cont, stat = by_sched.get("continuous"), by_sched.get("static")
    if not cont or not stat:
        check("ext_continuous", False, "missing scheduler rows")
        return
    check(
        "ext_continuous",
        cont["latency_p95_s"] <= stat["latency_p95_s"] + 1e-9,
        f"continuous p95 latency {fmt(cont['latency_p95_s'])}s "
        f"vs static {fmt(stat['latency_p95_s'])}s",
    )
    summary_rows.append(
        ("ext_continuous", "continuous", cont["tok_s"], cont["hit_rate"], None)
    )


def check_prefill(rows):
    by_chunk = {int(r["prefill_chunk"]): r for r in rows}
    c1 = by_chunk.get(1)
    chunked = [r for c, r in by_chunk.items() if c > 1]
    if not c1 or not chunked:
        check("ext_prefill", False, "missing chunk rows")
        return
    best = min(r["ttft_p95_s"] for r in chunked)
    check(
        "ext_prefill",
        best <= c1["ttft_p95_s"] + 1e-9,
        f"best chunked p95 TTFT {fmt(best)}s vs chunk=1 {fmt(c1['ttft_p95_s'])}s",
    )
    top = max(c for c in by_chunk if c > 1)
    summary_rows.append(
        ("ext_prefill", f"chunk {top}", by_chunk[top]["tok_s"], by_chunk[top]["hit_rate"], None)
    )


def check_overlap(rows):
    groups = {}
    for r in rows:
        groups.setdefault((r["dims"], r["capacity"]), {})[int(r["lookahead"])] = r
    best_row = None
    for (dims, cap), depths in sorted(groups.items()):
        la0 = depths.get(0)
        ahead = [r for d, r in depths.items() if d > 0]
        if not la0 or not ahead:
            check("ext_overlap", False, f"{dims}/C={cap}: missing lookahead rows")
            continue
        best = min(r["stall_s"] for r in ahead)
        check(
            "ext_overlap",
            best < la0["stall_s"],
            f"{dims}/C={cap}: best lookahead stall {fmt(best)}s "
            f"vs depth-0 {fmt(la0['stall_s'])}s",
        )
        cand = max(ahead, key=lambda r: r["overlap_fraction"])
        if best_row is None or cand["overlap_fraction"] > best_row["overlap_fraction"]:
            best_row = cand
    if best_row:
        summary_rows.append(
            (
                "ext_overlap",
                f"{best_row['dims']}/C={int(best_row['capacity'])} "
                f"lookahead {int(best_row['lookahead'])}",
                best_row["tok_s"],
                best_row["hit_rate"],
                best_row["overlap_fraction"],
            )
        )


def check_preempt(rows):
    groups = {}
    for r in rows:
        groups.setdefault(int(r["capacity"]), {})[int(r["preempt_on"])] = r
    shown = None
    for cap, sides in sorted(groups.items()):
        off, on = sides.get(0), sides.get(1)
        if not off or not on:
            check("ext_preempt", False, f"C={cap}: missing preempt rows")
            continue
        check(
            "ext_preempt",
            on["preemptions"] > 0,
            f"C={cap}: preemption fired {int(on['preemptions'])} times",
        )
        check(
            "ext_preempt",
            on["high_ttft_p95_s"] <= off["high_ttft_p95_s"] + 1e-9,
            f"C={cap}: preempt-on High p95 TTFT {fmt(on['high_ttft_p95_s'])}s "
            f"vs off {fmt(off['high_ttft_p95_s'])}s",
        )
        check(
            "ext_preempt",
            on["tok_s"] >= 0.95 * off["tok_s"],
            f"C={cap}: preempt-on {fmt(on['tok_s'])} tok/s "
            f"vs off {fmt(off['tok_s'])} (>= 95% required)",
        )
        check(
            "ext_preempt",
            on["hit_rate"] >= off["hit_rate"] - 0.05,
            f"C={cap}: preempt-on hit-rate {fmt(on['hit_rate'])} "
            f"vs off {fmt(off['hit_rate'])} (within 0.05 required)",
        )
        shown = shown or on
    if shown:
        summary_rows.append(
            (
                "ext_preempt",
                f"preempt on @ C={int(shown['capacity'])}",
                shown["tok_s"],
                shown["hit_rate"],
                shown.get("overlap_fraction"),
            )
        )


def check_quant(rows):
    groups = {}
    for r in rows:
        groups.setdefault(int(r["fp16_eq_capacity"]), []).append(r)
    shown = None
    for cap, arms in sorted(groups.items()):
        fp16 = next((r for r in arms if r["quant"] == "fp16"), None)
        fallback = [r for r in arms if r["little_tier"] != "none"]
        if not fp16 or not fallback:
            check("ext_quant", False, f"C={cap}: missing fp16 / fallback arms")
            continue
        for r in arms:
            d = r["degraded_token_frac"]
            check(
                "ext_quant",
                finite(d) and 0.0 <= d <= 1.0,
                f"C={cap} {r['arm']}: degraded_token_frac {d!r} in [0,1]",
            )
            if r["little_tier"] == "none":
                check(
                    "ext_quant",
                    d == 0.0,
                    f"C={cap} {r['arm']}: fallback off => degraded 0 (got {d!r})",
                )
        best = min(fallback, key=lambda r: r["stall_s"])
        check(
            "ext_quant",
            best["stall_s"] < fp16["stall_s"],
            f"C={cap}: int4+fallback stall {fmt(best['stall_s'])}s "
            f"vs fp16 {fmt(fp16['stall_s'])}s at equal bytes",
        )
        check(
            "ext_quant",
            best["tok_s"] > fp16["tok_s"],
            f"C={cap}: int4+fallback {fmt(best['tok_s'])} tok/s "
            f"vs fp16 {fmt(fp16['tok_s'])} at equal bytes",
        )
        shown = shown or best
    if shown:
        summary_rows.append(
            (
                "ext_quant",
                f"{shown['arm']} @ C={int(shown['fp16_eq_capacity'])} "
                f"(degraded {shown['degraded_token_frac']:.4f})",
                shown["tok_s"],
                shown["hit_rate"],
                None,
            )
        )


def check_stream(rows):
    for i, r in enumerate(rows):
        total = r["completed"] + r["cancelled"] + r["rejected"]
        check(
            "ext_stream",
            total == r["n_requests"],
            f"row {i} ({r['arm']}): terminal outcomes {int(total)} "
            f"of {int(r['n_requests'])} requests",
        )
    deadline = [r for r in rows if r["arm"] == "deadline"]
    off = next((r for r in deadline if not r["admission"]), None)
    on = next((r for r in deadline if r["admission"]), None)
    if not off or not on:
        check("ext_stream", False, "missing deadline admission off/on rows")
    else:
        check(
            "ext_stream",
            off["rejected"] == 0 and on["rejected"] > 0,
            f"admission rejects only when on ({int(off['rejected'])} off, "
            f"{int(on['rejected'])} on)",
        )
        check(
            "ext_stream",
            on["goodput_tok_s"] > off["goodput_tok_s"],
            f"admission goodput {fmt(on['goodput_tok_s'])} tok/s "
            f"vs off {fmt(off['goodput_tok_s'])} (strict improvement required)",
        )
        check(
            "ext_stream",
            0.95 * off["tok_s"] <= on["tok_s"] <= 1.05 * off["tok_s"],
            f"admission raw {fmt(on['tok_s'])} tok/s vs off {fmt(off['tok_s'])} "
            f"(within 5% required)",
        )
    storm = next((r for r in rows if r["arm"] == "cancel-storm"), None)
    if not storm:
        check("ext_stream", False, "missing cancel-storm row")
    else:
        check(
            "ext_stream",
            storm["cancelled"] > 0,
            f"cancel storm fired ({int(storm['cancelled'])} cancelled)",
        )
        counters = (storm.get("metrics") or {}).get("counters", {})
        pins_set = counters.get("pins_set", 0)
        pins_rel = counters.get("pins_released", 0)
        check(
            "ext_stream",
            abs(pins_set - pins_rel) <= TRACE_TOL and pins_set > 0,
            f"cancel storm pin ledger balanced "
            f"({int(pins_set)} set, {int(pins_rel)} released)",
        )
    if on:
        summary_rows.append(
            (
                "ext_stream",
                f"admission on ({int(on['rejected'])} rejected, "
                f"goodput {on['goodput_tok_s']:.2f} tok/s)",
                on["tok_s"],
                on["hit_rate"],
                None,
            )
        )


def check_fault(rows):
    by = {}
    for i, r in enumerate(rows):
        arm = require(r, "arm", f"ext_fault row {i}")
        retry = require(r, "retry", f"ext_fault row {i}")
        by[(arm, retry)] = r
    clean = by.get(("fault-free", "off"))
    off = by.get(("crash-storm", "off"))
    on = by.get(("crash-storm", "on"))
    mix = by.get(("brownout-mix", "on"))
    if not (clean and off and on and mix):
        check("ext_fault", False, f"missing arms (have {sorted(by)})")
        return
    n = require(clean, "n_requests", "ext_fault fault-free")
    check(
        "ext_fault",
        clean["injected"] == 0 and clean["failed"] == 0 and clean["completed"] == n,
        f"fault-free arm clean ({int(clean['completed'])}/{int(n)} completed, "
        f"{int(clean['injected'])} injected)",
    )
    check(
        "ext_fault",
        off["injected"] > 0 and off["failed"] > 0,
        f"crash storm disrupts with retries off ({int(off['injected'])} injected, "
        f"{int(off['failed'])} failed)",
    )
    for (arm, retry), r in sorted(by.items()):
        ctx = f"ext_fault {arm}/retry-{retry}"
        injected = require(r, "injected", ctx)
        recovered = require(r, "recovered", ctx)
        failed = require(r, "failed", ctx)
        check(
            "ext_fault",
            injected == recovered + failed,
            f"{arm}/retry-{retry}: conservation {int(injected)} injected == "
            f"{int(recovered)} recovered + {int(failed)} failed (exact)",
        )
        total = r["completed"] + r["cancelled"] + r["rejected"] + failed
        check(
            "ext_fault",
            total == r["n_requests"],
            f"{arm}/retry-{retry}: terminal outcomes {int(total)} "
            f"of {int(r['n_requests'])} requests",
        )
        check(
            "ext_fault",
            require(r, "bit_identical", ctx) == 1,
            f"{arm}/retry-{retry}: Completed tokens bit-identical to fault-free",
        )
    check(
        "ext_fault",
        on["completed"] > off["completed"],
        f"retry-on completed {int(on['completed'])} vs retry-off "
        f"{int(off['completed'])} under the same storm (strict lift required)",
    )
    check(
        "ext_fault",
        on["completed"] >= 0.99 * n,
        f"retry-on completed {int(on['completed'])}/{int(n)} (>= 99% required)",
    )
    check(
        "ext_fault",
        on["tok_s"] >= 0.90 * clean["tok_s"],
        f"retry-on {fmt(on['tok_s'])} tok/s vs fault-free {fmt(clean['tok_s'])} "
        f"(>= 90% required)",
    )
    summary_rows.append(
        (
            "ext_fault",
            f"crash-storm retry-on ({int(on['injected'])} reclaimed, "
            f"{int(on['retries'])} retries, {int(on['migrations'])} migrations)",
            on["tok_s"],
            on["hit_rate"],
            None,
        )
    )


def check_steal(rows):
    by = {}
    for i, r in enumerate(rows):
        replicas = int(require(r, "replicas", f"ext_steal row {i}"))
        steal = int(require(r, "steal", f"ext_steal row {i}"))
        by[(replicas, steal)] = r
    fleets = sorted({k[0] for k in by})
    for replicas in fleets:
        off, on = by.get((replicas, 0)), by.get((replicas, 1))
        if not off or not on:
            check("ext_steal", False, f"{replicas} replicas: missing off/on pair")
            continue
        ctx = f"{replicas} replicas"
        check(
            "ext_steal",
            on["steals"] > 0,
            f"{ctx}: steal-on fired {int(on['steals'])} steals "
            f"({int(on['live_steals'])} live)",
        )
        check(
            "ext_steal",
            off["steals"] == 0,
            f"{ctx}: steal-off fired {int(off['steals'])} steals (must be 0)",
        )
        check(
            "ext_steal",
            on["latency_p95_s"] < off["latency_p95_s"],
            f"{ctx}: steal-on p95 latency {fmt(on['latency_p95_s'])}s vs "
            f"off {fmt(off['latency_p95_s'])}s (strict win required)",
        )
        check(
            "ext_steal",
            on["tok_s"] >= 0.98 * off["tok_s"],
            f"{ctx}: steal-on {fmt(on['tok_s'])} tok/s vs off {fmt(off['tok_s'])} "
            f"(>= 98% required)",
        )
        check(
            "ext_steal",
            on["hit_rate"] >= off["hit_rate"] - 0.02,
            f"{ctx}: steal-on hit-rate {fmt(on['hit_rate'])} vs off "
            f"{fmt(off['hit_rate'])} (within 0.02)",
        )
    top = by.get((max(fleets), 1)) if fleets else None
    if top:
        summary_rows.append(
            (
                "ext_steal",
                f"steal-on @ {max(fleets)} replicas ({int(top['steals'])} steals, "
                f"{int(top['live_steals'])} live)",
                top["tok_s"],
                top["hit_rate"],
                None,
            )
        )


def finite(v):
    return isinstance(v, (int, float)) and math.isfinite(v)


def check_metrics(name, rows):
    """Validate the per-row metrics snapshot: counters finite and
    non-negative, trace totals reconciled with TransferStats."""
    problems = []
    max_drift = 0.0
    snapshots = 0
    for i, r in enumerate(rows):
        m = r.get("metrics")
        if not isinstance(m, dict):
            problems.append(f"row {i}: missing metrics snapshot")
            continue
        snapshots += 1
        for k, v in sorted(m.get("counters", {}).items()):
            if not finite(v) or v < 0:
                problems.append(f"row {i}: counter {k}={v!r}")
        triplet_keys = [
            "trace_stall_s", "trace_overlapped_s", "trace_h2d_s",
            "stats_stall_s", "stats_overlapped_s", "stats_h2d_s",
        ]
        vals = {k: m.get(k) for k in ["events"] + triplet_keys}
        bad = [f"{k}={v!r}" for k, v in vals.items() if not finite(v)]
        if bad:
            problems.append(f"row {i}: non-finite {', '.join(bad)}")
            continue
        if vals["events"] <= 0:
            problems.append(f"row {i}: empty trace ({vals['events']} events)")
        for side in ("stall", "overlapped", "h2d"):
            drift = abs(vals[f"trace_{side}_s"] - vals[f"stats_{side}_s"])
            max_drift = max(max_drift, drift)
    check(
        name,
        not problems,
        f"metrics snapshots clean ({snapshots}/{len(rows)} rows)"
        if not problems
        else "; ".join(problems[:5]),
    )
    if snapshots:
        check(
            name,
            max_drift <= TRACE_TOL,
            f"trace vs TransferStats max drift {max_drift:.3g} (tol {TRACE_TOL:g})",
        )


def check_trace_export(results_dir):
    """Shape-check the optional Chrome-trace export from the smoke run."""
    path = os.path.join(results_dir, "ext_overlap_trace.json")
    if not os.path.exists(path):
        print(f"[skip] {path} not present (smoke ran without --trace)")
        return
    try:
        with open(path) as f:
            t = json.load(f)
    except ValueError as e:
        check("trace_export", False, f"unparseable {path}: {e}")
        return
    evs = t.get("traceEvents")
    check(
        "trace_export",
        isinstance(evs, list) and len(evs) > 0,
        f"{len(evs) if isinstance(evs, list) else 0} traceEvents in {path}",
    )
    check(
        "trace_export",
        isinstance(t.get("melinoe"), dict) and "counters" in t["melinoe"],
        "embedded metrics registry under \"melinoe\"",
    )


def write_summary():
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    lines = ["## Repro invariant gate", ""]
    lines.append("| experiment | headline config | tok/s | hit-rate | overlap frac |")
    lines.append("|---|---|---|---|---|")
    for exp, headline, tok_s, hit, ovl in summary_rows:
        ovl_s = f"{ovl:.3f}" if isinstance(ovl, (int, float)) else "—"
        lines.append(f"| {exp} | {headline} | {tok_s:.2f} | {hit:.4f} | {ovl_s} |")
    lines.append("")
    lines.append(
        f"**{'PASS' if not failures else 'FAIL'}** — "
        f"{len(failures)} invariant regression(s)."
    )
    for f in failures:
        lines.append(f"- ❌ {f}")
    text = "\n".join(lines) + "\n"
    print(text)
    if path:
        with open(path, "a") as f:
            f.write(text)


def main():
    results_dir = sys.argv[1] if len(sys.argv) > 1 else "results"
    checkers = {
        "ext_cluster": check_cluster,
        "ext_continuous": check_continuous,
        "ext_prefill": check_prefill,
        "ext_overlap": check_overlap,
        "ext_preempt": check_preempt,
        "ext_quant": check_quant,
        "ext_stream": check_stream,
        "ext_fault": check_fault,
        "ext_steal": check_steal,
    }
    for name in REQUIRED:
        rows = load(results_dir, name)
        if rows is None:
            continue
        if not isinstance(rows, list) or not rows:
            check(name, False, f"results JSON holds no rows (got {type(rows).__name__})")
            continue
        try:
            checkers[name](rows)
            if name not in UNTRACED:
                check_metrics(name, rows)
        except GateError as e:
            check(name, False, str(e))
        except KeyError as e:
            check(name, False, f"results row is missing key {e} (smoke/gate drift?)")
        except (TypeError, ValueError) as e:
            check(name, False, f"malformed results JSON ({e!r})")
    check_trace_export(results_dir)
    write_summary()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
