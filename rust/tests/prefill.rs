//! Chunked-prefill integration tests.
//!
//! The artifact-free tests run the cluster simulator (analytic cost
//! model + synthetic per-task routing traces) and lock in the PR's
//! acceptance behaviour unconditionally: on long-prompt Poisson
//! workloads, prefill chunks ≥ 8 cut p95 TTFT hard versus
//! token-at-a-time prefill, with TPOT and the expert-cache hit rate no
//! worse and identical per-request token accounting.  The engine-level
//! test (artifact-gated, skips without built artifacts) additionally
//! asserts that decoded tokens are *bit-identical* across chunk sizes —
//! chunking only reshapes the cost timeline, never the numerics.

use melinoe::clock::GpuSpec;
use melinoe::cluster::workload::{OutputLen, TaskProfile};
use melinoe::cluster::{balancer, run_cluster, ClusterConfig, ClusterReport};
use melinoe::coordinator::workload::Arrival;
use melinoe::policies::PolicyConfig;
use melinoe::repro::Ctx;

/// Long-prompt, short-output scenario at ~0.8× the fleet's
/// token-at-a-time capacity: queueing is stable, so p95 TTFT reflects
/// prefill latency rather than unbounded queue growth.
fn long_prompt_cfg(seed: u64) -> ClusterConfig {
    let mut cfg = ClusterConfig::synthetic(1, 32, 1, GpuSpec::h100(), seed);
    // small model so the test stays fast
    cfg.spec.n_layers = 4;
    cfg.spec.n_experts = 32;
    cfg.spec.top_k = 4;
    cfg.spec.capacity = 12; // hot set (8) fully resident, plus slack
    cfg.tasks = TaskProfile::synthetic(1, 4, 32, 8, 0.95);
    cfg.workload.prompt_tokens = 96;
    cfg.workload.output = OutputLen::Fixed(8);
    cfg.max_batch = 4;
    let est = cfg.spec.est_service_seconds(96, 8).max(1e-12);
    cfg.with_arrival(Arrival::Poisson(0.8 / est))
}

fn run_chunk(cfg: &ClusterConfig, chunk: usize) -> ClusterReport {
    let mut b = balancer::by_name("expert-affinity").unwrap();
    run_cluster(&cfg.clone().with_prefill_chunk(chunk), b.as_mut()).unwrap()
}

#[test]
fn chunked_prefill_cuts_p95_ttft_with_tpot_and_hit_rate_no_worse() {
    for seed in [7u64, 21, 42] {
        let cfg = long_prompt_cfg(seed);
        let c1 = run_chunk(&cfg, 1);
        let c8 = run_chunk(&cfg, 8);
        let c32 = run_chunk(&cfg, 32);
        assert_eq!(c1.n_requests, 32, "seed {seed}");
        assert_eq!(c1.prefill_chunk, 1);
        assert_eq!(c8.prefill_chunk, 8);
        assert_eq!(c32.prefill_chunk, 32);

        // the headline: chunk ≥ 8 cuts p95 TTFT hard (a 96-token prompt
        // takes ⌈96/chunk⌉ steps instead of 96, each amortizing the
        // per-step dispatch overhead across its chunk)
        for (label, rep) in [("chunk=8", &c8), ("chunk=32", &c32)] {
            assert!(
                rep.ttft.p95 < c1.ttft.p95 * 0.9,
                "seed {seed}: {label} p95 ttft {:.3}s not well under chunk=1 {:.3}s",
                rep.ttft.p95,
                c1.ttft.p95
            );
            // decodes still emit exactly one token per step — TPOT no worse
            // (small slack: queueing alignment shifts which steps overlap)
            assert!(
                rep.tpot.p50 <= c1.tpot.p50 * 1.15 + 1e-9,
                "seed {seed}: {label} tpot p50 {:.5}s worse than chunk=1 {:.5}s",
                rep.tpot.p50,
                c1.tpot.p50
            );
            // identical pre-drawn routing replayed → hit rate no worse
            assert!(
                rep.hit_rate >= c1.hit_rate - 0.02,
                "seed {seed}: {label} hit rate {:.4} fell below chunk=1 {:.4}",
                rep.hit_rate,
                c1.hit_rate
            );
            // faster prefill can only help throughput
            assert!(
                rep.tokens_per_sec >= c1.tokens_per_sec * 0.95,
                "seed {seed}: {label} {:.2} tok/s under chunk=1 {:.2}",
                rep.tokens_per_sec,
                c1.tokens_per_sec
            );
            // identical traffic: every request completes with the same
            // token accounting at every chunk setting
            assert_eq!(rep.n_requests, c1.n_requests, "seed {seed}: {label}");
            assert_eq!(rep.output_tokens, c1.output_tokens, "seed {seed}: {label}");
        }
    }
}

#[test]
fn bigger_chunks_monotonically_shrink_prefill_steps() {
    // makespan falls (or holds) as the chunk grows: fewer, amortized
    // prefill steps for the same routed work
    let cfg = long_prompt_cfg(5);
    let m1 = run_chunk(&cfg, 1).makespan;
    let m8 = run_chunk(&cfg, 8).makespan;
    let m32 = run_chunk(&cfg, 32).makespan;
    assert!(m8 < m1, "chunk=8 makespan {m8:.3}s >= chunk=1 {m1:.3}s");
    assert!(m32 <= m8 * 1.02, "chunk=32 makespan {m32:.3}s regressed over chunk=8 {m8:.3}s");
}

// ------------------------------------------------------- engine-level
// (artifact-gated: skips cleanly when no PJRT artifacts are built)

/// First preset with complete artifacts (config + eval set), if any.
fn any_preset() -> Option<Ctx> {
    let dir = melinoe::artifacts_dir();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        if let Ok(ctx) = Ctx::load(&dir, preset) {
            if ctx.eval_set("dolly").is_ok() {
                return Some(ctx);
            }
        }
    }
    eprintln!("SKIP: no artifacts built (run `make artifacts`)");
    None
}

#[test]
fn engine_decode_bit_identical_across_chunk_sizes() {
    let Some(ctx) = any_preset() else { return };
    let pol = PolicyConfig::base_offload(ctx.cfg.n_experts);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100()).with_ignore_eos(true);
    let eval = ctx.eval_set("dolly").unwrap();
    // a genuinely long prompt so chunking has steps to merge
    let prompt: Vec<usize> =
        eval.samples[0].prompt.iter().cycle().take(32).copied().collect();

    let mut outs: Vec<Vec<usize>> = Vec::new();
    let mut ttfts = Vec::new();
    let mut transfers = Vec::new();
    for chunk in [1usize, 4, 32] {
        let mut sess = engine.session();
        sess.set_prefill_chunk(chunk);
        engine.admit(&mut sess, &prompt, 8).unwrap();
        let mut fins = Vec::new();
        while sess.active() > 0 {
            fins.extend(engine.step(&mut sess).unwrap());
        }
        assert_eq!(fins.len(), 1, "chunk {chunk}");
        ttfts.push(fins[0].sim_first_token - fins[0].sim_admitted);
        transfers.push(sess.pcie.stats.h2d_count);
        outs.push(fins[0].tokens.clone());
    }
    // chunking reshapes the cost timeline, never the numerics
    assert_eq!(outs[0], outs[1], "chunk=4 diverged from token-at-a-time");
    assert_eq!(outs[0], outs[2], "chunk=32 diverged from token-at-a-time");
    // same per-token residency requests → same demand transfers
    assert_eq!(transfers[0], transfers[1]);
    assert_eq!(transfers[0], transfers[2]);
    // and the chunked timeline reaches the first token sooner
    assert!(ttfts[1] < ttfts[0], "chunk=4 ttft {} >= chunk=1 {}", ttfts[1], ttfts[0]);
    assert!(ttfts[2] < ttfts[1] * 1.001, "chunk=32 ttft {} regressed", ttfts[2]);
}
