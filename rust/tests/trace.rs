//! Structured-tracing integration tests.
//!
//! The artifact-free tests drive the cluster simulator with tracing on
//! and lock in the observability contract: every run passes the
//! conservation audits `run_cluster` applies per replica (lane
//! monotonicity, trace-vs-`TransferStats` reconciliation, prefetch
//! issued/landed matching, pin-ledger and occupancy replay), the merged
//! fleet timeline carries sane counters, and — the zero-overhead
//! guarantee — every number in the [`ClusterReport`] is bit-identical
//! with tracing on vs off.  The engine-level test (artifact-gated,
//! skips without built artifacts) asserts decoded tokens are
//! bit-identical too, and reconciles the engine's own trace.

use melinoe::clock::GpuSpec;
use melinoe::cluster::replica::Replica;
use melinoe::cluster::workload::{self, OutputLen, TaskProfile};
use melinoe::cluster::{balancer, run_cluster, ClusterConfig, ClusterReport};
use melinoe::coordinator::workload::Arrival;
use melinoe::coordinator::SchedulerMode;
use melinoe::policies::PolicyConfig;
use melinoe::repro::Ctx;
use melinoe::trace::TraceEvent;

/// Small but non-trivial fleet: cache pressure (capacity below the task
/// hot set), lookahead pipeline on, so every event family fires.
fn traced_cfg(seed: u64) -> ClusterConfig {
    let mut cfg =
        ClusterConfig::synthetic(2, 24, 2, GpuSpec::h100(), seed).with_trace(true);
    cfg.spec.n_layers = 4;
    cfg.spec.n_experts = 32;
    cfg.spec.top_k = 4;
    cfg.spec.capacity = 6; // below the hot set → demand misses + evictions
    cfg.spec.lookahead = 1;
    cfg.tasks = TaskProfile::synthetic(2, 4, 32, 8, 0.9);
    cfg.workload.prompt_tokens = 8;
    cfg.workload.output = OutputLen::Fixed(6);
    cfg.max_batch = 3;
    cfg.with_arrival(Arrival::Burst)
}

fn run(cfg: &ClusterConfig) -> ClusterReport {
    let mut b = balancer::by_name("expert-affinity").unwrap();
    run_cluster(cfg, b.as_mut()).unwrap()
}

#[test]
fn traced_runs_pass_conservation_audits_and_count_sanely() {
    for seed in [3u64, 17, 42] {
        let cfg = traced_cfg(seed);
        // run_cluster itself fails on any per-replica audit violation;
        // an Ok report with a merged trace is the primary assertion
        let rep = run(&cfg);
        let tr = rep.trace.as_ref().expect("tracing was on");
        tr.audit_lane_monotonic().unwrap();
        assert!(!tr.events.is_empty(), "seed {seed}: empty trace");
        // lanes: one per replica plus the dispatcher
        assert_eq!(tr.lanes.len(), cfg.replicas + 1, "seed {seed}");
        assert_eq!(tr.lanes.get(&(cfg.replicas as u32)).map(String::as_str), Some("dispatcher"));

        let c = |k: &str| tr.registry.counters.get(k).copied().unwrap_or(0);
        let n = cfg.workload.n_requests as u64;
        assert_eq!(c("dispatches"), n, "seed {seed}: every request dispatched once");
        assert_eq!(c("requests_admitted"), n, "seed {seed}");
        assert_eq!(c("requests_retired"), n, "seed {seed}");
        assert!(c("steps") > 0, "seed {seed}");
        // every landed transfer answers an issued one; leftovers may
        // still sit in flight at drain time, never the reverse
        assert!(c("transfer_landed") <= c("prefetch_issued"), "seed {seed}");
        // pin ledger balances: pins come from admits + resumes, releases
        // from retires + suspends, and nothing stays suspended at drain
        assert_eq!(
            c("pins_set") + c("suspends"),
            c("pins_released") + c("resumes"),
            "seed {seed}"
        );
        // per-request token accounting survives into the event stream
        let retired_tokens: u64 = tr
            .events
            .iter()
            .filter_map(|s| match s.ev {
                TraceEvent::RequestRetire { output_tokens, .. } => Some(output_tokens as u64),
                _ => None,
            })
            .sum();
        assert_eq!(retired_tokens, rep.output_tokens as u64, "seed {seed}");
    }
}

#[test]
fn direct_replica_trace_reconciles_and_replays_cache_state() {
    let cfg = traced_cfg(9);
    let reqs = workload::generate(
        &cfg.workload,
        &cfg.tasks,
        cfg.spec.n_layers,
        cfg.spec.n_experts,
        cfg.spec.top_k,
    );
    let mut r = Replica::new(0, cfg.spec.clone(), SchedulerMode::Continuous)
        .with_prefill_chunk(cfg.prefill_chunk)
        .with_trace(true);
    for req in reqs {
        r.enqueue(req);
    }
    let mut guard = 0;
    while r.has_work() {
        r.run_one_step(cfg.max_batch);
        guard += 1;
        assert!(guard < 200_000, "replica failed to drain");
    }
    let tr = r.take_trace().expect("tracing was on");
    assert_eq!(tr.lanes.get(&0).map(String::as_str), Some("replica 0"));
    tr.audit_lane_monotonic().unwrap();
    // the trace's snapshot-delta stall/overlap/h2d totals must equal the
    // TransferEngine's own accounting exactly (same additions, observed
    // at emission time)
    tr.reconcile(&r.pcie.stats, 1e-6).unwrap();
    tr.audit_prefetch_landed(r.pcie.in_flight_len()).unwrap();
    tr.audit_pins(r.cache.layers[0].pinned_owners()).unwrap();
    let resident: Vec<usize> = r.cache.layers.iter().map(|l| l.resident_len()).collect();
    tr.audit_occupancy(&resident).unwrap();
    // and the chrome export is loadable json with the registry embedded
    let j = tr.to_chrome_json().to_string();
    let parsed = melinoe::util::json::Json::parse(&j).unwrap();
    assert!(!parsed.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    assert!(parsed.get("melinoe").unwrap().get("counters").is_ok());
}

#[test]
fn report_numbers_bit_identical_with_tracing_on_vs_off() {
    for seed in [5u64, 42] {
        let on_cfg = traced_cfg(seed);
        let off_cfg = on_cfg.clone().with_trace(false);
        let on = run(&on_cfg);
        let off = run(&off_cfg);
        assert!(on.trace.is_some() && off.trace.is_none());
        // tracing is pure observation: the simulation's numbers do not
        // move by a single ULP
        assert_eq!(on.n_requests, off.n_requests, "seed {seed}");
        assert_eq!(on.output_tokens, off.output_tokens, "seed {seed}");
        assert_eq!(on.makespan.to_bits(), off.makespan.to_bits(), "seed {seed}");
        assert_eq!(on.hit_rate.to_bits(), off.hit_rate.to_bits(), "seed {seed}");
        assert_eq!(on.stall_seconds.to_bits(), off.stall_seconds.to_bits(), "seed {seed}");
        assert_eq!(
            on.overlapped_seconds.to_bits(),
            off.overlapped_seconds.to_bits(),
            "seed {seed}"
        );
        assert_eq!(on.h2d_seconds.to_bits(), off.h2d_seconds.to_bits(), "seed {seed}");
        assert_eq!(on.pcie_gb.to_bits(), off.pcie_gb.to_bits(), "seed {seed}");
        assert_eq!(on.ttft.p95.to_bits(), off.ttft.p95.to_bits(), "seed {seed}");
        assert_eq!(on.latency.p99.to_bits(), off.latency.p99.to_bits(), "seed {seed}");
        assert_eq!(on.preemptions, off.preemptions, "seed {seed}");
    }
}

// ------------------------------------------------------- engine-level
// (artifact-gated: skips cleanly when no PJRT artifacts are built)

/// First preset with complete artifacts (config + eval set), if any.
fn any_preset() -> Option<Ctx> {
    let dir = melinoe::artifacts_dir();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        if let Ok(ctx) = Ctx::load(&dir, preset) {
            if ctx.eval_set("dolly").is_ok() {
                return Some(ctx);
            }
        }
    }
    eprintln!("SKIP: no artifacts built (run `make artifacts`)");
    None
}

#[test]
fn engine_decode_bit_identical_with_tracing_on_vs_off() {
    let Some(ctx) = any_preset() else { return };
    let pol = PolicyConfig::base_offload(ctx.cfg.n_experts);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100()).with_ignore_eos(true);
    let eval = ctx.eval_set("dolly").unwrap();
    let prompt = &eval.samples[0].prompt;

    let mut outs: Vec<Vec<usize>> = Vec::new();
    let mut sims = Vec::new();
    for tracing in [false, true] {
        let mut sess = engine.session();
        sess.set_tracing(tracing);
        engine.admit(&mut sess, prompt, 8).unwrap();
        let mut fins = Vec::new();
        while sess.active() > 0 {
            fins.extend(engine.step(&mut sess).unwrap());
        }
        assert_eq!(fins.len(), 1, "tracing {tracing}");
        outs.push(fins[0].tokens.clone());
        sims.push(sess.now());
        if tracing {
            let tr = sess.take_trace().expect("tracing was on");
            tr.audit_lane_monotonic().unwrap();
            tr.reconcile(&sess.pcie.stats, 1e-6).unwrap();
            assert!(tr.registry.counters.get("steps").copied().unwrap_or(0) > 0);
        }
    }
    assert_eq!(outs[0], outs[1], "tracing changed the decoded tokens");
    assert_eq!(sims[0].to_bits(), sims[1].to_bits(), "tracing moved the sim clock");
}
