//! Continuous-batching integration tests (artifact-free).
//!
//! These run the cluster simulator on the analytic cost model with
//! synthetic per-task routing traces, so they assert the PR's acceptance
//! behaviour unconditionally: under open-loop Poisson arrivals with
//! skewed (bimodal) output lengths, step-level continuous scheduling
//! strictly beats run-to-completion static batching on p95 latency and
//! throughput, with cache hit rate no worse — freed decode slots
//! re-admit queued requests instead of idling behind the longest batch
//! member, and affinity-pure traffic keeps the LFU cache warm across
//! mid-flight admissions.

use melinoe::clock::GpuSpec;
use melinoe::cluster::workload::{OutputLen, TaskProfile};
use melinoe::cluster::{balancer, run_cluster, ClusterConfig, ClusterReport};
use melinoe::coordinator::workload::Arrival;
use melinoe::coordinator::SchedulerMode;

/// Saturated single-task scenario with 10x output-length skew: offered
/// load ≈ 2.5× a single decode stream's capacity, so scheduling
/// efficiency — not offered load — bounds throughput.
fn skewed_cfg(seed: u64) -> ClusterConfig {
    let mut cfg = ClusterConfig::synthetic(1, 40, 1, GpuSpec::h100(), seed);
    // small model so the test stays fast
    cfg.spec.n_layers = 4;
    cfg.spec.n_experts = 32;
    cfg.spec.top_k = 4;
    cfg.spec.capacity = 12; // hot set (8) fully resident, plus slack
    cfg.tasks = TaskProfile::synthetic(1, 4, 32, 8, 0.95);
    cfg.workload.prompt_tokens = 2;
    cfg.max_batch = 4;
    let output = OutputLen::Bimodal { short: 4, long: 40, long_frac: 0.3 };
    let est = cfg
        .spec
        .est_service_seconds(cfg.workload.prompt_tokens, output.mean().ceil() as usize)
        .max(1e-12);
    cfg.with_output(output).with_arrival(Arrival::Poisson(2.5 / est))
}

fn run(cfg: &ClusterConfig) -> ClusterReport {
    let mut b = balancer::by_name("expert-affinity").unwrap();
    run_cluster(cfg, b.as_mut()).unwrap()
}

#[test]
fn continuous_beats_static_on_skewed_output_lengths() {
    for seed in [7u64, 21, 42] {
        let stat = run(&skewed_cfg(seed).with_scheduler(SchedulerMode::Static));
        let cont = run(&skewed_cfg(seed).with_scheduler(SchedulerMode::Continuous));
        // identical pre-drawn traffic on both sides
        assert_eq!(stat.n_requests, 40, "seed {seed}");
        assert_eq!(cont.n_requests, 40, "seed {seed}");
        assert_eq!(stat.output_tokens, cont.output_tokens, "seed {seed}");

        assert!(
            cont.latency.p95 < stat.latency.p95,
            "seed {seed}: continuous p95 {:.3}s >= static p95 {:.3}s",
            cont.latency.p95,
            stat.latency.p95
        );
        assert!(
            cont.tokens_per_sec > stat.tokens_per_sec,
            "seed {seed}: continuous {:.2} tok/s <= static {:.2} tok/s",
            cont.tokens_per_sec,
            stat.tokens_per_sec
        );
        assert!(
            cont.hit_rate >= stat.hit_rate - 0.02,
            "seed {seed}: continuous hit rate {:.4} fell below static {:.4}",
            cont.hit_rate,
            stat.hit_rate
        );
    }
}

#[test]
fn continuous_keeps_slots_occupied() {
    let cfg = skewed_cfg(5);
    let stat = run(&cfg.clone().with_scheduler(SchedulerMode::Static));
    let cont = run(&cfg.with_scheduler(SchedulerMode::Continuous));
    // same token work, shorter busy window: the continuous replica packs
    // more live sequences per step instead of idling drained slots
    assert_eq!(stat.output_tokens, cont.output_tokens);
    let stat_busy: f64 = stat.replicas.iter().map(|r| r.busy_seconds).sum();
    let cont_busy: f64 = cont.replicas.iter().map(|r| r.busy_seconds).sum();
    assert!(
        cont_busy < stat_busy,
        "continuous busy {cont_busy:.3}s >= static busy {stat_busy:.3}s"
    );
}

#[test]
fn ttft_improves_under_continuous_admission() {
    // queued requests stop waiting for whole-batch drains, so the time
    // to first token falls fleet-wide
    let cfg = skewed_cfg(11);
    let stat = run(&cfg.clone().with_scheduler(SchedulerMode::Static));
    let cont = run(&cfg.with_scheduler(SchedulerMode::Continuous));
    assert!(
        cont.ttft.p95 < stat.ttft.p95,
        "continuous ttft p95 {:.3}s >= static {:.3}s",
        cont.ttft.p95,
        stat.ttft.p95
    );
}
