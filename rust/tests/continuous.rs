//! Continuous-batching integration tests (artifact-free).
//!
//! These run the cluster simulator on the analytic cost model with
//! synthetic per-task routing traces, so they assert the PR's acceptance
//! behaviour unconditionally: under open-loop Poisson arrivals with
//! skewed (bimodal) output lengths, step-level continuous scheduling
//! strictly beats run-to-completion static batching on p95 latency and
//! throughput, with cache hit rate no worse — freed decode slots
//! re-admit queued requests instead of idling behind the longest batch
//! member, and affinity-pure traffic keeps the LFU cache warm across
//! mid-flight admissions.

use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use melinoe::clock::GpuSpec;
use melinoe::cluster::workload::{OutputLen, TaskProfile};
use melinoe::cluster::{balancer, run_cluster, ClusterConfig, ClusterReport};
use melinoe::coordinator::workload::Arrival;
use melinoe::coordinator::{
    Decoder, PreemptPolicy, Priority, Request, Response, Scheduler, SchedulerMode, SeqFinish,
    ServerConfig,
};

/// Saturated single-task scenario with 10x output-length skew: offered
/// load ≈ 2.5× a single decode stream's capacity, so scheduling
/// efficiency — not offered load — bounds throughput.
fn skewed_cfg(seed: u64) -> ClusterConfig {
    let mut cfg = ClusterConfig::synthetic(1, 40, 1, GpuSpec::h100(), seed);
    // small model so the test stays fast
    cfg.spec.n_layers = 4;
    cfg.spec.n_experts = 32;
    cfg.spec.top_k = 4;
    cfg.spec.capacity = 12; // hot set (8) fully resident, plus slack
    cfg.tasks = TaskProfile::synthetic(1, 4, 32, 8, 0.95);
    cfg.workload.prompt_tokens = 2;
    cfg.max_batch = 4;
    let output = OutputLen::Bimodal { short: 4, long: 40, long_frac: 0.3 };
    let est = cfg
        .spec
        .est_service_seconds(cfg.workload.prompt_tokens, output.mean().ceil() as usize)
        .max(1e-12);
    cfg.with_output(output).with_arrival(Arrival::Poisson(2.5 / est))
}

fn run(cfg: &ClusterConfig) -> ClusterReport {
    let mut b = balancer::by_name("expert-affinity").unwrap();
    run_cluster(cfg, b.as_mut()).unwrap()
}

#[test]
fn continuous_beats_static_on_skewed_output_lengths() {
    for seed in [7u64, 21, 42] {
        let stat = run(&skewed_cfg(seed).with_scheduler(SchedulerMode::Static));
        let cont = run(&skewed_cfg(seed).with_scheduler(SchedulerMode::Continuous));
        // identical pre-drawn traffic on both sides
        assert_eq!(stat.n_requests, 40, "seed {seed}");
        assert_eq!(cont.n_requests, 40, "seed {seed}");
        assert_eq!(stat.output_tokens, cont.output_tokens, "seed {seed}");

        assert!(
            cont.latency.p95 < stat.latency.p95,
            "seed {seed}: continuous p95 {:.3}s >= static p95 {:.3}s",
            cont.latency.p95,
            stat.latency.p95
        );
        assert!(
            cont.tokens_per_sec > stat.tokens_per_sec,
            "seed {seed}: continuous {:.2} tok/s <= static {:.2} tok/s",
            cont.tokens_per_sec,
            stat.tokens_per_sec
        );
        assert!(
            cont.hit_rate >= stat.hit_rate - 0.02,
            "seed {seed}: continuous hit rate {:.4} fell below static {:.4}",
            cont.hit_rate,
            stat.hit_rate
        );
    }
}

#[test]
fn continuous_keeps_slots_occupied() {
    let cfg = skewed_cfg(5);
    let stat = run(&cfg.clone().with_scheduler(SchedulerMode::Static));
    let cont = run(&cfg.with_scheduler(SchedulerMode::Continuous));
    // same token work, shorter busy window: the continuous replica packs
    // more live sequences per step instead of idling drained slots
    assert_eq!(stat.output_tokens, cont.output_tokens);
    let stat_busy: f64 = stat.replicas.iter().map(|r| r.busy_seconds).sum();
    let cont_busy: f64 = cont.replicas.iter().map(|r| r.busy_seconds).sum();
    assert!(
        cont_busy < stat_busy,
        "continuous busy {cont_busy:.3}s >= static busy {stat_busy:.3}s"
    );
}

// ---------------------------------------------------- scheduler fairness
// Chunked prefill must piggyback on decode steps, never displace them: a
// huge prompt admitted mid-flight may not delay an in-flight decode's
// next token beyond the one step they share.

/// Step-level mock decoder with real prefill semantics: a sequence
/// consumes up to `chunk` prompt tokens per step while in prefill (the
/// step covering the last prompt token emits the first output token) and
/// exactly one output token per step afterwards.  Records the step index
/// of every emission so tests can assert gap-free decode cadence.
struct ChunkMock {
    chunk: usize,
    step_no: u64,
    clock: f64,
    next: u64,
    seqs: Vec<MockSeq>,
    /// emissions[seq] — the step index at which each output token landed.
    emissions: std::collections::HashMap<u64, Vec<u64>>,
}

struct MockSeq {
    id: u64,
    prompt_left: usize,
    out: Vec<usize>,
    produced: usize,
    admitted: f64,
    first: f64,
}

impl ChunkMock {
    fn new() -> ChunkMock {
        ChunkMock {
            chunk: 1,
            step_no: 0,
            clock: 0.0,
            next: 0,
            seqs: Vec::new(),
            emissions: std::collections::HashMap::new(),
        }
    }
}

impl Decoder for ChunkMock {
    fn admit(&mut self, prompt: &[usize], max_output: usize) -> anyhow::Result<u64> {
        let id = self.next;
        self.next += 1;
        self.seqs.push(MockSeq {
            id,
            prompt_left: prompt.len(),
            out: (0..max_output.max(1)).collect(),
            produced: 0,
            admitted: self.clock,
            first: 0.0,
        });
        Ok(id)
    }

    fn step(&mut self) -> anyhow::Result<Vec<SeqFinish>> {
        self.step_no += 1;
        self.clock += 1.0;
        let now = self.clock;
        let mut done = Vec::new();
        let mut keep = Vec::new();
        for mut s in self.seqs.drain(..) {
            if s.prompt_left > self.chunk {
                // mid-prefill: consume a chunk, no token yet
                s.prompt_left -= self.chunk;
                keep.push(s);
                continue;
            }
            // the chunk covering the last prompt token (or a plain
            // decode step) emits exactly one token
            s.prompt_left = 0;
            if s.produced == 0 {
                s.first = now;
            }
            s.produced += 1;
            self.emissions.entry(s.id).or_default().push(self.step_no);
            if s.produced >= s.out.len() {
                done.push(SeqFinish {
                    seq: s.id,
                    tokens: s.out,
                    sim_admitted: s.admitted,
                    sim_first_token: s.first,
                    sim_finished: now,
                });
            } else {
                keep.push(s);
            }
        }
        self.seqs = keep;
        Ok(done)
    }

    fn active(&self) -> usize {
        self.seqs.len()
    }

    fn now(&self) -> f64 {
        self.clock
    }

    fn set_prefill_chunk(&mut self, chunk: usize) {
        self.chunk = chunk.max(1);
    }
}

fn submit(
    s: &mut Scheduler<ChunkMock>,
    id: u64,
    prompt: Vec<usize>,
    out: usize,
) -> Receiver<Response> {
    let (tx, rx) = channel();
    let req = Request { id, prompt, max_output: out, priority: Priority::Normal };
    s.enqueue(req, tx, Instant::now());
    rx
}

/// A 10k-token prompt admitted mid-flight never delays an in-flight
/// decode's next token beyond one step, at any chunk setting: the decode
/// emits on every consecutive scheduler step from its first token to its
/// last, while the monster prompt prefills alongside.
#[test]
fn huge_prompt_never_stalls_inflight_decode_at_any_chunk() {
    for chunk in [1usize, 8, 64, 4096] {
        let cfg = ServerConfig {
            max_batch: 4,
            batch_wait: Duration::from_millis(1),
            max_output: 16,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: chunk,
            preempt: PreemptPolicy::Off,
        };
        let mut s = Scheduler::new(ChunkMock::new(), cfg);
        // the in-flight decode: 1-token prompt, 16 output tokens
        let rx_decode = submit(&mut s, 0, vec![7], 16);
        s.tick().unwrap();
        s.tick().unwrap();
        // the monster arrives mid-flight
        let rx_big = submit(&mut s, 1, vec![0; 10_000], 4);
        let mut guard = 0;
        while s.has_work() {
            s.tick().unwrap();
            guard += 1;
            assert!(guard < 20_000, "chunk {chunk}: scheduler failed to drain");
        }
        let emissions = &s.decoder().emissions[&0];
        assert_eq!(emissions.len(), 16, "chunk {chunk}");
        assert!(
            emissions.windows(2).all(|w| w[1] - w[0] == 1),
            "chunk {chunk}: decode cadence has gaps: {emissions:?}"
        );
        let decode = rx_decode.recv().unwrap();
        assert_eq!(decode.tokens.len(), 16);
        // the monster still finishes: ceil(10000/chunk) prefill steps
        // (the last one emits its first token) + 3 more decode steps
        let big = rx_big.recv().unwrap();
        assert_eq!(big.tokens.len(), 4, "chunk {chunk}");
        let big_em = &s.decoder().emissions[&1];
        let expected_first = 2 + 10_000_usize.div_ceil(chunk) as u64;
        assert_eq!(big_em[0], expected_first, "chunk {chunk}");
    }
}

#[test]
fn ttft_improves_under_continuous_admission() {
    // queued requests stop waiting for whole-batch drains, so the time
    // to first token falls fleet-wide
    let cfg = skewed_cfg(11);
    let stat = run(&cfg.clone().with_scheduler(SchedulerMode::Static));
    let cont = run(&cfg.with_scheduler(SchedulerMode::Continuous));
    assert!(
        cont.ttft.p95 < stat.ttft.p95,
        "continuous ttft p95 {:.3}s >= static {:.3}s",
        cont.ttft.p95,
        stat.ttft.p95
    );
}
