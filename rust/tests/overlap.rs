//! Layer-ahead overlapped expert-transfer pipeline tests.
//!
//! Three tiers:
//!
//! 1. **Pipeline invariants** (property tests over the raw
//!    `TransferEngine`, via the `util::prop` harness): per-link
//!    transfers never reorder, the stall/overlap accounting is
//!    conserved against total transfer time, and `wait_for` on a
//!    completed transfer is free.
//! 2. **Cluster-level wins** (artifact-free: analytic cost model +
//!    pre-drawn routing traces, the PR's acceptance behaviour): at equal
//!    cache capacity under pressure, lookahead prefetch strictly cuts
//!    total decode stall time and lifts tok/s versus admit-only
//!    prefetch, with hit-rate no worse — and a miss caught in-flight
//!    charges less than a cold demand fetch.
//! 3. **Bit-identity** (artifact-gated, mirrors the prefill
//!    chunk-identity test): decoded tokens are identical across
//!    `--lookahead 0/1/2` and across `Prefetch::None` vs
//!    `Prefetch::Lookahead` — the pipeline reshapes residency timing
//!    only, never routing.

use melinoe::cache::EvictionKind;
use melinoe::clock::{CostModel, GpuSpec, PaperDims, SimClock};
use melinoe::cluster::replica::ReplicaSpec;
use melinoe::cluster::workload::{OutputLen, PriorityMix, TaskProfile, WorkloadSpec};
use melinoe::cluster::{balancer, run_cluster, ClusterConfig, ClusterReport};
use melinoe::coordinator::workload::Arrival;
use melinoe::coordinator::{PreemptPolicy, SchedulerMode};
use melinoe::pcie::TransferEngine;
use melinoe::policies::PolicyConfig;
use melinoe::quant::QuantMode;
use melinoe::repro::Ctx;
use melinoe::util::prop::{check, shrink_vec};

fn cm() -> CostModel {
    CostModel::new(
        GpuSpec::h100(),
        PaperDims { n_layers: 8, n_experts: 16, top_k: 2, d_model: 2048, d_ff: 1024, vocab: 50304 },
    )
}

// ------------------------------------------------------ pipeline invariants

/// One randomized op against the engine: issue a demand, issue a tracked
/// prefetch, advance the clock, or claim an outstanding prefetch.
/// (kind, layer, expert, microseconds) — tuples shrink with the stock
/// vector shrinker.
type Op = (u8, usize, usize, u64);

fn run_ops(ops: &[Op]) -> (TransferEngine, SimClock, Vec<f64>, bool) {
    let cm = cm();
    let mut eng = TransferEngine::new();
    let mut clock = SimClock::new();
    let mut completions: Vec<f64> = Vec::new();
    let mut outstanding: Vec<(usize, usize)> = Vec::new();
    let mut residuals_free = true;
    for &(kind, layer, expert, micros) in ops {
        match kind % 4 {
            0 => {
                eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16);
                // a demand completes exactly when the decode resumes
                completions.push(clock.now());
            }
            1 => {
                if !eng.in_flight_contains(layer, expert) {
                    let done = eng.prefetch_expert(&cm, &clock, layer, expert, QuantMode::Fp16);
                    completions.push(done);
                    outstanding.push((layer, expert));
                }
            }
            2 => clock.advance(micros as f64 * 1e-6),
            _ => {
                if let Some((l, e)) = outstanding.pop() {
                    let before = clock.now();
                    let r = eng.wait_for(l, e, &mut clock).expect("tracked transfer");
                    if (clock.now() - before - r).abs() > 1e-9 {
                        residuals_free = false;
                    }
                }
            }
        }
    }
    // settle: claim everything still outstanding after the link drains
    // (tiny margin absorbs float rounding in now + link_wait)
    clock.advance(eng.link_wait(clock.now()) + 1e-9);
    for (l, e) in outstanding {
        let before = clock.now();
        let r = eng.wait_for(l, e, &mut clock).expect("tracked transfer");
        // the link drained, so every claim here must be free
        if r != 0.0 || clock.now() != before {
            residuals_free = false;
        }
    }
    (eng, clock, completions, residuals_free)
}

fn gen_ops(r: &mut melinoe::util::rng::Rng) -> Vec<Op> {
    (0..r.range(1, 40))
        .map(|_| (r.below(4) as u8, r.below(4), r.below(16), r.below(3000) as u64))
        .collect()
}

#[test]
fn prop_link_never_reorders() {
    check(
        150,
        gen_ops,
        |ops| shrink_vec(ops, |_| vec![]),
        |ops| {
            let (_, _, completions, _) = run_ops(ops);
            // single FIFO link: completion times are non-decreasing in
            // issue order, for any interleaving of demand/prefetch/compute
            completions.windows(2).all(|w| w[0] <= w[1] + 1e-12)
        },
    );
}

#[test]
fn prop_stall_plus_overlap_conserved() {
    check(
        150,
        gen_ops,
        |ops| shrink_vec(ops, |_| vec![]),
        |ops| {
            let (eng, _, _, _) = run_ops(ops);
            let s = &eng.stats;
            // every transfer's duration is accounted at least once
            // (demand stalls include link-queue waits on top), and
            // overlap can never exceed the total transfer time
            s.stall_time + s.overlapped_time >= s.h2d_seconds - 1e-9
                && s.overlapped_time <= s.h2d_seconds + 1e-9
                && s.overlapped_time >= -1e-9
                && s.stall_time >= -1e-9
        },
    );
}

#[test]
fn prop_wait_for_completed_transfer_is_free() {
    check(
        150,
        gen_ops,
        |ops| shrink_vec(ops, |_| vec![]),
        |ops| {
            // run_ops claims every leftover transfer after the link has
            // drained and flags any non-free claim; residual claims mid-
            // flight must advance the clock by exactly the residual
            run_ops(ops).3
        },
    );
}

#[test]
fn conservation_exact_without_link_queueing() {
    let cm = cm();
    let dt = cm.transfer_time(QuantMode::Fp16);
    // caught mid-flight: hidden + residual == duration, exactly
    let mut eng = TransferEngine::new();
    let mut clock = SimClock::new();
    eng.prefetch_expert(&cm, &clock, 0, 1, QuantMode::Fp16);
    clock.advance(0.25 * dt);
    eng.wait_for(0, 1, &mut clock).unwrap();
    let s = &eng.stats;
    assert!((s.stall_time + s.overlapped_time - s.h2d_seconds).abs() < 1e-12);
    assert!((s.stall_time - 0.75 * dt).abs() < 1e-12);
    // claimed at issue time (no compute at all): the whole duration stalls
    let mut eng = TransferEngine::new();
    let mut clock = SimClock::new();
    eng.prefetch_expert(&cm, &clock, 0, 1, QuantMode::Fp16);
    eng.wait_for(0, 1, &mut clock).unwrap();
    assert!((eng.stats.stall_time - dt).abs() < 1e-12);
    assert!(eng.stats.overlapped_time.abs() < 1e-12);
}

#[test]
fn caught_in_flight_miss_cheaper_than_cold_demand() {
    let cm = cm();
    // cold demand: full transfer stalls the decode
    let mut cold = TransferEngine::new();
    let mut c0 = SimClock::new();
    let demand_stall = cold.demand_h2d(&cm, &mut c0, QuantMode::Fp16);
    // the same miss with its prefetch already on the link: residual only
    let mut eng = TransferEngine::new();
    let mut c1 = SimClock::new();
    eng.prefetch_expert(&cm, &c1, 2, 5, QuantMode::Fp16);
    c1.advance(demand_stall * 0.5); // compute hides half the transfer
    let residual = eng.wait_for(2, 5, &mut c1).unwrap();
    assert!(residual > 0.0, "mid-flight catch must have a residual");
    assert!(
        residual < demand_stall,
        "caught in-flight ({residual}) must charge less than cold demand ({demand_stall})"
    );
}

// ------------------------------------------------------- cluster-level wins

/// High-pressure single-task scenario: Mixtral-scale experts (one
/// transfer is ~ a layer's compute) with capacity below the hot-set
/// size, so admit-only prefetch leaves steady per-step misses — the
/// regime the layer-ahead pipeline is built for.
fn pressure_cfg(seed: u64) -> ClusterConfig {
    let dims = PaperDims {
        n_layers: 8,
        n_experts: 8,
        top_k: 2,
        d_model: 4096,
        d_ff: 14336,
        vocab: 32000,
    };
    let spec = ReplicaSpec {
        n_layers: dims.n_layers,
        n_experts: dims.n_experts,
        top_k: dims.top_k,
        capacity: 3,
        eviction: EvictionKind::Lfu,
        quant: QuantMode::Int4,
        prefetch: true,
        lookahead: 0,
        gpu: GpuSpec::h100(),
        dims,
    };
    let tasks = TaskProfile::synthetic(1, dims.n_layers, dims.n_experts, 5, 0.9);
    ClusterConfig {
        replicas: 1,
        max_batch: 4,
        max_queue: 64,
        scheduler: SchedulerMode::Continuous,
        prefill_chunk: 1,
        preempt: PreemptPolicy::Off,
        spec,
        workload: WorkloadSpec {
            n_requests: 24,
            arrival: Arrival::Burst,
            prompt_tokens: 4,
            output: OutputLen::Fixed(12),
            balanced_tasks: false,
            priorities: PriorityMix::none(),
            seed,
        },
        tasks,
    }
}

fn run_lookahead(cfg: &ClusterConfig, depth: usize) -> ClusterReport {
    let mut b = balancer::by_name("expert-affinity").unwrap();
    run_cluster(&cfg.clone().with_lookahead(depth), b.as_mut()).unwrap()
}

#[test]
fn lookahead_cuts_stall_and_lifts_throughput_at_equal_capacity() {
    for seed in [7u64, 21, 42] {
        let cfg = pressure_cfg(seed);
        let la0 = run_lookahead(&cfg, 0);
        let la1 = run_lookahead(&cfg, 1);
        let la2 = run_lookahead(&cfg, 2);
        assert_eq!(la0.lookahead, 0, "seed {seed}");
        assert_eq!(la1.lookahead, 1);
        assert_eq!(la2.lookahead, 2);
        // identical traffic at every depth
        assert_eq!(la1.n_requests, la0.n_requests, "seed {seed}");
        assert_eq!(la1.output_tokens, la0.output_tokens, "seed {seed}");
        assert!(la0.stall_seconds > 0.0, "seed {seed}: pressure config must stall");

        for (label, rep) in [("lookahead=1", &la1), ("lookahead=2", &la2)] {
            // the headline: strictly less decode time lost to transfers
            assert!(
                rep.stall_seconds < la0.stall_seconds,
                "seed {seed}: {label} stall {:.4}s not under admit-only {:.4}s",
                rep.stall_seconds,
                la0.stall_seconds
            );
            // hidden transfer time is the mechanism
            assert!(
                rep.overlapped_seconds > la0.overlapped_seconds,
                "seed {seed}: {label} overlapped {:.4}s <= admit-only {:.4}s",
                rep.overlapped_seconds,
                la0.overlapped_seconds
            );
            assert!(
                rep.overlap_fraction > la0.overlap_fraction,
                "seed {seed}: {label} overlap fraction did not rise"
            );
            // and it shows up end to end: better tok/s at equal capacity
            assert!(
                rep.tokens_per_sec > la0.tokens_per_sec,
                "seed {seed}: {label} {:.2} tok/s <= admit-only {:.2}",
                rep.tokens_per_sec,
                la0.tokens_per_sec
            );
            // prefetched experts land before use: hit-rate no worse
            // (tiny slack: commit-vs-insert can reorder evictions)
            assert!(
                rep.hit_rate >= la0.hit_rate - 0.02,
                "seed {seed}: {label} hit rate {:.4} fell below admit-only {:.4}",
                rep.hit_rate,
                la0.hit_rate
            );
        }
        // deeper lookahead has more overlap headroom on this config
        assert!(
            la2.stall_seconds <= la1.stall_seconds * 1.05 + 1e-9,
            "seed {seed}: depth 2 stall {:.4}s regressed over depth 1 {:.4}s",
            la2.stall_seconds,
            la1.stall_seconds
        );
    }
}

#[test]
fn lookahead_costs_only_the_predictor_when_there_is_nothing_to_prefetch() {
    // pressure-free cache (every expert fits): the pipeline has nothing
    // to move, so depth 1 must behave exactly like depth 0 except for
    // the per-step predictor consult — which depth 0 must NOT charge
    let mut cfg = pressure_cfg(5);
    cfg.spec.capacity = cfg.spec.n_experts;
    let la0 = run_lookahead(&cfg, 0);
    let la1 = run_lookahead(&cfg, 1);
    assert_eq!(la0.output_tokens, la1.output_tokens);
    // same transfers either way: one first-touch load per distinct
    // expert, whether it arrives by demand or by pipeline
    assert!((la0.pcie_gb - la1.pcie_gb).abs() < 1e-9, "{} vs {}", la0.pcie_gb, la1.pcie_gb);
    // the pipeline never makes stall worse on a pressure-free cache
    // (warmup first-touches become residuals instead of full stalls)
    assert!(la1.stall_seconds <= la0.stall_seconds + 1e-6);
    // depth 0 skips the predictor entirely; depth 1 pays it per step,
    // and with (almost) nothing to hide that cost must be visible
    assert!(
        la1.makespan > la0.makespan,
        "depth 1 makespan {:.4}s not above depth 0 {:.4}s — per-step predictor consult missing",
        la1.makespan,
        la0.makespan
    );
}

// ------------------------------------------------------- engine-level
// (artifact-gated: skips cleanly when no PJRT artifacts are built)

/// First preset with complete artifacts (config + eval set), if any.
fn any_preset() -> Option<Ctx> {
    let dir = melinoe::artifacts_dir();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        if let Ok(ctx) = Ctx::load(&dir, preset) {
            if ctx.eval_set("dolly").is_ok() {
                return Some(ctx);
            }
        }
    }
    eprintln!("SKIP: no artifacts built (run `make artifacts`)");
    None
}

#[test]
fn engine_decode_bit_identical_across_lookahead_depths() {
    let Some(ctx) = any_preset() else { return };
    // a tight cache so the pipeline actually fires, but a
    // residency-independent policy (no sparsity gate) so routing cannot
    // depend on what prefetch landed
    let cap = (ctx.cfg.n_experts / 4).max(ctx.cfg.top_k);
    let eval = ctx.eval_set("dolly").unwrap();
    let prompt = eval.samples[0].prompt.clone();

    let mut outs: Vec<Vec<usize>> = Vec::new();
    let mut stalls: Vec<f64> = Vec::new();
    for depth in [0usize, 1, 2] {
        let pol = if depth == 0 {
            PolicyConfig::base_offload(cap)
        } else {
            PolicyConfig::base_offload(cap).with_lookahead(depth)
        };
        let parts = ctx.parts(&pol, "dolly").unwrap();
        let engine = parts.engine(&ctx, GpuSpec::h100()).with_ignore_eos(true);
        let out = engine.decode(&prompt, 12).unwrap();
        stalls.push(out.report.transfers.stall_time);
        outs.push(out.tokens);
    }
    // Prefetch::None vs Lookahead{1,2}: the pipeline reshapes residency
    // timing only, never routing — tokens are bit-identical
    assert_eq!(outs[0], outs[1], "lookahead=1 diverged from admit-only decode");
    assert_eq!(outs[0], outs[2], "lookahead=2 diverged from admit-only decode");
    // and the pipeline should not add transfer stalls (small slack: the
    // engine-side predictor is honest, so a cold trace can mispredict
    // the first steps and queue demands behind speculative traffic)
    assert!(
        stalls[1] <= stalls[0] * 1.2 + 1e-9,
        "lookahead=1 stall {} well above baseline {}",
        stalls[1],
        stalls[0]
    );
}
