//! Priority-aware preemption tests.
//!
//! Four tiers:
//!
//! 1. **Cluster-level acceptance** (artifact-free: analytic cost model +
//!    pre-drawn routing traces): under a priority-skewed Poisson workload
//!    at equal capacity, preemption on cuts High-priority p95 TTFT and
//!    p95 latency versus preemption off (which already admits
//!    priority-first), with aggregate tok/s and hit-rate no worse than
//!    5% off baseline and identical per-request token accounting — the
//!    suspended work is conserved, only reordered.
//! 2. **Mock-Decoder bound** (the public `Scheduler` API driven
//!    synchronously): a High arrival's time to first token is bounded by
//!    the preemption threshold plus a couple of steps even when every
//!    slot is held by a long Low decode.
//! 3. **Pin-ledger property**: experts a `pin_set` protects survive any
//!    storm of `prefill_union` refreshes and reserve/`commit` arrivals,
//!    and become evictable again after `release`.
//! 4. **Bit-identity** (artifact-gated, mirrors the prefill/lookahead
//!    identity tests): a sequence suspended mid-decode or mid-prefill
//!    resumes to exactly the tokens of an uninterrupted run — suspension
//!    reshapes residency timing only, never numerics.

use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use melinoe::cache::{EvictionKind, LayerCache};
use melinoe::clock::GpuSpec;
use melinoe::cluster::workload::{OutputLen, PriorityMix, TaskProfile};
use melinoe::cluster::{balancer, run_cluster, ClusterConfig, ClusterReport};
use melinoe::coordinator::workload::Arrival;
use melinoe::coordinator::{
    Decoder, PreemptPolicy, Priority, Request, Response, Scheduler, SchedulerMode, SeqFinish,
    ServerConfig,
};
use melinoe::policies::PolicyConfig;
use melinoe::repro::Ctx;
use melinoe::util::prop::check_no_shrink;
use melinoe::util::rng::Rng;

// ------------------------------------------------------ cluster acceptance

/// Priority-skewed saturated scenario: one replica, two slots, fixed
/// 32-token outputs, 20% High over a mostly-Low mix, offered load ≈
/// 1.5× capacity — a High arrival almost always finds the slots full,
/// so the off/on contrast isolates the preemption decision.
fn preempt_cfg(seed: u64) -> (ClusterConfig, f64) {
    let mut cfg = ClusterConfig::synthetic(1, 40, 1, GpuSpec::h100(), seed);
    // small model so the test stays fast
    cfg.spec.n_layers = 4;
    cfg.spec.n_experts = 32;
    cfg.spec.top_k = 4;
    cfg.spec.capacity = 12; // hot set (8) fully resident, plus slack
    cfg.tasks = TaskProfile::synthetic(1, 4, 32, 8, 0.95);
    cfg.workload.prompt_tokens = 2;
    cfg.workload.output = OutputLen::Fixed(32);
    cfg.workload.priorities = PriorityMix { high: 0.2, low: 0.8 };
    cfg.max_batch = 2;
    let est = cfg.spec.est_service_seconds(2, 32).max(1e-12);
    // threshold: one solo token-step of waiting, then preempt
    let thresh = est / 34.0;
    (cfg.with_arrival(Arrival::Poisson(1.5 / est)), thresh)
}

fn run(cfg: &ClusterConfig) -> ClusterReport {
    let mut b = balancer::by_name("expert-affinity").unwrap();
    run_cluster(cfg, b.as_mut()).unwrap()
}

fn class(rep: &ClusterReport, p: Priority) -> &melinoe::cluster::PriorityClass {
    rep.priorities.iter().find(|c| c.priority == p).expect("class present")
}

#[test]
fn preemption_cuts_high_priority_p95_ttft_and_latency() {
    for seed in [7u64, 21, 42] {
        let (cfg, thresh) = preempt_cfg(seed);
        let off = run(&cfg);
        let on = run(&cfg.clone().with_preempt(PreemptPolicy::After(thresh)));
        // identical pre-drawn traffic on both sides
        assert_eq!(off.n_requests, 40, "seed {seed}");
        assert_eq!(on.n_requests, 40, "seed {seed}");
        assert_eq!(off.output_tokens, on.output_tokens, "seed {seed}");
        assert_eq!(off.preemptions, 0, "seed {seed}: off must never suspend");
        assert!(on.preemptions > 0, "seed {seed}: the skewed mix must trigger preemption");

        let (h_off, h_on) = (class(&off, Priority::High), class(&on, Priority::High));
        assert!(h_off.requests > 0, "seed {seed}: mix must draw High requests");
        // the headline: High p95 TTFT and p95 latency fall
        assert!(
            h_on.ttft.p95 < h_off.ttft.p95,
            "seed {seed}: preempt-on High p95 ttft {:.4}s not under off {:.4}s",
            h_on.ttft.p95,
            h_off.ttft.p95
        );
        assert!(
            h_on.latency.p95 < h_off.latency.p95,
            "seed {seed}: preempt-on High p95 latency {:.4}s not under off {:.4}s",
            h_on.latency.p95,
            h_off.latency.p95
        );
        // the cost lands visibly on the preempted class, not hidden
        let l_on = class(&on, Priority::Low);
        assert!(l_on.preempted_wait.p99 > 0.0, "seed {seed}: suspended time must surface");
        assert_eq!(
            class(&off, Priority::Low).preempted_wait.p99,
            0.0,
            "seed {seed}: off reports zero suspended time"
        );
        // aggregate efficiency holds: work is conserved, only reordered
        assert!(
            on.tokens_per_sec >= 0.95 * off.tokens_per_sec,
            "seed {seed}: preempt-on {:.2} tok/s under 95% of off {:.2}",
            on.tokens_per_sec,
            off.tokens_per_sec
        );
        assert!(
            on.hit_rate >= off.hit_rate - 0.05,
            "seed {seed}: preempt-on hit rate {:.4} fell below off {:.4}",
            on.hit_rate,
            off.hit_rate
        );
    }
}

/// Preempted-then-resumed sequences complete with exactly the same
/// per-request token accounting as the uninterrupted run, and the same
/// total routed cache traffic — suspension never adds, drops, or reroutes
/// a token.
#[test]
fn preemption_conserves_per_request_token_accounting() {
    let (cfg, thresh) = preempt_cfg(5);
    let off = run(&cfg);
    let on = run(&cfg.clone().with_preempt(PreemptPolicy::After(thresh)));
    assert!(on.preemptions > 0);
    let totals = |rep: &ClusterReport| {
        let mut v: Vec<(usize, usize)> = rep
            .replicas
            .iter()
            .map(|r| (r.requests, r.output_tokens))
            .collect();
        v.sort_unstable();
        v
    };
    assert_eq!(totals(&off), totals(&on), "same requests and tokens per replica");
    assert_eq!(off.output_tokens, on.output_tokens);
}

// --------------------------------------------------------- mock decoder

/// Echo decoder with suspend/resume: one output token per step (the
/// prompt reversed), a fixed simulated `dt` per step.
struct EchoMock {
    dt: f64,
    clock: f64,
    next: u64,
    seqs: Vec<EchoSeq>,
}

struct EchoSeq {
    id: u64,
    out: Vec<usize>,
    produced: usize,
    admitted: f64,
    first: f64,
}

impl Decoder for EchoMock {
    fn admit(&mut self, prompt: &[usize], max_output: usize) -> anyhow::Result<u64> {
        let id = self.next;
        self.next += 1;
        let out: Vec<usize> = prompt.iter().rev().copied().take(max_output.max(1)).collect();
        self.seqs.push(EchoSeq { id, out, produced: 0, admitted: self.clock, first: 0.0 });
        Ok(id)
    }

    fn step(&mut self) -> anyhow::Result<Vec<SeqFinish>> {
        self.clock += self.dt;
        let now = self.clock;
        let mut done = Vec::new();
        let mut keep = Vec::new();
        for mut s in self.seqs.drain(..) {
            if s.produced == 0 {
                s.first = now;
            }
            s.produced += 1;
            if s.produced >= s.out.len() {
                done.push(SeqFinish {
                    seq: s.id,
                    tokens: s.out,
                    sim_admitted: s.admitted,
                    sim_first_token: s.first,
                    sim_finished: now,
                });
            } else {
                keep.push(s);
            }
        }
        self.seqs = keep;
        Ok(done)
    }

    fn active(&self) -> usize {
        self.seqs.len()
    }

    fn now(&self) -> f64 {
        self.clock
    }

    fn suspend(&mut self, seq: u64) -> anyhow::Result<Box<dyn std::any::Any>> {
        let i = self
            .seqs
            .iter()
            .position(|s| s.id == seq)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {seq}"))?;
        Ok(Box::new(self.seqs.remove(i)))
    }

    fn resume(&mut self, state: Box<dyn std::any::Any>) -> anyhow::Result<u64> {
        let s = state
            .downcast::<EchoSeq>()
            .map_err(|_| anyhow::anyhow!("foreign suspended state"))?;
        let id = s.id;
        self.seqs.push(*s);
        Ok(id)
    }
}

fn submit(
    s: &mut Scheduler<EchoMock>,
    id: u64,
    prompt: Vec<usize>,
    out: usize,
    priority: Priority,
) -> Receiver<Response> {
    let (tx, rx) = channel();
    s.enqueue(Request { id, prompt, max_output: out, priority }, tx, Instant::now());
    rx
}

/// Every slot held by a 100-token Low decode: a High arrival's first
/// token lands within `threshold + 2 steps` of its submission (one step
/// to cross the threshold at a boundary, one for its own decode), and
/// the suspended Low still drains to its full bit-identical echo.
#[test]
fn mock_high_ttft_bounded_under_full_slots() {
    let thresh = 3.0;
    let dt = 1.0;
    let cfg = ServerConfig {
        max_batch: 2,
        batch_wait: Duration::from_millis(1),
        max_output: 128,
        scheduler: SchedulerMode::Continuous,
        prefill_chunk: 1,
        preempt: PreemptPolicy::After(thresh),
    };
    let dec = EchoMock { dt, clock: 0.0, next: 0, seqs: Vec::new() };
    let mut s = Scheduler::new(dec, cfg);
    let long: Vec<usize> = (0..100).collect();
    let rl0 = submit(&mut s, 0, long.clone(), 100, Priority::Low);
    let rl1 = submit(&mut s, 1, long.clone(), 100, Priority::Low);
    s.tick().unwrap();
    s.tick().unwrap();
    let submitted_at = s.decoder().now();
    let rh = submit(&mut s, 2, vec![3, 1, 4], 3, Priority::High);
    let mut first_token_at = f64::NAN;
    let mut guard = 0;
    while s.has_work() {
        s.tick().unwrap();
        // the mock emits one token per step, so the High's first token
        // lands exactly (out_len - 1) steps before its response
        if first_token_at.is_nan() && rh.try_recv().is_ok() {
            first_token_at = s.decoder().now() - 2.0 * dt;
        }
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
    }
    assert!(
        first_token_at - submitted_at <= thresh + 2.0 * dt + 1e-9,
        "High waited {} with threshold {thresh}",
        first_token_at - submitted_at
    );
    let echo: Vec<usize> = long.iter().rev().copied().collect();
    let (l0, l1) = (rl0.recv().unwrap(), rl1.recv().unwrap());
    assert_eq!(l0.tokens, echo, "suspended Low must continue bit-identically");
    assert_eq!(l1.tokens, echo);
    assert_eq!([&l0, &l1].iter().filter(|r| r.preempted_wait > 0.0).count(), 1);
    let stats = s.into_stats();
    assert_eq!(stats.preemptions, 1);
    assert!(stats.preempted_wait.p99 > 0.0);
}

// ------------------------------------------------------ pin-ledger property

/// Experts protected by `pin_set` survive arbitrary storms of
/// `prefill_union` refreshes and reserve/`commit` arrivals; after
/// `release` a capacity-sized refresh may evict them again.
#[test]
fn prop_pin_set_survives_prefill_union_and_commit_storms() {
    check_no_shrink(
        120,
        |r| {
            let capacity = r.range(2, 7);
            let pinned_n = r.range(1, capacity + 1);
            let seed = r.next_u64();
            let ops = r.range(20, 120);
            (capacity, pinned_n, seed, ops)
        },
        |&(capacity, pinned_n, seed, ops)| {
            const E: usize = 16;
            let mut rng = Rng::new(seed);
            let mut c = LayerCache::new(E, capacity, EvictionKind::Lfu);
            let pinned = rng.sample_indices(E, pinned_n);
            c.prefill_union(&pinned);
            c.pin_set(1, &pinned);
            if !pinned.iter().all(|&e| c.contains(e)) {
                return false; // cold fill of ≤ capacity experts must land
            }
            for _ in 0..ops {
                match rng.below(3) {
                    0 => {
                        let n = rng.range(1, capacity + 2);
                        let target = rng.sample_indices(E, n);
                        c.prefill_union(&target);
                    }
                    1 => {
                        let e = rng.below(E);
                        c.reserve(e);
                        c.commit(e, &[]);
                    }
                    _ => {
                        c.token_tick();
                        c.request(rng.below(E));
                    }
                }
                if !pinned.iter().all(|&e| c.contains(e)) {
                    return false; // a bulk path evicted a pinned expert
                }
            }
            // after release, a full-capacity refresh of disjoint experts
            // evicts the formerly pinned set in policy order
            c.release(1);
            let disjoint: Vec<usize> =
                (0..E).filter(|e| !pinned.contains(e)).take(capacity).collect();
            c.prefill_union(&disjoint);
            disjoint.iter().filter(|&&e| c.contains(e)).count() == capacity
                && pinned.iter().any(|&e| !c.contains(e))
        },
    );
}

// ------------------------------------------------------- engine-level
// (artifact-gated: skips cleanly when no PJRT artifacts are built)

/// First preset with complete artifacts (config + eval set), if any.
fn any_preset() -> Option<Ctx> {
    let dir = melinoe::artifacts_dir();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        if let Ok(ctx) = Ctx::load(&dir, preset) {
            if ctx.eval_set("dolly").is_ok() {
                return Some(ctx);
            }
        }
    }
    eprintln!("SKIP: no artifacts built (run `make artifacts`)");
    None
}

/// A sequence suspended mid-decode — and one suspended mid-prefill under
/// chunked prefill — resumes to exactly the tokens of an uninterrupted
/// run, even with an unrelated sequence admitted and retired while it
/// was detached (perturbing cache residency, clock and buffer memo).
#[test]
fn engine_suspend_resume_bit_identical_mid_decode_and_mid_prefill() {
    let Some(ctx) = any_preset() else { return };
    // a tight cache so suspension genuinely perturbs residency, but a
    // residency-independent policy so routing cannot depend on it
    let cap = (ctx.cfg.n_experts / 4).max(ctx.cfg.top_k);
    let pol = PolicyConfig::base_offload(cap);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100()).with_ignore_eos(true);
    let eval = ctx.eval_set("dolly").unwrap();
    let prompt: Vec<usize> =
        eval.samples[0].prompt.iter().cycle().take(24).copied().collect();
    let other: Vec<usize> = eval.samples[1 % eval.samples.len()].prompt.clone();
    let max_output = 8;

    // uninterrupted baseline (prefill chunk 8 throughout)
    let baseline = {
        let mut sess = engine.session();
        sess.set_prefill_chunk(8);
        engine.admit(&mut sess, &prompt, max_output).unwrap();
        let mut fins = Vec::new();
        while sess.active() > 0 {
            fins.extend(engine.step(&mut sess).unwrap());
        }
        assert_eq!(fins.len(), 1);
        fins.pop().unwrap().tokens
    };

    // suspend after `steps_before` scheduler steps, run an unrelated
    // request to completion while detached, then resume and drain.
    // steps_before = 1 suspends mid-prefill (24-token prompt, chunk 8);
    // steps_before = 5 suspends mid-decode.
    for steps_before in [1usize, 5] {
        let mut sess = engine.session();
        sess.set_prefill_chunk(8);
        let id = engine.admit(&mut sess, &prompt, max_output).unwrap();
        for _ in 0..steps_before {
            let fins = engine.step(&mut sess).unwrap();
            assert!(fins.is_empty(), "must suspend before retirement");
        }
        let detached = engine.suspend(&mut sess, id).unwrap();
        assert_eq!(sess.active(), 0);
        // unrelated traffic churns the cache and clock while detached
        engine.admit(&mut sess, &other, 4).unwrap();
        while sess.active() > 0 {
            engine.step(&mut sess).unwrap();
        }
        let resumed = engine.resume(&mut sess, detached).unwrap();
        assert_eq!(resumed, id, "resume keeps the sequence handle");
        let mut fins = Vec::new();
        while sess.active() > 0 {
            fins.extend(engine.step(&mut sess).unwrap());
        }
        let fin = fins.into_iter().find(|f| f.seq == id).expect("sequence retires");
        assert_eq!(
            fin.tokens, baseline,
            "steps_before={steps_before}: suspension changed decoded tokens"
        );
    }
}
