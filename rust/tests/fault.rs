//! Fault-injection property storm (no artifacts required — the fleet
//! simulation runs on the analytic cost model with synthetic routing
//! traces).
//!
//! Three properties over randomized fault plans, at several workload
//! seeds:
//!
//! 1. **Bit-identical recovery** — every request a faulty run completes
//!    carries exactly the token count the fault-free run produced for
//!    the same request id (re-decode and migration replay the pre-drawn
//!    routing trace, so recovery never changes the output).
//! 2. **Recovery conservation** — every sequence reclaimed by a fault
//!    resolves exactly once: `injected == recovered + failed`, and the
//!    four terminal outcomes partition the workload.
//! 3. **No dispatch to Down replicas** — `run_cluster` hard-fails
//!    (`Err`, not a silent misroute) if the balancer ever selects a
//!    crashed replica, and its trace audits hard-fail on leaked pins or
//!    unbalanced recovery counters; an `Ok` return *is* the property.

use std::collections::HashMap;

use melinoe::clock::GpuSpec;
use melinoe::cluster::{balancer, run_cluster, ClusterConfig, ClusterReport};
use melinoe::coordinator::workload::Arrival;
use melinoe::coordinator::Outcome;
use melinoe::fault::{FaultSpec, RetryPolicy};

fn base(replicas: usize, requests: usize, seed: u64) -> ClusterConfig {
    // burst saturation: queues are full from t=0, so faults always find
    // work to disrupt
    ClusterConfig::synthetic(replicas, requests, 4, GpuSpec::h100(), seed)
        .with_arrival(Arrival::Burst)
        .with_trace(true)
}

fn run(cfg: &ClusterConfig) -> ClusterReport {
    let mut b = balancer::by_name("expert-affinity").unwrap();
    run_cluster(cfg, b.as_mut()).unwrap()
}

fn est(cfg: &ClusterConfig) -> f64 {
    cfg.spec
        .est_service_seconds(
            cfg.workload.prompt_tokens,
            cfg.workload.output.mean().ceil().max(1.0) as usize,
        )
        .max(1e-9)
}

#[test]
fn random_fault_plans_conserve_and_recover_bit_identically() {
    for seed in [3u64, 11, 29, 47, 83] {
        let clean_cfg = base(3, 36, seed);
        let clean = run(&clean_cfg);
        let clean_tokens: HashMap<u64, usize> = clean
            .outcomes
            .iter()
            .filter(|(_, o, _)| *o == Outcome::Completed)
            .map(|(id, _, n)| (*id, *n))
            .collect();
        let e = est(&clean_cfg);
        let horizon = clean.makespan.max(e);
        for (name, spec) in [
            ("crash-storm", FaultSpec::crash_storm(horizon / 3.0, horizon, e / 2.0)),
            ("mixed", FaultSpec::mixed(horizon / 3.0, horizon, e / 2.0)),
        ] {
            let cfg = base(3, 36, seed)
                .with_faults(spec)
                .with_retry(RetryPolicy::retries(16, e / 8.0));
            // run_cluster hard-fails on dispatch-to-Down, leaked pins,
            // double terminals, and conservation violations; unwrap in
            // `run` is the no-misroute / no-leak property
            let rep = run(&cfg);
            assert_eq!(
                rep.completed + rep.cancelled + rep.rejected + rep.failed,
                rep.n_requests,
                "{name} seed {seed}: terminal outcomes must partition the workload"
            );
            assert_eq!(
                rep.injected,
                rep.recovered + rep.failed,
                "{name} seed {seed}: recovery conservation"
            );
            for (id, o, n) in &rep.outcomes {
                match o {
                    Outcome::Completed => assert_eq!(
                        clean_tokens.get(id),
                        Some(n),
                        "{name} seed {seed}: request {id} completed with a \
                         different token count than the fault-free run"
                    ),
                    Outcome::Failed => assert_eq!(
                        *n, 0,
                        "{name} seed {seed}: failed request {id} must not \
                         contribute output tokens"
                    ),
                    _ => {}
                }
            }
        }
    }
}

#[test]
fn retry_off_fails_reclaimed_requests_but_still_conserves() {
    let clean_cfg = base(2, 24, 5);
    let clean = run(&clean_cfg);
    let e = est(&clean_cfg);
    let horizon = clean.makespan.max(e);
    // mtbf far below the makespan: several crashes are near-certain
    let cfg = base(2, 24, 5)
        .with_faults(FaultSpec::crash_storm(horizon / 6.0, horizon, e / 2.0))
        .with_retry(RetryPolicy::off());
    let rep = run(&cfg);
    assert!(rep.injected > 0, "storm injected nothing — mtbf sizing is broken");
    assert_eq!(rep.recovered, 0, "retry-off must not recover reclaimed sequences");
    assert_eq!(rep.injected, rep.failed);
    assert_eq!(rep.retries, 0);
    assert_eq!(
        rep.completed + rep.cancelled + rep.rejected + rep.failed,
        rep.n_requests
    );
}

#[test]
fn fault_machinery_is_inert_when_disabled() {
    for seed in [2u64, 19] {
        let plain = run(&base(3, 24, seed));
        // faults none + retry armed must not perturb a single bit
        let armed_cfg = base(3, 24, seed)
            .with_faults(FaultSpec::none())
            .with_retry(RetryPolicy::retries(8, 0.25));
        let armed = run(&armed_cfg);
        assert_eq!(plain.outcomes, armed.outcomes, "seed {seed}");
        assert_eq!(
            plain.makespan.to_bits(),
            armed.makespan.to_bits(),
            "seed {seed}: makespan diverged with inert fault machinery"
        );
        assert_eq!(plain.hit_rate.to_bits(), armed.hit_rate.to_bits(), "seed {seed}");
        assert_eq!((armed.injected, armed.retries, armed.migrations, armed.failed), (0, 0, 0, 0));
    }
}
