//! Integration tests over the real PJRT artifacts.
//!
//! These exercise the full L3 stack — artifact loading, the decode engine,
//! offload policies, the serving loop — against a built preset.  They look
//! for artifacts under `$MELINOE_ARTIFACTS` (falling back to ./artifacts)
//! and skip gracefully when none are built yet, so `cargo test` stays
//! green on a fresh checkout; `make test` builds artifacts first.

use melinoe::cache::EvictionKind;
use melinoe::clock::GpuSpec;
use melinoe::coordinator::{Decoder, PreemptPolicy, SchedulerMode, SeqFinish, Server, ServerConfig};
use melinoe::engine::{DecodeSession, Engine};
use melinoe::moe::load_goldens;
use melinoe::policies::{PolicyConfig, Prefetch};
use melinoe::quant::QuantMode;
use melinoe::repro::{Ctx, EngineParts};

/// First preset with complete artifacts, if any.
fn any_preset() -> Option<Ctx> {
    let dir = melinoe::artifacts_dir();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        if let Ok(ctx) = Ctx::load(&dir, preset) {
            if ctx.dir.join("eval").join("goldens.json").exists() {
                return Some(ctx);
            }
        }
    }
    eprintln!("SKIP: no artifacts built (run `make artifacts`)");
    None
}

fn full_residency(ctx: &Ctx) -> PolicyConfig {
    PolicyConfig::base_offload(ctx.cfg.n_experts)
}

#[test]
fn golden_decode_matches_python() {
    let Some(ctx) = any_preset() else { return };
    let goldens = load_goldens(&ctx.dir).unwrap();
    assert!(!goldens.is_empty());
    let mut checked = 0;
    for variant in ["base", "ft_dolly"] {
        let subset: Vec<_> = goldens.iter().filter(|g| g.variant == variant).collect();
        if subset.is_empty() {
            continue;
        }
        let pol = full_residency(&ctx).with_variant(variant);
        let parts = ctx.parts(&pol, "dolly").unwrap();
        let engine = parts.engine(&ctx, GpuSpec::h100());
        for g in subset.iter().take(4) {
            let out = engine.decode(&g.prompt, g.expected.len().max(1)).unwrap();
            assert_eq!(
                out.tokens, g.expected,
                "rust decode diverged from python golden ({variant}, {:?})",
                g.dataset
            );
            checked += 1;
        }
    }
    assert!(checked >= 2, "goldens present but none checked");
}

#[test]
fn all_resident_means_no_transfers() {
    let Some(ctx) = any_preset() else { return };
    let pol = full_residency(&ctx);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100());
    let eval = ctx.eval_set("dolly").unwrap();
    let out = engine.decode(&eval.samples[0].prompt, 8).unwrap();
    // cold-start fills only: at most E inserts per layer, zero evictions
    assert_eq!(out.report.cache.evictions, 0);
    assert!(out.report.transfers.h2d_count <= (ctx.cfg.n_experts * ctx.cfg.n_layers) as u64);
    // steady state: repeated decodes of the same prompt would all hit; we
    // at least require a healthy hit rate after warmup.
    assert!(out.report.cache.hit_rate() > 0.0);
}

#[test]
fn tight_cache_transfers_more_than_loose() {
    let Some(ctx) = any_preset() else { return };
    let eval = ctx.eval_set("dolly").unwrap();
    let mut misses = Vec::new();
    for cap in [ctx.cfg.top_k, ctx.cfg.n_experts] {
        let pol = PolicyConfig::base_offload(cap);
        let parts = ctx.parts(&pol, "dolly").unwrap();
        let engine = parts.engine(&ctx, GpuSpec::h100());
        let out = engine.decode(&eval.samples[0].prompt, 12).unwrap();
        misses.push(out.report.transfers.h2d_count);
    }
    assert!(
        misses[0] >= misses[1],
        "tiny cache should transfer at least as much: {misses:?}"
    );
}

#[test]
fn quantized_residency_preserves_decoding_roughly() {
    let Some(ctx) = any_preset() else { return };
    let eval = ctx.eval_set("dolly").unwrap();
    let mut outs = Vec::new();
    for q in [QuantMode::Fp16, QuantMode::Int4] {
        let pol = full_residency(&ctx).with_quant(q);
        let parts = ctx.parts(&pol, "dolly").unwrap();
        let engine = parts.engine(&ctx, GpuSpec::h100());
        outs.push(engine.decode(&eval.samples[1].prompt, 12).unwrap().tokens);
    }
    // int4 may flip some tokens but must produce a comparable-length,
    // non-degenerate continuation
    assert!(!outs[1].is_empty());
    let agree = outs[0].iter().zip(&outs[1]).filter(|(a, b)| a == b).count();
    assert!(
        agree * 2 >= outs[0].len().min(outs[1].len()),
        "int4 decode diverged wholesale: {outs:?}"
    );
}

#[test]
fn predictor_prefetch_reduces_demand_stall() {
    let Some(ctx) = any_preset() else { return };
    let cap = ctx.cfg.cache_capacity;
    let eval = ctx.eval_set("dolly").unwrap();
    let variant = if ctx.cfg.variants.iter().any(|v| v == "ft_dolly") { "ft_dolly" } else { "base" };
    let np = PolicyConfig::melinoe_no_prefetch(variant, cap);
    let wp = PolicyConfig::melinoe(variant, cap);
    let run = |pol: PolicyConfig| {
        let parts = ctx.parts(&pol, "dolly").unwrap();
        let engine = parts.engine(&ctx, GpuSpec::h100());
        let out = engine.decode(&eval.samples[0].prompt, 16).unwrap();
        (out.report.transfers.stall_time, out.metrics.sim_seconds)
    };
    let (stall_np, _) = run(np);
    let (stall_wp, _) = run(wp);
    assert!(
        stall_wp <= stall_np * 1.05 + 1e-6,
        "prefetch should not increase demand stalls: {stall_wp} vs {stall_np}"
    );
}

#[test]
fn fiddler_executes_on_cpu_for_big_experts() {
    let Some(ctx) = any_preset() else { return };
    // Fiddler's CPU path wins when experts are large (Mixtral dims) —
    // force the decision by using the mixtral cost dims via the policy.
    let pol = PolicyConfig::fiddler(ctx.cfg.top_k);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::rtx4090());
    let eval = ctx.eval_set("dolly").unwrap();
    let out = engine.decode(&eval.samples[0].prompt, 12).unwrap();
    // on coarse-expert models the CPU path should fire at least once;
    // on fine-grained models transfers may win — accept either but
    // require the decode to have resolved every miss one way or another.
    assert_eq!(out.report.cache.requests(), out.report.cache.hits + out.report.cache.misses);
    assert!(out.cpu_execs + out.report.transfers.h2d_count >= out.report.cache.misses);
}

#[test]
fn floe_skips_weak_nonresident_experts() {
    let Some(ctx) = any_preset() else { return };
    let pol = PolicyConfig::floe(ctx.cfg.cache_capacity);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100());
    let eval = ctx.eval_set("dolly").unwrap();
    let mut skips = 0;
    for s in eval.samples.iter().take(3) {
        skips += engine.decode(&s.prompt, 12).unwrap().sparsity_skips;
    }
    // K=8 fine-grained routing has plenty of small gates; K=2 coarse
    // models may legitimately skip rarely.
    if ctx.cfg.top_k >= 4 {
        assert!(skips > 0, "floe never skipped on a fine-grained model");
    }
}

#[test]
fn teacher_forced_nll_finite_and_positive() {
    let Some(ctx) = any_preset() else { return };
    let pol = full_residency(&ctx);
    let parts = ctx.parts(&pol, "gsm").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100());
    let eval = ctx.eval_set("gsm").unwrap();
    let mut toks = eval.samples[0].prompt.clone();
    toks.extend_from_slice(&eval.samples[0].reference);
    let nlls = engine.teacher_forced_nll(&toks).unwrap();
    assert_eq!(nlls.len(), toks.len() - 1);
    assert!(nlls.iter().all(|v| v.is_finite() && *v >= 0.0));
}

#[test]
fn batch_lockstep_matches_single_decode_tokens() {
    let Some(ctx) = any_preset() else { return };
    let pol = full_residency(&ctx);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100());
    let eval = ctx.eval_set("dolly").unwrap();
    let p = eval.samples[0].prompt.clone();
    let single = engine.decode(&p, 10).unwrap().tokens;
    let (batch_outs, _) = engine.decode_batch(&[p.clone()], 10).unwrap();
    assert_eq!(batch_outs[0], single);
}

#[test]
fn batched_decode_shares_cache_across_sequences() {
    let Some(ctx) = any_preset() else { return };
    let pol = PolicyConfig::base_offload(ctx.cfg.cache_capacity);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100());
    let eval = ctx.eval_set("dolly").unwrap();
    let prompts: Vec<Vec<usize>> = eval.samples.iter().take(2).map(|s| s.prompt.clone()).collect();
    let (_, rep_batch) = engine.decode_batch(&prompts, 8).unwrap();
    let mut solo = 0u64;
    for p in &prompts {
        solo += engine.decode(p, 8).unwrap().report.transfers.h2d_count;
    }
    // Interleaving divergent sequences through one cache can either share
    // (fewer transfers) or thrash (more) — the engine must stay within a
    // small constant factor of the two cold solo runs either way, and the
    // accounting must balance.
    assert!(
        rep_batch.transfers.h2d_count <= solo * 2 + (ctx.cfg.n_layers * ctx.cfg.top_k) as u64,
        "batch {} vs solo {}",
        rep_batch.transfers.h2d_count,
        solo
    );
    assert!(rep_batch.cache.misses >= rep_batch.transfers.h2d_count); // every H2D came from a miss
}

/// Step-granular session: a batch member that exhausts its budget (or
/// hits EOS) retires immediately — it stops contributing compute and
/// cache requests — and its slot accepts a mid-flight admission.
#[test]
fn session_retires_early_and_admits_mid_flight() {
    let Some(ctx) = any_preset() else { return };
    let pol = full_residency(&ctx);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100()).with_ignore_eos(true);
    let eval = ctx.eval_set("dolly").unwrap();
    let p = eval.samples[0].prompt.clone();

    let mut sess = engine.session();
    let short = engine.admit(&mut sess, &p, 2).unwrap();
    let long = engine.admit(&mut sess, &p, 8).unwrap();
    assert_eq!(sess.active(), 2);

    // run until the short sequence retires
    let mut fins = Vec::new();
    while fins.is_empty() {
        fins = engine.step(&mut sess).unwrap();
    }
    assert_eq!(fins.len(), 1);
    assert_eq!(fins[0].seq, short);
    assert_eq!(fins[0].tokens.len(), 2);
    assert_eq!(sess.active(), 1, "the retired member's slot frees immediately");
    let requests_at_retire = sess.cache.total_stats().requests();

    // mid-flight admission into the freed slot
    let third = engine.admit(&mut sess, &p, 2).unwrap();
    assert_eq!(sess.active(), 2);
    let mut finished = Vec::new();
    while sess.active() > 0 {
        finished.extend(engine.step(&mut sess).unwrap());
    }
    assert!(finished.iter().any(|f| f.seq == third));
    assert!(finished.iter().any(|f| f.seq == long));
    // both survivors kept decoding after the retirement, so cache
    // traffic grew — but only from live sequences
    assert!(sess.cache.total_stats().requests() > requests_at_retire);
    // the mid-flight admission overlaps the long sequence's window
    let f3 = finished.iter().find(|f| f.seq == third).unwrap();
    let fl = finished.iter().find(|f| f.seq == long).unwrap();
    assert!(f3.sim_admitted > fl.sim_admitted);
    assert!(f3.sim_admitted < fl.sim_finished);
    assert!(f3.sim_first_token >= f3.sim_admitted);
}

/// ROADMAP "session-persistent device buffers": the stacked-buffer memo
/// lives on the `DecodeSession`, so serving wrappers that rebuild their
/// borrowing `Engine` view every step keep the routed-set fast path warm
/// across steps.  Two identical sequences route identically, so the
/// second one's dispatches must hit the memo the first populated — even
/// though a fresh engine view drives every step.
#[test]
fn buf_cache_memo_persists_across_engine_rebuilds() {
    let Some(ctx) = any_preset() else { return };
    if std::env::var("MELINOE_NO_BUFCACHE").is_ok() {
        eprintln!("SKIP: buffer cache disabled via MELINOE_NO_BUFCACHE");
        return;
    }
    let pol = full_residency(&ctx);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let eval = ctx.eval_set("dolly").unwrap();
    let p = eval.samples[0].prompt.clone();
    let mut sess = parts.engine(&ctx, GpuSpec::h100()).session();
    {
        let engine = parts.engine(&ctx, GpuSpec::h100()).with_ignore_eos(true);
        engine.admit(&mut sess, &p, 4).unwrap();
        engine.admit(&mut sess, &p, 4).unwrap();
    }
    while sess.active() > 0 {
        // rebuild the borrowing engine view every step — the serving
        // wrapper pattern the memo must survive
        let engine = parts.engine(&ctx, GpuSpec::h100()).with_ignore_eos(true);
        engine.step(&mut sess).unwrap();
    }
    assert!(sess.buf_cache_entries() > 0, "no routed set was memoized");
    assert!(
        sess.buf_cache_hits() > 0,
        "identical routed sets never hit the session memo across rebuilt engine views"
    );
}

/// Chunked prefill through the public serving wrapper: the session's
/// chunk setting shortens the simulated prefill timeline while leaving
/// the decoded tokens untouched (the full bit-identity sweep lives in
/// rust/tests/prefill.rs).
#[test]
fn session_prefill_chunk_roundtrip() {
    let Some(ctx) = any_preset() else { return };
    let pol = full_residency(&ctx);
    let parts = ctx.parts(&pol, "dolly").unwrap();
    let engine = parts.engine(&ctx, GpuSpec::h100()).with_ignore_eos(true);
    let mut sess = engine.session();
    assert_eq!(sess.prefill_chunk(), 1);
    sess.set_prefill_chunk(0); // clamps
    assert_eq!(sess.prefill_chunk(), 1);
    sess.set_prefill_chunk(16);
    assert_eq!(sess.prefill_chunk(), 16);
}

#[test]
fn gamma_eviction_interpolates() {
    let Some(ctx) = any_preset() else { return };
    let eval = ctx.eval_set("dolly").unwrap();
    let mut tx = Vec::new();
    for kind in [EvictionKind::Lru, EvictionKind::Gamma(0.9), EvictionKind::Lfu] {
        let pol = PolicyConfig::base_offload(ctx.cfg.cache_capacity).with_eviction(kind);
        let parts = ctx.parts(&pol, "dolly").unwrap();
        let engine = parts.engine(&ctx, GpuSpec::h100());
        let out = engine.decode(&eval.samples[0].prompt, 16).unwrap();
        tx.push(out.report.transfers.h2d_count);
    }
    // all three are valid cache policies; none should be wildly degenerate
    let max = *tx.iter().max().unwrap() as f64;
    let min = *tx.iter().min().unwrap() as f64;
    assert!(max <= min * 3.0 + 16.0, "eviction policies diverged absurdly: {tx:?}");
}

#[test]
fn serving_loop_end_to_end() {
    let Some(ctx) = any_preset() else { return };
    let preset = ctx.preset.clone();
    drop(ctx);

    struct Owned {
        ctx: Ctx,
        parts: EngineParts,
        sess: DecodeSession,
    }
    impl Decoder for Owned {
        fn admit(&mut self, prompt: &[usize], max_output: usize) -> anyhow::Result<u64> {
            let engine: Engine = self.parts.engine(&self.ctx, GpuSpec::h100());
            engine.admit(&mut self.sess, prompt, max_output)
        }
        fn step(&mut self) -> anyhow::Result<Vec<SeqFinish>> {
            let engine: Engine = self.parts.engine(&self.ctx, GpuSpec::h100());
            engine.step(&mut self.sess)
        }
        fn active(&self) -> usize {
            self.sess.active()
        }
        fn now(&self) -> f64 {
            self.sess.now()
        }
    }

    let server = Server::start(
        move || {
            let ctx = Ctx::load(&melinoe::artifacts_dir(), &preset)?;
            let pol = PolicyConfig::base_offload(ctx.cfg.cache_capacity);
            let parts = ctx.parts(&pol, "dolly")?;
            let sess = parts.engine(&ctx, GpuSpec::h100()).session();
            Ok(Owned { ctx, parts, sess })
        },
        ServerConfig {
            max_batch: 2,
            batch_wait: std::time::Duration::from_millis(5),
            max_output: 8,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
            preempt: PreemptPolicy::Off,
        },
    );
    // submit prompts loaded fresh (server thread owns its own ctx)
    let ctx2 = any_preset().unwrap();
    let eval = ctx2.eval_set("dolly").unwrap();
    let rxs: Vec<_> =
        eval.samples.iter().take(4).map(|s| server.submit(s.prompt.clone(), 8)).collect();
    for rx in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert!(!r.tokens.is_empty());
        assert!(r.sim_latency > 0.0);
        assert!(r.sim_ttft > 0.0 && r.sim_ttft <= r.sim_latency);
        assert!(r.batch_size >= 1 && r.batch_size <= 2);
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests, 4);
    assert!(stats.steps > 0);
    assert!(stats.ttft.p50 > 0.0);
}

#[test]
fn prefetch_plans_differ_between_prompts() {
    let Some(ctx) = any_preset() else { return };
    // requires a trained predictor for the base variant
    let pol = PolicyConfig::base_offload(ctx.cfg.cache_capacity)
        .with_prefetch(Prefetch::Predictor);
    let Ok(parts) = ctx.parts(&pol, "dolly") else {
        eprintln!("SKIP: no base predictor artifact");
        return;
    };
    let eval = ctx.eval_set("dolly").unwrap();
    let pw = parts.predictor.as_ref().unwrap();
    let a = melinoe::predictor::predict_plan(
        &ctx.rt, pw, &ctx.cfg, &parts.store.embed, &eval.samples[0].prompt, ctx.cfg.cache_capacity,
    )
    .unwrap();
    // plans are valid expert ids with the right cardinality
    for set in &a.per_layer {
        assert_eq!(set.len(), ctx.cfg.cache_capacity.min(ctx.cfg.n_experts));
        assert!(set.iter().all(|&e| e < ctx.cfg.n_experts));
    }
}
