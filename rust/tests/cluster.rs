//! Cluster-layer integration tests.
//!
//! Unlike rust/tests/integration.rs these need no PJRT artifacts: the
//! fleet simulation runs on the analytic cost model with synthetic
//! per-task routing traces, so they assert the PR's acceptance behaviour
//! unconditionally — expert-affinity dispatch strictly beats round-robin
//! on fleet cache hit-rate and simulated throughput for heterogeneous
//! traffic, at every fleet size.

use melinoe::clock::GpuSpec;
use melinoe::cluster::{balancer, compare, run_cluster, ClusterConfig, BALANCERS};
use melinoe::coordinator::workload::Arrival;

fn cfg(replicas: usize, requests: usize, seed: u64) -> ClusterConfig {
    ClusterConfig::synthetic(replicas, requests, 4, GpuSpec::h100(), seed)
}

#[test]
fn affinity_strictly_beats_round_robin_across_fleet_sizes() {
    for replicas in [2usize, 4, 8] {
        // burst => saturated => makespan measures serving efficiency
        let cfg = cfg(replicas, 48, 42).with_arrival(Arrival::Burst);
        let reports = compare(&cfg, BALANCERS).unwrap();
        let rr = &reports[0];
        let affinity = &reports[2];
        assert_eq!(rr.n_requests, 48);
        assert_eq!(affinity.n_requests, 48);
        assert!(
            affinity.hit_rate > rr.hit_rate,
            "replicas={replicas}: affinity hit rate {:.4} <= round-robin {:.4}",
            affinity.hit_rate,
            rr.hit_rate
        );
        assert!(
            affinity.tokens_per_sec > rr.tokens_per_sec,
            "replicas={replicas}: affinity tok/s {:.2} <= round-robin {:.2}",
            affinity.tokens_per_sec,
            rr.tokens_per_sec
        );
        assert!(
            affinity.pcie_gb < rr.pcie_gb,
            "replicas={replicas}: affinity moved more PCIe bytes than round-robin"
        );
    }
}

#[test]
fn open_loop_poisson_serves_everything_with_finite_latency() {
    let cfg = cfg(4, 64, 7);
    for name in BALANCERS {
        let mut b = balancer::by_name(name).unwrap();
        let rep = run_cluster(&cfg, b.as_mut()).unwrap();
        assert_eq!(rep.n_requests, 64, "{name}");
        assert!(rep.makespan.is_finite() && rep.makespan > 0.0);
        assert!(rep.latency.p99.is_finite() && rep.latency.p99 > 0.0);
        assert!(rep.queue_wait.p50 <= rep.queue_wait.p99);
        // conservation: every replica's requests sum to the workload
        let total: usize = rep.replicas.iter().map(|r| r.requests).sum();
        assert_eq!(total, 64, "{name}");
    }
}

#[test]
fn affinity_latency_tail_not_worse_under_saturation() {
    // under burst saturation the queue dominates latency; affinity's
    // faster service must not inflate the tail far above round-robin's.
    // The margin allows for affinity's deliberately deeper per-task
    // queues (load_penalty trades queue depth for cache overlap).
    let cfg = cfg(4, 48, 21).with_arrival(Arrival::Burst);
    let reports = compare(&cfg, BALANCERS).unwrap();
    let (rr, affinity) = (&reports[0], &reports[2]);
    assert!(
        affinity.latency.p99 <= rr.latency.p99 * 1.25,
        "affinity p99 {:.2}s vs round-robin p99 {:.2}s",
        affinity.latency.p99,
        rr.latency.p99
    );
}

#[test]
fn deterministic_given_seed() {
    let cfg = cfg(3, 32, 9).with_arrival(Arrival::Burst);
    let mut b1 = balancer::by_name("expert-affinity").unwrap();
    let mut b2 = balancer::by_name("expert-affinity").unwrap();
    let r1 = run_cluster(&cfg, b1.as_mut()).unwrap();
    let r2 = run_cluster(&cfg, b2.as_mut()).unwrap();
    assert_eq!(r1.output_tokens, r2.output_tokens);
    assert!((r1.makespan - r2.makespan).abs() < 1e-12);
    assert!((r1.hit_rate - r2.hit_rate).abs() < 1e-12);
}
