//! Offline vendored stand-in for the `anyhow` crate.
//!
//! The offline registry does not carry crates.io, so this reimplements the
//! small API surface the melinoe crate uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait.  Semantics match upstream where it matters:
//!
//! * `Error` is a cheap opaque error carrying a message and an optional
//!   boxed source; like upstream it deliberately does **not** implement
//!   `std::error::Error` so that the blanket `From<E: std::error::Error>`
//!   conversion (what makes `?` ergonomic) stays coherent.
//! * `.context(..)` prepends a message, preserving the cause chain.

use std::fmt;

/// Opaque error: message plus optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Error wrapping a concrete `std::error::Error` value.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend context, keeping the original as the cause.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root-cause chain, outermost first (Display strings).
    pub fn chain(&self) -> Vec<String> {
        let mut out = vec![self.msg.clone()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = match &self.source {
            Some(boxed) => Some(&**boxed),
            None => None,
        };
        while let Some(e) = cur {
            out.push(e.to_string());
            cur = e.source();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — alias with the opaque error as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("x = {x} and {}", 8);
        assert_eq!(b.to_string(), "x = 7 and 8");
        let c = anyhow!(io_err());
        assert_eq!(c.to_string(), "gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "gone");
        assert_eq!(e.chain().len(), 2);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading weights").unwrap_err();
        assert_eq!(e.to_string(), "loading weights: gone");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "too big: {n}");
            if n == 0 {
                bail!("zero");
            }
            Ok(n)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
    }
}
