//! Offline stub of the `xla` crate (xla_extension PJRT bindings).
//!
//! The real bindings need the native xla_extension shared library, which
//! the offline image does not carry.  This stub keeps the melinoe crate
//! compiling and its artifact-independent paths fully functional:
//!
//! * [`Literal`] is a real host-side tensor: construction, reshape,
//!   element access, dtype conversion, and `.npz` loading (numpy
//!   `np.savez`, stored/uncompressed zip entries) all work.
//! * PJRT types ([`PjRtClient`], [`PjRtLoadedExecutable`], [`PjRtBuffer`])
//!   exist and type-check, but `compile`/`execute` return a descriptive
//!   error.  Every artifact-dependent test/harness in melinoe already
//!   treats a load/compile failure as "artifacts unavailable → skip", so
//!   the stub degrades cleanly instead of poisoning the build.

use std::fmt;
use std::path::Path;

/// Stub error type (the real crate wraps XLA status codes).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

const OFFLINE: &str =
    "PJRT unavailable: offline xla stub (install the real xla_extension bindings to execute HLO)";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    S32,
    F32,
    F64,
}

/// Array shape: dims + element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// Element storage.  Kept public-but-hidden so the [`NativeType`] trait can
/// name it; user code goes through the typed [`Literal`] API.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Repr {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host element types the stub understands (f32 / f64 / i32).
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    #[doc(hidden)]
    fn into_repr(v: Vec<Self>) -> Repr;
    #[doc(hidden)]
    fn from_repr(r: &Repr) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }
    fn into_repr(v: Vec<f32>) -> Repr {
        Repr::F32(v)
    }
    fn from_repr(r: &Repr) -> Option<Vec<f32>> {
        match r {
            Repr::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f64 {
    fn element_type() -> ElementType {
        ElementType::F64
    }
    fn into_repr(v: Vec<f64>) -> Repr {
        Repr::F64(v)
    }
    fn from_repr(r: &Repr) -> Option<Vec<f64>> {
        match r {
            Repr::F64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }
    fn into_repr(v: Vec<i32>) -> Repr {
        Repr::I32(v)
    }
    fn from_repr(r: &Repr) -> Option<Vec<i32>> {
        match r {
            Repr::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host tensor literal (functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    repr: Repr,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { repr: T::into_repr(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { repr: T::into_repr(vec![v]), dims: Vec::new() }
    }

    pub fn element_count(&self) -> usize {
        match &self.repr {
            Repr::F32(v) => v.len(),
            Repr::F64(v) => v.len(),
            Repr::I32(v) => v.len(),
            Repr::Tuple(_) => 0,
        }
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.repr, Repr::Tuple(_)) {
            return err("reshape of a tuple literal");
        }
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.element_count() {
            return err(format!(
                "reshape {:?} -> {:?}: element count mismatch ({} elements)",
                self.dims,
                dims,
                self.element_count()
            ));
        }
        Ok(Literal { repr: self.repr.clone(), dims: dims.to_vec() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(match &self.repr {
            Repr::F32(_) => ElementType::F32,
            Repr::F64(_) => ElementType::F64,
            Repr::I32(_) => ElementType::S32,
            Repr::Tuple(_) => return err("tuple literal has no element type"),
        })
    }

    /// Convert to another element type (numeric casts only).
    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        let repr = match (&self.repr, ty) {
            (Repr::F32(v), PrimitiveType::F32) => Repr::F32(v.clone()),
            (Repr::F64(v), PrimitiveType::F32) => Repr::F32(v.iter().map(|&x| x as f32).collect()),
            (Repr::I32(v), PrimitiveType::F32) => Repr::F32(v.iter().map(|&x| x as f32).collect()),
            (Repr::F32(v), PrimitiveType::F64) => Repr::F64(v.iter().map(|&x| x as f64).collect()),
            (Repr::F64(v), PrimitiveType::F64) => Repr::F64(v.clone()),
            (Repr::I32(v), PrimitiveType::F64) => Repr::F64(v.iter().map(|&x| x as f64).collect()),
            (Repr::F32(v), PrimitiveType::S32) => Repr::I32(v.iter().map(|&x| x as i32).collect()),
            (Repr::F64(v), PrimitiveType::S32) => Repr::I32(v.iter().map(|&x| x as i32).collect()),
            (Repr::I32(v), PrimitiveType::S32) => Repr::I32(v.clone()),
            (Repr::Tuple(_), _) => return err("convert of a tuple literal"),
        };
        Ok(Literal { repr, dims: self.dims.clone() })
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_repr(&self.repr)
            .ok_or_else(|| Error(format!("to_vec: literal is {:?}-typed", self.ty())))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty()? })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(v) => Ok(v),
            _ => err("to_tuple on a non-tuple literal"),
        }
    }

    /// Unwrap a single-element tuple.
    pub fn to_tuple1(self) -> Result<Literal> {
        let mut v = self.to_tuple()?;
        if v.len() != 1 {
            return err(format!("to_tuple1 on a {}-element tuple", v.len()));
        }
        Ok(v.pop().unwrap())
    }

    /// Build a tuple literal (used by tests; real executables return these).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { repr: Repr::Tuple(elems), dims: Vec::new() }
    }
}

/// Loading host literals from raw byte containers (the real crate's trait;
/// here only the `.npz` path the melinoe loader uses).
pub trait FromRawBytes: Sized {
    type Context;
    fn read_npz<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npz<P: AsRef<Path>>(path: P, _ctx: &()) -> Result<Vec<(String, Literal)>> {
        npz::read(path.as_ref())
    }
}

mod npz {
    //! Minimal `.npz` reader: a zip archive of `.npy` members written by
    //! `np.savez` (ZIP_STORED — `np.savez_compressed` is rejected since no
    //! deflate implementation exists offline).

    use super::{err, Error, Literal, Repr, Result};
    use std::path::Path;

    fn u16le(b: &[u8], off: usize) -> u32 {
        b[off] as u32 | (b[off + 1] as u32) << 8
    }

    fn u32le(b: &[u8], off: usize) -> u32 {
        b[off] as u32 | (b[off + 1] as u32) << 8 | (b[off + 2] as u32) << 16
            | (b[off + 3] as u32) << 24
    }

    pub fn read(path: &Path) -> Result<Vec<(String, Literal)>> {
        let bytes =
            std::fs::read(path).map_err(|e| Error(format!("read {}: {e}", path.display())))?;
        let mut out = Vec::new();
        let mut i = 0usize;
        while i + 30 <= bytes.len() {
            let sig = u32le(&bytes, i);
            if sig != 0x0403_4b50 {
                break; // central directory or end-of-archive record
            }
            let method = u16le(&bytes, i + 8);
            let flags = u16le(&bytes, i + 6);
            let csize = u32le(&bytes, i + 18) as usize;
            let usize_ = u32le(&bytes, i + 22) as usize;
            let nlen = u16le(&bytes, i + 26) as usize;
            let elen = u16le(&bytes, i + 28) as usize;
            if i + 30 + nlen + elen + csize > bytes.len() {
                return err("truncated zip entry");
            }
            let name = String::from_utf8_lossy(&bytes[i + 30..i + 30 + nlen]).into_owned();
            let data = &bytes[i + 30 + nlen + elen..i + 30 + nlen + elen + csize];
            if flags & 0x08 != 0 || csize == 0xffff_ffff {
                return err("npz uses streaming/zip64 entries (unsupported by the offline stub)");
            }
            if method != 0 {
                return err(format!(
                    "npz member {name:?} is compressed (method {method}); \
                     write artifacts with np.savez, not np.savez_compressed"
                ));
            }
            if csize != usize_ {
                return err(format!("stored zip entry {name:?} with csize != usize"));
            }
            let key = name.strip_suffix(".npy").unwrap_or(&name).to_string();
            out.push((key, parse_npy(data, &name)?));
            i += 30 + nlen + elen + csize;
        }
        if out.is_empty() {
            return err(format!("{}: no npy members found", path.display()));
        }
        Ok(out)
    }

    fn parse_npy(b: &[u8], name: &str) -> Result<Literal> {
        if b.len() < 12 || &b[0..6] != b"\x93NUMPY" {
            return err(format!("{name}: not an npy file"));
        }
        let major = b[6];
        let (hlen, data_off) = if major == 1 {
            (u16le(b, 8) as usize, 10)
        } else {
            (u32le(b, 8) as usize, 12)
        };
        if data_off + hlen > b.len() {
            return err(format!("{name}: truncated npy header"));
        }
        let header = String::from_utf8_lossy(&b[data_off..data_off + hlen]).into_owned();
        let descr = dict_str(&header, "descr").ok_or_else(|| Error(format!("{name}: no descr")))?;
        if header.contains("'fortran_order': True") {
            return err(format!("{name}: fortran-order arrays unsupported"));
        }
        let shape = dict_shape(&header).ok_or_else(|| Error(format!("{name}: no shape")))?;
        let count: usize = shape.iter().product::<usize>().max(1);
        let n_elems = if shape.is_empty() { 1 } else { count };
        let data = &b[data_off + hlen..];
        let repr = match descr.as_str() {
            "<f4" | "|f4" => {
                need(data, n_elems * 4, name)?;
                Repr::F32(
                    data.chunks_exact(4)
                        .take(n_elems)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            "<f8" => {
                need(data, n_elems * 8, name)?;
                Repr::F64(
                    data.chunks_exact(8)
                        .take(n_elems)
                        .map(|c| {
                            f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                        })
                        .collect(),
                )
            }
            "<i4" => {
                need(data, n_elems * 4, name)?;
                Repr::I32(
                    data.chunks_exact(4)
                        .take(n_elems)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            "<i8" => {
                need(data, n_elems * 8, name)?;
                Repr::I32(
                    data.chunks_exact(8)
                        .take(n_elems)
                        .map(|c| {
                            i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                                as i32
                        })
                        .collect(),
                )
            }
            other => return err(format!("{name}: unsupported dtype {other:?}")),
        };
        Ok(Literal { repr, dims: shape.iter().map(|&d| d as i64).collect() })
    }

    fn need(data: &[u8], bytes: usize, name: &str) -> Result<()> {
        if data.len() < bytes {
            return err(format!("{name}: npy payload shorter than its shape"));
        }
        Ok(())
    }

    /// Extract a quoted string value from the npy header dict.
    fn dict_str(header: &str, key: &str) -> Option<String> {
        let pat = format!("'{key}':");
        let rest = &header[header.find(&pat)? + pat.len()..];
        let open = rest.find('\'')?;
        let rest = &rest[open + 1..];
        let close = rest.find('\'')?;
        Some(rest[..close].to_string())
    }

    /// Extract the shape tuple from the npy header dict.
    fn dict_shape(header: &str) -> Option<Vec<usize>> {
        let rest = &header[header.find("'shape':")? + 8..];
        let open = rest.find('(')?;
        let close = rest.find(')')?;
        let inner = &rest[open + 1..close];
        let mut dims = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            dims.push(part.parse::<usize>().ok()?);
        }
        Some(dims)
    }
}

/// Parsed HLO module (opaque in the stub: presence-checked only).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read HLO text from a file.  Fails if the file is missing; actual
    /// parsing/validation happens at (stubbed) compile time upstream.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("read {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation handle (opaque).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client.  `cpu()` succeeds so loaders can report the more useful
/// per-executable compile error instead of failing at client creation.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(OFFLINE)
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer { lit: Literal::vec1(data).reshape(&dims)? })
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }
}

/// Loaded executable.  Unconstructible through the stub (compile errors),
/// but the methods exist so call sites type-check.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(OFFLINE)
    }

    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(OFFLINE)
    }
}

/// Device buffer (host-backed in the stub).
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.array_shape().unwrap().dims(), &[4]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(m.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_convert() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.ty().unwrap(), ElementType::S32);
        let f = s.convert(PrimitiveType::F32).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![7.0]);
    }

    #[test]
    fn tuples() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2.0f32)]);
        let parts = t.clone().to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.to_tuple1().is_err());
        let one = Literal::tuple(vec![Literal::scalar(3.0f32)]);
        assert_eq!(one.to_tuple1().unwrap().to_vec::<f32>().unwrap(), vec![3.0]);
    }

    #[test]
    fn pjrt_paths_fail_gracefully() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation).is_err());
        let buf = client.buffer_from_host_buffer(&[1.0f32, 2.0], &[2], None).unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
    }

    /// Build a tiny stored-zip npz in memory, write it, read it back.
    #[test]
    fn npz_reader_stored_entries() {
        fn npy_f32(shape: &[usize], data: &[f32]) -> Vec<u8> {
            let shape_s = match shape.len() {
                0 => "()".to_string(),
                1 => format!("({},)", shape[0]),
                _ => format!(
                    "({})",
                    shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
                ),
            };
            let mut header = format!(
                "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_s}, }}"
            );
            while (10 + header.len() + 1) % 64 != 0 {
                header.push(' ');
            }
            header.push('\n');
            let mut out = Vec::new();
            out.extend_from_slice(b"\x93NUMPY\x01\x00");
            out.extend_from_slice(&(header.len() as u16).to_le_bytes());
            out.extend_from_slice(header.as_bytes());
            for &x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
            out
        }
        fn zip_entry(name: &str, payload: &[u8]) -> Vec<u8> {
            let mut e = Vec::new();
            e.extend_from_slice(&0x0403_4b50u32.to_le_bytes());
            e.extend_from_slice(&[20, 0]); // version needed
            e.extend_from_slice(&[0, 0]); // flags
            e.extend_from_slice(&[0, 0]); // method: stored
            e.extend_from_slice(&[0, 0, 0, 0]); // mtime/mdate
            e.extend_from_slice(&[0, 0, 0, 0]); // crc (unchecked)
            e.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            e.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            e.extend_from_slice(&(name.len() as u16).to_le_bytes());
            e.extend_from_slice(&[0, 0]); // extra len
            e.extend_from_slice(name.as_bytes());
            e.extend_from_slice(payload);
            e
        }
        let mut file = Vec::new();
        file.extend_from_slice(&zip_entry("a.npy", &npy_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0])));
        file.extend_from_slice(&zip_entry("b.npy", &npy_f32(&[3], &[9.0, 8.0, 7.0])));
        // end-of-central-directory signature terminates the scan
        file.extend_from_slice(&0x0605_4b50u32.to_le_bytes());
        let path = std::env::temp_dir().join("melinoe_stub_test.npz");
        std::fs::write(&path, &file).unwrap();
        let entries = Literal::read_npz(&path, &()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(entries.len(), 2);
        let (name, lit) = &entries[0];
        assert_eq!(name, "a");
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(entries[1].1.to_vec::<f32>().unwrap(), vec![9.0, 8.0, 7.0]);
    }
}
