//! Group-wise symmetric integer quantization (HQQ-INT4 stand-in).
//!
//! The paper keeps GPU-resident experts in HQQ INT4 so that more experts
//! fit a fixed VRAM budget (§3.2, Table 12), and Mixtral-Offloading
//! quantizes experts to 3 bits (§4.2 / Appendix A).  HQQ itself is
//! proprietary-ish tooling; we implement plain symmetric group-wise
//! quantization with the same *systems* effect — byte footprint shrinks by
//! bits/16 (+ per-group scale overhead) — and a *real* numeric effect: the
//! engine dequantizes the stored blob before executing the expert, so
//! quality degradation is measured, not assumed.

use anyhow::{bail, Result};

pub const GROUP: usize = 32;

/// Quantization mode for expert residency & transfers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantMode {
    /// fp16 residency (bytes = 2/elem at paper scale; f32 numerics here).
    Fp16,
    /// 4-bit group quantization (MELINOE / FLoE residency).
    Int4,
    /// 3-bit group quantization (Mixtral-Offloading's aggressive setting).
    Int3,
}

impl QuantMode {
    /// Bytes per weight element at *paper scale* (fp16 baseline = 2 bytes).
    /// Includes per-group f16 scale overhead for the int modes.
    pub fn bytes_per_element(self) -> f64 {
        match self {
            QuantMode::Fp16 => 2.0,
            QuantMode::Int4 => 4.0 / 8.0 + 2.0 / GROUP as f64,
            QuantMode::Int3 => 3.0 / 8.0 + 2.0 / GROUP as f64,
        }
    }

    /// How many quantized experts fit in the VRAM of one fp16 expert.
    pub fn capacity_multiplier(self) -> f64 {
        QuantMode::Fp16.bytes_per_element() / self.bytes_per_element()
    }

    pub fn bits(self) -> u32 {
        match self {
            QuantMode::Fp16 => 16,
            QuantMode::Int4 => 4,
            QuantMode::Int3 => 3,
        }
    }

    /// VRAM cost of one resident expert at this tier, in units of one
    /// fp16 expert.  Exact binary fractions (fp16 = 1, int4 = 9/32,
    /// int3 = 7/32), so summed budget accounting in f64 is exact and the
    /// byte-occupancy audits can compare with `==`-tight tolerances.
    pub fn cost_units(self) -> f64 {
        self.bytes_per_element() / QuantMode::Fp16.bytes_per_element()
    }

    /// Dense index for per-tier counters (`Fp16 = 0 … Int3 = 2`).
    pub fn idx(self) -> usize {
        match self {
            QuantMode::Fp16 => 0,
            QuantMode::Int4 => 1,
            QuantMode::Int3 => 2,
        }
    }

    /// All tiers, in `idx` order.
    pub const ALL: [QuantMode; 3] = [QuantMode::Fp16, QuantMode::Int4, QuantMode::Int3];

    pub fn name(self) -> &'static str {
        match self {
            QuantMode::Fp16 => "fp16",
            QuantMode::Int4 => "int4",
            QuantMode::Int3 => "int3",
        }
    }

    pub fn parse(s: &str) -> Result<QuantMode> {
        Ok(match s {
            "fp16" => QuantMode::Fp16,
            "int4" => QuantMode::Int4,
            "int3" => QuantMode::Int3,
            _ => bail!("unknown quant mode {s:?} (fp16|int4|int3)"),
        })
    }
}

/// A "little" fallback copy must be strictly smaller than the serving
/// tier, or keeping it resident costs more than it saves.
pub fn validate_little_tier(quant: QuantMode, little: QuantMode) -> Result<()> {
    if little.bits() >= quant.bits() {
        bail!(
            "--little-tier {} must be strictly smaller than --quant {} \
             (a little copy needs fewer bits than the serving tier)",
            little.name(),
            quant.name()
        );
    }
    Ok(())
}

/// A group-quantized f32 blob: signed integers packed one-per-i8 (we trade
/// host RAM for simplicity — *simulated* bytes use `QuantMode` accounting),
/// with one f32 scale per group.
#[derive(Debug, Clone)]
pub struct QuantBlob {
    pub mode: QuantMode,
    pub len: usize,
    pub q: Vec<i8>,
    pub scales: Vec<f32>,
}

/// Symmetric group quantization: scale = max|x| / qmax per group.
pub fn quantize(data: &[f32], mode: QuantMode) -> QuantBlob {
    assert_ne!(mode, QuantMode::Fp16, "fp16 is not quantized");
    let qmax = ((1i32 << (mode.bits() - 1)) - 1) as f32; // 7 for int4, 3 for int3
    let mut q = Vec::with_capacity(data.len());
    let mut scales = Vec::with_capacity(data.len().div_ceil(GROUP));
    for group in data.chunks(GROUP) {
        let amax = group.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = if amax > 0.0 { amax / qmax } else { 1.0 };
        scales.push(scale);
        for &x in group {
            let v = (x / scale).round().clamp(-qmax, qmax);
            q.push(v as i8);
        }
    }
    QuantBlob { mode, len: data.len(), q, scales }
}

pub fn dequantize(blob: &QuantBlob) -> Vec<f32> {
    let mut out = Vec::with_capacity(blob.len);
    for (gi, group) in blob.q.chunks(GROUP).enumerate() {
        let scale = blob.scales[gi];
        for &v in group {
            out.push(v as f32 * scale);
        }
    }
    out
}

/// Max absolute quantization error bound for one group: scale / 2.
pub fn max_error_bound(data: &[f32], mode: QuantMode) -> f32 {
    let qmax = ((1i32 << (mode.bits() - 1)) - 1) as f32;
    data.chunks(GROUP)
        .map(|g| g.iter().fold(0.0f32, |m, &x| m.max(x.abs())) / qmax / 2.0)
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        for mode in [QuantMode::Int4, QuantMode::Int3] {
            let data: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
            let blob = quantize(&data, mode);
            let back = dequantize(&blob);
            assert_eq!(back.len(), data.len());
            let bound = max_error_bound(&data, mode) * 1.0001 + 1e-7;
            for (a, b) in data.iter().zip(&back) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn int4_tighter_than_int3() {
        let mut rng = Rng::new(2);
        let data: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let err = |mode| {
            let blob = quantize(&data, mode);
            let back = dequantize(&blob);
            data.iter().zip(&back).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
        };
        assert!(err(QuantMode::Int4) < err(QuantMode::Int3));
    }

    #[test]
    fn zeros_stay_zero() {
        let blob = quantize(&[0.0; 64], QuantMode::Int4);
        assert!(dequantize(&blob).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn capacity_multiplier_sane() {
        // int4 ≈ 3.5×, int3 ≈ 4.5× more experts per byte than fp16
        assert!((QuantMode::Int4.capacity_multiplier() - 3.55).abs() < 0.1);
        assert!(QuantMode::Int3.capacity_multiplier() > 4.0);
        assert_eq!(QuantMode::Fp16.capacity_multiplier(), 1.0);
    }

    #[test]
    fn cost_units_are_exact_binary_fractions() {
        // exact f64 fractions: budget sums in the cache never drift
        assert_eq!(QuantMode::Fp16.cost_units(), 1.0);
        assert_eq!(QuantMode::Int4.cost_units(), 9.0 / 32.0);
        assert_eq!(QuantMode::Int3.cost_units(), 7.0 / 32.0);
        for m in QuantMode::ALL {
            assert_eq!(QuantMode::ALL[m.idx()], m);
        }
    }

    #[test]
    fn little_tier_must_be_strictly_smaller() {
        assert!(validate_little_tier(QuantMode::Fp16, QuantMode::Int4).is_ok());
        assert!(validate_little_tier(QuantMode::Int4, QuantMode::Int3).is_ok());
        assert!(validate_little_tier(QuantMode::Int4, QuantMode::Int4).is_err());
        assert!(validate_little_tier(QuantMode::Int4, QuantMode::Fp16).is_err());
        let err = validate_little_tier(QuantMode::Int3, QuantMode::Int4).unwrap_err();
        assert!(err.to_string().contains("strictly smaller"), "{err}");
    }

    #[test]
    fn ragged_tail_group() {
        let data: Vec<f32> = (0..45).map(|i| i as f32 / 45.0).collect();
        let blob = quantize(&data, QuantMode::Int4);
        assert_eq!(dequantize(&blob).len(), 45);
        assert_eq!(blob.scales.len(), 2);
    }
}
