//! The offloaded decode engine — the paper's post-deployment stage (§3.2).
//!
//! For every token, every layer:
//!   1. `layer_step` (PJRT): attention over the KV cache + router probs;
//!   2. top-K selection on the host (paper Eq. 1);
//!   3. the offload policy resolves each routed expert — cache hit,
//!      demand PCIe transfer (stalling the simulated clock, Eq. 3),
//!      CPU execution (Fiddler), or sparsity skip (FLoE);
//!   4. `expert_group` (PJRT, the Pallas kernel) executes the routed
//!      experts with the *actual* resident weights (dequantized if the
//!      policy quantizes residency) — quality effects are real;
//!   5. host residual add; after the last layer, `lm_head` + greedy pick.
//!
//! Two time axes are tracked: simulated seconds (the cost model at paper
//! scale — all reported throughput numbers) and wallclock (sanity).

use std::time::Instant;

use anyhow::Result;

use crate::cache::ExpertCache;
use crate::clock::{CostModel, GpuSpec, SimClock};
use crate::metrics::{Report, RequestMetrics};
use crate::moe::{MoeConfig, PredictorWeights, RoutingProfile, WeightStore};
use crate::pcie::TransferEngine;
use crate::policies::{PolicyConfig, Prefetch};
use crate::predictor::{predict_plan, predict_plan_batch, profile_plan, PrefetchPlan};
use crate::runtime::Runtime;
use crate::tensor::add;

pub const EOS: usize = 2;

/// Routing activity recorded during decoding (Figs. 1b, 7–10).
#[derive(Debug, Clone)]
pub struct ActivationTrace {
    pub n_experts: usize,
    /// counts[layer][expert] — total requests.
    pub counts: Vec<Vec<u64>>,
    /// steps[t][layer] — experts selected at decode step t.
    pub steps: Vec<Vec<Vec<usize>>>,
}

impl ActivationTrace {
    fn new(n_layers: usize, n_experts: usize) -> Self {
        ActivationTrace {
            n_experts,
            counts: vec![vec![0; n_experts]; n_layers],
            steps: Vec::new(),
        }
    }

    /// Fraction of activations captured by the top-`c` experts of a layer.
    pub fn topc_share(&self, layer: usize, c: usize) -> f64 {
        let mut v = self.counts[layer].clone();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = v.iter().sum();
        if total == 0 {
            return 0.0;
        }
        v.iter().take(c).sum::<u64>() as f64 / total as f64
    }

    /// Mean top-c share across layers.
    pub fn mean_topc_share(&self, c: usize) -> f64 {
        let l = self.counts.len();
        (0..l).map(|i| self.topc_share(i, c)).sum::<f64>() / l as f64
    }

    /// Sorted activation-share curve for a layer (Fig. 1b's x-axis).
    pub fn share_curve(&self, layer: usize) -> Vec<f64> {
        let mut v = self.counts[layer].clone();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = v.iter().sum::<u64>().max(1);
        v.iter().map(|&c| c as f64 / total as f64).collect()
    }
}

/// Result of one decoded request.
pub struct DecodeOutput {
    pub tokens: Vec<usize>,
    pub metrics: RequestMetrics,
    pub report: Report,
    pub trace: ActivationTrace,
    /// CPU-executed expert invocations (Fiddler path).
    pub cpu_execs: u64,
    /// Experts skipped by the sparsity threshold (FLoE path).
    pub sparsity_skips: u64,
}

/// Engine over one loaded checkpoint + one offload policy.
pub struct Engine<'a> {
    pub rt: &'a Runtime,
    pub cfg: &'a MoeConfig,
    pub weights: &'a WeightStore,
    pub policy: PolicyConfig,
    pub cost: CostModel,
    pub predictor: Option<&'a PredictorWeights>,
    pub profile: Option<&'a RoutingProfile>,
    /// Device-buffer memo of stacked routed sets (§Perf fast path).  The
    /// big expert weights upload once per distinct routed set; repeats —
    /// which MELINOE's fine-tuning makes the common case — re-dispatch
    /// without any host→device weight traffic.
    buf_cache: std::cell::RefCell<
        std::collections::HashMap<(usize, Vec<usize>), std::rc::Rc<StackedBufs>>,
    >,
    use_buffers: bool,
    /// Decode a fixed number of tokens regardless of EOS (serving-bench
    /// convention): throughput comparisons stay fair when checkpoints
    /// produce different natural output lengths.
    pub ignore_eos: bool,
}

/// Device-resident stacked expert weights.
pub struct StackedBufs {
    pub wg: xla::PjRtBuffer,
    pub wu: xla::PjRtBuffer,
    pub wd: xla::PjRtBuffer,
}

const BUF_CACHE_CAP: usize = 512;

struct SeqState {
    x: Vec<f32>,
    k_caches: Vec<xla::Literal>,
    v_caches: Vec<xla::Literal>,
    pos: usize,
    tokens: Vec<usize>, // generated
    done: bool,
}

impl<'a> Engine<'a> {
    pub fn new(
        rt: &'a Runtime,
        cfg: &'a MoeConfig,
        weights: &'a WeightStore,
        policy: PolicyConfig,
        gpu: GpuSpec,
    ) -> Engine<'a> {
        let cost = CostModel::new(gpu, cfg.cost);
        let use_buffers = std::env::var("MELINOE_NO_BUFCACHE").is_err();
        Engine {
            rt,
            cfg,
            weights,
            policy,
            cost,
            predictor: None,
            profile: None,
            buf_cache: std::cell::RefCell::new(std::collections::HashMap::new()),
            use_buffers,
            ignore_eos: false,
        }
    }

    pub fn with_ignore_eos(mut self, v: bool) -> Self {
        self.ignore_eos = v;
        self
    }

    /// Stacked routed-set weights as device buffers (memoized).
    fn stacked_buffers(&self, layer: usize, idx: &[usize]) -> Result<std::rc::Rc<StackedBufs>> {
        let key = (layer, idx.to_vec());
        if let Some(hit) = self.buf_cache.borrow().get(&key) {
            return Ok(hit.clone());
        }
        let st = self.weights.stack_experts(layer, idx, self.cfg.d_model, self.cfg.d_ff)?;
        let (k, d, dff) = (idx.len(), self.cfg.d_model, self.cfg.d_ff);
        let host = |lit: &xla::Literal| lit.to_vec::<f32>();
        let bufs = std::rc::Rc::new(StackedBufs {
            wg: self.rt.to_device(&host(&st.wg)?, &[k, dff, d])?,
            wu: self.rt.to_device(&host(&st.wu)?, &[k, dff, d])?,
            wd: self.rt.to_device(&host(&st.wd)?, &[k, d, dff])?,
        });
        let mut cache = self.buf_cache.borrow_mut();
        if cache.len() >= BUF_CACHE_CAP {
            cache.clear();
        }
        cache.insert(key, bufs.clone());
        Ok(bufs)
    }

    /// Execute the routed experts via the fastest available path.
    /// The `expert_group` executable has a static top-K parameter shape;
    /// a sparsity-reduced selection (FLoE) is padded with zero-gate
    /// duplicates — the kernel is linear in the gates, so padding is
    /// exact (validated by `test_moe_ffn_zero_gates`).
    fn run_experts(
        &self,
        layer: usize,
        idx: &[usize],
        gates: &[f32],
        h2: &xla::Literal,
    ) -> Result<Vec<f32>> {
        let (mut idx_p, mut gates_p);
        let (idx, gates) = if idx.len() < self.cfg.top_k {
            idx_p = idx.to_vec();
            gates_p = gates.to_vec();
            while idx_p.len() < self.cfg.top_k {
                idx_p.push(idx[0]);
                gates_p.push(0.0);
            }
            (&idx_p[..], &gates_p[..])
        } else {
            (idx, gates)
        };
        if self.use_buffers {
            let bufs = self.stacked_buffers(layer, idx)?;
            self.rt.expert_group_b(gates, h2, &bufs.wg, &bufs.wu, &bufs.wd)
        } else {
            let st = self.weights.stack_experts(layer, idx, self.cfg.d_model, self.cfg.d_ff)?;
            self.rt.expert_group(gates, h2, &st.wg, &st.wu, &st.wd)
        }
    }

    pub fn with_predictor(mut self, p: &'a PredictorWeights) -> Self {
        self.predictor = Some(p);
        self
    }

    pub fn with_profile(mut self, p: &'a RoutingProfile) -> Self {
        self.profile = Some(p);
        self
    }

    fn effective_capacity(&self) -> usize {
        self.policy.effective_capacity(self.cfg.n_experts)
    }

    fn new_cache(&self) -> ExpertCache {
        let caps = self.policy.effective_layer_capacities(self.cfg.n_layers, self.cfg.n_experts);
        ExpertCache::with_capacities(self.cfg.n_experts, &caps, self.policy.eviction)
    }

    fn prefetch_plan(&self, prompts: &[Vec<usize>]) -> Result<PrefetchPlan> {
        // uniform upper bound; per-layer prefill truncates to each layer's
        // actual slot count
        let cap = self.effective_capacity();
        match self.policy.prefetch {
            Prefetch::None => Ok(PrefetchPlan::empty(self.cfg.n_layers)),
            Prefetch::Predictor => {
                let pw = self
                    .predictor
                    .ok_or_else(|| anyhow::anyhow!("policy wants predictor weights"))?;
                if prompts.len() == 1 {
                    predict_plan(self.rt, pw, self.cfg, &self.weights.embed, &prompts[0], cap)
                } else {
                    predict_plan_batch(self.rt, pw, self.cfg, &self.weights.embed, prompts, cap)
                }
            }
            Prefetch::Profile => {
                let pr =
                    self.profile.ok_or_else(|| anyhow::anyhow!("policy wants a routing profile"))?;
                Ok(profile_plan(pr, self.cfg, cap))
            }
        }
    }

    fn apply_prefetch(
        &self,
        plan: &PrefetchPlan,
        cache: &mut ExpertCache,
        pcie: &mut TransferEngine,
        clock: &mut SimClock,
    ) {
        if self.policy.prefetch == Prefetch::None {
            return;
        }
        clock.advance(self.cost.predictor_time());
        for (l, set) in plan.per_layer.iter().enumerate() {
            let loads = cache.layer(l).prefill(set);
            for _ in loads {
                pcie.prefetch_h2d(&self.cost, clock, self.policy.quant);
            }
        }
        // No sync barrier: prefetch transfers overlap prefill compute
        // (non-blocking, pinned memory — §3.2).  Early demand misses
        // naturally serialize behind the in-flight prefetch traffic via
        // the link-occupancy model in `pcie`.
    }

    /// Select experts for one token at one layer, applying FLoE sparsity.
    /// Returns (expert, gate) pairs and the skip count.
    fn select(&self, probs: &crate::tensor::HostTensor, cache: &ExpertCache, layer: usize) -> (Vec<(usize, f32)>, u64) {
        let idx = probs.topk(self.cfg.top_k);
        let mut skips = 0;
        let tau = self.policy.sparsity_tau;
        let mut sel: Vec<(usize, f32)> = Vec::with_capacity(idx.len());
        let total: f32 = idx.iter().map(|&e| probs.data[e]).sum();
        for &e in &idx {
            let g = probs.data[e];
            if tau > 0.0 && g < tau && !cache.layers[layer].contains(e) {
                skips += 1;
                continue;
            }
            sel.push((e, g));
        }
        if skips > 0 && !sel.is_empty() {
            // renormalize surviving gates to the original top-K mass
            let kept: f32 = sel.iter().map(|(_, g)| g).sum();
            if kept > 0.0 {
                let scale = total / kept;
                for s in &mut sel {
                    s.1 *= scale;
                }
            }
        }
        (sel, skips)
    }

    /// Resolve residency for the selected experts of one (seq, layer) and
    /// advance the clock.  Returns the number of CPU-executed experts.
    #[allow(clippy::too_many_arguments)]
    fn resolve_residency(
        &self,
        layer: usize,
        selected: &[(usize, f32)],
        cache: &mut ExpertCache,
        pcie: &mut TransferEngine,
        clock: &mut SimClock,
        cpu_execs: &mut u64,
    ) {
        let pinned: Vec<usize> = selected.iter().map(|(e, _)| *e).collect();
        let quant = self.policy.quant;
        for &(e, _) in selected {
            let hit = cache.layer(layer).request(e);
            if hit {
                continue;
            }
            if self.policy.cpu_compute {
                // Fiddler: run on CPU when cheaper than transfer + GPU exec
                let cpu_t = self.cost.cpu_expert_time(1);
                let gpu_t =
                    self.cost.transfer_time(quant) + self.cost.expert_exec_time(1, 1, quant);
                if cpu_t < gpu_t {
                    clock.advance(cpu_t);
                    *cpu_execs += 1;
                    continue; // no residency change
                }
            }
            pcie.demand_h2d(&self.cost, clock, quant);
            if let Some(_evicted) = cache.layer(layer).insert(e, &pinned) {
                pcie.evict_d2h(&self.cost, quant);
            }
        }
    }

    /// One full forward step for one sequence; returns logits if requested.
    #[allow(clippy::too_many_arguments)]
    fn step_seq(
        &self,
        st: &mut SeqState,
        token: usize,
        cache: &mut ExpertCache,
        pcie: &mut TransferEngine,
        clock: &mut SimClock,
        trace: &mut ActivationTrace,
        cpu_execs: &mut u64,
        skips: &mut u64,
        want_logits: bool,
    ) -> Result<Option<crate::tensor::HostTensor>> {
        st.x = self.weights.embed.row(token.min(self.cfg.vocab_size - 1)).to_vec();
        let mut step_sel: Vec<Vec<usize>> = Vec::with_capacity(self.cfg.n_layers);
        for l in 0..self.cfg.n_layers {
            let out = self.rt.layer_step(
                &st.x,
                &self.weights.layers[l],
                &st.k_caches[l],
                &st.v_caches[l],
                st.pos,
            )?;
            st.k_caches[l] = out.k_cache;
            st.v_caches[l] = out.v_cache;
            clock.advance(self.cost.attn_time(1));

            let (sel, s) = self.select(&out.probs, cache, l);
            *skips += s;
            for &(e, _) in &sel {
                trace.counts[l][e] += 1;
            }
            step_sel.push(sel.iter().map(|(e, _)| *e).collect());
            self.resolve_residency(l, &sel, cache, pcie, clock, cpu_execs);

            if sel.is_empty() {
                st.x = out.h_res;
            } else {
                let idx: Vec<usize> = sel.iter().map(|(e, _)| *e).collect();
                let gates: Vec<f32> = sel.iter().map(|(_, g)| *g).collect();
                let y = self.run_experts(l, &idx, &gates, &out.h2)?;
                clock.advance(self.cost.expert_exec_time(idx.len(), idx.len(), self.policy.quant));
                st.x = add(&out.h_res, &y);
            }
        }
        trace.steps.push(step_sel);
        cache.token_tick();
        st.pos += 1;
        if want_logits {
            clock.advance(self.cost.head_time(1));
            let logits = self.rt.lm_head(&st.x, &self.weights.lnf_lit, &self.weights.embed_lit)?;
            Ok(Some(logits))
        } else {
            Ok(None)
        }
    }

    fn new_seq(&self) -> Result<SeqState> {
        let mut k_caches = Vec::with_capacity(self.cfg.n_layers);
        let mut v_caches = Vec::with_capacity(self.cfg.n_layers);
        for _ in 0..self.cfg.n_layers {
            let (k, v) = self.rt.init_kv(self.cfg)?;
            k_caches.push(k);
            v_caches.push(v);
        }
        Ok(SeqState { x: vec![0.0; self.cfg.d_model], k_caches, v_caches, pos: 0, tokens: Vec::new(), done: false })
    }

    /// Greedy-decode one request.
    pub fn decode(&self, prompt: &[usize], max_output: usize) -> Result<DecodeOutput> {
        let wall = Instant::now();
        let mut clock = SimClock::new();
        let mut cache = self.new_cache();
        let mut pcie = TransferEngine::new();
        let mut trace = ActivationTrace::new(self.cfg.n_layers, self.cfg.n_experts);
        let (mut cpu_execs, mut skips) = (0u64, 0u64);

        let plan = self.prefetch_plan(std::slice::from_ref(&prompt.to_vec()))?;
        self.apply_prefetch(&plan, &mut cache, &mut pcie, &mut clock);

        let mut st = self.new_seq()?;
        let mut logits = None;
        for (i, &t) in prompt.iter().enumerate() {
            let last = i == prompt.len() - 1;
            logits = self.step_seq(
                &mut st, t, &mut cache, &mut pcie, &mut clock, &mut trace,
                &mut cpu_execs, &mut skips, last,
            )?;
        }
        let ttft = clock.now();
        let mut next = logits.expect("prompt must be non-empty").argmax();
        while st.tokens.len() < max_output {
            st.tokens.push(next);
            if next == EOS && !self.ignore_eos {
                break;
            }
            let lg = self.step_seq(
                &mut st, next, &mut cache, &mut pcie, &mut clock, &mut trace,
                &mut cpu_execs, &mut skips, true,
            )?;
            next = lg.unwrap().argmax();
        }

        let metrics = RequestMetrics {
            prompt_tokens: prompt.len(),
            output_tokens: st.tokens.len(),
            sim_seconds: clock.now(),
            sim_ttft: ttft,
            wall_seconds: wall.elapsed().as_secs_f64(),
        };
        let report = Report {
            requests: vec![metrics.clone()],
            cache: cache.total_stats(),
            transfers: pcie.stats.clone(),
            misses_per_layer: cache.misses_per_layer(),
            wall_seconds: metrics.wall_seconds,
        };
        Ok(DecodeOutput { tokens: st.tokens, metrics, report, trace, cpu_execs, sparsity_skips: skips })
    }

    /// Teacher-forced pass over `tokens`: returns per-position NLLs of
    /// tokens[1..] (perplexity measurements, Tables 4 / Fig. 4).
    pub fn teacher_forced_nll(&self, tokens: &[usize]) -> Result<Vec<f64>> {
        let mut clock = SimClock::new();
        let mut cache = self.new_cache();
        let mut pcie = TransferEngine::new();
        let mut trace = ActivationTrace::new(self.cfg.n_layers, self.cfg.n_experts);
        let (mut cpu, mut skips) = (0u64, 0u64);
        let mut st = self.new_seq()?;
        let mut nlls = Vec::with_capacity(tokens.len().saturating_sub(1));
        for (i, &t) in tokens.iter().enumerate() {
            let want = i + 1 < tokens.len();
            let lg = self.step_seq(
                &mut st, t, &mut cache, &mut pcie, &mut clock, &mut trace,
                &mut cpu, &mut skips, want,
            )?;
            if let Some(lg) = lg {
                nlls.push(crate::eval::token_nll(&lg.data, tokens[i + 1]));
            }
        }
        Ok(nlls)
    }

    /// Lockstep batched greedy decoding (Fig. 5).  All sequences share the
    /// expert cache; per step each unique missing expert transfers once.
    pub fn decode_batch(&self, prompts: &[Vec<usize>], max_output: usize) -> Result<(Vec<Vec<usize>>, Report)> {
        let wall = Instant::now();
        let b = prompts.len();
        let mut clock = SimClock::new();
        let mut cache = self.new_cache();
        let mut pcie = TransferEngine::new();
        let mut trace = ActivationTrace::new(self.cfg.n_layers, self.cfg.n_experts);
        let (mut cpu_execs, mut skips) = (0u64, 0u64);

        let plan = self.prefetch_plan(prompts)?;
        self.apply_prefetch(&plan, &mut cache, &mut pcie, &mut clock);

        let mut seqs: Vec<SeqState> = (0..b).map(|_| self.new_seq()).collect::<Result<_>>()?;
        // current input token per sequence: walk prompts then generations
        let max_prompt = prompts.iter().map(|p| p.len()).max().unwrap_or(0);
        let mut ttft = 0.0;

        for step in 0..(max_prompt + max_output) {
            // gather (seq, token) for sequences active this step
            let mut active: Vec<(usize, usize, bool)> = Vec::new(); // (seq, token, want_logits)
            for (s, seq) in seqs.iter().enumerate() {
                if seq.done {
                    continue;
                }
                let p = &prompts[s];
                if step < p.len() {
                    active.push((s, p[step], step == p.len() - 1));
                } else if step >= p.len() && !seq.tokens.is_empty() {
                    let last = *seq.tokens.last().unwrap();
                    active.push((s, last, true));
                }
            }
            if active.is_empty() {
                break;
            }
            // per-layer lockstep over sequences
            let mut outs: Vec<Option<crate::tensor::HostTensor>> = vec![None; b];
            for &(s, tok, want) in &active {
                let st = &mut seqs[s];
                // batched compute: charge attention once per layer per step
                // by discounting the per-seq clock advance below.
                outs[s] = self.step_seq_batch_member(
                    st, tok, &mut cache, &mut pcie, &mut clock, &mut trace,
                    &mut cpu_execs, &mut skips, want, active.len(),
                )?;
            }
            cache.token_tick();
            for &(s, _, want) in &active {
                if !want {
                    continue;
                }
                let next = outs[s].as_ref().unwrap().argmax();
                let seq = &mut seqs[s];
                seq.tokens.push(next);
                if (next == EOS && !self.ignore_eos) || seq.tokens.len() >= max_output {
                    seq.done = true;
                }
            }
            if step == max_prompt - 1 {
                ttft = clock.now();
            }
        }

        let sim = clock.now();
        let outputs: Vec<Vec<usize>> = seqs.iter().map(|s| s.tokens.clone()).collect();
        let requests = outputs
            .iter()
            .enumerate()
            .map(|(i, o)| RequestMetrics {
                prompt_tokens: prompts[i].len(),
                output_tokens: o.len(),
                sim_seconds: sim,
                sim_ttft: ttft,
                wall_seconds: wall.elapsed().as_secs_f64(),
            })
            .collect();
        let report = Report {
            requests,
            cache: cache.total_stats(),
            transfers: pcie.stats.clone(),
            misses_per_layer: cache.misses_per_layer(),
            wall_seconds: wall.elapsed().as_secs_f64(),
        };
        Ok((outputs, report))
    }

    /// step_seq variant for batch members: attention/head costs are
    /// amortized — the GPU runs the whole batch in one kernel, so member
    /// i>0 contributes only marginal compute (the cost model's batch
    /// scaling), not another full pass.
    #[allow(clippy::too_many_arguments)]
    fn step_seq_batch_member(
        &self,
        st: &mut SeqState,
        token: usize,
        cache: &mut ExpertCache,
        pcie: &mut TransferEngine,
        clock: &mut SimClock,
        trace: &mut ActivationTrace,
        cpu_execs: &mut u64,
        skips: &mut u64,
        want_logits: bool,
        batch: usize,
    ) -> Result<Option<crate::tensor::HostTensor>> {
        st.x = self.weights.embed.row(token.min(self.cfg.vocab_size - 1)).to_vec();
        for l in 0..self.cfg.n_layers {
            let out = self.rt.layer_step(
                &st.x,
                &self.weights.layers[l],
                &st.k_caches[l],
                &st.v_caches[l],
                st.pos,
            )?;
            st.k_caches[l] = out.k_cache;
            st.v_caches[l] = out.v_cache;
            // amortized attention: full cost once per batch step
            clock.advance(self.cost.attn_time(batch) / batch as f64);

            let (sel, s) = self.select(&out.probs, cache, l);
            *skips += s;
            for &(e, _) in &sel {
                trace.counts[l][e] += 1;
            }
            self.resolve_residency(l, &sel, cache, pcie, clock, cpu_execs);

            if sel.is_empty() {
                st.x = out.h_res;
            } else {
                let idx: Vec<usize> = sel.iter().map(|(e, _)| *e).collect();
                let gates: Vec<f32> = sel.iter().map(|(_, g)| *g).collect();
                let y = self.run_experts(l, &idx, &gates, &out.h2)?;
                // weight-read cost amortizes across the batch; per-token
                // MXU compute does not.
                clock.advance(
                    self.cost.expert_exec_time(idx.len(), idx.len(), self.policy.quant)
                        / batch as f64
                        + self.cost.dims.expert_flops() * idx.len() as f64 / self.cost.gpu.flops,
                );
                st.x = add(&out.h_res, &y);
            }
        }
        st.pos += 1;
        if want_logits {
            clock.advance(self.cost.head_time(batch) / batch as f64);
            let logits = self.rt.lm_head(&st.x, &self.weights.lnf_lit, &self.weights.embed_lit)?;
            Ok(Some(logits))
        } else {
            Ok(None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_with(counts: Vec<Vec<u64>>) -> ActivationTrace {
        ActivationTrace { n_experts: counts[0].len(), counts, steps: Vec::new() }
    }

    #[test]
    fn topc_share_concentrated() {
        let t = trace_with(vec![vec![90, 5, 5, 0]]);
        assert!((t.topc_share(0, 1) - 0.9).abs() < 1e-12);
        assert!((t.topc_share(0, 2) - 0.95).abs() < 1e-12);
        assert!((t.topc_share(0, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn topc_share_uniform() {
        let t = trace_with(vec![vec![10; 8]]);
        assert!((t.topc_share(0, 2) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn topc_share_empty_is_zero() {
        let t = trace_with(vec![vec![0; 4]]);
        assert_eq!(t.topc_share(0, 2), 0.0);
    }

    #[test]
    fn mean_topc_share_averages_layers() {
        let t = trace_with(vec![vec![10, 0], vec![5, 5]]);
        assert!((t.mean_topc_share(1) - (1.0 + 0.5) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn share_curve_sorted_and_normalized() {
        let t = trace_with(vec![vec![1, 7, 2]]);
        let c = t.share_curve(0);
        assert!((c[0] - 0.7).abs() < 1e-12);
        assert!(c.windows(2).all(|w| w[0] >= w[1]));
        assert!((c.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
