//! The offloaded decode engine — the paper's post-deployment stage (§3.2).
//!
//! For every token, every layer:
//!   1. `layer_step` (PJRT): attention over the KV cache + router probs;
//!   2. top-K selection on the host (paper Eq. 1);
//!   3. the offload policy resolves each routed expert — cache hit,
//!      residual wait on an in-flight lookahead prefetch (`--lookahead`,
//!      the layer-ahead transfer pipeline), demand PCIe transfer
//!      (stalling the simulated clock, Eq. 3), CPU execution (Fiddler),
//!      sparsity skip (FLoE), or — when a little-tier copy of the expert
//!      is resident and the expected wait on the full transfer exceeds
//!      `--fallback-threshold` — a *degraded* execution from the low-bit
//!      little copy at zero stall (the big-little fallback; every such
//!      assignment is counted into `degraded_token_frac`);
//!   4. `expert_group` (PJRT, the Pallas kernel) executes the routed
//!      experts with the *actual* resident weights (dequantized if the
//!      policy quantizes residency) — quality effects are real;
//!   5. host residual add; after the last layer, `lm_head` + greedy pick.
//!
//! Decoding is *step-granular*: a [`DecodeSession`] holds the in-flight
//! sequences ([`SeqState`]: token buffer, KV handles, per-sequence slice
//! of the simulated timeline) and [`Engine::step`] advances all of them.
//! A decoding sequence emits exactly one token per step; a sequence still
//! in *prefill* consumes up to [`DecodeSession::prefill_chunk`] prompt
//! tokens in the same step (Sarathi-style chunked prefill): the chunk
//! runs layer-major with residency resolved over the chunk's union
//! expert set, and the per-step cost amortization spreads fixed costs
//! (kernel dispatch, attention/head weight reads, expert weight
//! streaming) over every token the step consumes.  Sequences are
//! admitted mid-flight ([`Engine::admit`]) and retire at EOS
//! immediately, so the active batch size — and with it the amortization
//! — changes every step.  A scheduler may also detach a sequence at a
//! step boundary ([`Engine::suspend`], priority preemption) and reattach
//! it later ([`Engine::resume`]) with bit-identical continuation; while
//! a sequence is in flight its planned hot set is registered in the
//! cache's scheduler-owned pin ledger, so burst admissions and lookahead
//! commits can never evict a live sequence's warm working set.  This is
//! what the coordinator's continuous scheduler and the cluster layer
//! build on; [`Engine::decode`] and [`Engine::decode_batch`] are thin
//! run-to-completion wrappers.
//!
//! Two time axes are tracked: simulated seconds (the cost model at paper
//! scale — all reported throughput numbers) and wallclock (sanity).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::cache::ExpertCache;
use crate::clock::{CostModel, GpuSpec, SimClock};
use crate::coordinator::SeqFinish;
use crate::metrics::{Report, RequestMetrics};
use crate::moe::{MoeConfig, PredictorWeights, RoutingProfile, WeightStore};
use crate::pcie::TransferEngine;
use crate::policies::{PolicyConfig, Prefetch};
use crate::predictor::{
    predict_next_layer, predict_plan, predict_plan_batch, profile_plan, PrefetchPlan,
};
use crate::quant::{dequantize, quantize};
use crate::runtime::Runtime;
use crate::tensor::add;
use crate::trace::{PcieSnap, Recorder, Trace, TraceEvent};

pub const EOS: usize = 2;

/// Routing activity recorded during decoding (Figs. 1b, 7–10).
#[derive(Debug, Clone)]
pub struct ActivationTrace {
    pub n_experts: usize,
    /// counts[layer][expert] — total requests.
    pub counts: Vec<Vec<u64>>,
    /// steps[t][layer] — experts selected at decode step t (recorded for
    /// single-sequence sessions, the Fig. 7–10 shape).
    pub steps: Vec<Vec<Vec<usize>>>,
}

impl ActivationTrace {
    fn new(n_layers: usize, n_experts: usize) -> Self {
        ActivationTrace {
            n_experts,
            counts: vec![vec![0; n_experts]; n_layers],
            steps: Vec::new(),
        }
    }

    /// Fraction of activations captured by the top-`c` experts of a layer.
    pub fn topc_share(&self, layer: usize, c: usize) -> f64 {
        let mut v = self.counts[layer].clone();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = v.iter().sum();
        if total == 0 {
            return 0.0;
        }
        v.iter().take(c).sum::<u64>() as f64 / total as f64
    }

    /// Mean top-c share across layers.
    pub fn mean_topc_share(&self, c: usize) -> f64 {
        let l = self.counts.len();
        (0..l).map(|i| self.topc_share(i, c)).sum::<f64>() / l as f64
    }

    /// Sorted activation-share curve for a layer (Fig. 1b's x-axis).
    pub fn share_curve(&self, layer: usize) -> Vec<f64> {
        let mut v = self.counts[layer].clone();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = v.iter().sum::<u64>().max(1);
        v.iter().map(|&c| c as f64 / total as f64).collect()
    }
}

/// Result of one decoded request.
pub struct DecodeOutput {
    pub tokens: Vec<usize>,
    pub metrics: RequestMetrics,
    pub report: Report,
    pub trace: ActivationTrace,
    /// CPU-executed expert invocations (Fiddler path).
    pub cpu_execs: u64,
    /// Experts skipped by the sparsity threshold (FLoE path).
    pub sparsity_skips: u64,
}

/// Engine over one loaded checkpoint + one offload policy.
pub struct Engine<'a> {
    pub rt: &'a Runtime,
    pub cfg: &'a MoeConfig,
    pub weights: &'a WeightStore,
    pub policy: PolicyConfig,
    pub cost: CostModel,
    pub predictor: Option<&'a PredictorWeights>,
    pub profile: Option<&'a RoutingProfile>,
    use_buffers: bool,
    /// Decode a fixed number of tokens regardless of EOS (serving-bench
    /// convention): throughput comparisons stay fair when checkpoints
    /// produce different natural output lengths.
    pub ignore_eos: bool,
}

/// Memo key of one stacked routed set: (layer, sorted-or-as-routed ids).
type BufKey = (usize, Vec<usize>);
/// Device-buffer memo of stacked routed sets (§Perf fast path).
type BufMap = std::collections::HashMap<BufKey, std::rc::Rc<StackedBufs>>;

/// Device-resident stacked expert weights.
pub struct StackedBufs {
    pub wg: xla::PjRtBuffer,
    pub wu: xla::PjRtBuffer,
    pub wd: xla::PjRtBuffer,
}

const BUF_CACHE_CAP: usize = 512;

/// Per-sequence decode state: token buffer, per-layer KV handles, and the
/// per-sequence slice of the simulated timeline.  Owned by a
/// [`DecodeSession`]; resumable across [`Engine::step`] calls.
pub struct SeqState {
    pub id: u64,
    k_caches: Vec<xla::Literal>,
    v_caches: Vec<xla::Literal>,
    pos: usize,
    prompt: Vec<usize>,
    max_output: usize,
    /// Generated tokens (EOS included when it fires).
    pub tokens: Vec<usize>,
    /// This sequence's own predicted prefetch sets (empty when the
    /// policy doesn't prefetch); the session union is rebuilt from the
    /// *live* sequences on every admission, so retired traffic stops
    /// influencing the plan.
    plan: PrefetchPlan,
    sim_admitted: f64,
    sim_first_token: f64,
}

/// Resumable decode state shared by every in-flight sequence: the
/// simulated clock, the expert cache, PCIe accounting, the routing trace,
/// and the union prefetch plan of the changing in-flight set.
pub struct DecodeSession {
    pub clock: SimClock,
    pub cache: ExpertCache,
    pub pcie: TransferEngine,
    pub trace: ActivationTrace,
    pub cpu_execs: u64,
    pub sparsity_skips: u64,
    /// (token, expert) assignments served by a degraded little-tier copy
    /// (big-little fallback) instead of the full-tier weights.
    pub degraded_execs: u64,
    /// All routed (token, expert) assignments — the denominator of
    /// [`DecodeSession::degraded_token_frac`].
    pub total_assignments: u64,
    seqs: Vec<SeqState>,
    next_id: u64,
    /// Prompt tokens a prefilling sequence may consume in one step (≥ 1;
    /// 1 recovers token-at-a-time prefill).  Decodes always emit exactly
    /// one token per step regardless.
    prefill_chunk: usize,
    /// Device-buffer memo of stacked routed sets (§Perf fast path).  The
    /// big expert weights upload once per distinct routed set; repeats —
    /// which MELINOE's fine-tuning makes the common case — re-dispatch
    /// without any host→device weight traffic.  The memo lives on the
    /// *session* so serving wrappers that rebuild their borrowing
    /// [`Engine`] view every step keep the fast path warm (ROADMAP
    /// "session-persistent device buffers").
    buf_cache: std::cell::RefCell<BufMap>,
    buf_hits: std::cell::Cell<u64>,
    /// Structured event recorder (off by default — a disabled recorder
    /// is a `None` and every emission is a no-op branch; see
    /// [`DecodeSession::set_tracing`]).
    rec: Recorder,
}

impl DecodeSession {
    /// Number of in-flight sequences.
    pub fn active(&self) -> usize {
        self.seqs.len()
    }

    /// Current simulated time (seconds).
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Per-step prompt-token budget for prefilling sequences.
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Set the per-step prefill chunk (clamped to ≥ 1).
    pub fn set_prefill_chunk(&mut self, chunk: usize) {
        self.prefill_chunk = chunk.max(1);
    }

    /// Distinct routed sets memoized as device buffers.
    pub fn buf_cache_entries(&self) -> usize {
        self.buf_cache.borrow().len()
    }

    /// Dispatches served from the device-buffer memo (no re-upload).
    pub fn buf_cache_hits(&self) -> u64 {
        self.buf_hits.get()
    }

    /// Enable or disable sim-time structured tracing.  Tracing does not
    /// change decode numerics: decoded tokens are bit-identical with
    /// tracing on or off (a property test locks this in).
    pub fn set_tracing(&mut self, on: bool) {
        if on {
            if !self.rec.enabled() {
                self.rec = Recorder::on(0, "engine");
            }
        } else {
            self.rec = Recorder::off();
        }
    }

    /// Whether structured tracing is currently enabled.
    pub fn tracing(&self) -> bool {
        self.rec.enabled()
    }

    /// Drain the recorded events (disables tracing); `None` when tracing
    /// was never enabled.
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.rec.take()
    }

    /// Fraction of routed assignments served degraded by the big-little
    /// fallback (0.0 whenever the fallback is disabled; always in [0, 1]).
    pub fn degraded_token_frac(&self) -> f64 {
        crate::metrics::degraded_frac(self.degraded_execs, self.total_assignments)
    }

    /// Tokens an in-flight sequence has produced so far (empty for an
    /// unknown or still-prefilling sequence).  The streaming front-end
    /// polls this after each step to forward newly decoded tokens.
    pub fn emitted_tokens(&self, seq: u64) -> Vec<usize> {
        self.seqs.iter().find(|s| s.id == seq).map(|s| s.tokens.clone()).unwrap_or_default()
    }

    /// Record a scheduler-originated event (rejection, queue-side
    /// cancellation, stream stall) onto this session's trace lane at the
    /// current simulated time.  No-op when tracing is off.
    pub fn note(&mut self, ev: TraceEvent) {
        self.rec.emit(self.clock.now(), ev);
    }

    /// Cache/transfer snapshot (callers fill in `requests`).
    pub fn report_base(&self) -> Report {
        Report {
            requests: Vec::new(),
            cache: self.cache.total_stats(),
            transfers: self.pcie.stats.clone(),
            misses_per_layer: self.cache.misses_per_layer(),
            degraded_token_frac: self.degraded_token_frac(),
            wall_seconds: 0.0,
        }
    }
}

/// One step's mutable view of the session, split from the sequence being
/// stepped so the borrow checker can hand out disjoint pieces.
struct StepCtx<'s> {
    cache: &'s mut ExpertCache,
    pcie: &'s mut TransferEngine,
    clock: &'s mut SimClock,
    trace: &'s mut ActivationTrace,
    cpu_execs: &'s mut u64,
    sparsity_skips: &'s mut u64,
    degraded_execs: &'s mut u64,
    total_assignments: &'s mut u64,
    bufs: &'s std::cell::RefCell<BufMap>,
    buf_hits: &'s std::cell::Cell<u64>,
    rec: &'s mut Recorder,
}

impl<'a> Engine<'a> {
    pub fn new(
        rt: &'a Runtime,
        cfg: &'a MoeConfig,
        weights: &'a WeightStore,
        policy: PolicyConfig,
        gpu: GpuSpec,
    ) -> Engine<'a> {
        let cost = CostModel::new(gpu, cfg.cost);
        let use_buffers = std::env::var("MELINOE_NO_BUFCACHE").is_err();
        Engine {
            rt,
            cfg,
            weights,
            policy,
            cost,
            predictor: None,
            profile: None,
            use_buffers,
            ignore_eos: false,
        }
    }

    pub fn with_ignore_eos(mut self, v: bool) -> Self {
        self.ignore_eos = v;
        self
    }

    /// Stacked routed-set weights as device buffers, memoized in the
    /// session (`memo`/`hits` are the session's cells).  `degraded[i]`
    /// marks experts served by the big-little fallback: their weights go
    /// through a quantize→dequantize roundtrip at the little tier before
    /// upload, so the quality effect of a degraded execution is real.
    /// Degraded entries memoize under ids offset by `n_experts`, so a
    /// full-precision dispatch of the same routed set never aliases a
    /// degraded one.
    fn stacked_buffers(
        &self,
        memo: &std::cell::RefCell<BufMap>,
        hits: &std::cell::Cell<u64>,
        layer: usize,
        idx: &[usize],
        degraded: &[bool],
    ) -> Result<std::rc::Rc<StackedBufs>> {
        let key_ids: Vec<usize> = idx
            .iter()
            .zip(degraded)
            .map(|(&e, &dg)| if dg { e + self.cfg.n_experts } else { e })
            .collect();
        let key = (layer, key_ids);
        if let Some(hit) = memo.borrow().get(&key) {
            hits.set(hits.get() + 1);
            return Ok(hit.clone());
        }
        let st = self.weights.stack_experts(layer, idx, self.cfg.d_model, self.cfg.d_ff)?;
        let (k, d, dff) = (idx.len(), self.cfg.d_model, self.cfg.d_ff);
        let mut wg = st.wg.to_vec::<f32>()?;
        let mut wu = st.wu.to_vec::<f32>()?;
        let mut wd = st.wd.to_vec::<f32>()?;
        if let Some(lt) = self.policy.little_tier {
            let per = d * dff; // elements per expert in each stacked matrix
            for (i, &dg) in degraded.iter().enumerate() {
                if !dg {
                    continue;
                }
                for w in [&mut wg, &mut wu, &mut wd] {
                    let s = &mut w[i * per..(i + 1) * per];
                    let rt = dequantize(&quantize(s, lt));
                    s.copy_from_slice(&rt);
                }
            }
        }
        let bufs = std::rc::Rc::new(StackedBufs {
            wg: self.rt.to_device(&wg, &[k, dff, d])?,
            wu: self.rt.to_device(&wu, &[k, dff, d])?,
            wd: self.rt.to_device(&wd, &[k, d, dff])?,
        });
        let mut cache = memo.borrow_mut();
        if cache.len() >= BUF_CACHE_CAP {
            cache.clear();
        }
        cache.insert(key, bufs.clone());
        Ok(bufs)
    }

    /// Execute the routed experts via the fastest available path.
    /// The `expert_group` executable has a static top-K parameter shape;
    /// a sparsity-reduced selection (FLoE) is padded with zero-gate
    /// duplicates — the kernel is linear in the gates, so padding is
    /// exact (validated by `test_moe_ffn_zero_gates`).
    fn run_experts(
        &self,
        memo: &std::cell::RefCell<BufMap>,
        hits: &std::cell::Cell<u64>,
        layer: usize,
        idx: &[usize],
        gates: &[f32],
        degraded: &[bool],
        h2: &xla::Literal,
    ) -> Result<Vec<f32>> {
        let (mut idx_p, mut gates_p, mut deg_p);
        let (idx, gates, degraded) = if idx.len() < self.cfg.top_k {
            idx_p = idx.to_vec();
            gates_p = gates.to_vec();
            deg_p = degraded.to_vec();
            while idx_p.len() < self.cfg.top_k {
                idx_p.push(idx[0]);
                gates_p.push(0.0);
                deg_p.push(degraded[0]);
            }
            (&idx_p[..], &gates_p[..], &deg_p[..])
        } else {
            (idx, gates, degraded)
        };
        // degraded selections always take the buffered path: the
        // quantize→dequantize roundtrip happens on the host copy before
        // upload, which the literal-direct path has no hook for
        if self.use_buffers || degraded.iter().any(|&d| d) {
            let bufs = self.stacked_buffers(memo, hits, layer, idx, degraded)?;
            self.rt.expert_group_b(gates, h2, &bufs.wg, &bufs.wu, &bufs.wd)
        } else {
            let st = self.weights.stack_experts(layer, idx, self.cfg.d_model, self.cfg.d_ff)?;
            self.rt.expert_group(gates, h2, &st.wg, &st.wu, &st.wd)
        }
    }

    pub fn with_predictor(mut self, p: &'a PredictorWeights) -> Self {
        self.predictor = Some(p);
        self
    }

    pub fn with_profile(mut self, p: &'a RoutingProfile) -> Self {
        self.profile = Some(p);
        self
    }

    fn effective_capacity(&self) -> usize {
        self.policy.effective_capacity(self.cfg.n_experts)
    }

    fn new_cache(&self) -> ExpertCache {
        let caps = self.policy.effective_layer_capacities(self.cfg.n_layers, self.cfg.n_experts);
        let mut cache =
            ExpertCache::with_capacities(self.cfg.n_experts, &caps, self.policy.eviction);
        cache.set_tiers(self.policy.quant, self.policy.little_tier);
        cache
    }

    fn prefetch_plan(&self, prompts: &[Vec<usize>]) -> Result<PrefetchPlan> {
        // uniform upper bound; per-layer prefill truncates to each layer's
        // actual slot count
        let cap = self.effective_capacity();
        match self.policy.prefetch {
            Prefetch::None => Ok(PrefetchPlan::empty(self.cfg.n_layers)),
            Prefetch::Predictor => {
                let pw = self
                    .predictor
                    .ok_or_else(|| anyhow::anyhow!("policy wants predictor weights"))?;
                if prompts.len() == 1 {
                    predict_plan(self.rt, pw, self.cfg, &self.weights.embed, &prompts[0], cap)
                } else {
                    predict_plan_batch(self.rt, pw, self.cfg, &self.weights.embed, prompts, cap)
                }
            }
            Prefetch::Profile => {
                let pr =
                    self.profile.ok_or_else(|| anyhow::anyhow!("policy wants a routing profile"))?;
                Ok(profile_plan(pr, self.cfg, cap))
            }
            // lookahead's admit-time plan comes from whatever source the
            // engine carries; with neither, the per-step pipeline still
            // runs off the session's observed activation counts
            Prefetch::Lookahead { .. } => {
                if let Some(pw) = self.predictor {
                    if prompts.len() == 1 {
                        predict_plan(self.rt, pw, self.cfg, &self.weights.embed, &prompts[0], cap)
                    } else {
                        predict_plan_batch(self.rt, pw, self.cfg, &self.weights.embed, prompts, cap)
                    }
                } else if let Some(pr) = self.profile {
                    Ok(profile_plan(pr, self.cfg, cap))
                } else {
                    Ok(PrefetchPlan::empty(self.cfg.n_layers))
                }
            }
        }
    }

    /// Select experts for one token at one layer, applying FLoE sparsity.
    /// Returns (expert, gate) pairs and the skip count.
    fn select(
        &self,
        probs: &crate::tensor::HostTensor,
        cache: &ExpertCache,
        layer: usize,
    ) -> (Vec<(usize, f32)>, u64) {
        let idx = probs.topk(self.cfg.top_k);
        let mut skips = 0;
        let tau = self.policy.sparsity_tau;
        let mut sel: Vec<(usize, f32)> = Vec::with_capacity(idx.len());
        let total: f32 = idx.iter().map(|&e| probs.data[e]).sum();
        for &e in &idx {
            let g = probs.data[e];
            if tau > 0.0 && g < tau && !cache.layers[layer].contains(e) {
                skips += 1;
                continue;
            }
            sel.push((e, g));
        }
        if skips > 0 && !sel.is_empty() {
            // renormalize surviving gates to the original top-K mass
            let kept: f32 = sel.iter().map(|(_, g)| g).sum();
            if kept > 0.0 {
                let scale = total / kept;
                for s in &mut sel {
                    s.1 *= scale;
                }
            }
        }
        (sel, skips)
    }

    /// Resolve residency for one token's selected experts at one layer
    /// and advance the clock on misses.  A miss first consults the
    /// in-flight transfer pipeline: an expert whose lookahead prefetch is
    /// already on the link pays only the *residual* wait and lands via
    /// the cache's commit path, instead of re-paying the full transfer.
    /// `pinned` is the whole chunk's union expert set at this layer, so
    /// resolving one chunk token can never evict an expert another chunk
    /// token executes.
    ///
    /// Returns the experts this token will execute *degraded* from their
    /// little-tier copies (empty unless the big-little fallback fires).
    fn resolve_residency(
        &self,
        layer: usize,
        selected: &[(usize, f32)],
        pinned: &[usize],
        ctx: &mut StepCtx,
    ) -> Vec<usize> {
        let quant = self.policy.quant;
        let tier = quant.idx() as u8;
        let l32 = layer as u32;
        let mut degraded = Vec::new();
        for &(e, _) in selected {
            let hit = ctx.cache.layer(layer).request(e);
            if hit {
                continue;
            }
            // big-little fallback: a miss whose little-tier copy is
            // resident may execute degraded at zero stall when the
            // expected wait on the full-tier transfer (residual of an
            // in-flight prefetch, else a cold demand estimate) exceeds
            // the policy threshold.  The big copy is *not* installed —
            // an in-flight transfer keeps draining and lands normally.
            if let Some(lt) = self.policy.little_tier {
                if ctx.cache.layers[layer].has_little(e) {
                    let now = ctx.clock.now();
                    let wait = ctx
                        .pcie
                        .residual_of(layer, e, now)
                        .unwrap_or_else(|| ctx.pcie.demand_estimate(&self.cost, now, quant));
                    if wait > self.policy.fallback_threshold {
                        *ctx.degraded_execs += 1;
                        ctx.rec.emit(
                            now,
                            TraceEvent::DegradedExec {
                                layer: l32,
                                expert: e as u32,
                                tier: lt.idx() as u8,
                            },
                        );
                        degraded.push(e);
                        continue;
                    }
                }
            }
            let snap = PcieSnap::of(&ctx.pcie.stats);
            if ctx.pcie.wait_for(layer, e, ctx.clock).is_some() {
                // the claim consumed the transfer's one stall-free use;
                // commit lands it whenever the pin set allows
                let t = ctx.clock.now();
                ctx.rec.emit(
                    t,
                    TraceEvent::DemandStall {
                        layer: l32,
                        expert: e as u32,
                        tier,
                        residual: true,
                        delta: snap.delta(&ctx.pcie.stats),
                    },
                );
                let out =
                    ctx.pcie.commit_arrival(ctx.cache.layer(layer), &self.cost, quant, e, pinned);
                ctx.rec.emit(t, TraceEvent::TransferLanded { layer: l32, expert: e as u32, tier });
                if out.loaded {
                    ctx.rec.emit(t, TraceEvent::CacheInsert { layer: l32, expert: e as u32 });
                    if let Some(v) = out.evicted {
                        ctx.rec.emit(t, TraceEvent::CacheEvict { layer: l32, expert: v as u32 });
                    }
                } else if !out.resident {
                    ctx.rec.emit(t, TraceEvent::PinProtected { layer: l32, expert: e as u32 });
                }
                continue;
            }
            if self.policy.cpu_compute {
                // Fiddler: run on CPU when cheaper than transfer + GPU
                // exec; the GPU path pays the current link-queue wait
                // before its own transfer, so a congested link correctly
                // favors CPU compute
                let cpu_t = self.cost.cpu_expert_time(1);
                let gpu_t = ctx.pcie.link_wait(ctx.clock.now())
                    + self.cost.transfer_time(quant)
                    + self.cost.expert_exec_time(1, 1, quant);
                if cpu_t < gpu_t {
                    ctx.clock.advance(cpu_t);
                    *ctx.cpu_execs += 1;
                    continue; // no residency change
                }
            }
            ctx.pcie.demand_h2d(&self.cost, ctx.clock, quant);
            let t = ctx.clock.now();
            ctx.rec.emit(
                t,
                TraceEvent::DemandStall {
                    layer: l32,
                    expert: e as u32,
                    tier,
                    residual: false,
                    delta: snap.delta(&ctx.pcie.stats),
                },
            );
            let evicted = ctx.cache.layer(layer).insert(e, pinned);
            if evicted.is_some() {
                ctx.pcie.evict_d2h(&self.cost, quant);
            }
            if ctx.rec.enabled() {
                if ctx.cache.layers[layer].contains(e) {
                    ctx.rec.emit(t, TraceEvent::CacheInsert { layer: l32, expert: e as u32 });
                    if let Some(v) = evicted {
                        ctx.rec.emit(t, TraceEvent::CacheEvict { layer: l32, expert: v as u32 });
                    }
                } else {
                    ctx.rec.emit(t, TraceEvent::PinProtected { layer: l32, expert: e as u32 });
                }
            }
        }
        degraded
    }

    /// Land every lookahead transfer that has completed by now
    /// (`TransferEngine::commit_arrival`).  Entries for the layer being
    /// resolved commit under the chunk-union pin set, so an arriving
    /// prefetch can never evict an expert the current chunk executes;
    /// an arrival that cannot commit (every resident pinned) stays in
    /// staging, claimable at zero residual, instead of being re-paid as
    /// a demand fetch.
    fn land_arrived(&self, layer: usize, pinned: &[usize], ctx: &mut StepCtx) {
        let now = ctx.clock.now();
        let quant = self.policy.quant;
        let tier = quant.idx() as u8;
        for (tl, te) in ctx.pcie.drain_arrived(now) {
            let pin: &[usize] = if tl == layer { pinned } else { &[] };
            let out = ctx.pcie.commit_arrival(ctx.cache.layer(tl), &self.cost, quant, te, pin);
            if out.resident {
                // the in-flight entry is consumed: the transfer landed
                ctx.rec.emit(
                    now,
                    TraceEvent::TransferLanded { layer: tl as u32, expert: te as u32, tier },
                );
                if out.loaded {
                    ctx.rec.emit(
                        now,
                        TraceEvent::CacheInsert { layer: tl as u32, expert: te as u32 },
                    );
                    if let Some(v) = out.evicted {
                        ctx.rec.emit(
                            now,
                            TraceEvent::CacheEvict { layer: tl as u32, expert: v as u32 },
                        );
                    }
                }
            } else {
                // every resident pinned: the arrival re-stages (still in
                // flight, claimable at zero residual) — not landed yet
                ctx.rec
                    .emit(now, TraceEvent::PinProtected { layer: tl as u32, expert: te as u32 });
                ctx.pcie.track_landed(tl, te, now);
            }
        }
    }

    /// Layer-ahead prefetch: issue non-blocking transfers for the next
    /// `depth` layers' predicted experts (`predict_next_layer` over the
    /// sequence's admit-time plan, the session's observed activation
    /// counts, and layer ℓ's actual selections), before this layer's
    /// expert execution so the transfers hide behind its compute.
    /// Resident and already-in-flight experts are skipped; the cache's
    /// reservation bound caps the in-flight set at the layer's slot
    /// count.
    fn issue_lookahead(
        &self,
        st: &SeqState,
        layer: usize,
        depth: usize,
        cur_union: &[usize],
        ctx: &mut StepCtx,
    ) {
        for d in 1..=depth {
            let nl = layer + d;
            if nl >= self.cfg.n_layers {
                break;
            }
            let cap = ctx.cache.layers[nl].capacity();
            for e in predict_next_layer(&st.plan, &ctx.trace.counts, cur_union, nl, cap) {
                if ctx.cache.layers[nl].contains(e) || ctx.pcie.in_flight_contains(nl, e) {
                    continue;
                }
                if !ctx.cache.layer(nl).reserve(e) {
                    break; // reservations saturated this layer
                }
                let snap = PcieSnap::of(&ctx.pcie.stats);
                ctx.pcie.prefetch_expert(&self.cost, ctx.clock, nl, e, self.policy.quant);
                ctx.rec.emit(
                    ctx.clock.now(),
                    TraceEvent::PrefetchIssued {
                        layer: nl as u32,
                        expert: e as u32,
                        tier: self.policy.quant.idx() as u8,
                        delta: snap.delta(&ctx.pcie.stats),
                    },
                );
            }
        }
    }

    /// One forward step for one sequence, covering `tokens` — a single
    /// decode token, or a chunked-prefill slice of the prompt.  The chunk
    /// runs layer-major: every chunk token advances through layer ℓ (KV
    /// appended in order, so the numerics match token-at-a-time decoding
    /// exactly) before the chunk moves to layer ℓ+1, which lets residency
    /// resolve under the chunk's union expert set and the cost model
    /// charge the union's weight streaming once per layer.
    ///
    /// `step_tokens` is the total number of tokens the whole live batch
    /// consumes this step: fixed per-step costs (kernel dispatch,
    /// attention/head weight reads, expert weight streaming) amortize
    /// across it, per-token MXU compute and demand transfers do not.
    /// With single-token slices and `step_tokens` = live batch size this
    /// reduces exactly to the pre-chunking decode step.  Returns the last
    /// token's logits (when requested) and per-token per-layer selections.
    fn step_chunk(
        &self,
        st: &mut SeqState,
        tokens: &[usize],
        step_tokens: usize,
        ctx: &mut StepCtx,
        want_logits: bool,
    ) -> Result<(Option<crate::tensor::HostTensor>, Vec<Vec<Vec<usize>>>)> {
        let c = tokens.len();
        debug_assert!(c >= 1, "a step consumes at least one token");
        let t = step_tokens.max(1);
        let tf = t as f64;
        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&tok| self.weights.embed.row(tok.min(self.cfg.vocab_size - 1)).to_vec())
            .collect();
        let mut sel_tokens: Vec<Vec<Vec<usize>>> = vec![Vec::with_capacity(self.cfg.n_layers); c];
        for l in 0..self.cfg.n_layers {
            // chunk forward at this layer, in token order: each token's
            // attention sees every earlier chunk token's freshly written KV
            let mut outs = Vec::with_capacity(c);
            for (i, x) in xs.iter().enumerate() {
                let out = self.rt.layer_step(
                    x,
                    &self.weights.layers[l],
                    &st.k_caches[l],
                    &st.v_caches[l],
                    st.pos + i,
                )?;
                st.k_caches[l] = out.k_cache;
                st.v_caches[l] = out.v_cache;
                // one token's share of the step's batched attention cost
                ctx.clock.advance(self.cost.attn_time(t) / tf);
                outs.push((out.probs, out.h2, out.h_res));
            }
            // per-token routing; accumulate the chunk's union working set
            let mut selections: Vec<Vec<(usize, f32)>> = Vec::with_capacity(c);
            let mut union: Vec<usize> = Vec::new();
            let mut assignments = 0usize;
            for (i, (probs, _, _)) in outs.iter().enumerate() {
                let (sel, s) = self.select(probs, ctx.cache, l);
                *ctx.sparsity_skips += s;
                for &(e, _) in &sel {
                    ctx.trace.counts[l][e] += 1;
                    assignments += 1;
                    *ctx.total_assignments += 1;
                    if !union.contains(&e) {
                        union.push(e);
                    }
                }
                sel_tokens[i].push(sel.iter().map(|(e, _)| *e).collect());
                selections.push(sel);
            }
            // land lookahead transfers that arrived during earlier
            // layers' compute — committed residency turns would-be
            // misses into hits below
            self.land_arrived(l, &union, ctx);
            // residency: each token resolves against the cache with the
            // chunk union pinned — a miss transfers once (an in-flight
            // prefetch pays only its residual), later chunk tokens hit,
            // and nothing the chunk executes can be evicted.  Tokens the
            // big-little fallback serves degraded come back per token so
            // the exec below uses the roundtripped little-tier weights.
            let mut degraded_tok: Vec<Vec<usize>> = Vec::with_capacity(c);
            for sel in &selections {
                degraded_tok.push(self.resolve_residency(l, sel, &union, ctx));
            }
            // layer-ahead pipeline: issue the next layers' predicted
            // experts now, so the transfers overlap this layer's
            // execution below
            let depth = self.policy.prefetch.lookahead_depth();
            if depth > 0 {
                self.issue_lookahead(st, l, depth, &union, ctx);
            }
            // execute: real numerics per token; the union's weights
            // stream once per layer in the cost model (chunk_exec_time)
            for (i, (_, h2, h_res)) in outs.into_iter().enumerate() {
                let sel = &selections[i];
                if sel.is_empty() {
                    xs[i] = h_res;
                } else {
                    let idx: Vec<usize> = sel.iter().map(|(e, _)| *e).collect();
                    let gates: Vec<f32> = sel.iter().map(|(_, g)| *g).collect();
                    let dg: Vec<bool> = idx.iter().map(|e| degraded_tok[i].contains(e)).collect();
                    let y = self.run_experts(ctx.bufs, ctx.buf_hits, l, &idx, &gates, &dg, &h2)?;
                    xs[i] = add(&h_res, &y);
                }
            }
            if !union.is_empty() {
                ctx.clock.advance(self.cost.chunk_exec_time(
                    union.len(),
                    assignments,
                    t,
                    self.policy.quant,
                ));
            }
        }
        st.pos += c;
        if want_logits {
            ctx.clock.advance(self.cost.head_time(t) / tf);
            let last = xs.last().expect("chunk has at least one token");
            let logits = self.rt.lm_head(last, &self.weights.lnf_lit, &self.weights.embed_lit)?;
            Ok((Some(logits), sel_tokens))
        } else {
            Ok((None, sel_tokens))
        }
    }

    fn new_seq(
        &self,
        id: u64,
        prompt: &[usize],
        max_output: usize,
        plan: PrefetchPlan,
        now: f64,
    ) -> Result<SeqState> {
        let mut k_caches = Vec::with_capacity(self.cfg.n_layers);
        let mut v_caches = Vec::with_capacity(self.cfg.n_layers);
        for _ in 0..self.cfg.n_layers {
            let (k, v) = self.rt.init_kv(self.cfg)?;
            k_caches.push(k);
            v_caches.push(v);
        }
        Ok(SeqState {
            id,
            k_caches,
            v_caches,
            pos: 0,
            prompt: prompt.to_vec(),
            max_output,
            tokens: Vec::new(),
            plan,
            sim_admitted: now,
            sim_first_token: now,
        })
    }

    /// Start an empty decode session (prefill chunk 1 — token-at-a-time;
    /// see [`DecodeSession::set_prefill_chunk`]).
    pub fn session(&self) -> DecodeSession {
        DecodeSession {
            clock: SimClock::new(),
            cache: self.new_cache(),
            pcie: TransferEngine::new(),
            trace: ActivationTrace::new(self.cfg.n_layers, self.cfg.n_experts),
            cpu_execs: 0,
            sparsity_skips: 0,
            degraded_execs: 0,
            total_assignments: 0,
            seqs: Vec::new(),
            next_id: 0,
            prefill_chunk: 1,
            buf_cache: std::cell::RefCell::new(BufMap::new()),
            buf_hits: std::cell::Cell::new(0),
            rec: Recorder::off(),
        }
    }

    /// Attach-time plan refresh, shared by [`Engine::admit`] and
    /// [`Engine::resume`]: register `owner`'s planned hot set in the
    /// scheduler-owned pin ledger (so bulk admissions and lookahead
    /// commits can never evict it while the sequence is live), rebuild
    /// the union prefetch plan of the *live* in-flight set plus `plan`
    /// (in-flight plans first, so established residents win capacity
    /// ties), and top the cache up additively with tracked non-blocking
    /// transfers.
    fn attach_plan(&self, sess: &mut DecodeSession, owner: u64, plan: &PrefetchPlan) {
        sess.cache.pin_set(owner, &plan.per_layer);
        sess.rec.emit(sess.clock.now(), TraceEvent::PinSet { owner });
        // refresh the little store before the big-tier top-up: the
        // fallback works under any prefetch policy, including None
        self.install_little_set(sess);
        if self.policy.prefetch == Prefetch::None {
            return;
        }
        let caps = self.policy.effective_layer_capacities(self.cfg.n_layers, self.cfg.n_experts);
        let mut plans: Vec<&PrefetchPlan> = sess.seqs.iter().map(|s| &s.plan).collect();
        plans.push(plan);
        let union = PrefetchPlan::union_capped(&plans, &caps);
        sess.clock.advance(self.cost.predictor_time());
        for (l, set) in union.per_layer.iter().enumerate() {
            if set.is_empty() {
                continue;
            }
            // a non-resident expert whose lookahead transfer is
            // already on the link arrives via the tracked pipeline —
            // re-issuing it here would double-pay the transfer.
            // (Resident in-flight experts stay in the target: the
            // union protects them from eviction and never re-loads
            // residents.)
            let want: Vec<usize> = set
                .iter()
                .copied()
                .filter(|&e| {
                    sess.cache.layers[l].contains(e) || !sess.pcie.in_flight_contains(l, e)
                })
                .collect();
            // tracked issue: residency is immediate (prefill_union
            // above), but the link entry keeps the stall/overlap
            // split exact and lets an evicted-then-remissed expert
            // catch its own transfer at the residual
            let out = sess.cache.layer(l).prefill_union(&want);
            let t = sess.clock.now();
            for &v in &out.evicted {
                sess.rec.emit(t, TraceEvent::CacheEvict { layer: l as u32, expert: v as u32 });
            }
            for e in out.loaded {
                let snap = PcieSnap::of(&sess.pcie.stats);
                sess.pcie.prefetch_expert(&self.cost, &sess.clock, l, e, self.policy.quant);
                sess.rec.emit(
                    t,
                    TraceEvent::PrefetchIssued {
                        layer: l as u32,
                        expert: e as u32,
                        tier: self.policy.quant.idx() as u8,
                        delta: snap.delta(&sess.pcie.stats),
                    },
                );
                sess.rec.emit(t, TraceEvent::CacheInsert { layer: l as u32, expert: e as u32 });
            }
        }
        // No sync barrier: prefetch transfers overlap compute
        // (non-blocking, pinned memory — §3.2).  Early demand misses
        // naturally serialize behind the in-flight prefetch traffic
        // via the link-occupancy model in `pcie`.
    }

    /// Refresh the little store: per layer, rank experts by the session's
    /// observed activation counts (the predictor's signal accumulates
    /// there) and install little-tier copies of the hottest ones — up to
    /// the store's carved capacity, skipping big residents.  Installs
    /// ride the untracked [`TransferEngine::prefetch_h2d`] path at the
    /// little tier and emit [`TraceEvent::LittleInstall`] carrying the
    /// byte delta, so `Trace::reconcile` balances.  A displaced little
    /// copy is simply dropped (no D2H: little copies are derived,
    /// read-only data) and emits [`TraceEvent::LittleEvict`].
    fn install_little_set(&self, sess: &mut DecodeSession) {
        let Some(lt) = self.policy.little_tier else {
            return;
        };
        for l in 0..self.cfg.n_layers {
            let cap = sess.cache.layers[l].little_capacity();
            if cap == 0 {
                continue;
            }
            let mut ranked: Vec<usize> = (0..self.cfg.n_experts).collect();
            ranked.sort_by_key(|&e| std::cmp::Reverse(sess.trace.counts[l][e]));
            // big residents never need a little copy — filter before
            // taking, so the store fills with the hottest *eligible* set
            ranked.retain(|&e| !sess.cache.layers[l].contains(e));
            ranked.truncate(cap);
            for e in ranked {
                if sess.cache.layers[l].has_little(e) {
                    continue;
                }
                let snap = PcieSnap::of(&sess.pcie.stats);
                sess.pcie.prefetch_h2d(&self.cost, &sess.clock, lt);
                let t = sess.clock.now();
                if let Some(evicted) = sess.cache.layer(l).install_little(e) {
                    sess.rec.emit(
                        t,
                        TraceEvent::LittleInstall {
                            layer: l as u32,
                            expert: e as u32,
                            tier: lt.idx() as u8,
                            delta: snap.delta(&sess.pcie.stats),
                        },
                    );
                    if let Some(v) = evicted {
                        sess.rec.emit(
                            t,
                            TraceEvent::LittleEvict { layer: l as u32, expert: v as u32 },
                        );
                    }
                }
            }
        }
    }

    /// Admit one sequence into the session — mid-flight admission is the
    /// continuous-batching case.  Allocates KV caches, pins the planned
    /// hot set in the cache's scheduler ledger, rebuilds the union
    /// prefetch plan of the *live* in-flight set plus the newcomer
    /// (retired sequences no longer influence the plan), and tops
    /// the cache up additively — a refresh never drops the planned
    /// working set, and warm residents outside it are evicted only under
    /// capacity pressure, in normal policy order.
    ///
    /// The per-request plan is predicted *once* here, from the whole
    /// prompt, and reused across every prefill chunk the sequence
    /// consumes — chunked prefill never re-runs the predictor per chunk
    /// (and [`Engine::resume`] reuses it too, never re-predicting).
    pub fn admit(
        &self,
        sess: &mut DecodeSession,
        prompt: &[usize],
        max_output: usize,
    ) -> Result<u64> {
        anyhow::ensure!(!prompt.is_empty(), "cannot admit an empty prompt");
        let mut incoming = PrefetchPlan::empty(self.cfg.n_layers);
        if self.policy.prefetch != Prefetch::None {
            incoming = self.prefetch_plan(std::slice::from_ref(&prompt.to_vec()))?;
        }
        let id = sess.next_id;
        sess.next_id += 1;
        // allocate the fallible state *before* attach_plan's side effects
        // (ledger pins, clock advance, issued transfers): a failed KV
        // allocation must not leak pins for a sequence that never existed
        let mut seq = self.new_seq(id, prompt, max_output, incoming, sess.clock.now())?;
        sess.rec.emit(sess.clock.now(), TraceEvent::RequestAdmit { seq: id });
        self.attach_plan(sess, id, &seq.plan);
        seq.sim_admitted = sess.clock.now();
        seq.sim_first_token = seq.sim_admitted;
        sess.seqs.push(seq);
        Ok(id)
    }

    /// Detach an in-flight sequence from its decode slot (priority
    /// preemption).  The returned [`SeqState`] owns everything the
    /// sequence needs to continue — token buffer, per-layer KV handles,
    /// prompt cursor (mid-prefill progress included), memoized prefetch
    /// plan, timeline marks — so a later [`Engine::resume`] continues
    /// bit-identically.  The sequence's pin-ledger entries release
    /// immediately: a suspended sequence no longer protects its warm set.
    pub fn suspend(&self, sess: &mut DecodeSession, seq: u64) -> Result<SeqState> {
        let i = sess
            .seqs
            .iter()
            .position(|s| s.id == seq)
            .ok_or_else(|| anyhow::anyhow!("sequence {seq} is not in flight"))?;
        sess.cache.release(seq);
        let now = sess.clock.now();
        sess.rec.emit(now, TraceEvent::Suspend { seq });
        sess.rec.emit(now, TraceEvent::PinRelease { owner: seq });
        Ok(sess.seqs.remove(i))
    }

    /// Cancel an in-flight sequence: the one-way version of
    /// [`Engine::suspend`].  The slot frees and the pin-ledger entries
    /// release immediately — same reclaim path as suspension — but the
    /// detached state is returned only so the caller can harvest the
    /// tokens produced so far; it is never resumed.  Emits
    /// [`TraceEvent::Cancel`] + [`TraceEvent::PinRelease`] so the pin
    /// conservation audit proves a cancelled sequence leaks nothing.
    pub fn cancel(&self, sess: &mut DecodeSession, seq: u64) -> Result<SeqState> {
        let i = sess
            .seqs
            .iter()
            .position(|s| s.id == seq)
            .ok_or_else(|| anyhow::anyhow!("sequence {seq} is not in flight"))?;
        sess.cache.release(seq);
        let now = sess.clock.now();
        sess.rec.emit(now, TraceEvent::Cancel { seq });
        sess.rec.emit(now, TraceEvent::PinRelease { owner: seq });
        Ok(sess.seqs.remove(i))
    }

    /// Reattach a sequence detached by [`Engine::suspend`], keeping its
    /// original handle.  The admit-time machinery is rebuilt from the
    /// sequence's *memoized* plan — the union prefetch plan refreshes
    /// over the live set, the pin ledger re-registers the hot set, and
    /// the cache tops up additively — but the predictor itself never
    /// re-runs.  Decoded tokens are bit-identical to an uninterrupted
    /// run: suspension reshapes residency timing only, never numerics.
    pub fn resume(&self, sess: &mut DecodeSession, st: SeqState) -> Result<u64> {
        anyhow::ensure!(
            sess.seqs.iter().all(|s| s.id != st.id),
            "sequence {} is already in flight",
            st.id
        );
        let id = st.id;
        sess.rec.emit(sess.clock.now(), TraceEvent::Resume { seq: id });
        self.attach_plan(sess, id, &st.plan);
        sess.seqs.push(st);
        Ok(id)
    }

    /// Advance every in-flight sequence one step: decodes emit exactly
    /// one token; sequences still in prefill consume up to the session's
    /// [`DecodeSession::prefill_chunk`] prompt tokens (the chunk covering
    /// the last prompt token also emits the first output token).  The
    /// cost model's per-step amortization uses the *total tokens the
    /// step consumes* across the live batch — decodes piggyback on a
    /// prefill chunk's weight reads and vice versa — and changes as
    /// sequences retire.  Sequences that hit EOS or their budget retire
    /// immediately — their slots (and their share of the batch's compute
    /// and cache traffic) free before the next step.
    pub fn step(&self, sess: &mut DecodeSession) -> Result<Vec<SeqFinish>> {
        let batch = sess.seqs.len();
        if batch == 0 {
            return Ok(Vec::new());
        }
        // layer-ahead prediction machinery: one consult per step covers
        // every in-flight sequence's next-layer candidate sets
        if self.policy.prefetch.lookahead_depth() > 0 {
            sess.clock.advance(self.cost.predictor_time());
        }
        let chunk = sess.prefill_chunk.max(1);
        // per-sequence token counts this step: prefills take a chunk
        // (clamped to the prompt boundary), decodes exactly one
        let counts: Vec<usize> = sess
            .seqs
            .iter()
            .map(|st| {
                let left = st.prompt.len().saturating_sub(st.pos);
                if left > 0 {
                    chunk.min(left)
                } else {
                    1
                }
            })
            .collect();
        let step_tokens: usize = counts.iter().sum();
        sess.rec.emit(
            sess.clock.now(),
            TraceEvent::StepStart { tokens: step_tokens as u32, batch: batch as u32 },
        );
        let mut single_sel: Option<Vec<Vec<Vec<usize>>>> = None;
        for i in 0..batch {
            let (tokens, want) = {
                let st = &sess.seqs[i];
                if st.pos < st.prompt.len() {
                    let c = counts[i];
                    sess.rec.emit(
                        sess.clock.now(),
                        TraceEvent::PrefillChunk { seq: st.id, tokens: c as u32 },
                    );
                    (st.prompt[st.pos..st.pos + c].to_vec(), st.pos + c >= st.prompt.len())
                } else {
                    let last =
                        *st.tokens.last().expect("active sequence past its prompt has tokens");
                    (vec![last], true)
                }
            };
            let mut ctx = StepCtx {
                cache: &mut sess.cache,
                pcie: &mut sess.pcie,
                clock: &mut sess.clock,
                trace: &mut sess.trace,
                cpu_execs: &mut sess.cpu_execs,
                sparsity_skips: &mut sess.sparsity_skips,
                degraded_execs: &mut sess.degraded_execs,
                total_assignments: &mut sess.total_assignments,
                bufs: &sess.buf_cache,
                buf_hits: &sess.buf_hits,
                rec: &mut sess.rec,
            };
            let (logits, sel) =
                self.step_chunk(&mut sess.seqs[i], &tokens, step_tokens, &mut ctx, want)?;
            if batch == 1 {
                single_sel = Some(sel);
            }
            if want {
                let next = logits.expect("logits requested").argmax();
                let now = sess.clock.now();
                let st = &mut sess.seqs[i];
                if st.tokens.is_empty() {
                    st.sim_first_token = now;
                }
                if st.max_output > 0 {
                    st.tokens.push(next);
                }
            }
        }
        sess.cache.token_tick();
        if let Some(sel) = single_sel {
            // per-token entries keep the Fig. 7–10 trace shape identical
            // across chunk sizes
            sess.trace.steps.extend(sel);
        }
        // retire sequences that hit EOS or their budget; a retiring
        // sequence's pin-ledger entries release with its slot
        let now = sess.clock.now();
        sess.rec
            .emit(now, TraceEvent::StepEnd { tokens: step_tokens as u32, batch: batch as u32 });
        let ignore_eos = self.ignore_eos;
        let mut finished = Vec::new();
        let mut keep = Vec::with_capacity(batch);
        for st in sess.seqs.drain(..) {
            let done = st.pos >= st.prompt.len()
                && (st.tokens.len() >= st.max_output
                    || (!ignore_eos && st.tokens.last() == Some(&EOS)));
            if done {
                finished.push(SeqFinish {
                    seq: st.id,
                    tokens: st.tokens,
                    sim_admitted: st.sim_admitted,
                    sim_first_token: st.sim_first_token,
                    sim_finished: now,
                });
            } else {
                keep.push(st);
            }
        }
        sess.seqs = keep;
        for fin in &finished {
            sess.cache.release(fin.seq);
            sess.rec.emit(
                now,
                TraceEvent::RequestRetire {
                    seq: fin.seq,
                    output_tokens: fin.tokens.len() as u32,
                },
            );
            sess.rec.emit(now, TraceEvent::PinRelease { owner: fin.seq });
        }
        Ok(finished)
    }

    /// Greedy-decode one request (run-to-completion wrapper over a
    /// single-sequence session).
    pub fn decode(&self, prompt: &[usize], max_output: usize) -> Result<DecodeOutput> {
        let wall = Instant::now();
        let mut sess = self.session();
        self.admit(&mut sess, prompt, max_output)?;
        let mut fin = None;
        while sess.active() > 0 {
            if let Some(f) = self.step(&mut sess)?.pop() {
                fin = Some(f);
            }
        }
        let fin = fin.expect("admitted sequence must retire");
        let metrics = RequestMetrics {
            prompt_tokens: prompt.len(),
            output_tokens: fin.tokens.len(),
            sim_seconds: sess.clock.now(),
            sim_ttft: fin.sim_first_token,
            wall_seconds: wall.elapsed().as_secs_f64(),
        };
        let mut report = sess.report_base();
        report.requests = vec![metrics.clone()];
        report.wall_seconds = metrics.wall_seconds;
        Ok(DecodeOutput {
            tokens: fin.tokens,
            metrics,
            report,
            trace: sess.trace,
            cpu_execs: sess.cpu_execs,
            sparsity_skips: sess.sparsity_skips,
        })
    }

    /// Teacher-forced pass over `tokens`: returns per-position NLLs of
    /// tokens[1..] (perplexity measurements, Tables 4 / Fig. 4).
    pub fn teacher_forced_nll(&self, tokens: &[usize]) -> Result<Vec<f64>> {
        let mut clock = SimClock::new();
        let mut cache = self.new_cache();
        let mut pcie = TransferEngine::new();
        let mut trace = ActivationTrace::new(self.cfg.n_layers, self.cfg.n_experts);
        let (mut cpu, mut skips) = (0u64, 0u64);
        let (mut deg, mut assigns) = (0u64, 0u64);
        let bufs = std::cell::RefCell::new(BufMap::new());
        let buf_hits = std::cell::Cell::new(0u64);
        let mut rec = Recorder::off();
        let mut st = self.new_seq(0, tokens, 0, PrefetchPlan::empty(self.cfg.n_layers), 0.0)?;
        let mut nlls = Vec::with_capacity(tokens.len().saturating_sub(1));
        for (i, &t) in tokens.iter().enumerate() {
            let want = i + 1 < tokens.len();
            let mut ctx = StepCtx {
                cache: &mut cache,
                pcie: &mut pcie,
                clock: &mut clock,
                trace: &mut trace,
                cpu_execs: &mut cpu,
                sparsity_skips: &mut skips,
                degraded_execs: &mut deg,
                total_assignments: &mut assigns,
                bufs: &bufs,
                buf_hits: &buf_hits,
                rec: &mut rec,
            };
            let (lg, _sel) = self.step_chunk(&mut st, &[t], 1, &mut ctx, want)?;
            cache.token_tick();
            if let Some(lg) = lg {
                nlls.push(crate::eval::token_nll(&lg.data, tokens[i + 1]));
            }
        }
        Ok(nlls)
    }

    /// Batched greedy decoding (Fig. 5): admit every prompt into one
    /// session, then step to completion.  All sequences share the expert
    /// cache; members retiring at EOS stop contributing compute and cache
    /// requests, and the per-step amortization tracks the shrinking live
    /// batch.
    pub fn decode_batch(
        &self,
        prompts: &[Vec<usize>],
        max_output: usize,
    ) -> Result<(Vec<Vec<usize>>, Report)> {
        let wall = Instant::now();
        let mut sess = self.session();
        let mut ids = Vec::with_capacity(prompts.len());
        for p in prompts {
            ids.push(self.admit(&mut sess, p, max_output)?);
        }
        let mut fins: HashMap<u64, SeqFinish> = HashMap::new();
        while sess.active() > 0 {
            for f in self.step(&mut sess)? {
                fins.insert(f.seq, f);
            }
        }
        let wall_s = wall.elapsed().as_secs_f64();
        let mut outputs = Vec::with_capacity(prompts.len());
        let mut requests = Vec::with_capacity(prompts.len());
        for (i, id) in ids.iter().enumerate() {
            let f = fins.remove(id).expect("every admitted sequence retires");
            requests.push(RequestMetrics {
                prompt_tokens: prompts[i].len(),
                output_tokens: f.tokens.len(),
                // absolute retirement time (admission ≈ session start)
                sim_seconds: f.sim_finished,
                sim_ttft: f.sim_first_token,
                wall_seconds: wall_s,
            });
            outputs.push(f.tokens);
        }
        let mut report = sess.report_base();
        report.requests = requests;
        report.wall_seconds = wall_s;
        Ok((outputs, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_with(counts: Vec<Vec<u64>>) -> ActivationTrace {
        ActivationTrace { n_experts: counts[0].len(), counts, steps: Vec::new() }
    }

    #[test]
    fn topc_share_concentrated() {
        let t = trace_with(vec![vec![90, 5, 5, 0]]);
        assert!((t.topc_share(0, 1) - 0.9).abs() < 1e-12);
        assert!((t.topc_share(0, 2) - 0.95).abs() < 1e-12);
        assert!((t.topc_share(0, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn topc_share_uniform() {
        let t = trace_with(vec![vec![10; 8]]);
        assert!((t.topc_share(0, 2) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn topc_share_empty_is_zero() {
        let t = trace_with(vec![vec![0; 4]]);
        assert_eq!(t.topc_share(0, 2), 0.0);
    }

    #[test]
    fn mean_topc_share_averages_layers() {
        let t = trace_with(vec![vec![10, 0], vec![5, 5]]);
        assert!((t.mean_topc_share(1) - (1.0 + 0.5) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn share_curve_sorted_and_normalized() {
        let t = trace_with(vec![vec![1, 7, 2]]);
        let c = t.share_curve(0);
        assert!((c[0] - 0.7).abs() < 1e-12);
        assert!(c.windows(2).all(|w| w[0] >= w[1]));
        assert!((c.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
