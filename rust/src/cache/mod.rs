//! Per-layer GPU-resident expert caches.
//!
//! The unit of residency is one expert's (gate, up, down) weight block.
//! Three eviction policies are provided:
//!
//! * **LRU**   — least-recently-used (paper Table 13 left column).
//! * **LFU**   — least-frequently-used, the paper's main-results policy
//!               (§4.1 "The expert cache uses an LFU eviction policy").
//! * **γ-discounted** — the γ-cache of Definition C.1: a discounted request
//!   count `Count ← γ·Count + r` per token tick, evicting the resident
//!   expert with the smallest discounted count.  γ→0 behaves like LRU,
//!   γ=1 is exactly LFU — the interpolation the appendix proves.
//!
//! The engine *pins* the experts selected by the current token so that a
//! tight cache (e.g. the DeepSpeed-MoE-style capacity = K configuration)
//! can never evict an expert it is about to execute.
//!
//! On top of the per-step pin argument there is a *scheduler-owned pin
//! ledger* ([`LayerCache::pin_set`] / [`LayerCache::release`]): the
//! scheduler registers every in-flight sequence's full planned hot set —
//! not just the current step's experts — and the two *bulk* residency
//! paths, [`LayerCache::prefill_union`] (burst admission refresh) and
//! [`LayerCache::commit`] (lookahead arrival), never evict a
//! ledger-pinned resident.  Demand misses keep today's policy-order
//! eviction: genuine per-token churn may still displace a warm expert,
//! but bulk and speculative traffic cannot wipe a live sequence's warm
//! working set.  Preempted and retired sequences release their pins.
//!
//! Residency is *byte-budgeted per tier* (§3.2 / Table 12): every
//! resident entry carries the layer's [`QuantMode`] tier, the slot count
//! is a byte budget divided by the tier's per-expert cost (int4 fits
//! ~3.6× the experts of fp16 in the same VRAM), and an optional
//! *little store* ([`LayerCache::enable_little`]) carves a fixed
//! fraction of that byte budget into low-bit fallback copies of hot
//! experts — MoBiLE's big-little scheme.  [`LayerCache::used_units`] /
//! [`LayerCache::budget_units`] expose the exact occupancy arithmetic
//! (cost units are exact binary fractions, so the sums never drift) and
//! a property test holds `used ≤ budget` through insert/evict/pin
//! storms at every tier mix.

use crate::quant::QuantMode;
use std::collections::{HashMap, HashSet};

/// Fraction of a layer's byte budget carved out for little fallback
/// copies when [`LayerCache::enable_little`] is on.  One quarter keeps
/// ~92% of the big store's slots at int4/int3 tier mixes while funding
/// a little set large enough to cover the hot experts.
pub const LITTLE_BUDGET_FRAC: f64 = 0.25;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EvictionKind {
    Lru,
    Lfu,
    /// γ-discounted counts (Definition C.1).
    Gamma(f64),
}

impl EvictionKind {
    pub fn parse(s: &str) -> anyhow::Result<EvictionKind> {
        if let Some(g) = s.strip_prefix("gamma:") {
            let g: f64 = g.parse()?;
            // the γ-cache discount (Definition C.1) is only defined on
            // [0, 1]; NaN fails the range check too
            if !(0.0..=1.0).contains(&g) {
                anyhow::bail!("gamma must be in [0, 1] (0≈LRU, 1=LFU), got {g}");
            }
            return Ok(EvictionKind::Gamma(g));
        }
        Ok(match s {
            "lru" => EvictionKind::Lru,
            "lfu" => EvictionKind::Lfu,
            _ => anyhow::bail!("unknown eviction policy {s:?} (lru|lfu|gamma:<g>)"),
        })
    }
}

#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub prefetch_loads: u64,
}

impl CacheStats {
    pub fn requests(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.requests() == 0 {
            return 0.0;
        }
        self.hits as f64 / self.requests() as f64
    }

    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.prefetch_loads += other.prefetch_loads;
    }
}

/// What a [`LayerCache::prefill_union`] refresh did: which experts it
/// loaded, and which residents it evicted to make room.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PrefillOutcome {
    pub loaded: Vec<usize>,
    pub evicted: Vec<usize>,
}

/// Expert cache for a single MoE layer.
#[derive(Debug, Clone)]
pub struct LayerCache {
    n_experts: usize,
    capacity: usize,
    kind: EvictionKind,
    /// Precision tier of every big-store resident (the serving tier).
    /// `capacity` slots at this tier define the layer's byte budget.
    tier: QuantMode,
    /// Tier of the little fallback store, when enabled.
    little_tier: Option<QuantMode>,
    /// Little-store slot count, carved out of the byte budget.
    little_capacity: usize,
    /// Low-bit fallback copies of hot experts (never in `resident`, so
    /// hit/miss accounting and decode numerics are untouched when the
    /// fallback never fires).
    little: HashSet<usize>,
    resident: HashSet<usize>,
    /// Slots held for in-flight lookahead prefetches (reserve/commit
    /// path): reserved experts are not yet resident, but reservations
    /// bound how many prefetches the layer can absorb.
    reserved: HashSet<usize>,
    /// LFU / γ-discounted request counts (per expert).
    counts: Vec<f64>,
    /// LRU timestamps (per expert).
    last_used: Vec<u64>,
    tick: u64,
    /// Scheduler-owned pin ledger: owner (sequence/request id) → its
    /// planned hot set at this layer, capped at the slot count.
    pins: HashMap<u64, Vec<usize>>,
    /// Per-expert ledger pin counts (several owners may pin one expert).
    pin_counts: Vec<u32>,
    pub stats: CacheStats,
}

impl LayerCache {
    pub fn new(n_experts: usize, capacity: usize, kind: EvictionKind) -> LayerCache {
        LayerCache {
            n_experts,
            capacity: capacity.min(n_experts),
            kind,
            tier: QuantMode::Fp16,
            little_tier: None,
            little_capacity: 0,
            little: HashSet::new(),
            resident: HashSet::new(),
            reserved: HashSet::new(),
            counts: vec![0.0; n_experts],
            last_used: vec![0; n_experts],
            tick: 0,
            pins: HashMap::new(),
            pin_counts: vec![0; n_experts],
            stats: CacheStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    pub fn tier(&self) -> QuantMode {
        self.tier
    }

    pub fn little_tier(&self) -> Option<QuantMode> {
        self.little_tier
    }

    /// Set the big-store precision tier.  The slot count is unchanged —
    /// callers size `capacity` for the tier via
    /// `PolicyConfig::effective_capacity`, so `capacity × tier cost` *is*
    /// the layer's byte budget.  Construction-time call.
    pub fn set_tier(&mut self, tier: QuantMode) {
        debug_assert!(self.resident.is_empty(), "set_tier is a construction-time call");
        self.tier = tier;
    }

    /// Carve `frac` of the layer's byte budget into a little fallback
    /// store at tier `little`: little slots are funded by *shrinking*
    /// the big store, so total budget bytes never grow.  Exact unit
    /// arithmetic — after the carve,
    /// `budget_units() ≤ old capacity × tier cost` always holds.
    /// Construction-time call (the stores must be empty).
    pub fn enable_little(&mut self, little: QuantMode, frac: f64) {
        debug_assert!(
            self.resident.is_empty() && self.little.is_empty(),
            "enable_little is a construction-time call"
        );
        let budget = self.capacity as f64 * self.tier.cost_units();
        let little_cap =
            ((budget * frac.clamp(0.0, 1.0) / little.cost_units()) as usize).min(self.n_experts);
        let big_cap =
            ((budget - little_cap as f64 * little.cost_units()) / self.tier.cost_units()) as usize;
        self.little_tier = Some(little);
        self.little_capacity = little_cap;
        self.capacity = big_cap.min(self.n_experts);
    }

    /// The layer's VRAM byte budget in fp16-expert units: big slots at
    /// the serving tier plus the little carve-out.
    pub fn budget_units(&self) -> f64 {
        self.capacity as f64 * self.tier.cost_units()
            + self.little_capacity as f64 * self.little_tier.map_or(0.0, |t| t.cost_units())
    }

    /// Bytes currently occupied, in fp16-expert units: the sum of
    /// per-tier entry costs across both stores.  Invariant (property
    /// tested): `used_units() ≤ budget_units()` at all times.
    pub fn used_units(&self) -> f64 {
        self.resident.len() as f64 * self.tier.cost_units()
            + self.little.len() as f64 * self.little_tier.map_or(0.0, |t| t.cost_units())
    }

    /// Entries resident at any tier (big + little) — what the trace
    /// occupancy-replay audit balances against.
    pub fn occupancy_len(&self) -> usize {
        self.resident.len() + self.little.len()
    }

    pub fn little_capacity(&self) -> usize {
        self.little_capacity
    }

    pub fn little_len(&self) -> usize {
        self.little.len()
    }

    pub fn has_little(&self, expert: usize) -> bool {
        self.little.contains(&expert)
    }

    /// Install a little fallback copy of `expert`, evicting the coldest
    /// little entry (policy order) when the carve-out is full.  Returns
    /// `None` when nothing changed (no carve-out, already installed,
    /// out of range); otherwise `Some(evicted)` so the caller can
    /// account the transfer and emit matching trace events.
    pub fn install_little(&mut self, expert: usize) -> Option<Option<usize>> {
        if self.little_capacity == 0 || expert >= self.n_experts || self.little.contains(&expert) {
            return None;
        }
        let mut evicted = None;
        if self.little.len() >= self.little_capacity {
            let victim = self
                .little
                .iter()
                .copied()
                .filter(|&e| e != expert)
                .min_by(|&a, &b| self.eviction_rank(a, b));
            let Some(victim) = victim else { return None };
            self.little.remove(&victim);
            evicted = Some(victim);
        }
        self.little.insert(expert);
        Some(evicted)
    }

    pub fn contains(&self, expert: usize) -> bool {
        self.resident.contains(&expert)
    }

    pub fn resident_set(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.resident.iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// Token boundary: advance recency time and apply γ decay.
    pub fn token_tick(&mut self) {
        self.tick += 1;
        if let EvictionKind::Gamma(g) = self.kind {
            for c in &mut self.counts {
                *c *= g;
            }
        }
    }

    /// Record a routing request for `expert`.  Returns true on cache hit.
    /// On miss the caller decides whether to `insert` (a Fiddler-style
    /// CPU execution serves the miss without changing residency).
    pub fn request(&mut self, expert: usize) -> bool {
        debug_assert!(expert < self.n_experts);
        self.counts[expert] += 1.0;
        self.last_used[expert] = self.tick;
        let hit = self.resident.contains(&expert);
        if hit {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
        }
        hit
    }

    /// Insert `expert`, evicting if at capacity.  Experts in `pinned` are
    /// never chosen as victims.  Returns the evicted expert, if any.
    pub fn insert(&mut self, expert: usize, pinned: &[usize]) -> Option<usize> {
        if self.capacity == 0 {
            return None;
        }
        if self.resident.contains(&expert) {
            return None;
        }
        let mut evicted = None;
        if self.resident.len() >= self.capacity {
            if let Some(victim) = self.pick_victim(pinned, expert) {
                self.resident.remove(&victim);
                self.stats.evictions += 1;
                evicted = Some(victim);
            } else {
                return None; // everything pinned; caller executes un-cached
            }
        }
        self.resident.insert(expert);
        evicted
    }

    /// Register `owner`'s planned hot set in the pin ledger, replacing
    /// any previous set it held.  The set is deduplicated and capped at
    /// the layer's slot count (a plan bigger than the cache can hold
    /// would otherwise freeze the whole layer), keeping the plan's own
    /// ranking — its leading experts are the predictor's best.
    /// Ledger-pinned *residents* survive any [`LayerCache::prefill_union`]
    /// or [`LayerCache::commit`]; pinning does not itself load anything.
    pub fn pin_set(&mut self, owner: u64, experts: &[usize]) {
        self.release(owner);
        let mut set: Vec<usize> = Vec::new();
        for &e in experts {
            if set.len() >= self.capacity {
                break;
            }
            if e < self.n_experts && !set.contains(&e) {
                set.push(e);
            }
        }
        for &e in &set {
            self.pin_counts[e] += 1;
        }
        self.pins.insert(owner, set);
    }

    /// Drop `owner`'s ledger entry (sequence retired or preempted).
    pub fn release(&mut self, owner: u64) {
        if let Some(set) = self.pins.remove(&owner) {
            for e in set {
                self.pin_counts[e] -= 1;
            }
        }
    }

    /// Whether any in-flight owner holds `expert` in its pinned hot set.
    pub fn ledger_pinned(&self, expert: usize) -> bool {
        self.pin_counts[expert] > 0
    }

    /// Number of owners with a live ledger entry.
    pub fn pinned_owners(&self) -> usize {
        self.pins.len()
    }

    /// Slots currently held for in-flight prefetches.
    pub fn reserved_len(&self) -> usize {
        self.reserved.len()
    }

    pub fn is_reserved(&self, expert: usize) -> bool {
        self.reserved.contains(&expert)
    }

    /// Hold a slot for an in-flight lookahead prefetch of `expert`.
    /// Returns `false` — and the caller skips the prefetch — when the
    /// expert is already resident or reserved, or when *reservations*
    /// have saturated the layer's slot count.  Note the bound is on
    /// outstanding reservations, not physically free slots: on a full
    /// cache (the pressure regime lookahead targets) prefetch must still
    /// flow, and the commit evicts in policy order when it lands —
    /// never touching the step's pin set.
    pub fn reserve(&mut self, expert: usize) -> bool {
        if self.capacity == 0
            || self.resident.contains(&expert)
            || self.reserved.contains(&expert)
            || self.reserved.len() >= self.capacity
        {
            return false;
        }
        self.reserved.insert(expert);
        true
    }

    /// Drop the reservation held for `expert` without landing it — the
    /// in-flight transfer was lost to a link flap or arrived checksum-
    /// corrupt, so the slot hold must not leak (a leaked reservation
    /// would permanently shrink the layer's prefetch window).
    pub fn unreserve(&mut self, expert: usize) {
        self.reserved.remove(&expert);
    }

    /// Crash: VRAM contents are gone.  Drains the big store, the little
    /// store, and every outstanding reservation; returns the evicted
    /// `(big, little)` expert lists (sorted, for deterministic trace
    /// emission) so the caller can emit the matching `CacheEvict` /
    /// `LittleEvict` events and keep the occupancy-replay audit
    /// balanced.  The pin ledger is *not* touched here: the replica
    /// releases each owner explicitly so every `PinSet` still meets its
    /// `PinRelease` in the event stream.  Hit/miss statistics survive —
    /// they describe traffic served, not state lost.
    pub fn crash_clear(&mut self) -> (Vec<usize>, Vec<usize>) {
        let mut big: Vec<usize> = self.resident.drain().collect();
        big.sort_unstable();
        let mut little: Vec<usize> = self.little.drain().collect();
        little.sort_unstable();
        self.reserved.clear();
        (big, little)
    }

    /// Land an in-flight prefetch: clear the reservation and make the
    /// expert resident.  Eviction (if the cache filled up since the
    /// reservation) follows normal policy order but never touches
    /// `pinned` *or a ledger-pinned resident* — an arriving prefetch can
    /// never evict the step's pin set nor a live sequence's planned hot
    /// set.  When every resident is protected the arrival is dropped
    /// (no residency change).  Returns the evicted expert, if any.
    pub fn commit(&mut self, expert: usize, pinned: &[usize]) -> Option<usize> {
        self.reserved.remove(&expert);
        if self.capacity == 0 || self.resident.contains(&expert) {
            return None;
        }
        let mut evicted = None;
        if self.resident.len() >= self.capacity {
            let pinned: HashSet<usize> = pinned.iter().copied().collect();
            let victim = self
                .resident
                .iter()
                .copied()
                .filter(|&e| !pinned.contains(&e) && !self.ledger_pinned(e) && e != expert)
                .min_by(|&a, &b| self.eviction_rank(a, b));
            let Some(victim) = victim else { return None };
            self.resident.remove(&victim);
            self.stats.evictions += 1;
            evicted = Some(victim);
        }
        self.resident.insert(expert);
        self.stats.prefetch_loads += 1;
        evicted
    }

    /// Preload a prefetch set (start of request): replaces current
    /// residency.  Returns the experts newly loaded (transfers).
    pub fn prefill(&mut self, experts: &[usize]) -> Vec<usize> {
        let target: HashSet<usize> = experts.iter().copied().take(self.capacity).collect();
        let loads: Vec<usize> =
            target.iter().copied().filter(|e| !self.resident.contains(e)).collect();
        self.stats.prefetch_loads += loads.len() as u64;
        self.resident = target;
        loads
    }

    /// Additive prefetch refresh (mid-flight admission under continuous
    /// batching): load the target experts *without* dropping warm
    /// residents unless capacity forces it, and then only by evicting
    /// residents outside the target set — and outside the scheduler's
    /// pin ledger — in normal policy order: a burst admission's refresh
    /// can never evict the planned working set of any live sequence.  On
    /// a cold cache this equals [`LayerCache::prefill`].  Returns both
    /// the experts loaded *and* the victims evicted to make room, so the
    /// caller's trace stream can account every residency change.
    pub fn prefill_union(&mut self, experts: &[usize]) -> PrefillOutcome {
        let mut out = PrefillOutcome::default();
        if self.capacity == 0 {
            return out;
        }
        let target: HashSet<usize> = experts.iter().copied().take(self.capacity).collect();
        for &e in experts.iter().take(self.capacity) {
            if self.resident.contains(&e) {
                continue;
            }
            if self.resident.len() >= self.capacity {
                let victim = self
                    .resident
                    .iter()
                    .copied()
                    .filter(|&r| !target.contains(&r) && !self.ledger_pinned(r))
                    .min_by(|&a, &b| self.eviction_rank(a, b));
                let Some(victim) = victim else { break };
                self.resident.remove(&victim);
                self.stats.evictions += 1;
                out.evicted.push(victim);
            }
            self.resident.insert(e);
            self.stats.prefetch_loads += 1;
            out.loaded.push(e);
        }
        out
    }

    /// Policy ordering for victim selection (smaller = evicted first).
    fn eviction_rank(&self, a: usize, b: usize) -> std::cmp::Ordering {
        let (sa, sb) = match self.kind {
            EvictionKind::Lru => (self.last_used[a] as f64, self.last_used[b] as f64),
            EvictionKind::Lfu | EvictionKind::Gamma(_) => (self.counts[a], self.counts[b]),
        };
        sa.total_cmp(&sb).then(a.cmp(&b))
    }

    fn pick_victim(&self, pinned: &[usize], incoming: usize) -> Option<usize> {
        let pinned: HashSet<usize> = pinned.iter().copied().collect();
        self.resident
            .iter()
            .copied()
            .filter(|e| !pinned.contains(e) && *e != incoming)
            .min_by(|&a, &b| self.eviction_rank(a, b))
    }
}

/// All layers' caches for one model.
#[derive(Debug, Clone)]
pub struct ExpertCache {
    pub layers: Vec<LayerCache>,
}

impl ExpertCache {
    pub fn new(n_layers: usize, n_experts: usize, capacity: usize, kind: EvictionKind) -> Self {
        Self::with_capacities(n_experts, &vec![capacity; n_layers], kind)
    }

    /// Layer-wise budgets (paper §5 future work): layer ℓ holds
    /// `capacities[ℓ]` resident experts.
    pub fn with_capacities(n_experts: usize, capacities: &[usize], kind: EvictionKind) -> Self {
        ExpertCache {
            layers: capacities.iter().map(|&c| LayerCache::new(n_experts, c, kind)).collect(),
        }
    }

    /// Apply the serving tier (and optional little carve-out at
    /// [`LITTLE_BUDGET_FRAC`]) to every layer.  Construction-time call —
    /// see [`LayerCache::set_tier`] / [`LayerCache::enable_little`].
    pub fn set_tiers(&mut self, tier: QuantMode, little: Option<QuantMode>) {
        for l in &mut self.layers {
            l.set_tier(tier);
            if let Some(lt) = little {
                l.enable_little(lt, LITTLE_BUDGET_FRAC);
            }
        }
    }

    pub fn layer(&mut self, l: usize) -> &mut LayerCache {
        &mut self.layers[l]
    }

    pub fn token_tick(&mut self) {
        for l in &mut self.layers {
            l.token_tick();
        }
    }

    /// Register `owner`'s per-layer planned hot sets in every layer's pin
    /// ledger (scheduler-owned eviction protection; see
    /// [`LayerCache::pin_set`]).  Layers beyond `per_layer` pin nothing.
    pub fn pin_set(&mut self, owner: u64, per_layer: &[Vec<usize>]) {
        for (l, cache) in self.layers.iter_mut().enumerate() {
            cache.pin_set(owner, per_layer.get(l).map(|s| s.as_slice()).unwrap_or(&[]));
        }
    }

    /// Drop `owner`'s ledger entries across all layers (sequence retired
    /// or preempted).
    pub fn release(&mut self, owner: u64) {
        for cache in &mut self.layers {
            cache.release(owner);
        }
    }

    pub fn total_stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for l in &self.layers {
            s.merge(&l.stats);
        }
        s
    }

    /// Average misses per layer (the paper's Tx/L metric).
    pub fn misses_per_layer(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.stats.misses as f64).sum::<f64>() / self.layers.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, shrink_vec};
    use crate::util::rng::Rng;

    fn run_trace(kind: EvictionKind, capacity: usize, trace: &[usize]) -> LayerCache {
        let mut c = LayerCache::new(16, capacity, kind);
        for &e in trace {
            c.token_tick();
            if !c.request(e) {
                c.insert(e, &[e]);
            }
        }
        c
    }

    #[test]
    fn parse_validates_gamma_range() {
        assert_eq!(EvictionKind::parse("lru").unwrap(), EvictionKind::Lru);
        assert_eq!(EvictionKind::parse("lfu").unwrap(), EvictionKind::Lfu);
        assert_eq!(EvictionKind::parse("gamma:0.5").unwrap(), EvictionKind::Gamma(0.5));
        assert_eq!(EvictionKind::parse("gamma:0").unwrap(), EvictionKind::Gamma(0.0));
        assert_eq!(EvictionKind::parse("gamma:1.0").unwrap(), EvictionKind::Gamma(1.0));
        for bad in ["gamma:-0.1", "gamma:1.01", "gamma:NaN", "gamma:nan", "gamma:inf"] {
            let err = EvictionKind::parse(bad).unwrap_err().to_string();
            assert!(err.contains("gamma must be in [0, 1]"), "{bad}: {err}");
        }
        assert!(EvictionKind::parse("gamma:x").is_err());
        assert!(EvictionKind::parse("mru").is_err());
    }

    #[test]
    fn hit_after_insert() {
        let mut c = LayerCache::new(8, 2, EvictionKind::Lfu);
        assert!(!c.request(3));
        c.insert(3, &[]);
        assert!(c.request(3));
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = LayerCache::new(8, 2, EvictionKind::Lru);
        for e in [0, 1] {
            c.token_tick();
            c.request(e);
            c.insert(e, &[]);
        }
        c.token_tick();
        c.request(0); // 0 now more recent than 1
        c.token_tick();
        c.request(2);
        let evicted = c.insert(2, &[]);
        assert_eq!(evicted, Some(1));
    }

    #[test]
    fn lfu_evicts_least_frequent() {
        let mut c = LayerCache::new(8, 2, EvictionKind::Lfu);
        for _ in 0..3 {
            c.request(0);
        }
        c.insert(0, &[]);
        c.request(1);
        c.insert(1, &[]);
        c.request(2);
        assert_eq!(c.insert(2, &[]), Some(1));
    }

    #[test]
    fn gamma_one_matches_lfu_victims() {
        let mut rng = Rng::new(3);
        let trace: Vec<usize> = (0..400).map(|_| rng.below(16)).collect();
        let a = run_trace(EvictionKind::Lfu, 4, &trace);
        let b = run_trace(EvictionKind::Gamma(1.0), 4, &trace);
        assert_eq!(a.resident_set(), b.resident_set());
        assert_eq!(a.stats.misses, b.stats.misses);
    }

    #[test]
    fn gamma_small_behaves_recency_like() {
        // with γ≈0, only the latest request has weight — like LRU on this
        // pattern: 0 is requested often early, then never again.
        let mut trace = vec![0, 0, 0, 0];
        trace.extend([1, 2, 3, 1, 2, 3, 1, 2, 3]);
        let g = run_trace(EvictionKind::Gamma(1e-6), 3, &trace);
        assert!(!g.contains(0), "stale hot expert must be evicted under γ→0");
        // under LFU (γ=1) expert 0's early burst keeps it resident
        let f = run_trace(EvictionKind::Lfu, 3, &trace);
        assert!(f.contains(0));
    }

    #[test]
    fn pinned_never_evicted() {
        let mut c = LayerCache::new(8, 2, EvictionKind::Lru);
        c.request(0);
        c.insert(0, &[]);
        c.request(1);
        c.insert(1, &[]);
        c.request(2);
        let ev = c.insert(2, &[0, 1]);
        assert!(ev.is_none());
        assert!(c.contains(0) && c.contains(1) && !c.contains(2));
    }

    #[test]
    fn prefill_counts_loads() {
        let mut c = LayerCache::new(16, 4, EvictionKind::Lfu);
        c.insert(1, &[]);
        let loads = c.prefill(&[1, 2, 3, 4, 5, 6]);
        assert_eq!(c.resident_len(), 4);
        assert_eq!(loads.len() + 1, 4); // expert 1 was already resident
        assert_eq!(c.stats.prefetch_loads, 3);
    }

    #[test]
    fn prefill_union_is_additive_and_protects_target() {
        let mut c = LayerCache::new(16, 4, EvictionKind::Lfu);
        // warm two demand-loaded experts, one of them hot
        for _ in 0..3 {
            c.request(7);
        }
        c.insert(7, &[]);
        c.request(9);
        c.insert(9, &[]);
        // additive refresh: room for both targets, nothing dropped
        let out = c.prefill_union(&[1, 2]);
        assert_eq!(out.loaded, vec![1, 2]);
        assert!(out.evicted.is_empty());
        assert!(c.contains(7) && c.contains(9), "refresh must not drop warm residents");
        assert_eq!(c.resident_len(), 4);
        // at capacity: only non-target residents are evictable, coldest
        // (LFU) first — expert 9 (1 request) goes before expert 7 (3)
        let out = c.prefill_union(&[1, 2, 3]);
        assert_eq!(out.loaded, vec![3]);
        assert_eq!(out.evicted, vec![9], "the eviction is reported, not swallowed");
        assert!(!c.contains(9) && c.contains(7));
        assert_eq!(c.stats.evictions, 1);
        // when every resident is part of the target, loading just stops
        let out = c.prefill_union(&[1, 2, 3, 7, 11]);
        assert!(c.contains(1) && c.contains(2) && c.contains(3) && c.contains(7));
        assert!(out.loaded.is_empty() && out.evicted.is_empty() && !c.contains(11));
        assert_eq!(c.resident_len(), 4);
        // cold cache: equivalent to prefill
        let mut cold = LayerCache::new(16, 4, EvictionKind::Lfu);
        let out = cold.prefill_union(&[5, 6, 7, 8, 9]);
        assert_eq!(out.loaded, vec![5, 6, 7, 8]);
        assert_eq!(cold.resident_len(), 4);
    }

    #[test]
    fn reserve_commit_roundtrip() {
        let mut c = LayerCache::new(16, 2, EvictionKind::Lfu);
        assert!(c.reserve(3));
        assert!(c.is_reserved(3) && !c.contains(3));
        assert!(!c.reserve(3), "double reservation refused");
        assert_eq!(c.reserved_len(), 1);
        assert_eq!(c.commit(3, &[]), None);
        assert!(c.contains(3) && !c.is_reserved(3));
        assert_eq!(c.stats.prefetch_loads, 1);
        // resident experts are not reservable
        assert!(!c.reserve(3));
        // committing an already-resident expert is a no-op
        assert_eq!(c.commit(3, &[]), None);
        assert_eq!(c.stats.prefetch_loads, 1);
    }

    #[test]
    fn reserve_caps_at_capacity() {
        let mut c = LayerCache::new(16, 2, EvictionKind::Lfu);
        assert!(c.reserve(0));
        assert!(c.reserve(1));
        assert!(!c.reserve(2), "reservations saturate at the slot count");
        assert_eq!(c.reserved_len(), 2);
        assert!(!LayerCache::new(8, 0, EvictionKind::Lfu).reserve(1));
    }

    #[test]
    fn unreserve_frees_the_slot_hold() {
        let mut c = LayerCache::new(16, 2, EvictionKind::Lfu);
        assert!(c.reserve(0));
        assert!(c.reserve(1));
        assert!(!c.reserve(2), "saturated");
        c.unreserve(0);
        assert!(!c.is_reserved(0));
        assert!(c.reserve(2), "lost transfer's hold is reusable");
        c.unreserve(9); // unknown expert is a no-op
        assert_eq!(c.reserved_len(), 2);
    }

    #[test]
    fn crash_clear_drains_both_stores_and_reservations() {
        let mut c = LayerCache::new(16, 4, EvictionKind::Lfu);
        c.enable_little(QuantMode::Int3, 0.25);
        assert!(c.little_capacity() >= 1 && c.capacity() >= 3);
        assert_eq!(c.install_little(9), Some(None));
        c.insert(5, &[]);
        c.insert(2, &[]);
        assert!(c.reserve(7));
        c.request(5);
        let hits_before = c.stats.hits;
        let (big, little) = c.crash_clear();
        assert_eq!(big, vec![2, 5], "sorted for deterministic trace emission");
        assert_eq!(little, vec![9]);
        assert_eq!(c.resident_len(), 0);
        assert_eq!(c.little_len(), 0);
        assert_eq!(c.reserved_len(), 0);
        assert_eq!(c.stats.hits, hits_before, "traffic stats survive the crash");
        // a second crash on empty state is a no-op
        assert_eq!(c.crash_clear(), (vec![], vec![]));
    }

    #[test]
    fn commit_evicts_in_policy_order_but_never_pinned() {
        let mut c = LayerCache::new(16, 2, EvictionKind::Lfu);
        for _ in 0..3 {
            c.request(7);
        }
        c.insert(7, &[]);
        c.request(9);
        c.insert(9, &[]);
        assert!(c.reserve(4));
        // cache filled since the reservation: commit evicts the coldest
        // non-pinned resident (9, one request, vs 7 with three)
        assert_eq!(c.commit(4, &[7]), Some(9));
        assert!(c.contains(4) && c.contains(7) && !c.contains(9));
        // everything pinned: the arrival is dropped, residency unchanged
        assert!(c.reserve(5));
        assert_eq!(c.commit(5, &[4, 7]), None);
        assert!(!c.contains(5) && !c.is_reserved(5));
        assert_eq!(c.resident_len(), 2);
    }

    // ---------------------------------------------------- pin ledger
    #[test]
    fn pin_set_release_roundtrip_and_caps_at_capacity() {
        let mut c = LayerCache::new(16, 3, EvictionKind::Lfu);
        c.pin_set(7, &[1, 2, 2, 4, 5, 6]); // dedup + cap at 3
        assert!(c.ledger_pinned(1) && c.ledger_pinned(2) && c.ledger_pinned(4));
        assert!(!c.ledger_pinned(5) && !c.ledger_pinned(6), "cap at the slot count");
        assert_eq!(c.pinned_owners(), 1);
        // replacing an owner's set drops the old pins
        c.pin_set(7, &[9]);
        assert!(!c.ledger_pinned(1) && c.ledger_pinned(9));
        // overlapping owners: the expert stays pinned until both release
        c.pin_set(8, &[9]);
        c.release(7);
        assert!(c.ledger_pinned(9));
        c.release(8);
        assert!(!c.ledger_pinned(9));
        assert_eq!(c.pinned_owners(), 0);
        // releasing an unknown owner is a no-op
        c.release(12345);
        // out-of-range experts are ignored
        c.pin_set(1, &[99, 3]);
        assert!(c.ledger_pinned(3) && !c.ledger_pinned(15));
        // zero capacity pins nothing
        let mut z = LayerCache::new(8, 0, EvictionKind::Lfu);
        z.pin_set(1, &[1, 2]);
        assert!(!z.ledger_pinned(1));
    }

    #[test]
    fn prefill_union_never_evicts_ledger_pinned() {
        let mut c = LayerCache::new(16, 3, EvictionKind::Lfu);
        // warm the live sequence's working set and pin it
        c.prefill_union(&[1, 2, 3]);
        c.pin_set(0, &[1, 2, 3]);
        // a burst admission refresh cannot displace the pinned residents
        let out = c.prefill_union(&[10, 11, 12]);
        assert!(out.loaded.is_empty(), "no victim available: refresh loads nothing");
        assert!(c.contains(1) && c.contains(2) && c.contains(3));
        // release one slot's protection: the refresh may now evict it
        c.pin_set(0, &[1, 2]);
        let out = c.prefill_union(&[10]);
        assert_eq!(out.loaded, vec![10]);
        assert_eq!(out.evicted, vec![3]);
        assert!(c.contains(1) && c.contains(2) && !c.contains(3));
    }

    #[test]
    fn commit_never_evicts_ledger_pinned() {
        let mut c = LayerCache::new(16, 2, EvictionKind::Lfu);
        c.prefill_union(&[1, 2]);
        c.pin_set(0, &[1, 2]);
        assert!(c.reserve(5));
        // all residents ledger-pinned: the arrival is dropped
        assert_eq!(c.commit(5, &[]), None);
        assert!(!c.contains(5) && c.contains(1) && c.contains(2));
        // unpin expert 2: the commit may evict it in policy order
        c.pin_set(0, &[1]);
        assert!(c.reserve(5));
        assert_eq!(c.commit(5, &[]), Some(2));
        assert!(c.contains(5) && c.contains(1) && !c.contains(2));
    }

    #[test]
    fn demand_insert_still_churns_past_the_ledger() {
        // ledger protection is scoped to the bulk paths: a genuine
        // demand miss may still displace a ledger-pinned resident
        let mut c = LayerCache::new(16, 2, EvictionKind::Lru);
        c.prefill_union(&[1, 2]);
        c.pin_set(0, &[1, 2]);
        c.token_tick();
        c.request(9);
        assert!(c.insert(9, &[9]).is_some(), "demand path keeps policy-order eviction");
        assert!(c.contains(9));
    }

    #[test]
    fn zero_capacity_never_resident() {
        let mut c = LayerCache::new(8, 0, EvictionKind::Lfu);
        c.request(1);
        assert!(c.insert(1, &[]).is_none());
        assert_eq!(c.resident_len(), 0);
    }

    // ------------------------------------------------------- property tests
    #[test]
    fn prop_capacity_never_exceeded() {
        check(
            200,
            |r| {
                let cap = r.below(5);
                let trace: Vec<usize> = (0..r.below(80)).map(|_| r.below(16)).collect();
                (cap, trace)
            },
            |(cap, trace)| {
                shrink_vec(trace, |_| vec![]).into_iter().map(|t| (*cap, t)).collect()
            },
            |(cap, trace)| {
                for kind in [EvictionKind::Lru, EvictionKind::Lfu, EvictionKind::Gamma(0.9)] {
                    let c = run_trace(kind, *cap, trace);
                    if c.resident_len() > *cap {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn prop_hits_plus_misses_equals_requests() {
        check(
            200,
            |r| (0..r.below(60)).map(|_| r.below(16)).collect::<Vec<usize>>(),
            |t| shrink_vec(t, |_| vec![]),
            |trace| {
                let c = run_trace(EvictionKind::Lfu, 4, trace);
                c.stats.requests() == trace.len() as u64
            },
        );
    }

    #[test]
    fn prop_requested_expert_resident_after_insert() {
        check(
            200,
            |r| (0..r.range(1, 40)).map(|_| r.below(16)).collect::<Vec<usize>>(),
            |t| shrink_vec(t, |_| vec![]),
            |trace| {
                let mut c = LayerCache::new(16, 3, EvictionKind::Gamma(0.5));
                for &e in trace {
                    c.token_tick();
                    if !c.request(e) {
                        c.insert(e, &[e]);
                    }
                    if !c.contains(e) {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn prop_misses_monotone_in_capacity_for_repeating_trace() {
        // For cyclic traces, larger caches can only help (no Belady
        // anomaly for LFU on stationary patterns).
        check(
            50,
            |r| {
                let period = r.range(2, 6);
                let reps = r.range(2, 10);
                let mut t = Vec::new();
                for _ in 0..reps {
                    for e in 0..period {
                        t.push(e);
                    }
                }
                t
            },
            |t| shrink_vec(t, |_| vec![]),
            |trace| {
                let m4 = run_trace(EvictionKind::Lfu, 4, trace).stats.misses;
                let m8 = run_trace(EvictionKind::Lfu, 8, trace).stats.misses;
                m8 <= m4
            },
        );
    }

    // ------------------------------------------------- tiers & little store
    #[test]
    fn enable_little_carves_budget_without_growing_it() {
        let mut c = LayerCache::new(64, 32, EvictionKind::Lfu);
        c.set_tier(QuantMode::Int4);
        let before = c.budget_units(); // 32 × 9/32 = 9.0 exactly
        assert_eq!(before, 9.0);
        c.enable_little(QuantMode::Int3, LITTLE_BUDGET_FRAC);
        assert_eq!(c.little_tier(), Some(QuantMode::Int3));
        assert!(c.little_capacity() > 0, "the carve-out funds real little slots");
        assert!(c.capacity() < 32, "little slots are paid for by the big store");
        assert!(c.budget_units() <= before + 1e-12, "the carve never grows the budget");
    }

    #[test]
    fn little_store_installs_and_evicts_in_policy_order() {
        let mut c = LayerCache::new(16, 8, EvictionKind::Lfu);
        c.set_tier(QuantMode::Int4);
        c.enable_little(QuantMode::Int3, 0.5);
        let cap = c.little_capacity();
        assert!(cap >= 2);
        // fill the carve-out; expert 0 is hot, the rest cold
        for _ in 0..5 {
            c.request(0);
        }
        for e in 0..cap {
            assert_eq!(c.install_little(e), Some(None));
            assert!(c.has_little(e));
        }
        assert_eq!(c.install_little(0), None, "already installed is a no-op");
        // overflow evicts the coldest little entry, never the hot one
        let out = c.install_little(15).unwrap().unwrap();
        assert_ne!(out, 0);
        assert!(c.has_little(15) && c.has_little(0));
        assert_eq!(c.little_len(), cap);
        // little copies never appear in big residency or hit accounting
        assert!(!c.contains(15));
        let hits = c.stats.hits;
        c.request(15);
        assert_eq!(c.stats.hits, hits, "a little copy is not a cache hit");
    }

    #[test]
    fn no_little_store_without_carve_out() {
        let mut c = LayerCache::new(16, 4, EvictionKind::Lfu);
        assert_eq!(c.install_little(3), None);
        assert_eq!(c.little_len(), 0);
        assert_eq!(c.budget_units(), 4.0, "fp16 default: one unit per slot");
    }

    #[test]
    fn prop_byte_occupancy_never_exceeds_budget() {
        // satellite: random insert/evict/pin/prefill/commit storms across
        // tier mixes never push per-tier byte occupancy past the budget
        check(
            150,
            |r| {
                let tier = [QuantMode::Fp16, QuantMode::Int4][r.below(2)];
                let little = match (tier, r.below(3)) {
                    (QuantMode::Int4, 0) => Some(QuantMode::Int3),
                    (QuantMode::Fp16, 0) => Some(QuantMode::Int4),
                    _ => None,
                };
                let cap = r.below(10);
                let ops: Vec<usize> = (0..r.below(120)).map(|_| r.below(1 << 12)).collect();
                (tier, little, cap, ops)
            },
            |(tier, little, cap, ops)| {
                shrink_vec(ops, |_| vec![])
                    .into_iter()
                    .map(|o| (*tier, *little, *cap, o))
                    .collect()
            },
            |(tier, little, cap, ops)| {
                let mut c = LayerCache::new(16, *cap, EvictionKind::Gamma(0.8));
                c.set_tier(*tier);
                if let Some(lt) = *little {
                    c.enable_little(lt, LITTLE_BUDGET_FRAC);
                }
                let budget = c.budget_units();
                assert!(budget <= *cap as f64 * tier.cost_units() + 1e-12);
                for &op in ops {
                    let e = op % 16;
                    match (op >> 4) % 6 {
                        0 => {
                            c.token_tick();
                            if !c.request(e) {
                                c.insert(e, &[e]);
                            }
                        }
                        1 => {
                            c.install_little(e);
                        }
                        2 => {
                            c.pin_set((op >> 7) as u64 % 4, &[e, (e + 3) % 16]);
                        }
                        3 => {
                            c.release((op >> 7) as u64 % 4);
                        }
                        4 => {
                            c.prefill_union(&[e, (e + 1) % 16, (e + 5) % 16]);
                        }
                        _ => {
                            if c.reserve(e) {
                                c.commit(e, &[(e + 1) % 16]);
                            }
                        }
                    }
                    if c.used_units() > c.budget_units() + 1e-12 {
                        return false;
                    }
                    if c.resident_len() > c.capacity() || c.little_len() > c.little_capacity() {
                        return false;
                    }
                    if c.occupancy_len() != c.resident_len() + c.little_len() {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn prop_full_residency_no_misses_after_warmup() {
        check(
            100,
            |r| (0..r.range(1, 50)).map(|_| r.below(8)).collect::<Vec<usize>>(),
            |t| shrink_vec(t, |_| vec![]),
            |trace| {
                let c = run_trace(EvictionKind::Lfu, 8, trace);
                // misses can only be cold-start: at most one per expert
                c.stats.misses <= 8 && c.stats.evictions == 0
            },
        );
    }
}

#[cfg(test)]
mod layerwise_tests {
    use super::*;

    #[test]
    fn with_capacities_per_layer() {
        let caps = [1usize, 3, 0, 8];
        let mut c = ExpertCache::with_capacities(8, &caps, EvictionKind::Lfu);
        for (l, &cap) in caps.iter().enumerate() {
            assert_eq!(c.layers[l].capacity(), cap.min(8));
            for e in 0..8 {
                c.layer(l).request(e);
                c.layer(l).insert(e, &[e]);
            }
            assert!(c.layers[l].resident_len() <= cap);
        }
    }

    #[test]
    fn uniform_constructor_equivalent() {
        let a = ExpertCache::new(4, 8, 3, EvictionKind::Lru);
        let b = ExpertCache::with_capacities(8, &[3, 3, 3, 3], EvictionKind::Lru);
        assert_eq!(a.layers.len(), b.layers.len());
        assert!(a.layers.iter().zip(&b.layers).all(|(x, y)| x.capacity() == y.capacity()));
    }
}
