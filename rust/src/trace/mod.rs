//! Sim-time structured tracing + metrics registry + Perfetto export.
//!
//! MELINOE's argument is about *where time goes* — expert churn, PCIe
//! stall vs overlap, pin-ledger protection — so the serving stack can
//! emit a structured event stream stamped with the simulated clock:
//!
//! * [`TraceEvent`] — one `Copy` variant per interesting transition
//!   (request admit/retire, step start/end, prefill chunks, prefetch
//!   issued / transfer landed, demand stalls with their residual flag,
//!   cache insert/evict, pin ledger set/release, suspend/resume, and
//!   cluster dispatch decisions with the balancer's affinity score).
//! * [`Recorder`] — the handle the engine / replica / scheduler hold.
//!   Off by default and **zero-allocation when off**: the disabled
//!   recorder is an `Option<Box<Sink>>::None`, so `emit` is a branch on
//!   a null pointer and every event payload is a stack `Copy` value.
//! * [`MetricsRegistry`] — named counters / gauges / fixed-bucket
//!   histograms updated *from the event stream* (a single entry point,
//!   so counters can never disagree with the events), including the
//!   per-expert churn table (loads / evictions / demand misses /
//!   pin-protected evict attempts per expert id) and per-layer stalls.
//! * [`Trace`] — the drained result: events + registry + lane names,
//!   mergeable across replicas, exportable as Chrome trace-event JSON
//!   ([`Trace::to_chrome_json`]) that Perfetto / `chrome://tracing`
//!   open directly (one process per replica, one thread per subsystem:
//!   compute, PCIe link, scheduler).
//!
//! The payoff beyond visibility is the **conservation audit**: every
//! PCIe-touching event embeds the [`PcieDelta`] the call added to
//! [`TransferStats`], so trace-derived stall/overlap/h2d totals must
//! reconcile with the engine's own accounting ([`Trace::reconcile`]),
//! pin events must replay to the cache's ledger ([`Trace::audit_pins`]),
//! insert/evict events must replay to cache occupancy
//! ([`Trace::audit_occupancy`]), and every `PrefetchIssued` must be
//! consumed by a `TransferLanded` or still be on the link
//! ([`Trace::audit_prefetch_landed`], widened under fault injection to
//! admit lost and corrupt transfers) — a cross-layer self-check of the
//! PR 4 overlap accounting and the PR 5 pin ledger.  `run_cluster` runs
//! all the audits per replica whenever tracing is on, plus the fleet
//! recovery-conservation audit ([`Trace::audit_recovery`]: every fault-
//! reclaimed request is either recovered or failed, never dropped).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::metrics::Table;
use crate::pcie::TransferStats;
use crate::quant::QuantMode;
use crate::util::json::{arr, num, obj, s, Json};

/// Human-readable tier name for an event's `tier` payload
/// ([`QuantMode::idx`]-encoded, so event payloads stay `Copy`).
fn tier_name(tier: u8) -> &'static str {
    QuantMode::ALL.get(tier as usize).map_or("?", |m| m.name())
}

// ------------------------------------------------------------------ deltas

/// Snapshot of the [`TransferStats`] time accumulators, taken *before* a
/// pcie call so the call's exact contribution can be attached to the
/// event ([`PcieSnap::delta`]).  Plain `Copy` — snapshotting allocates
/// nothing, so it is safe on the step hot path whether or not tracing
/// is enabled.
#[derive(Debug, Clone, Copy, Default)]
pub struct PcieSnap {
    stall: f64,
    overlapped: f64,
    h2d_seconds: f64,
}

impl PcieSnap {
    pub fn of(stats: &TransferStats) -> PcieSnap {
        PcieSnap {
            stall: stats.stall_time,
            overlapped: stats.overlapped_time,
            h2d_seconds: stats.h2d_seconds,
        }
    }

    /// What the intervening pcie call(s) added.  Components may be
    /// *negative*: a stall window un-hides previously-overlapped queued
    /// transfers (`unhide_window`), which moves time from `overlapped`
    /// to `stall` — the per-event deltas still sum to the stats totals,
    /// which is exactly what the reconciliation audit checks.
    pub fn delta(&self, stats: &TransferStats) -> PcieDelta {
        PcieDelta {
            stall: stats.stall_time - self.stall,
            overlapped: stats.overlapped_time - self.overlapped,
            h2d_seconds: stats.h2d_seconds - self.h2d_seconds,
        }
    }
}

/// The contribution one pcie call made to the stall/overlap/h2d
/// accumulators, embedded in the event that caused it.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PcieDelta {
    pub stall: f64,
    pub overlapped: f64,
    pub h2d_seconds: f64,
}

// ------------------------------------------------------------------ events

/// One structured, sim-clock-stamped event.  All payloads are `Copy`
/// (no strings, no vecs): emitting an event never allocates beyond the
/// recorder's own buffer growth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// A sequence entered a decode slot.
    RequestAdmit { seq: u64 },
    /// A sequence retired (EOS or budget), freeing its slot.
    RequestRetire { seq: u64, output_tokens: u32 },
    /// A batch token-step began (`tokens` = step token total including
    /// prefill chunks, `batch` = live sequences).
    StepStart { tokens: u32, batch: u32 },
    /// The step's compute + transfer settlement finished.
    StepEnd { tokens: u32, batch: u32 },
    /// A prefilling sequence consumed `tokens` prompt tokens this step.
    PrefillChunk { seq: u64, tokens: u32 },
    /// A tracked non-blocking transfer was issued onto the PCIe link.
    /// `tier` is the payload's [`QuantMode::idx`] (byte-accurate costing).
    PrefetchIssued { layer: u32, expert: u32, tier: u8, delta: PcieDelta },
    /// An in-flight transfer was consumed: drained-and-committed, or
    /// claimed by a `wait_for`.  Every `PrefetchIssued` is matched by
    /// exactly one `TransferLanded` or a still-in-flight entry at end
    /// of run ([`Trace::audit_prefetch_landed`]).
    TransferLanded { layer: u32, expert: u32, tier: u8 },
    /// The decode blocked on a transfer: a cold demand miss
    /// (`residual: false`) or the residual wait on a caught in-flight
    /// prefetch (`residual: true`).
    DemandStall { layer: u32, expert: u32, tier: u8, residual: bool, delta: PcieDelta },
    /// A little (low-bit) fallback copy was installed in the layer's
    /// carve-out; the untracked background transfer's [`PcieDelta`]
    /// rides along so the reconciliation audit stays exact.
    LittleInstall { layer: u32, expert: u32, tier: u8, delta: PcieDelta },
    /// A little copy was displaced by a hotter install.
    LittleEvict { layer: u32, expert: u32 },
    /// A demand miss was served by executing the resident little copy at
    /// zero stall instead of waiting out the full-tier transfer — the
    /// degraded-quality exec counted into `degraded_token_frac`.
    DegradedExec { layer: u32, expert: u32, tier: u8 },
    /// An expert became resident (demand insert, prefill top-up, or
    /// in-flight commit).
    CacheInsert { layer: u32, expert: u32 },
    /// A resident expert was evicted to make room.
    CacheEvict { layer: u32, expert: u32 },
    /// An arrival could not commit (or an insert could not evict)
    /// because every candidate victim was pinned — the pin ledger
    /// protecting a live sequence's warm set.
    PinProtected { layer: u32, expert: u32 },
    /// A sequence's planned hot set was registered in the pin ledger.
    PinSet { owner: u64 },
    /// A sequence's ledger pins were released (retire or suspend).
    PinRelease { owner: u64 },
    /// A sequence was preempted out of its slot at a step boundary.
    Suspend { seq: u64 },
    /// A suspended sequence reattached to a slot.
    Resume { seq: u64 },
    /// A sequence was cancelled (client disconnect or explicit cancel):
    /// the one-way version of [`TraceEvent::Suspend`] — the slot frees
    /// and the pin ledger releases, but the state is dropped, never
    /// resumed.  Always paired with a `PinRelease` when the sequence had
    /// reached a decode slot, so the pin conservation audit still
    /// balances.
    Cancel { seq: u64 },
    /// A deadline-tagged request was refused at admission because the
    /// estimated TTFT under current occupancy could not meet it.
    Reject { seq: u64 },
    /// A streaming consumer fell behind its bounded channel and the
    /// sequence was suspended at a step boundary instead of buffering
    /// unboundedly (backpressure).
    StreamStall { seq: u64 },
    /// The cluster dispatcher routed `request` to `replica`; `score` is
    /// the balancer's affinity score for the chosen replica.
    Dispatch { request: u64, replica: u32, score: f64 },
    /// A replica crashed: its cache/pin/queue state is lost and
    /// `reclaimed` sequences were handed back to the dispatcher for
    /// retry ([`Trace::audit_recovery`] conserves them).
    Crash { replica: u32, reclaimed: u32 },
    /// A dispatcher-side heartbeat observation of `replica`; `phi` is
    /// the missed-deadline suspicion level (0 = just heard from it).
    /// Emitted only when fault injection is enabled, so fault-free
    /// traces stay byte-identical.
    Heartbeat { replica: u32, phi: f64 },
    /// A reclaimed request was re-dispatched to `replica` on retry
    /// `attempt` (1-based) after its sim-time backoff.
    Retry { request: u64, attempt: u32, replica: u32 },
    /// A live suspended sequence was migrated off a browned-out replica
    /// (`from`) onto a healthy one (`to`) priced by the affinity score.
    Migrate { request: u64, from: u32, to: u32 },
    /// An in-flight expert transfer arrived checksum-corrupt and was
    /// discarded without committing; the expert must be re-fetched.
    Corrupt { layer: u32, expert: u32 },
    /// An in-flight expert transfer was lost to a link flap before it
    /// could land (the issue is consumed without a `TransferLanded`).
    TransferLost { layer: u32, expert: u32 },
    /// A reclaimed request exhausted its retry budget and resolved
    /// `Outcome::Failed` — the only way a request terminates without
    /// completing, cancelling, or being rejected.
    RequestFailed { request: u64 },
    /// An idle replica (`to`) stole work from a loaded peer (`from`),
    /// priced by affinity-minus-load.  `live: false` moves a queued
    /// request (the thief pays its cold cache); `live: true` moves a
    /// suspended in-flight sequence, charging the KV/plan migration
    /// transfer over PCIe on the thief's clock.
    Steal { request: u64, from: u32, to: u32, live: bool },
    /// Age-based promotion: a request waiting past the aging threshold
    /// was raised to priority class `to`
    /// ([`crate::coordinator::Priority::idx`] encoding) so a Low request
    /// under sustained High flood has bounded `preempted_wait`.
    Promote { request: u64, to: u8 },
}

/// An event with its simulated timestamp and lane (replica id, or the
/// dispatcher lane = fleet size).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stamped {
    pub t: f64,
    pub lane: u32,
    pub ev: TraceEvent,
}

// ---------------------------------------------------------------- registry

/// Fixed-bucket histogram: `counts[i]` holds samples `<= bounds[i]`,
/// with one overflow bucket past the last bound.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    pub bounds: &'static [f64],
    pub counts: Vec<u64>,
    pub sum: f64,
    pub n: u64,
}

impl Histogram {
    pub fn new(bounds: &'static [f64]) -> Histogram {
        Histogram { bounds, counts: vec![0; bounds.len() + 1], sum: 0.0, n: 0 }
    }

    pub fn record(&mut self, v: f64) {
        let i = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
        self.n += 1;
    }

    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(self.bounds, other.bounds);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
        self.n += other.n;
    }
}

/// Per-expert churn row: how often this expert id was loaded, evicted,
/// demand-missed, and how often the pin ledger blocked an evict attempt
/// that targeted (or an arrival that needed) it.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExpertChurn {
    pub loads: u64,
    pub evictions: u64,
    pub demand_misses: u64,
    pub pin_protected: u64,
}

/// Per-layer stall row.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerStall {
    pub events: u64,
    pub seconds: f64,
}

/// Trace-side stall/overlap/h2d totals: the sum of every event's
/// [`PcieDelta`].  Must reconcile with [`TransferStats`] within 1e-6.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PcieTotals {
    pub stall: f64,
    pub overlapped: f64,
    pub h2d_seconds: f64,
}

/// Stall-duration buckets (seconds): sub-0.1ms residuals up to
/// full-transfer stalls.
pub const STALL_BUCKETS: &[f64] = &[1e-4, 1e-3, 1e-2, 0.1, 1.0];
/// Live-batch-size buckets for the step histogram.
pub const BATCH_BUCKETS: &[f64] = &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0];

/// Named counters / gauges / histograms, updated exclusively from the
/// event stream ([`MetricsRegistry::observe`]) so the numbers can never
/// drift from the events.  Counter keys are `&'static str`: updating a
/// counter allocates nothing after its first insertion.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    pub counters: BTreeMap<&'static str, u64>,
    pub gauges: BTreeMap<&'static str, f64>,
    pub histograms: BTreeMap<&'static str, Histogram>,
    pub churn: BTreeMap<usize, ExpertChurn>,
    pub stall_by_layer: BTreeMap<usize, LayerStall>,
    pub pcie: PcieTotals,
}

impl MetricsRegistry {
    fn count(&mut self, key: &'static str) {
        self.count_n(key, 1);
    }

    fn count_n(&mut self, key: &'static str, n: u64) {
        *self.counters.entry(key).or_insert(0) += n;
    }

    fn gauge_max(&mut self, key: &'static str, v: f64) {
        let g = self.gauges.entry(key).or_insert(f64::NEG_INFINITY);
        if v > *g {
            *g = v;
        }
    }

    fn hist(&mut self, key: &'static str, bounds: &'static [f64], v: f64) {
        self.histograms.entry(key).or_insert_with(|| Histogram::new(bounds)).record(v);
    }

    fn add_delta(&mut self, d: &PcieDelta) {
        self.pcie.stall += d.stall;
        self.pcie.overlapped += d.overlapped;
        self.pcie.h2d_seconds += d.h2d_seconds;
    }

    /// The single entry point: fold one stamped event into every
    /// counter/gauge/histogram/table it touches.
    pub fn observe(&mut self, t: f64, ev: &TraceEvent) {
        self.gauge_max("sim_time", t);
        match ev {
            TraceEvent::RequestAdmit { .. } => self.count("requests_admitted"),
            TraceEvent::RequestRetire { .. } => self.count("requests_retired"),
            TraceEvent::StepStart { .. } => self.count("steps"),
            TraceEvent::StepEnd { batch, .. } => {
                self.hist("step_batch", BATCH_BUCKETS, *batch as f64);
            }
            TraceEvent::PrefillChunk { .. } => self.count("prefill_chunks"),
            TraceEvent::PrefetchIssued { expert, delta, .. } => {
                self.count("prefetch_issued");
                self.add_delta(delta);
                self.churn.entry(*expert as usize).or_default();
            }
            TraceEvent::TransferLanded { .. } => self.count("transfer_landed"),
            TraceEvent::LittleInstall { expert, delta, .. } => {
                self.count("little_installs");
                self.add_delta(delta);
                self.churn.entry(*expert as usize).or_default();
            }
            TraceEvent::LittleEvict { .. } => self.count("little_evictions"),
            TraceEvent::DegradedExec { .. } => self.count("degraded_execs"),
            TraceEvent::DemandStall { layer, expert, residual, delta, .. } => {
                self.count(if *residual { "residual_claims" } else { "demand_misses" });
                if !residual {
                    self.churn.entry(*expert as usize).or_default().demand_misses += 1;
                }
                self.add_delta(delta);
                self.hist("stall_seconds", STALL_BUCKETS, delta.stall);
                let row = self.stall_by_layer.entry(*layer as usize).or_default();
                row.events += 1;
                row.seconds += delta.stall;
            }
            TraceEvent::CacheInsert { expert, .. } => {
                self.count("cache_inserts");
                self.churn.entry(*expert as usize).or_default().loads += 1;
            }
            TraceEvent::CacheEvict { expert, .. } => {
                self.count("cache_evictions");
                self.churn.entry(*expert as usize).or_default().evictions += 1;
            }
            TraceEvent::PinProtected { expert, .. } => {
                self.count("pin_protected");
                self.churn.entry(*expert as usize).or_default().pin_protected += 1;
            }
            TraceEvent::PinSet { .. } => self.count("pins_set"),
            TraceEvent::PinRelease { .. } => self.count("pins_released"),
            TraceEvent::Suspend { .. } => self.count("suspends"),
            TraceEvent::Resume { .. } => self.count("resumes"),
            TraceEvent::Cancel { .. } => self.count("cancels"),
            TraceEvent::Reject { .. } => self.count("rejects"),
            TraceEvent::StreamStall { .. } => self.count("stream_stalls"),
            TraceEvent::Dispatch { .. } => self.count("dispatches"),
            TraceEvent::Crash { reclaimed, .. } => {
                self.count("crashes");
                self.count_n("seqs_reclaimed", *reclaimed as u64);
            }
            TraceEvent::Heartbeat { .. } => self.count("heartbeats"),
            TraceEvent::Retry { .. } => self.count("retries"),
            TraceEvent::Migrate { .. } => self.count("migrations"),
            TraceEvent::Corrupt { .. } => self.count("transfers_corrupt"),
            TraceEvent::TransferLost { .. } => self.count("transfers_lost"),
            TraceEvent::RequestFailed { .. } => self.count("requests_failed"),
            TraceEvent::Steal { live, .. } => {
                self.count("steals");
                if *live {
                    self.count("live_steals");
                }
            }
            TraceEvent::Promote { .. } => self.count("promotions"),
        }
    }

    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauge_max(k, *v);
        }
        for (k, h) in &other.histograms {
            match self.histograms.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.histograms.insert(k, h.clone());
                }
            }
        }
        for (e, c) in &other.churn {
            let row = self.churn.entry(*e).or_default();
            row.loads += c.loads;
            row.evictions += c.evictions;
            row.demand_misses += c.demand_misses;
            row.pin_protected += c.pin_protected;
        }
        for (l, st) in &other.stall_by_layer {
            let row = self.stall_by_layer.entry(*l).or_default();
            row.events += st.events;
            row.seconds += st.seconds;
        }
        self.pcie.stall += other.pcie.stall;
        self.pcie.overlapped += other.pcie.overlapped;
        self.pcie.h2d_seconds += other.pcie.h2d_seconds;
    }

    /// Full JSON snapshot (embedded as the `"melinoe"` key of the
    /// Chrome export; `trace summary` reads it back).
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.to_string(), num(*v as f64))).collect(),
        );
        let gauges =
            Json::Obj(self.gauges.iter().map(|(k, v)| (k.to_string(), num(*v))).collect());
        let churn = arr(self
            .churn
            .iter()
            .map(|(e, c)| {
                obj(vec![
                    ("expert", num(*e as f64)),
                    ("loads", num(c.loads as f64)),
                    ("evictions", num(c.evictions as f64)),
                    ("demand_misses", num(c.demand_misses as f64)),
                    ("pin_protected", num(c.pin_protected as f64)),
                ])
            })
            .collect());
        let stalls = arr(self
            .stall_by_layer
            .iter()
            .map(|(l, r)| {
                obj(vec![
                    ("layer", num(*l as f64)),
                    ("events", num(r.events as f64)),
                    ("seconds", num(r.seconds)),
                ])
            })
            .collect());
        let hists = arr(self
            .histograms
            .iter()
            .map(|(k, h)| {
                obj(vec![
                    ("name", s(*k)),
                    ("bounds", arr(h.bounds.iter().map(|b| num(*b)).collect())),
                    ("counts", arr(h.counts.iter().map(|c| num(*c as f64)).collect())),
                    ("sum", num(h.sum)),
                    ("n", num(h.n as f64)),
                ])
            })
            .collect());
        obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("pcie", obj(vec![
                ("stall_s", num(self.pcie.stall)),
                ("overlapped_s", num(self.pcie.overlapped)),
                ("h2d_s", num(self.pcie.h2d_seconds)),
            ])),
            ("churn", churn),
            ("stall_by_layer", stalls),
            ("histograms", hists),
        ])
    }
}

// ---------------------------------------------------------------- recorder

/// The live per-lane buffer behind an enabled recorder.
#[derive(Debug)]
struct Sink {
    lane: u32,
    name: String,
    events: Vec<Stamped>,
    registry: MetricsRegistry,
}

impl Sink {
    fn push(&mut self, t: f64, ev: TraceEvent) {
        self.registry.observe(t, &ev);
        self.events.push(Stamped { t, lane: self.lane, ev });
    }
}

/// The handle the engine / replica / scheduler hold.  Disabled is the
/// default and costs one null-check per emission site — no allocation,
/// no event construction survives past the (Copy) stack value.
#[derive(Debug, Default)]
pub struct Recorder {
    inner: Option<Box<Sink>>,
}

impl Recorder {
    /// The disabled recorder (`Default` is the same).
    pub fn off() -> Recorder {
        Recorder { inner: None }
    }

    /// An enabled recorder writing to `lane` (shown as the Perfetto
    /// process name).
    pub fn on(lane: u32, name: &str) -> Recorder {
        Recorder {
            inner: Some(Box::new(Sink {
                lane,
                name: name.to_string(),
                events: Vec::new(),
                registry: MetricsRegistry::default(),
            })),
        }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    pub fn emit(&mut self, t: f64, ev: TraceEvent) {
        if let Some(sink) = &mut self.inner {
            sink.push(t, ev);
        }
    }

    /// Drain into a [`Trace`], disabling the recorder.  `None` if it
    /// was never enabled.
    pub fn take(&mut self) -> Option<Trace> {
        self.inner.take().map(|sink| {
            let mut lanes = BTreeMap::new();
            lanes.insert(sink.lane, sink.name);
            Trace { events: sink.events, registry: sink.registry, lanes }
        })
    }
}

// ------------------------------------------------------------------- trace

/// A drained event stream with its registry and lane names.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<Stamped>,
    pub registry: MetricsRegistry,
    pub lanes: BTreeMap<u32, String>,
}

impl Trace {
    /// Append another lane's trace; events re-sort by (lane, time) so
    /// per-lane monotonicity survives merging interleaved lanes.
    pub fn merge(&mut self, other: Trace) {
        self.events.extend(other.events);
        self.registry.merge(&other.registry);
        self.lanes.extend(other.lanes);
        self.events.sort_by(|a, b| a.lane.cmp(&b.lane).then(a.t.total_cmp(&b.t)));
    }

    // ----------------------------------------------------------- audits

    /// Audit: within each lane, timestamps never go backwards.
    pub fn audit_lane_monotonic(&self) -> Result<()> {
        let mut last: BTreeMap<u32, f64> = BTreeMap::new();
        for e in &self.events {
            let prev = last.entry(e.lane).or_insert(f64::NEG_INFINITY);
            if e.t < *prev {
                bail!(
                    "lane {} time went backwards: {} after {} ({:?})",
                    e.lane,
                    e.t,
                    prev,
                    e.ev
                );
            }
            *prev = e.t;
        }
        Ok(())
    }

    /// Audit: trace-derived stall/overlap/h2d totals (the sum of every
    /// event's [`PcieDelta`]) match the engine's [`TransferStats`]
    /// within `tol`.  A missed emission site breaks this immediately.
    pub fn reconcile(&self, stats: &TransferStats, tol: f64) -> Result<()> {
        let p = &self.registry.pcie;
        for (name, trace, engine) in [
            ("stall", p.stall, stats.stall_time),
            ("overlapped", p.overlapped, stats.overlapped_time),
            ("h2d_seconds", p.h2d_seconds, stats.h2d_seconds),
        ] {
            if (trace - engine).abs() > tol {
                bail!(
                    "trace/stats {name} mismatch: trace {trace} vs TransferStats {engine} \
                     (tol {tol})"
                );
            }
        }
        // the per-tier byte counters must partition the aggregates
        // (relative tolerance: byte totals are ~GB-scale)
        for (name, total, by_tier) in [
            ("h2d_bytes", stats.h2d_bytes, &stats.h2d_bytes_by_tier),
            ("d2h_bytes", stats.d2h_bytes, &stats.d2h_bytes_by_tier),
        ] {
            let sum: f64 = by_tier.iter().sum();
            if (sum - total).abs() > tol * total.max(1.0) {
                bail!("per-tier {name} counters sum to {sum}, aggregate is {total} (tol {tol})");
            }
        }
        Ok(())
    }

    /// Audit: every `PrefetchIssued` was consumed by exactly one
    /// `TransferLanded`, lost to a link flap, discarded checksum-corrupt,
    /// or is still on the link at end of run.  Fault-free the lost /
    /// corrupt counters are absent and this is the original exact
    /// issued == landed + in-flight conservation.
    pub fn audit_prefetch_landed(&self, in_flight: usize) -> Result<()> {
        let c = |k: &str| self.registry.counters.get(k).copied().unwrap_or(0);
        let issued = c("prefetch_issued");
        let landed = c("transfer_landed");
        let lost = c("transfers_lost");
        let corrupt = c("transfers_corrupt");
        if issued != landed + lost + corrupt + in_flight as u64 {
            bail!(
                "prefetch/landed mismatch: {issued} issued != {landed} landed + \
                 {lost} lost + {corrupt} corrupt + {in_flight} in flight"
            );
        }
        Ok(())
    }

    /// Audit: fault-recovery conservation.  Every request reclaimed by
    /// a fault (`injected`) either resolved a non-Failed terminal
    /// outcome (`recovered`) or exhausted its retry budget (`failed`) —
    /// no request vanishes.  The trace's `requests_failed` counter must
    /// agree with the coordinator's `failed` stat, and a non-zero
    /// injection count must be witnessed by at least one `Crash` or
    /// `Migrate` event in the stream.
    pub fn audit_recovery(&self, injected: u64, recovered: u64, failed: u64) -> Result<()> {
        if injected != recovered + failed {
            bail!(
                "recovery conservation broken: {injected} injected != \
                 {recovered} recovered + {failed} failed"
            );
        }
        let traced = self.registry.counters.get("requests_failed").copied().unwrap_or(0);
        if traced != failed {
            bail!("trace counts {traced} failed requests, coordinator counts {failed}");
        }
        if injected > 0 {
            let crashes = self.registry.counters.get("crashes").copied().unwrap_or(0);
            let migrations = self.registry.counters.get("migrations").copied().unwrap_or(0);
            if crashes + migrations == 0 {
                bail!("{injected} requests reclaimed but no Crash/Migrate event in trace");
            }
        }
        Ok(())
    }

    /// Audit: work-stealing / promotion conservation.  The trace's
    /// `steals` and `promotions` counters must agree with the engine's
    /// own tallies — a steal or promotion that mutated scheduler state
    /// without leaving an event in the stream (or vice versa) breaks
    /// this immediately.
    pub fn audit_steal_promote(&self, steals: u64, promotions: u64) -> Result<()> {
        let c = |k: &str| self.registry.counters.get(k).copied().unwrap_or(0);
        if c("steals") != steals {
            bail!("trace counts {} steals, engine counts {steals}", c("steals"));
        }
        if c("promotions") != promotions {
            bail!("trace counts {} promotions, engine counts {promotions}", c("promotions"));
        }
        Ok(())
    }

    /// Audit: replaying `PinSet`/`PinRelease` yields the cache's final
    /// ledger population (`pinned_owners`).
    pub fn audit_pins(&self, pinned_owners: usize) -> Result<()> {
        let mut owners = std::collections::HashSet::new();
        for e in &self.events {
            match e.ev {
                TraceEvent::PinSet { owner } => {
                    owners.insert(owner);
                }
                TraceEvent::PinRelease { owner } => {
                    owners.remove(&owner);
                }
                _ => {}
            }
        }
        if owners.len() != pinned_owners {
            bail!(
                "pin-ledger mismatch: trace replay holds {} owners, cache ledger holds {}",
                owners.len(),
                pinned_owners
            );
        }
        Ok(())
    }

    /// Audit: per layer, `#CacheInsert − #CacheEvict` plus the little
    /// store's `#LittleInstall − #LittleEvict` equals the cache's final
    /// occupancy across both tiers (`LayerCache::occupancy_len`), so
    /// the replay balances at every tier mix.
    pub fn audit_occupancy(&self, resident_by_layer: &[usize]) -> Result<()> {
        let mut net: BTreeMap<u32, i64> = BTreeMap::new();
        for e in &self.events {
            match e.ev {
                TraceEvent::CacheInsert { layer, .. }
                | TraceEvent::LittleInstall { layer, .. } => *net.entry(layer).or_insert(0) += 1,
                TraceEvent::CacheEvict { layer, .. } | TraceEvent::LittleEvict { layer, .. } => {
                    *net.entry(layer).or_insert(0) -= 1
                }
                _ => {}
            }
        }
        for (layer, resident) in resident_by_layer.iter().enumerate() {
            let traced = net.get(&(layer as u32)).copied().unwrap_or(0);
            if traced != *resident as i64 {
                bail!(
                    "occupancy mismatch at layer {layer}: trace nets {traced} residents, \
                     cache holds {resident}"
                );
            }
        }
        Ok(())
    }

    // ----------------------------------------------------------- exports

    /// The metrics snapshot embedded in `ext_*` repro JSON rows: the
    /// registry counters plus both sides of the reconciliation (trace
    /// totals and the engine's `TransferStats` totals), so
    /// `scripts/check_repro.py` can gate on the 1e-6 agreement.
    pub fn metrics_json(&self, stall_s: f64, overlapped_s: f64, h2d_s: f64) -> Json {
        let counters = Json::Obj(
            self.registry
                .counters
                .iter()
                .map(|(k, v)| (k.to_string(), num(*v as f64)))
                .collect(),
        );
        obj(vec![
            ("events", num(self.events.len() as f64)),
            ("counters", counters),
            ("trace_stall_s", num(self.registry.pcie.stall)),
            ("trace_overlapped_s", num(self.registry.pcie.overlapped)),
            ("trace_h2d_s", num(self.registry.pcie.h2d_seconds)),
            ("stats_stall_s", num(stall_s)),
            ("stats_overlapped_s", num(overlapped_s)),
            ("stats_h2d_s", num(h2d_s)),
        ])
    }

    /// Chrome trace-event / Perfetto JSON.  Open at <https://ui.perfetto.dev>
    /// or `chrome://tracing`.  Layout: one *process* (pid) per lane
    /// (replica or dispatcher), and per lane one *thread* each for
    /// compute (step spans + stall slices), the PCIe link (transfer
    /// spans + landing instants), and the scheduler (cache/pin/request
    /// instants).  Timestamps are simulated microseconds.  The full
    /// [`MetricsRegistry`] snapshot rides along under the `"melinoe"`
    /// key.
    pub fn to_chrome_json(&self) -> Json {
        const TID_COMPUTE: f64 = 0.0;
        const TID_LINK: f64 = 1.0;
        const TID_SCHED: f64 = 2.0;
        let us = |t: f64| num(t * 1e6);
        let mut evs: Vec<Json> = Vec::new();
        // metadata: lane names + fixed thread names
        for (lane, name) in &self.lanes {
            let pid = num(*lane as f64);
            evs.push(obj(vec![
                ("ph", s("M")),
                ("name", s("process_name")),
                ("pid", pid.clone()),
                ("args", obj(vec![("name", s(name.clone()))])),
            ]));
            for (tid, tname) in
                [(TID_COMPUTE, "compute"), (TID_LINK, "pcie link"), (TID_SCHED, "scheduler")]
            {
                evs.push(obj(vec![
                    ("ph", s("M")),
                    ("name", s("thread_name")),
                    ("pid", pid.clone()),
                    ("tid", num(tid)),
                    ("args", obj(vec![("name", s(tname))])),
                ]));
            }
        }
        let instant = |t: f64, lane: u32, tid: f64, name: &str, args: Vec<(&str, Json)>| {
            obj(vec![
                ("ph", s("i")),
                ("name", s(name)),
                ("pid", num(lane as f64)),
                ("tid", num(tid)),
                ("ts", us(t)),
                ("s", s("t")),
                ("args", obj(args)),
            ])
        };
        for e in &self.events {
            let pid = num(e.lane as f64);
            match e.ev {
                TraceEvent::StepStart { tokens, batch } => evs.push(obj(vec![
                    ("ph", s("B")),
                    ("name", s("step")),
                    ("pid", pid),
                    ("tid", num(TID_COMPUTE)),
                    ("ts", us(e.t)),
                    ("args", obj(vec![
                        ("tokens", num(tokens as f64)),
                        ("batch", num(batch as f64)),
                    ])),
                ])),
                TraceEvent::StepEnd { .. } => evs.push(obj(vec![
                    ("ph", s("E")),
                    ("name", s("step")),
                    ("pid", pid),
                    ("tid", num(TID_COMPUTE)),
                    ("ts", us(e.t)),
                ])),
                TraceEvent::DemandStall { layer, expert, tier, residual, delta } => {
                    // the stall occupied [t - stall, t] on the compute lane
                    let dur = delta.stall.max(0.0);
                    evs.push(obj(vec![
                        ("ph", s("X")),
                        ("name", s(if residual { "residual wait" } else { "demand stall" })),
                        ("pid", pid),
                        ("tid", num(TID_COMPUTE)),
                        ("ts", us(e.t - dur)),
                        ("dur", us(dur)),
                        ("args", obj(vec![
                            ("layer", num(layer as f64)),
                            ("expert", num(expert as f64)),
                            ("tier", s(tier_name(tier))),
                            ("stall_s", num(delta.stall)),
                        ])),
                    ]));
                }
                TraceEvent::PrefetchIssued { layer, expert, tier, delta } => evs.push(obj(vec![
                    ("ph", s("X")),
                    ("name", s("prefetch")),
                    ("pid", pid),
                    ("tid", num(TID_LINK)),
                    ("ts", us(e.t)),
                    ("dur", us(delta.h2d_seconds.max(0.0))),
                    ("args", obj(vec![
                        ("layer", num(layer as f64)),
                        ("expert", num(expert as f64)),
                        ("tier", s(tier_name(tier))),
                    ])),
                ])),
                TraceEvent::TransferLanded { layer, expert, tier } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_LINK,
                    "landed",
                    vec![
                        ("layer", num(layer as f64)),
                        ("expert", num(expert as f64)),
                        ("tier", s(tier_name(tier))),
                    ],
                )),
                TraceEvent::LittleInstall { layer, expert, tier, delta } => evs.push(obj(vec![
                    ("ph", s("X")),
                    ("name", s("little install")),
                    ("pid", pid),
                    ("tid", num(TID_LINK)),
                    ("ts", us(e.t)),
                    ("dur", us(delta.h2d_seconds.max(0.0))),
                    ("args", obj(vec![
                        ("layer", num(layer as f64)),
                        ("expert", num(expert as f64)),
                        ("tier", s(tier_name(tier))),
                    ])),
                ])),
                TraceEvent::LittleEvict { layer, expert } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "little evict",
                    vec![("layer", num(layer as f64)), ("expert", num(expert as f64))],
                )),
                TraceEvent::DegradedExec { layer, expert, tier } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_COMPUTE,
                    "degraded exec",
                    vec![
                        ("layer", num(layer as f64)),
                        ("expert", num(expert as f64)),
                        ("tier", s(tier_name(tier))),
                    ],
                )),
                TraceEvent::RequestAdmit { seq } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "admit",
                    vec![("seq", num(seq as f64))],
                )),
                TraceEvent::RequestRetire { seq, output_tokens } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "retire",
                    vec![
                        ("seq", num(seq as f64)),
                        ("output_tokens", num(output_tokens as f64)),
                    ],
                )),
                TraceEvent::PrefillChunk { seq, tokens } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "prefill chunk",
                    vec![("seq", num(seq as f64)), ("tokens", num(tokens as f64))],
                )),
                TraceEvent::CacheInsert { layer, expert } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "cache insert",
                    vec![("layer", num(layer as f64)), ("expert", num(expert as f64))],
                )),
                TraceEvent::CacheEvict { layer, expert } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "cache evict",
                    vec![("layer", num(layer as f64)), ("expert", num(expert as f64))],
                )),
                TraceEvent::PinProtected { layer, expert } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "pin protected",
                    vec![("layer", num(layer as f64)), ("expert", num(expert as f64))],
                )),
                TraceEvent::PinSet { owner } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "pin set",
                    vec![("owner", num(owner as f64))],
                )),
                TraceEvent::PinRelease { owner } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "pin release",
                    vec![("owner", num(owner as f64))],
                )),
                TraceEvent::Suspend { seq } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "suspend",
                    vec![("seq", num(seq as f64))],
                )),
                TraceEvent::Resume { seq } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "resume",
                    vec![("seq", num(seq as f64))],
                )),
                TraceEvent::Cancel { seq } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "cancel",
                    vec![("seq", num(seq as f64))],
                )),
                TraceEvent::Reject { seq } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "reject",
                    vec![("seq", num(seq as f64))],
                )),
                TraceEvent::StreamStall { seq } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "stream stall",
                    vec![("seq", num(seq as f64))],
                )),
                TraceEvent::Dispatch { request, replica, score } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "dispatch",
                    vec![
                        ("request", num(request as f64)),
                        ("replica", num(replica as f64)),
                        ("score", num(score)),
                    ],
                )),
                TraceEvent::Crash { replica, reclaimed } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "crash",
                    vec![
                        ("replica", num(replica as f64)),
                        ("reclaimed", num(reclaimed as f64)),
                    ],
                )),
                TraceEvent::Heartbeat { replica, phi } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "heartbeat",
                    vec![("replica", num(replica as f64)), ("phi", num(phi))],
                )),
                TraceEvent::Retry { request, attempt, replica } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "retry",
                    vec![
                        ("request", num(request as f64)),
                        ("attempt", num(attempt as f64)),
                        ("replica", num(replica as f64)),
                    ],
                )),
                TraceEvent::Migrate { request, from, to } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "migrate",
                    vec![
                        ("request", num(request as f64)),
                        ("from", num(from as f64)),
                        ("to", num(to as f64)),
                    ],
                )),
                TraceEvent::Corrupt { layer, expert } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_LINK,
                    "corrupt transfer",
                    vec![("layer", num(layer as f64)), ("expert", num(expert as f64))],
                )),
                TraceEvent::TransferLost { layer, expert } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_LINK,
                    "transfer lost",
                    vec![("layer", num(layer as f64)), ("expert", num(expert as f64))],
                )),
                TraceEvent::RequestFailed { request } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "request failed",
                    vec![("request", num(request as f64))],
                )),
                TraceEvent::Steal { request, from, to, live } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "steal",
                    vec![
                        ("request", num(request as f64)),
                        ("from", num(from as f64)),
                        ("to", num(to as f64)),
                        ("live", num(if live { 1.0 } else { 0.0 })),
                    ],
                )),
                TraceEvent::Promote { request, to } => evs.push(instant(
                    e.t,
                    e.lane,
                    TID_SCHED,
                    "promote",
                    vec![("request", num(request as f64)), ("to", num(to as f64))],
                )),
            }
        }
        obj(vec![
            ("traceEvents", arr(evs)),
            ("displayTimeUnit", s("ms")),
            ("melinoe", self.registry.to_json()),
        ])
    }
}

// ---------------------------------------------------------- trace summary

/// Render the `trace summary` tables from the `"melinoe"` registry
/// snapshot of an exported Chrome JSON: top-`top_n` churned experts and
/// stall events by layer (plus the raw counters).
pub fn summary_tables(registry: &Json, top_n: usize) -> Result<Vec<(String, Table)>> {
    let mut out = Vec::new();

    let mut counters = Table::new(&["counter", "value"]);
    for (k, v) in registry.get("counters")?.as_obj()? {
        counters.row(vec![k.clone(), format!("{}", v.as_f64()? as u64)]);
    }
    out.push(("counters".to_string(), counters));

    let mut rows: Vec<(u64, u64, u64, u64, usize)> = Vec::new();
    for row in registry.get("churn")?.as_arr()? {
        rows.push((
            row.get("loads")?.as_f64()? as u64,
            row.get("evictions")?.as_f64()? as u64,
            row.get("demand_misses")?.as_f64()? as u64,
            row.get("pin_protected")?.as_f64()? as u64,
            row.get("expert")?.as_usize()?,
        ));
    }
    // most-churned first: loads + evictions, then demand misses
    rows.sort_by(|a, b| (b.0 + b.1, b.2).cmp(&(a.0 + a.1, a.2)));
    let mut churn = Table::new(&["expert", "loads", "evictions", "demand misses", "pin protected"]);
    for (loads, evs, misses, pinned, expert) in rows.into_iter().take(top_n.max(1)) {
        churn.row(vec![
            expert.to_string(),
            loads.to_string(),
            evs.to_string(),
            misses.to_string(),
            pinned.to_string(),
        ]);
    }
    out.push((format!("top {} churned experts", top_n.max(1)), churn));

    let mut stalls = Table::new(&["layer", "stall events", "stall seconds"]);
    for row in registry.get("stall_by_layer")?.as_arr()? {
        stalls.row(vec![
            row.get("layer")?.as_usize()?.to_string(),
            format!("{}", row.get("events")?.as_f64()? as u64),
            format!("{:.4}", row.get("seconds")?.as_f64()?),
        ]);
    }
    out.push(("stall events by layer".to_string(), stalls));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(stall: f64, overlapped: f64, h2d: f64) -> PcieDelta {
        PcieDelta { stall, overlapped, h2d_seconds: h2d }
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let mut r = Recorder::off();
        assert!(!r.enabled());
        r.emit(1.0, TraceEvent::StepStart { tokens: 1, batch: 1 });
        assert!(r.take().is_none());
        assert!(!Recorder::default().enabled());
    }

    #[test]
    fn recorder_collects_and_registry_counts() {
        let mut r = Recorder::on(3, "replica 3");
        r.emit(0.0, TraceEvent::RequestAdmit { seq: 7 });
        r.emit(0.1, TraceEvent::StepStart { tokens: 2, batch: 2 });
        r.emit(
            0.2,
            TraceEvent::DemandStall {
                layer: 1,
                expert: 4,
                tier: 0,
                residual: false,
                delta: d(0.05, 0.0, 0.05),
            },
        );
        r.emit(0.2, TraceEvent::CacheInsert { layer: 1, expert: 4 });
        r.emit(0.3, TraceEvent::StepEnd { tokens: 2, batch: 2 });
        r.emit(0.4, TraceEvent::RequestRetire { seq: 7, output_tokens: 5 });
        let tr = r.take().expect("enabled recorder drains");
        assert!(!r.enabled(), "take disables");
        assert_eq!(tr.events.len(), 6);
        assert_eq!(tr.lanes.get(&3).map(|s| s.as_str()), Some("replica 3"));
        let c = &tr.registry.counters;
        assert_eq!(c.get("requests_admitted"), Some(&1));
        assert_eq!(c.get("demand_misses"), Some(&1));
        assert_eq!(c.get("cache_inserts"), Some(&1));
        assert_eq!(c.get("steps"), Some(&1));
        assert_eq!(tr.registry.churn.get(&4).unwrap().demand_misses, 1);
        assert_eq!(tr.registry.churn.get(&4).unwrap().loads, 1);
        assert_eq!(tr.registry.stall_by_layer.get(&1).unwrap().events, 1);
        assert!((tr.registry.pcie.stall - 0.05).abs() < 1e-12);
        assert_eq!(tr.registry.gauges.get("sim_time"), Some(&0.4));
        tr.audit_lane_monotonic().unwrap();
    }

    #[test]
    fn histogram_buckets_and_merge() {
        let mut h = Histogram::new(STALL_BUCKETS);
        h.record(5e-5); // <= 1e-4
        h.record(0.5); // <= 1.0
        h.record(10.0); // overflow
        assert_eq!(h.counts, vec![1, 0, 0, 0, 1, 1]);
        assert_eq!(h.n, 3);
        let mut h2 = Histogram::new(STALL_BUCKETS);
        h2.record(5e-5);
        h.merge(&h2);
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.n, 4);
    }

    #[test]
    fn merge_sorts_per_lane_and_sums_registries() {
        let mut a = Recorder::on(0, "replica 0");
        a.emit(0.2, TraceEvent::StepStart { tokens: 1, batch: 1 });
        a.emit(0.4, TraceEvent::StepEnd { tokens: 1, batch: 1 });
        let mut b = Recorder::on(1, "replica 1");
        b.emit(0.1, TraceEvent::StepStart { tokens: 1, batch: 1 });
        b.emit(0.3, TraceEvent::StepEnd { tokens: 1, batch: 1 });
        let mut tr = a.take().unwrap();
        tr.merge(b.take().unwrap());
        assert_eq!(tr.events.len(), 4);
        assert_eq!(tr.lanes.len(), 2);
        assert_eq!(tr.registry.counters.get("steps"), Some(&2));
        tr.audit_lane_monotonic().unwrap();
        // lanes are grouped and time-ordered within each
        assert_eq!(tr.events[0].lane, 0);
        assert_eq!(tr.events[3].lane, 1);
    }

    #[test]
    fn reconcile_catches_missing_delta() {
        let mut r = Recorder::on(0, "x");
        r.emit(
            0.1,
            TraceEvent::PrefetchIssued { layer: 0, expert: 1, tier: 1, delta: d(0.0, 0.02, 0.02) },
        );
        let tr = r.take().unwrap();
        let mut stats = TransferStats {
            overlapped_time: 0.02,
            h2d_seconds: 0.02,
            ..TransferStats::default()
        };
        tr.reconcile(&stats, 1e-6).unwrap();
        stats.stall_time = 0.5; // an unemitted demand stall
        assert!(tr.reconcile(&stats, 1e-6).is_err());
        stats.stall_time = 0.0;
        // per-tier byte counters that do not partition the aggregate fail
        stats.h2d_bytes = 100.0;
        stats.h2d_bytes_by_tier = [50.0, 25.0, 0.0];
        assert!(tr.reconcile(&stats, 1e-6).is_err());
        stats.h2d_bytes_by_tier = [50.0, 25.0, 25.0];
        tr.reconcile(&stats, 1e-6).unwrap();
    }

    #[test]
    fn prefetch_landed_audit() {
        let mut r = Recorder::on(0, "x");
        let dl = d(0.0, 0.02, 0.02);
        r.emit(0.1, TraceEvent::PrefetchIssued { layer: 0, expert: 1, tier: 0, delta: dl });
        r.emit(0.2, TraceEvent::PrefetchIssued { layer: 0, expert: 2, tier: 0, delta: dl });
        r.emit(0.3, TraceEvent::TransferLanded { layer: 0, expert: 1, tier: 0 });
        let tr = r.take().unwrap();
        tr.audit_prefetch_landed(1).unwrap(); // one still in flight
        assert!(tr.audit_prefetch_landed(0).is_err());
    }

    #[test]
    fn prefetch_audit_admits_lost_and_corrupt_transfers() {
        let mut r = Recorder::on(0, "x");
        let dl = d(0.0, 0.02, 0.02);
        for e in 0..4 {
            r.emit(0.1, TraceEvent::PrefetchIssued { layer: 0, expert: e, tier: 0, delta: dl });
        }
        r.emit(0.2, TraceEvent::TransferLanded { layer: 0, expert: 0, tier: 0 });
        r.emit(0.3, TraceEvent::TransferLost { layer: 0, expert: 1 });
        r.emit(0.4, TraceEvent::Corrupt { layer: 0, expert: 2 });
        let tr = r.take().unwrap();
        // 4 issued = 1 landed + 1 lost + 1 corrupt + 1 in flight
        tr.audit_prefetch_landed(1).unwrap();
        assert!(tr.audit_prefetch_landed(0).is_err());
        let c = &tr.registry.counters;
        assert_eq!(c.get("transfers_lost"), Some(&1));
        assert_eq!(c.get("transfers_corrupt"), Some(&1));
    }

    #[test]
    fn recovery_audit_conserves_reclaimed_requests() {
        let mut r = Recorder::on(0, "sched");
        r.emit(1.0, TraceEvent::Crash { replica: 0, reclaimed: 3 });
        r.emit(1.5, TraceEvent::Retry { request: 7, attempt: 1, replica: 1 });
        r.emit(2.0, TraceEvent::RequestFailed { request: 9 });
        let tr = r.take().unwrap();
        let c = &tr.registry.counters;
        assert_eq!(c.get("crashes"), Some(&1));
        assert_eq!(c.get("seqs_reclaimed"), Some(&3));
        assert_eq!(c.get("retries"), Some(&1));
        assert_eq!(c.get("requests_failed"), Some(&1));
        tr.audit_recovery(3, 2, 1).unwrap();
        // conservation: injected != recovered + failed
        assert!(tr.audit_recovery(3, 3, 1).is_err());
        // trace/coordinator failed-count disagreement
        assert!(tr.audit_recovery(3, 1, 2).is_err());
        // injection witnessed by no Crash/Migrate event
        let empty = Recorder::on(1, "y").take().unwrap();
        assert!(empty.audit_recovery(1, 1, 0).is_err());
        empty.audit_recovery(0, 0, 0).unwrap();
    }

    #[test]
    fn fault_events_export_to_chrome() {
        let mut r = Recorder::on(0, "sched");
        r.emit(0.1, TraceEvent::Heartbeat { replica: 1, phi: 0.4 });
        r.emit(0.2, TraceEvent::Crash { replica: 1, reclaimed: 2 });
        r.emit(0.3, TraceEvent::Migrate { request: 4, from: 1, to: 0 });
        r.emit(0.4, TraceEvent::TransferLost { layer: 0, expert: 3 });
        r.emit(0.5, TraceEvent::Corrupt { layer: 1, expert: 5 });
        r.emit(0.6, TraceEvent::Retry { request: 4, attempt: 1, replica: 0 });
        r.emit(0.7, TraceEvent::RequestFailed { request: 8 });
        let tr = r.take().unwrap();
        let j = tr.to_chrome_json().to_string();
        let names = [
            "heartbeat",
            "crash",
            "migrate",
            "transfer lost",
            "corrupt transfer",
            "retry",
            "request failed",
        ];
        for name in names {
            assert!(j.contains(name), "{name} missing from chrome export");
        }
        let back = Json::parse(&j).unwrap();
        // 4 metadata (1 process + 3 threads) + 7 events
        assert_eq!(back.get("traceEvents").unwrap().as_arr().unwrap().len(), 11);
    }

    #[test]
    fn pin_and_occupancy_audits() {
        let mut r = Recorder::on(0, "x");
        r.emit(0.0, TraceEvent::PinSet { owner: 1 });
        r.emit(0.0, TraceEvent::PinSet { owner: 2 });
        r.emit(0.1, TraceEvent::PinSet { owner: 1 }); // re-pin is a set no-op
        r.emit(0.2, TraceEvent::PinRelease { owner: 2 });
        r.emit(0.0, TraceEvent::CacheInsert { layer: 0, expert: 1 });
        r.emit(0.1, TraceEvent::CacheInsert { layer: 0, expert: 2 });
        r.emit(0.2, TraceEvent::CacheEvict { layer: 0, expert: 1 });
        let tr = r.take().unwrap();
        tr.audit_pins(1).unwrap();
        assert!(tr.audit_pins(2).is_err());
        tr.audit_occupancy(&[1]).unwrap();
        assert!(tr.audit_occupancy(&[2]).is_err());
    }

    #[test]
    fn occupancy_audit_balances_with_mixed_tiers() {
        // big inserts/evicts and little installs/evicts replay together:
        // layer 0 nets two big + one little resident, layer 1 nets one
        // little after a displacement
        let mut r = Recorder::on(0, "x");
        r.emit(0.0, TraceEvent::CacheInsert { layer: 0, expert: 1 });
        r.emit(0.1, TraceEvent::CacheInsert { layer: 0, expert: 2 });
        let dl = d(0.0, 0.01, 0.01);
        r.emit(0.1, TraceEvent::LittleInstall { layer: 0, expert: 5, tier: 2, delta: dl });
        r.emit(0.2, TraceEvent::LittleInstall { layer: 1, expert: 7, tier: 2, delta: dl });
        r.emit(0.3, TraceEvent::LittleInstall { layer: 1, expert: 8, tier: 2, delta: dl });
        r.emit(0.3, TraceEvent::LittleEvict { layer: 1, expert: 7 });
        r.emit(0.4, TraceEvent::DegradedExec { layer: 1, expert: 8, tier: 2 });
        let tr = r.take().unwrap();
        tr.audit_occupancy(&[3, 1]).unwrap();
        assert!(tr.audit_occupancy(&[2, 1]).is_err(), "little copies count toward occupancy");
        let c = &tr.registry.counters;
        assert_eq!(c.get("little_installs"), Some(&3));
        assert_eq!(c.get("little_evictions"), Some(&1));
        assert_eq!(c.get("degraded_execs"), Some(&1));
        // the little installs' untracked transfer deltas reconcile
        let stats = TransferStats {
            overlapped_time: 0.03,
            h2d_seconds: 0.03,
            ..TransferStats::default()
        };
        tr.reconcile(&stats, 1e-6).unwrap();
    }

    #[test]
    fn chrome_export_and_summary_roundtrip() {
        let mut r = Recorder::on(0, "replica 0");
        r.emit(0.0, TraceEvent::StepStart { tokens: 2, batch: 2 });
        r.emit(
            0.01,
            TraceEvent::DemandStall {
                layer: 2,
                expert: 9,
                tier: 1,
                residual: true,
                delta: d(0.004, -0.001, 0.0),
            },
        );
        r.emit(0.01, TraceEvent::CacheInsert { layer: 2, expert: 9 });
        r.emit(0.02, TraceEvent::StepEnd { tokens: 2, batch: 2 });
        r.emit(0.03, TraceEvent::Dispatch { request: 5, replica: 0, score: 0.75 });
        let tr = r.take().unwrap();
        let j = tr.to_chrome_json();
        // survives our own parser (what `trace summary` does)
        let back = Json::parse(&j.to_string()).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 4 metadata (1 process + 3 threads) + 5 events
        assert_eq!(evs.len(), 9);
        assert!(j.to_string().contains("\"displayTimeUnit\""));
        let reg = back.get("melinoe").unwrap();
        let tables = summary_tables(reg, 5).unwrap();
        assert_eq!(tables.len(), 3);
        let churn = tables[1].1.render();
        assert!(churn.contains('9'), "expert 9 appears in the churn table: {churn}");
        let stalls = tables[2].1.render();
        assert!(stalls.contains('2'), "layer 2 appears in the stall table: {stalls}");
    }

    #[test]
    fn metrics_json_shape() {
        let mut r = Recorder::on(0, "x");
        r.emit(
            0.1,
            TraceEvent::DemandStall {
                layer: 0,
                expert: 3,
                tier: 0,
                residual: false,
                delta: d(0.2, 0.0, 0.2),
            },
        );
        let tr = r.take().unwrap();
        let j = tr.metrics_json(0.2, 0.0, 0.2);
        assert_eq!(j.get("trace_stall_s").unwrap().as_f64().unwrap(), 0.2);
        assert_eq!(j.get("stats_stall_s").unwrap().as_f64().unwrap(), 0.2);
        assert_eq!(
            j.get("counters").unwrap().get("demand_misses").unwrap().as_f64().unwrap(),
            1.0
        );
    }
}
