//! PCIe transfer engine: H2D/D2H accounting + async overlap model.
//!
//! Every expert-cache miss becomes a host-to-device transfer here; every
//! eviction a device-to-host buffer release.  The engine mirrors the
//! post-deployment mechanics of §3.2: offloaded experts live in *pinned*
//! host memory and transfers are issued *non-blocking*, so a transfer
//! whose issue time precedes the consuming kernel can partially overlap.
//! Counters feed Fig. 1a (transfer counts) and the Tx/L columns of
//! Table 3 / Figs. 12–13.

use crate::clock::{CostModel, SimClock};
use crate::quant::QuantMode;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    H2D,
    D2H,
}

/// Aggregate transfer statistics.
#[derive(Debug, Clone, Default)]
pub struct TransferStats {
    pub h2d_count: u64,
    pub d2h_count: u64,
    pub h2d_bytes: f64,
    pub d2h_bytes: f64,
    pub stall_time: f64,
    pub overlapped_time: f64,
}

impl TransferStats {
    pub fn total_count(&self) -> u64 {
        self.h2d_count + self.d2h_count
    }
}

/// Transfer engine with a single-link occupancy model: the PCIe link frees
/// at `link_free`; a non-blocking transfer issued early may overlap with
/// compute, a demand miss stalls the decode for its full duration.
#[derive(Debug, Clone)]
pub struct TransferEngine {
    pub pinned_host: bool,
    pub stats: TransferStats,
    link_free: f64,
}

impl TransferEngine {
    pub fn new() -> TransferEngine {
        TransferEngine { pinned_host: true, stats: TransferStats::default(), link_free: 0.0 }
    }

    /// Demand-fetch one expert: the decode stalls until the transfer
    /// completes (paper Eq. 3's N_miss · Time_transfer term).  Returns the
    /// stall duration applied to `clock`.
    pub fn demand_h2d(&mut self, cm: &CostModel, clock: &mut SimClock, mode: QuantMode) -> f64 {
        let mut dt = cm.transfer_time(mode);
        if !self.pinned_host {
            // pageable host memory roughly halves effective PCIe bandwidth
            dt += cm.dims.expert_bytes(mode) / cm.gpu.pcie_bw;
        }
        // serialize on the link
        let start = clock.now().max(self.link_free);
        let wait = start - clock.now();
        self.link_free = start + dt;
        let stall = wait + dt;
        clock.advance(stall);
        self.stats.h2d_count += 1;
        self.stats.h2d_bytes += cm.dims.expert_bytes(mode);
        self.stats.stall_time += stall;
        stall
    }

    /// Prefetch one expert (non-blocking): occupies the link but does not
    /// stall the clock; the caller advances the clock only if decode
    /// catches up with the link (`sync_prefetches`).
    pub fn prefetch_h2d(&mut self, cm: &CostModel, clock: &SimClock, mode: QuantMode) {
        let dt = cm.transfer_time(mode);
        let start = clock.now().max(self.link_free);
        self.link_free = start + dt;
        self.stats.h2d_count += 1;
        self.stats.h2d_bytes += cm.dims.expert_bytes(mode);
        self.stats.overlapped_time += dt;
    }

    /// Block until all issued prefetches have landed (start-of-decode
    /// barrier; the paper measures ~0.05 s here).  Returns the wait.
    pub fn sync_prefetches(&mut self, clock: &mut SimClock) -> f64 {
        let wait = (self.link_free - clock.now()).max(0.0);
        clock.advance(wait);
        self.stats.stall_time += wait;
        wait
    }

    /// Eviction: release a device buffer (counted as a D2H event — expert
    /// weights are read-only so no payload is written back, but buffer
    /// frees appear as D2H traffic in the paper's Fig. 1a profile).
    pub fn evict_d2h(&mut self, cm: &CostModel, mode: QuantMode) {
        self.stats.d2h_count += 1;
        self.stats.d2h_bytes += cm.dims.expert_bytes(mode);
    }
}

impl Default for TransferEngine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{GpuSpec, PaperDims};

    fn cm() -> CostModel {
        CostModel::new(
            GpuSpec::h100(),
            PaperDims { n_layers: 16, n_experts: 64, top_k: 8, d_model: 2048, d_ff: 1024, vocab: 50304 },
        )
    }

    #[test]
    fn demand_advances_clock_and_counts() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        let stall = eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16);
        assert!(stall > 0.0);
        assert_eq!(eng.stats.h2d_count, 1);
        assert!((clock.now() - stall).abs() < 1e-12);
    }

    #[test]
    fn link_serializes_transfers() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        let t1 = cm.transfer_time(QuantMode::Fp16);
        eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16);
        eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16);
        assert!((clock.now() - 2.0 * t1).abs() < 1e-9);
    }

    #[test]
    fn prefetch_does_not_stall() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        for _ in 0..4 {
            eng.prefetch_h2d(&cm, &clock, QuantMode::Int4);
        }
        assert_eq!(clock.now(), 0.0);
        assert_eq!(eng.stats.h2d_count, 4);
        // sync waits for the link
        let wait = eng.sync_prefetches(&mut clock);
        assert!(wait > 0.0);
        assert!((wait - 4.0 * cm.transfer_time(QuantMode::Int4)).abs() < 1e-9);
    }

    #[test]
    fn prefetch_overlap_reduces_stall_vs_demand() {
        let cm = cm();
        // scenario A: 4 demand misses
        let mut ca = SimClock::new();
        let mut ea = TransferEngine::new();
        for _ in 0..4 {
            ea.demand_h2d(&cm, &mut ca, QuantMode::Fp16);
        }
        // scenario B: 4 prefetches issued, then compute happens, then sync
        let mut cb = SimClock::new();
        let mut eb = TransferEngine::new();
        for _ in 0..4 {
            eb.prefetch_h2d(&cm, &cb, QuantMode::Fp16);
        }
        cb.advance(ca.now()); // same amount of compute
        eb.sync_prefetches(&mut cb);
        assert!(cb.now() <= ca.now() * 1.001 + 1e-12);
        assert!(eb.stats.stall_time < ea.stats.stall_time);
    }

    #[test]
    fn pageable_slower_than_pinned() {
        let cm = cm();
        let mut c1 = SimClock::new();
        let mut pinned = TransferEngine::new();
        pinned.demand_h2d(&cm, &mut c1, QuantMode::Fp16);
        let mut c2 = SimClock::new();
        let mut pageable = TransferEngine { pinned_host: false, ..TransferEngine::new() };
        pageable.demand_h2d(&cm, &mut c2, QuantMode::Fp16);
        assert!(c2.now() > c1.now());
    }

    #[test]
    fn eviction_counts_d2h() {
        let cm = cm();
        let mut eng = TransferEngine::new();
        eng.evict_d2h(&cm, QuantMode::Fp16);
        assert_eq!(eng.stats.d2h_count, 1);
        assert!(eng.stats.d2h_bytes > 0.0);
    }
}
