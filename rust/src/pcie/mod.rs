//! PCIe transfer engine: an asynchronous, link-serialized transfer
//! pipeline with in-flight residual-wait tracking.
//!
//! Every expert-cache miss becomes a host-to-device transfer here; every
//! eviction a device-to-host buffer release.  The engine mirrors the
//! post-deployment mechanics of §3.2: offloaded experts live in *pinned*
//! host memory and transfers are issued *non-blocking*, so a transfer
//! whose issue time precedes the consuming kernel can partially overlap
//! with compute.  Three issue paths share one FIFO link:
//!
//! * [`TransferEngine::demand_h2d`] — a cold miss: the decode stalls for
//!   the link-queue wait plus the full transfer (Eq. 3's
//!   `N_miss · Time_transfer` term).
//! * [`TransferEngine::prefetch_expert`] — tracked non-blocking
//!   prefetch, used both for the admit-time plan (residency set
//!   immediately by `LayerCache::prefill_union`) and for layer-ahead
//!   lookahead (residency commits when the transfer *lands*:
//!   [`TransferEngine::drain_arrived`] → `LayerCache::commit`).  Either
//!   way the in-flight `(layer, expert, completes_at)` entry means a
//!   decode that catches the transfer still on the link pays only the
//!   *residual* wait ([`TransferEngine::wait_for`]) instead of
//!   re-paying the full transfer.
//! * [`TransferEngine::prefetch_h2d`] — untracked non-blocking issue
//!   (optimistic overlap credit, never settled against stall windows).
//!   Used for *little-copy* installs — background traffic that never
//!   carries a claimable completion — and by barrier-style callers that
//!   pair it with [`TransferEngine::sync_prefetches`]; decode-critical
//!   traffic uses the tracked [`TransferEngine::prefetch_expert`].
//!
//! Accounting invariant: every transfer's duration lands in
//! `h2d_seconds`; the split between `stall_time` (decode blocked) and
//! `overlapped_time` (hidden behind compute) is settled at resolution —
//! a tracked transfer counts fully overlapped at issue and `wait_for`
//! moves the un-hidden residual share over to `stall_time`.  Counters
//! feed Fig. 1a (transfer counts), the Tx/L columns of Table 3 /
//! Figs. 12–13, and the overlap-fraction metric of `repro ext_overlap`.
//!
//! Transfers are *byte-accurate per tier*: every issue path takes the
//! [`QuantMode`] of the payload, so an int4 expert charges ~9/32 of the
//! fp16 link time and the per-tier byte counters
//! ([`TransferStats::h2d_bytes_by_tier`]) let the repro sweeps report
//! bytes-moved per precision alongside tok/s.  The sum of the per-tier
//! counters always equals the aggregate byte counters (the trace
//! `reconcile` audit checks this).

use crate::cache::LayerCache;
use crate::clock::{CostModel, SimClock};
use crate::quant::QuantMode;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    H2D,
    D2H,
}

/// Aggregate transfer statistics.
#[derive(Debug, Clone, Default)]
pub struct TransferStats {
    pub h2d_count: u64,
    pub d2h_count: u64,
    pub h2d_bytes: f64,
    pub d2h_bytes: f64,
    /// H2D bytes split by payload tier, indexed by [`QuantMode::idx`]
    /// (fp16/int4/int3).  Sums to `h2d_bytes` — `Trace::reconcile`
    /// asserts the balance to 1e-6.
    pub h2d_bytes_by_tier: [f64; 3],
    /// D2H bytes split by payload tier, indexed by [`QuantMode::idx`].
    pub d2h_bytes_by_tier: [f64; 3],
    /// Sum of H2D transfer durations on the link (queue waits excluded).
    pub h2d_seconds: f64,
    /// Decode time lost blocked on transfers: demand stalls (link wait +
    /// full duration), residual waits on caught in-flight prefetches, and
    /// explicit sync barriers.
    pub stall_time: f64,
    /// Transfer time hidden behind compute (prefetch traffic the decode
    /// never had to wait for).
    pub overlapped_time: f64,
}

impl TransferStats {
    pub fn total_count(&self) -> u64 {
        self.h2d_count + self.d2h_count
    }

    /// Fraction of transfer-related time hidden behind compute:
    /// `overlapped / (overlapped + stalled)`.
    pub fn overlap_fraction(&self) -> f64 {
        crate::metrics::overlap_fraction(self.overlapped_time, self.stall_time)
    }
}

/// One tracked transfer in flight on the PCIe link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InFlight {
    pub layer: usize,
    pub expert: usize,
    /// Transfer duration on the link (excludes queue wait ahead of it).
    pub duration: f64,
    /// Link-serialized completion time.
    pub completes_at: f64,
    /// Fault injection marked this transfer checksum-corrupt: it still
    /// occupies its link slot but never lands, is never claimable, and
    /// is removed by [`TransferEngine::take_corrupt`] once its link
    /// time elapses so the expert can be re-fetched.
    pub corrupt: bool,
}

/// What [`TransferEngine::commit_arrival`] did: whether the expert
/// ended up resident, whether this call made it resident (vs already
/// there), and which victim (if any) was evicted to make room.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommitOutcome {
    pub resident: bool,
    pub loaded: bool,
    pub evicted: Option<usize>,
}

/// Transfer engine over a single FIFO link: the link frees at
/// `link_free`, every issue serializes behind it, and tracked prefetches
/// carry per-expert completion times so a decode catching one mid-flight
/// charges only the residual wait.
#[derive(Debug, Clone)]
pub struct TransferEngine {
    pub pinned_host: bool,
    pub stats: TransferStats,
    link_free: f64,
    /// Link-flap bandwidth degradation: every transfer duration is
    /// multiplied by this factor.  `1.0` (the default) is nominal and
    /// bit-exact — `x * 1.0 == x` — so a never-flapped engine computes
    /// byte-identical timings to one without the field.
    slowdown: f64,
    /// Tracked transfers: link issues in FIFO order (`completes_at`
    /// non-decreasing at issue — a property test locks this in), plus
    /// landed-but-uncommitted staging entries re-queued by
    /// `track_landed` with `completes_at` in the past.  Consumers must
    /// not assume the Vec is sorted: `drain_arrived`/`wait_for` scan
    /// every entry.
    in_flight: Vec<InFlight>,
}

impl TransferEngine {
    pub fn new() -> TransferEngine {
        TransferEngine {
            pinned_host: true,
            stats: TransferStats::default(),
            link_free: 0.0,
            slowdown: 1.0,
            in_flight: Vec::new(),
        }
    }

    /// One expert's transfer duration on the link (pageable host memory
    /// roughly halves effective PCIe bandwidth; an active link flap
    /// multiplies the whole duration by the slowdown factor).
    fn h2d_duration(&self, cm: &CostModel, mode: QuantMode) -> f64 {
        let mut dt = cm.transfer_time(mode);
        if !self.pinned_host {
            dt += cm.dims.expert_bytes(mode) / cm.gpu.pcie_bw;
        }
        dt * self.slowdown
    }

    /// The active link-flap bandwidth-degradation factor (1.0 = nominal).
    pub fn slowdown(&self) -> f64 {
        self.slowdown
    }

    /// Degrade (or restore, with `1.0`) effective link bandwidth:
    /// subsequent transfer durations are multiplied by `factor`.
    /// Clamped below at nominal — a flap never speeds the link up.
    pub fn set_slowdown(&mut self, factor: f64) {
        self.slowdown = factor.max(1.0);
    }

    /// Drop every tracked in-flight transfer (link flap or crash): the
    /// issued transfers never land.  Returns the dropped
    /// `(layer, expert)` pairs so the caller can emit `TransferLost`
    /// events and clear the matching cache reservations.  The link time
    /// already spent stays in the issue-time accounting — the bytes
    /// really crossed the link before the loss.
    pub fn drop_in_flight(&mut self) -> Vec<(usize, usize)> {
        self.in_flight.drain(..).map(|t| (t.layer, t.expert)).collect()
    }

    /// Mark the oldest not-yet-corrupt tracked transfer checksum-
    /// corrupt.  It keeps occupying its link slot but will never land
    /// or be claimable; [`TransferEngine::take_corrupt`] removes it
    /// once its link time elapses.  Returns the marked pair, or `None`
    /// when nothing (uncorrupt) is in flight.
    pub fn corrupt_oldest_in_flight(&mut self) -> Option<(usize, usize)> {
        let t = self.in_flight.iter_mut().find(|t| !t.corrupt)?;
        t.corrupt = true;
        Some((t.layer, t.expert))
    }

    /// Remove corrupt transfers whose link time has elapsed by `now` —
    /// a checksum failure is only observable at arrival.  The caller
    /// emits `Corrupt` events and releases the cache reservations so
    /// the expert is re-fetched on its next use.
    pub fn take_corrupt(&mut self, now: f64) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        self.in_flight.retain(|t| {
            if t.corrupt && t.completes_at <= now {
                out.push((t.layer, t.expert));
                false
            } else {
                true
            }
        });
        out
    }

    fn account_h2d(&mut self, cm: &CostModel, mode: QuantMode, dt: f64) {
        let bytes = cm.dims.expert_bytes(mode);
        self.stats.h2d_count += 1;
        self.stats.h2d_bytes += bytes;
        self.stats.h2d_bytes_by_tier[mode.idx()] += bytes;
        self.stats.h2d_seconds += dt;
    }

    /// Time until the link drains from `now`'s point of view — what a
    /// transfer issued now would wait before starting.
    pub fn link_wait(&self, now: f64) -> f64 {
        (self.link_free - now).max(0.0)
    }

    /// Tracked in-flight transfers (lookahead prefetches not yet claimed
    /// or drained).
    pub fn in_flight_len(&self) -> usize {
        self.in_flight.len()
    }

    /// Whether a *claimable* (non-corrupt) tracked transfer for
    /// `(layer, expert)` is on the link.  A corrupt entry doesn't count:
    /// it will never land, so the caller is free to re-issue.
    pub fn in_flight_contains(&self, layer: usize, expert: usize) -> bool {
        self.in_flight.iter().any(|t| t.layer == layer && t.expert == expert && !t.corrupt)
    }

    /// Residual wait a decode would pay *right now* to claim the tracked
    /// transfer for `(layer, expert)` — a side-effect-free peek used by
    /// the little-fallback policy to decide whether waiting beats a
    /// degraded execution.  `None` when no such transfer is in flight.
    pub fn residual_of(&self, layer: usize, expert: usize, now: f64) -> Option<f64> {
        self.in_flight
            .iter()
            .find(|t| t.layer == layer && t.expert == expert && !t.corrupt)
            .map(|t| (t.completes_at - now).max(0.0))
    }

    /// What a cold demand fetch issued at `now` would stall: link-queue
    /// wait plus the full tier transfer.  Side-effect-free estimate (the
    /// fallback policy's cold-miss counterpart to
    /// [`TransferEngine::residual_of`]).
    pub fn demand_estimate(&self, cm: &CostModel, now: f64, mode: QuantMode) -> f64 {
        self.link_wait(now) + self.h2d_duration(cm, mode)
    }

    /// Move the parts of tracked transfers that fall inside the decode's
    /// stall window `[from, to]` out of the overlapped bucket: link time
    /// spent transferring while the decode was blocked is not hidden.
    /// Stall windows are disjoint (the clock is monotone), so each
    /// instant of a transfer is un-hidden at most once — together with
    /// the claimed-entry share in [`TransferEngine::wait_for`] this
    /// makes the tracked pipeline's stall/overlap split exact.  (The
    /// untracked `prefetch_h2d` path keeps its optimistic issue-time
    /// credit — it carries no completion record to attribute.)
    fn unhide_window(&mut self, from: f64, to: f64) {
        if to <= from {
            return;
        }
        for t in &self.in_flight {
            let start = t.completes_at - t.duration;
            let covered = (t.completes_at.min(to) - start.max(from)).max(0.0);
            self.stats.overlapped_time -= covered;
        }
    }

    /// Demand-fetch one expert: the decode stalls for the link-queue wait
    /// plus the full transfer (paper Eq. 3's N_miss · Time_transfer
    /// term).  Tracked transfers the decode blocks through lose their
    /// overlap credit.  Returns the stall duration applied to `clock`.
    pub fn demand_h2d(&mut self, cm: &CostModel, clock: &mut SimClock, mode: QuantMode) -> f64 {
        let dt = self.h2d_duration(cm, mode);
        let wait = self.link_wait(clock.now());
        self.link_free = clock.now().max(self.link_free) + dt;
        let stall = wait + dt;
        self.unhide_window(clock.now(), clock.now() + stall);
        clock.advance(stall);
        self.account_h2d(cm, mode, dt);
        self.stats.stall_time += stall;
        stall
    }

    /// Untracked non-blocking prefetch: occupies the link but does not
    /// stall the clock and leaves no in-flight record.  Counted fully
    /// overlapped (optimistic) — [`TransferEngine::sync_prefetches`] is
    /// the explicit barrier for callers that want start-of-decode
    /// semantics.  Little-copy installs use this path (they are pure
    /// background traffic with no claimable completion); decode-critical
    /// transfers use the tracked [`TransferEngine::prefetch_expert`].
    pub fn prefetch_h2d(&mut self, cm: &CostModel, clock: &SimClock, mode: QuantMode) {
        let dt = self.h2d_duration(cm, mode);
        let start = clock.now().max(self.link_free);
        self.link_free = start + dt;
        self.account_h2d(cm, mode, dt);
        self.stats.overlapped_time += dt;
    }

    /// Layer-ahead lookahead prefetch (non-blocking, tracked): occupies
    /// the link and records an in-flight `(layer, expert, completes_at)`
    /// entry.  Residency commits when the transfer lands
    /// ([`TransferEngine::drain_arrived`]); a decode that catches it
    /// mid-flight charges only the residual ([`TransferEngine::wait_for`]).
    /// Counted fully overlapped at issue; `wait_for` settles the split.
    /// Returns the completion time.
    pub fn prefetch_expert(
        &mut self,
        cm: &CostModel,
        clock: &SimClock,
        layer: usize,
        expert: usize,
        mode: QuantMode,
    ) -> f64 {
        let dt = self.h2d_duration(cm, mode);
        let start = clock.now().max(self.link_free);
        let completes_at = start + dt;
        self.link_free = completes_at;
        self.account_h2d(cm, mode, dt);
        self.stats.overlapped_time += dt;
        self.in_flight.push(InFlight { layer, expert, duration: dt, completes_at, corrupt: false });
        completes_at
    }

    /// Block until the tracked transfer for `(layer, expert)` lands,
    /// charging only the *residual* wait — the part of the transfer (and
    /// its link queue) that compute did not already hide.  Free when the
    /// transfer has completed.  Returns `None` when no such transfer is
    /// in flight (the caller falls back to a demand fetch).
    pub fn wait_for(&mut self, layer: usize, expert: usize, clock: &mut SimClock) -> Option<f64> {
        let i = self
            .in_flight
            .iter()
            .position(|t| t.layer == layer && t.expert == expert && !t.corrupt)?;
        let t = self.in_flight.remove(i);
        let residual = (t.completes_at - clock.now()).max(0.0);
        // settle the optimistic issue-time accounting: the un-hidden part
        // of the transfer's own duration moves from overlapped to stall,
        // and so does the stall-window share of every transfer still
        // queued on the link — the decode blocked through them too
        self.stats.overlapped_time -= residual.min(t.duration);
        self.unhide_window(clock.now(), clock.now() + residual);
        clock.advance(residual);
        self.stats.stall_time += residual;
        Some(residual)
    }

    /// Remove and return every tracked transfer that has completed by
    /// `now` — the caller commits them to the expert cache
    /// (`LayerCache::commit`).  Arrival order is preserved.
    pub fn drain_arrived(&mut self, now: f64) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        self.in_flight.retain(|t| {
            if !t.corrupt && t.completes_at <= now {
                out.push((t.layer, t.expert));
                false
            } else {
                true
            }
        });
        out
    }

    /// Keep a landed-but-uncommitted *arrival* claimable (drain path,
    /// when every resident was pinned): the expert stays in staging at
    /// zero residual until a later commit lands it or a miss claims it.
    /// A claim ([`TransferEngine::wait_for`]) consumes the entry — one
    /// paid transfer buys residency or exactly one stall-free
    /// execution, never more.
    pub fn track_landed(&mut self, layer: usize, expert: usize, now: f64) {
        self.in_flight.push(InFlight {
            layer,
            expert,
            duration: 0.0,
            completes_at: now,
            corrupt: false,
        });
    }

    /// Land one arrived (or just-claimed) lookahead transfer into the
    /// layer's residency: commit — never evicting `pinned` — and count
    /// the eviction as D2H traffic.  Returns a [`CommitOutcome`]
    /// describing what happened (resident? newly loaded? who was
    /// evicted?), so the caller can emit the matching trace events.
    /// Shared by the engine and the cluster replica so the commit/evict
    /// invariant cannot desynchronize; drain-path callers keep
    /// un-committable arrivals in staging via
    /// [`TransferEngine::track_landed`], while a caught-in-flight claim
    /// has already consumed the transfer's one stall-free use.
    pub fn commit_arrival(
        &mut self,
        cache: &mut LayerCache,
        cm: &CostModel,
        mode: QuantMode,
        expert: usize,
        pinned: &[usize],
    ) -> CommitOutcome {
        let was_resident = cache.contains(expert);
        let evicted = cache.commit(expert, pinned);
        if evicted.is_some() {
            self.evict_d2h(cm, mode);
        }
        let resident = cache.contains(expert);
        CommitOutcome { resident, loaded: resident && !was_resident, evicted }
    }

    /// Block until all issued transfers have landed (start-of-decode
    /// barrier; the paper measures ~0.05 s here).  Tracked entries stay
    /// queued for [`TransferEngine::drain_arrived`], but their no-longer-
    /// hidden shares move from overlapped to stall.  Returns the wait.
    pub fn sync_prefetches(&mut self, clock: &mut SimClock) -> f64 {
        let now = clock.now();
        let wait = self.link_wait(now);
        self.unhide_window(now, now + wait);
        clock.advance(wait);
        self.stats.stall_time += wait;
        wait
    }

    /// Eviction: release a device buffer (counted as a D2H event — expert
    /// weights are read-only so no payload is written back, but buffer
    /// frees appear as D2H traffic in the paper's Fig. 1a profile).
    pub fn evict_d2h(&mut self, cm: &CostModel, mode: QuantMode) {
        let bytes = cm.dims.expert_bytes(mode);
        self.stats.d2h_count += 1;
        self.stats.d2h_bytes += bytes;
        self.stats.d2h_bytes_by_tier[mode.idx()] += bytes;
    }
}

impl Default for TransferEngine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{GpuSpec, PaperDims};

    fn cm() -> CostModel {
        CostModel::new(
            GpuSpec::h100(),
            PaperDims { n_layers: 16, n_experts: 64, top_k: 8, d_model: 2048, d_ff: 1024, vocab: 50304 },
        )
    }

    #[test]
    fn demand_advances_clock_and_counts() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        let stall = eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16);
        assert!(stall > 0.0);
        assert_eq!(eng.stats.h2d_count, 1);
        assert!((clock.now() - stall).abs() < 1e-12);
        assert!((eng.stats.h2d_seconds - stall).abs() < 1e-12, "no queue: stall == duration");
    }

    #[test]
    fn link_serializes_transfers() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        let t1 = cm.transfer_time(QuantMode::Fp16);
        eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16);
        eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16);
        assert!((clock.now() - 2.0 * t1).abs() < 1e-9);
    }

    #[test]
    fn prefetch_does_not_stall() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        for _ in 0..4 {
            eng.prefetch_h2d(&cm, &clock, QuantMode::Int4);
        }
        assert_eq!(clock.now(), 0.0);
        assert_eq!(eng.stats.h2d_count, 4);
        // sync waits for the link
        let wait = eng.sync_prefetches(&mut clock);
        assert!(wait > 0.0);
        assert!((wait - 4.0 * cm.transfer_time(QuantMode::Int4)).abs() < 1e-9);
    }

    #[test]
    fn prefetch_overlap_reduces_stall_vs_demand() {
        let cm = cm();
        // scenario A: 4 demand misses
        let mut ca = SimClock::new();
        let mut ea = TransferEngine::new();
        for _ in 0..4 {
            ea.demand_h2d(&cm, &mut ca, QuantMode::Fp16);
        }
        // scenario B: 4 prefetches issued, then compute happens, then sync
        let mut cb = SimClock::new();
        let mut eb = TransferEngine::new();
        for _ in 0..4 {
            eb.prefetch_h2d(&cm, &cb, QuantMode::Fp16);
        }
        cb.advance(ca.now()); // same amount of compute
        eb.sync_prefetches(&mut cb);
        assert!(cb.now() <= ca.now() * 1.001 + 1e-12);
        assert!(eb.stats.stall_time < ea.stats.stall_time);
    }

    #[test]
    fn tracked_prefetch_registers_and_drains() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        let done = eng.prefetch_expert(&cm, &clock, 3, 17, QuantMode::Fp16);
        assert!(eng.in_flight_contains(3, 17));
        assert_eq!(eng.in_flight_len(), 1);
        assert!(eng.drain_arrived(clock.now()).is_empty(), "not yet landed");
        clock.advance(done);
        assert_eq!(eng.drain_arrived(clock.now()), vec![(3, 17)]);
        assert_eq!(eng.in_flight_len(), 0);
        // never waited on: the whole duration stays overlapped
        assert!((eng.stats.overlapped_time - eng.stats.h2d_seconds).abs() < 1e-12);
        assert_eq!(eng.stats.stall_time, 0.0);
    }

    #[test]
    fn caught_in_flight_charges_residual_not_full_transfer() {
        let cm = cm();
        let dt = cm.transfer_time(QuantMode::Fp16);
        // cold demand baseline
        let mut cd = SimClock::new();
        let mut ed = TransferEngine::new();
        let demand_stall = ed.demand_h2d(&cm, &mut cd, QuantMode::Fp16);
        // prefetch issued, compute hides 60% of it, decode catches it
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        eng.prefetch_expert(&cm, &clock, 0, 7, QuantMode::Fp16);
        clock.advance(0.6 * dt);
        let residual = eng.wait_for(0, 7, &mut clock).unwrap();
        assert!((residual - 0.4 * dt).abs() < 1e-12, "residual {residual} vs 0.4·{dt}");
        assert!(residual < demand_stall, "caught in-flight must beat a cold demand fetch");
        assert!((clock.now() - dt).abs() < 1e-12, "decode resumes exactly at arrival");
        // split settles: hidden 0.6·dt overlapped, residual 0.4·dt stalled
        assert!((eng.stats.overlapped_time - 0.6 * dt).abs() < 1e-12);
        assert!((eng.stats.stall_time - 0.4 * dt).abs() < 1e-12);
        assert!(
            (eng.stats.overlapped_time + eng.stats.stall_time - eng.stats.h2d_seconds).abs()
                < 1e-12,
            "stall + overlap conserves the transfer duration"
        );
    }

    #[test]
    fn stalling_through_queued_prefetches_unhides_their_overlap() {
        let cm = cm();
        let dt = cm.transfer_time(QuantMode::Fp16);
        let mut eng = TransferEngine::new();
        let mut clock = SimClock::new();
        eng.prefetch_expert(&cm, &clock, 0, 1, QuantMode::Fp16); // A
        eng.prefetch_expert(&cm, &clock, 0, 2, QuantMode::Fp16); // B, behind A
        // the decode immediately misses on B: it blocks 2·dt, through
        // the whole of A's transfer as well — nothing was hidden
        let r = eng.wait_for(0, 2, &mut clock).unwrap();
        assert!((r - 2.0 * dt).abs() < 1e-12);
        assert!(eng.stats.overlapped_time.abs() < 1e-12, "A kept overlap credit");
        assert!((eng.stats.stall_time - 2.0 * dt).abs() < 1e-12);
        // A's later claim is free and does not double-subtract
        assert_eq!(eng.wait_for(0, 1, &mut clock), Some(0.0));
        assert!(eng.stats.overlapped_time.abs() < 1e-12);
        assert!((eng.stats.stall_time - 2.0 * dt).abs() < 1e-12);
    }

    #[test]
    fn demand_behind_prefetch_unhides_queued_overlap() {
        let cm = cm();
        let dt = cm.transfer_time(QuantMode::Fp16);
        let mut eng = TransferEngine::new();
        let mut clock = SimClock::new();
        eng.prefetch_expert(&cm, &clock, 0, 1, QuantMode::Fp16); // occupies [0, dt]
        let stall = eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16); // queues behind it
        assert!((stall - 2.0 * dt).abs() < 1e-12, "link wait + own transfer");
        // the decode was blocked through the prefetch's transfer too
        assert!(eng.stats.overlapped_time.abs() < 1e-12);
        assert!((eng.stats.stall_time - 2.0 * dt).abs() < 1e-12);
    }

    #[test]
    fn wait_for_completed_transfer_is_free() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        let done = eng.prefetch_expert(&cm, &clock, 1, 2, QuantMode::Int4);
        clock.advance(done + 1.0);
        let before = clock.now();
        let residual = eng.wait_for(1, 2, &mut clock).unwrap();
        assert_eq!(residual, 0.0);
        assert_eq!(clock.now(), before);
        assert_eq!(eng.stats.stall_time, 0.0);
        // unknown transfers fall back to demand
        assert!(eng.wait_for(1, 2, &mut clock).is_none());
        assert!(eng.wait_for(9, 9, &mut clock).is_none());
    }

    #[test]
    fn link_wait_sees_queue_depth() {
        let cm = cm();
        let clock = SimClock::new();
        let mut eng = TransferEngine::new();
        assert_eq!(eng.link_wait(0.0), 0.0);
        eng.prefetch_expert(&cm, &clock, 0, 0, QuantMode::Fp16);
        eng.prefetch_expert(&cm, &clock, 0, 1, QuantMode::Fp16);
        let dt = cm.transfer_time(QuantMode::Fp16);
        assert!((eng.link_wait(0.0) - 2.0 * dt).abs() < 1e-12);
        assert!((eng.link_wait(dt) - dt).abs() < 1e-12);
        assert_eq!(eng.link_wait(10.0 * dt), 0.0);
    }

    #[test]
    fn pageable_slower_than_pinned() {
        let cm = cm();
        let mut c1 = SimClock::new();
        let mut pinned = TransferEngine::new();
        pinned.demand_h2d(&cm, &mut c1, QuantMode::Fp16);
        let mut c2 = SimClock::new();
        let mut pageable = TransferEngine { pinned_host: false, ..TransferEngine::new() };
        pageable.demand_h2d(&cm, &mut c2, QuantMode::Fp16);
        assert!(c2.now() > c1.now());
    }

    #[test]
    fn eviction_counts_d2h() {
        let cm = cm();
        let mut eng = TransferEngine::new();
        eng.evict_d2h(&cm, QuantMode::Fp16);
        assert_eq!(eng.stats.d2h_count, 1);
        assert!(eng.stats.d2h_bytes > 0.0);
    }

    #[test]
    fn per_tier_byte_counters_sum_to_aggregate() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        eng.demand_h2d(&cm, &mut clock, QuantMode::Fp16);
        eng.prefetch_h2d(&cm, &clock, QuantMode::Int4);
        eng.prefetch_expert(&cm, &clock, 0, 3, QuantMode::Int3);
        eng.evict_d2h(&cm, QuantMode::Fp16);
        eng.evict_d2h(&cm, QuantMode::Int4);
        let s = &eng.stats;
        assert!((s.h2d_bytes_by_tier.iter().sum::<f64>() - s.h2d_bytes).abs() < 1e-9);
        assert!((s.d2h_bytes_by_tier.iter().sum::<f64>() - s.d2h_bytes).abs() < 1e-9);
        for m in QuantMode::ALL {
            assert!(
                (s.h2d_bytes_by_tier[m.idx()] - cm.dims.expert_bytes(m)).abs() < 1e-9,
                "one h2d per tier"
            );
        }
        assert_eq!(s.d2h_bytes_by_tier[QuantMode::Int3.idx()], 0.0);
        // int tiers really move fewer bytes than fp16
        assert!(s.h2d_bytes_by_tier[1] < s.h2d_bytes_by_tier[0] / 3.0);
        assert!(s.h2d_bytes_by_tier[2] < s.h2d_bytes_by_tier[1]);
    }

    #[test]
    fn residual_peek_matches_wait_for_without_consuming() {
        let cm = cm();
        let dt = cm.transfer_time(QuantMode::Fp16);
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        assert_eq!(eng.residual_of(0, 7, clock.now()), None);
        eng.prefetch_expert(&cm, &clock, 0, 7, QuantMode::Fp16);
        clock.advance(0.6 * dt);
        let peek = eng.residual_of(0, 7, clock.now()).unwrap();
        assert!((peek - 0.4 * dt).abs() < 1e-12);
        assert!(eng.in_flight_contains(0, 7), "peek is side-effect-free");
        let stall0 = eng.stats.stall_time;
        let claimed = eng.wait_for(0, 7, &mut clock).unwrap();
        assert!((claimed - peek).abs() < 1e-12, "peek predicted the claim");
        assert!(eng.stats.stall_time > stall0);
        // landed transfers peek at zero residual
        let done = eng.prefetch_expert(&cm, &clock, 1, 2, QuantMode::Int4);
        assert_eq!(eng.residual_of(1, 2, done + 1.0), Some(0.0));
    }

    #[test]
    fn slowdown_scales_durations_and_restores_exactly() {
        let cm = cm();
        let mut c1 = SimClock::new();
        let mut nominal = TransferEngine::new();
        let base = nominal.demand_h2d(&cm, &mut c1, QuantMode::Fp16);
        let mut c2 = SimClock::new();
        let mut flapped = TransferEngine::new();
        flapped.set_slowdown(4.0);
        assert_eq!(flapped.slowdown(), 4.0);
        let slow = flapped.demand_h2d(&cm, &mut c2, QuantMode::Fp16);
        assert!((slow - 4.0 * base).abs() < 1e-12);
        // restore: durations are bit-identical to a never-flapped engine
        flapped.set_slowdown(1.0);
        assert_eq!(
            flapped.h2d_duration(&cm, QuantMode::Fp16),
            nominal.h2d_duration(&cm, QuantMode::Fp16)
        );
        // a flap never speeds the link up
        flapped.set_slowdown(0.25);
        assert_eq!(flapped.slowdown(), 1.0);
    }

    #[test]
    fn drop_in_flight_loses_tracked_transfers() {
        let cm = cm();
        let clock = SimClock::new();
        let mut eng = TransferEngine::new();
        eng.prefetch_expert(&cm, &clock, 0, 1, QuantMode::Fp16);
        eng.prefetch_expert(&cm, &clock, 1, 2, QuantMode::Fp16);
        let dropped = eng.drop_in_flight();
        assert_eq!(dropped, vec![(0, 1), (1, 2)]);
        assert_eq!(eng.in_flight_len(), 0);
        assert!(eng.drain_arrived(f64::MAX).is_empty(), "nothing ever lands");
    }

    #[test]
    fn corrupt_transfer_never_lands_and_is_taken_at_arrival() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        let done = eng.prefetch_expert(&cm, &clock, 2, 9, QuantMode::Fp16);
        assert_eq!(eng.corrupt_oldest_in_flight(), Some((2, 9)));
        // a corrupt entry is invisible to every consume path
        assert!(!eng.in_flight_contains(2, 9));
        assert_eq!(eng.residual_of(2, 9, clock.now()), None);
        assert!(eng.wait_for(2, 9, &mut clock).is_none());
        // the checksum failure is only observable once the link time elapses
        assert!(eng.take_corrupt(clock.now()).is_empty());
        clock.advance(done);
        assert!(eng.drain_arrived(clock.now()).is_empty(), "corrupt never commits");
        assert_eq!(eng.take_corrupt(clock.now()), vec![(2, 9)]);
        assert_eq!(eng.in_flight_len(), 0);
        // nothing left to corrupt
        assert_eq!(eng.corrupt_oldest_in_flight(), None);
    }

    #[test]
    fn demand_estimate_matches_actual_demand_stall() {
        let cm = cm();
        let mut clock = SimClock::new();
        let mut eng = TransferEngine::new();
        eng.prefetch_expert(&cm, &clock, 0, 1, QuantMode::Fp16); // queue depth
        let est = eng.demand_estimate(&cm, clock.now(), QuantMode::Int4);
        let stall = eng.demand_h2d(&cm, &mut clock, QuantMode::Int4);
        assert!((est - stall).abs() < 1e-12);
        // int tiers estimate (and pay) less than fp16 at equal queue depth
        let eng2 = TransferEngine::new();
        assert!(
            eng2.demand_estimate(&cm, 0.0, QuantMode::Int4)
                < eng2.demand_estimate(&cm, 0.0, QuantMode::Fp16)
        );
    }
}
