//! One function per paper table/figure.  See DESIGN.md §4 for the index
//! and the expected qualitative shape of each result.

use anyhow::Result;

use crate::cache::EvictionKind;
use crate::clock::GpuSpec;
use crate::metrics::{fmt2, fmt4, Table};
use crate::policies::PolicyConfig;
use crate::quant::QuantMode;
use crate::util::cli::Args;
use crate::util::json::{arr, num, obj, s, Json};
use crate::vram::VramBudget;

use super::{run_eval, run_perplexity, save_result, Ctx, RunSummary, Workload};

pub const ALL: &[&str] = &[
    "table1", "fig1a", "fig1b", "fig3", "table2", "table3", "fig4", "fig5", "table4",
    "table5", "table11", "fig6", "heatmaps", "fig11", "table12", "fig12", "fig13", "table13",
    "ext_layerwise", "ext_cluster", "ext_continuous", "ext_prefill", "ext_overlap",
    "ext_preempt", "ext_quant", "ext_stream", "ext_fault", "ext_steal",
];

fn workload(args: &Args) -> Result<Workload> {
    Ok(Workload {
        n_prompts: args.get_usize("prompts", Workload::default().n_prompts)?,
        max_output: args.get_usize("tokens", Workload::default().max_output)?,
        ignore_eos: true,
    })
}

fn ctx(_args: &Args, preset: &str) -> Result<Ctx> {
    Ctx::load(&crate::artifacts_dir(), preset)
}

/// Load a preset, or warn and skip (partial artifact builds stay usable).
fn try_ctx(args: &Args, preset: &str) -> Option<Ctx> {
    match ctx(args, preset) {
        Ok(c) => Some(c),
        Err(e) => {
            println!("  [skip {preset}: {e}]");
            None
        }
    }
}

/// Run a policy, or warn and skip (missing fine-tune/predictor variants).
fn try_run(
    c: &Ctx,
    policy: &PolicyConfig,
    ds: &str,
    gpu: GpuSpec,
    wl: Workload,
) -> Option<RunSummary> {
    match run_policy(c, policy, ds, gpu, wl) {
        Ok(r) => Some(r),
        Err(e) => {
            println!("  [skip {}/{}: {e}]", c.preset, policy.name);
            None
        }
    }
}

/// Metrics snapshot for a cluster report's merged trace (`Json::Null`
/// when tracing was off) — embedded in every ext_* repro row so
/// `scripts/check_repro.py` can reconcile the trace-derived stall /
/// overlap / H2D totals against the fleet's `TransferStats` sums.
/// The fleet's per-precision-tier byte counters ride along
/// (`h2d_bytes_<tier>` / `d2h_bytes_<tier>`), so equal-VRAM comparisons
/// across quant tiers are auditable from the JSON alone.
fn trace_metrics(rep: &crate::cluster::ClusterReport) -> Json {
    let mut j = match rep.trace.as_ref() {
        Some(t) => t.metrics_json(rep.stall_seconds, rep.overlapped_seconds, rep.h2d_seconds),
        None => return Json::Null,
    };
    if let Json::Obj(m) = &mut j {
        for (i, tier) in QuantMode::ALL.iter().enumerate() {
            m.insert(format!("h2d_bytes_{}", tier.name()), num(rep.h2d_bytes_by_tier[i]));
            m.insert(format!("d2h_bytes_{}", tier.name()), num(rep.d2h_bytes_by_tier[i]));
        }
    }
    j
}

fn summary_json(rs: &[RunSummary]) -> Json {
    arr(rs
        .iter()
        .map(|r| {
            obj(vec![
                ("policy", s(r.policy.clone())),
                ("tok_s", num(r.tokens_per_sec)),
                ("tx_per_layer", num(r.tx_per_layer)),
                ("h2d", num(r.h2d as f64)),
                ("d2h", num(r.d2h as f64)),
                ("hit_rate", num(r.hit_rate)),
                ("rouge_l", num(r.rouge_l)),
                ("accuracy", num(r.accuracy)),
                ("topc_share", num(r.topc_share)),
                ("wall_s", num(r.wall_seconds)),
            ])
        })
        .collect())
}

fn run_policy(
    ctx: &Ctx,
    policy: &PolicyConfig,
    ds: &str,
    gpu: GpuSpec,
    wl: Workload,
) -> Result<RunSummary> {
    let parts = ctx.parts(policy, ds)?;
    let engine = parts.engine(ctx, gpu).with_ignore_eos(wl.ignore_eos);
    let eval = ctx.eval_set(ds)?;
    run_eval(&engine, &eval, wl, ctx.cfg.cache_capacity)
}

fn print_and_save(id: &str, t: &Table, j: Json) -> Result<()> {
    let text = t.render();
    println!("{text}");
    save_result(id, &text, &j)
}

// ---------------------------------------------------------------- Table 1
/// Decoding throughput vs cache size (25% / 50% / 100% of experts).
pub fn table1(args: &Args) -> Result<()> {
    let wl = workload(args)?;
    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let mut t = Table::new(&["model", "cache 25%", "cache 50%", "cache all"]);
    let mut rows_json = Vec::new();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        let Some(c) = try_ctx(args, preset) else { continue };
        let e = c.cfg.n_experts;
        let mut cells = vec![preset.to_string()];
        let mut jrow = vec![("model", s(preset))];
        for (label, frac) in [("c25", 0.25), ("c50", 0.5), ("c100", 1.0)] {
            let cap = ((e as f64 * frac).round() as usize).max(1);
            let pol = PolicyConfig::base_offload(cap);
            let r = run_policy(&c, &pol, "dolly", gpu.clone(), wl)?;
            cells.push(fmt2(r.tokens_per_sec));
            jrow.push((label, num(r.tokens_per_sec)));
        }
        t.row(cells);
        rows_json.push(obj(jrow));
    }
    print_and_save("table1", &t, arr(rows_json))
}

// ---------------------------------------------------------------- Fig. 1a
/// H2D/D2H transfer counts, base vs fine-tuned (OLMoE, 64 output tokens).
pub fn fig1a(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 4)?,
        max_output: args.get_usize("tokens", 64)?,
        ignore_eos: true,
    };
    let c = ctx(args, "olmoe-micro")?;
    let gpu = GpuSpec::h100();
    let cap = c.cfg.cache_capacity;
    let mut t = Table::new(&["model", "H2D", "D2H", "total", "reduction"]);
    let base = run_policy(&c, &PolicyConfig::base_offload(cap), "dolly", gpu.clone(), wl)?;
    let ft = run_policy(
        &c,
        &PolicyConfig::base_offload(cap).with_variant("ft_dolly"),
        "dolly",
        gpu,
        wl,
    )?;
    let red = (base.h2d + base.d2h) as f64 / ((ft.h2d + ft.d2h).max(1)) as f64;
    t.row(vec!["base".into(), base.h2d.to_string(), base.d2h.to_string(), (base.h2d + base.d2h).to_string(), "1.00x".into()]);
    t.row(vec!["fine-tuned".into(), ft.h2d.to_string(), ft.d2h.to_string(), (ft.h2d + ft.d2h).to_string(), format!("{red:.2}x")]);
    print_and_save("fig1a", &t, summary_json(&[base, ft]))
}

// ---------------------------------------------------------------- Fig. 1b
/// Routing concentration: sorted activation-share curve + top-8 share.
pub fn fig1b(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 4)?,
        max_output: args.get_usize("tokens", 48)?,
        ignore_eos: true,
    };
    let c = ctx(args, "olmoe-micro")?;
    let eval = c.eval_set("dolly")?;
    let mut t = Table::new(&["model", "top-4", "top-8", "top-16", "top-32 share"]);
    let mut jrows = Vec::new();
    for variant in ["base", "ft_dolly"] {
        let pol = PolicyConfig::base_offload(c.cfg.cache_capacity).with_variant(variant);
        let parts = c.parts(&pol, "dolly")?;
        let engine = parts.engine(&c, GpuSpec::h100());
        // aggregate per-sequence traces (the paper averages within-sequence
        // concentration over prompts)
        let mut shares = [0.0f64; 4];
        let n = wl.n_prompts.min(eval.samples.len());
        let mut curve = vec![0.0f64; c.cfg.n_experts];
        for sample in eval.samples.iter().take(n) {
            let out = engine.decode(&sample.prompt, wl.max_output)?;
            for (i, k) in [4, 8, 16, 32].iter().enumerate() {
                shares[i] += out.trace.mean_topc_share(*k);
            }
            let sc = out.trace.share_curve(0);
            for (a, b) in curve.iter_mut().zip(sc) {
                *a += b;
            }
        }
        for v in &mut shares {
            *v /= n as f64;
        }
        for v in &mut curve {
            *v /= n as f64;
        }
        t.row(vec![
            variant.into(),
            fmt4(shares[0]),
            fmt4(shares[1]),
            fmt4(shares[2]),
            fmt4(shares[3]),
        ]);
        jrows.push(obj(vec![
            ("variant", s(variant)),
            ("top4", num(shares[0])),
            ("top8", num(shares[1])),
            ("top16", num(shares[2])),
            ("top32", num(shares[3])),
            ("curve_layer0", arr(curve.iter().map(|&v| num(v)).collect())),
        ]));
    }
    print_and_save("fig1b", &t, arr(jrows))
}

// ---------------------------------------------------------------- Fig. 3
/// Throughput vs all baselines across model/dataset/GPU configurations.
pub fn fig3(args: &Args) -> Result<()> {
    let wl = workload(args)?;
    let grid: &[(&str, &str)] = &[
        ("olmoe-micro", "h100"),
        ("olmoe-micro", "rtx4090"),
        ("phi-micro", "a100"),
        ("mixtral-micro", "rtx4090"),
    ];
    let mut t = Table::new(&["config", "melinoe", "fiddler", "mix-off", "deepspeed", "floe", "moe-inf"]);
    let mut jrows = Vec::new();
    for (preset, gpu_name) in grid {
        let Some(c) = try_ctx(args, preset) else { continue };
        let gpu = GpuSpec::by_name(gpu_name)?;
        for ds in ["dolly", "gsm"] {
            let ft = if ds == "dolly" { "ft_dolly" } else { "ft_gsm" };
            let pols = PolicyConfig::all_baselines(c.cfg.cache_capacity, c.cfg.top_k, ft);
            let mut cells = vec![format!("{preset}/{gpu_name}/{ds}")];
            let mut jcols = vec![("config", s(format!("{preset}/{gpu_name}/{ds}")))];
            let labels = ["melinoe", "fiddler", "mixoff", "deepspeed", "floe", "moeinf"];
            for (pol, label) in pols.iter().zip(labels) {
                match try_run(&c, pol, ds, gpu.clone(), wl) {
                    Some(r) => {
                        cells.push(fmt2(r.tokens_per_sec));
                        jcols.push((label, num(r.tokens_per_sec)));
                    }
                    None => cells.push("n/a".into()),
                }
            }
            t.row(cells);
            jrows.push(obj(jcols));
        }
    }
    print_and_save("fig3", &t, arr(jrows))
}

// ---------------------------------------------------------------- Table 2
/// Downstream quality: ROUGE-L (dolly-syn) and accuracy (gsm-syn).
pub fn table2(args: &Args) -> Result<()> {
    // quality harness: natural EOS behaviour
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 10)?,
        max_output: args.get_usize("tokens", 32)?,
        ignore_eos: false,
    };
    let mut t = Table::new(&["method", "preset", "dolly ROUGE-L", "gsm acc %"]);
    let mut jrows = Vec::new();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        let Some(c) = try_ctx(args, preset) else { continue };
        let cap = c.cfg.cache_capacity;
        let methods: Vec<(&str, Box<dyn Fn(&str) -> PolicyConfig>)> = vec![
            ("base", Box::new(move |_| PolicyConfig::base_offload(cap))),
            ("melinoe", Box::new(move |ft: &str| PolicyConfig::melinoe(ft, cap))),
            ("fiddler", Box::new(move |_| PolicyConfig::fiddler(cap))),
            ("mixtral-offloading", Box::new(move |_| PolicyConfig::mixtral_offloading(cap))),
            ("deepspeed-moe", Box::new(move |_| PolicyConfig::deepspeed_moe(cap))),
            ("floe", Box::new(move |_| PolicyConfig::floe(cap))),
            ("moe-infinity", Box::new(move |_| PolicyConfig::moe_infinity(cap))),
        ];
        for (name, make) in &methods {
            let rd = run_policy(&c, &make("ft_dolly"), "dolly", GpuSpec::h100(), wl)?;
            let rg = run_policy(&c, &make("ft_gsm"), "gsm", GpuSpec::h100(), wl)?;
            t.row(vec![name.to_string(), preset.into(), fmt4(rd.rouge_l), fmt2(rg.accuracy)]);
            jrows.push(obj(vec![
                ("method", s(*name)),
                ("preset", s(preset)),
                ("rouge_l", num(rd.rouge_l)),
                ("accuracy", num(rg.accuracy)),
            ]));
        }
    }
    print_and_save("table2", &t, arr(jrows))
}

// ---------------------------------------------------------------- Table 3
/// Fine-tuning vs prefetching ablation: tok/s with Tx/L in parentheses.
pub fn table3(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 4)?,
        max_output: args.get_usize("tokens", 64)?,
        ignore_eos: true,
    };
    let mut t = Table::new(&["setting", "olmoe dolly", "mixtral dolly", "olmoe gsm", "mixtral gsm"]);
    let mut cells: Vec<Vec<String>> =
        vec![vec!["base".into()], vec!["fine-tuned".into()], vec!["fine-tuned + prefetch".into()]];
    let mut jrows = Vec::new();
    for ds in ["dolly", "gsm"] {
        let ft = if ds == "dolly" { "ft_dolly" } else { "ft_gsm" };
        for preset in ["olmoe-micro", "mixtral-micro"] {
            let Some(c) = try_ctx(args, preset) else {
                for cell in cells.iter_mut() {
                    cell.push("n/a".into());
                }
                continue;
            };
            let cap = c.cfg.cache_capacity;
            let pols = [
                PolicyConfig::base_offload(cap),
                PolicyConfig::melinoe_no_prefetch(ft, cap).with_quant(QuantMode::Fp16),
                PolicyConfig::melinoe(ft, cap).with_quant(QuantMode::Fp16),
            ];
            for (i, pol) in pols.iter().enumerate() {
                let r = run_policy(&c, pol, ds, GpuSpec::h100(), wl)?;
                cells[i].push(format!("{} ({:.0})", fmt2(r.tokens_per_sec), r.tx_per_layer));
                jrows.push(obj(vec![
                    ("setting", s(pol.name.clone())),
                    ("preset", s(preset)),
                    ("dataset", s(ds)),
                    ("tok_s", num(r.tokens_per_sec)),
                    ("tx_per_layer", num(r.tx_per_layer)),
                ]));
            }
        }
    }
    // column order fix: we iterated ds-major; reorder to header order
    for row in cells {
        let reordered = vec![row[0].clone(), row[1].clone(), row[2].clone(), row[3].clone(), row[4].clone()];
        t.row(reordered);
    }
    print_and_save("table3", &t, arr(jrows))
}

// ---------------------------------------------------------------- Fig. 4
/// λ_cs / λ_rm sweeps: transfers per layer & perplexity.
pub fn fig4(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 3)?,
        max_output: args.get_usize("tokens", 48)?,
        ignore_eos: true,
    };
    let c = ctx(args, "olmoe-micro")?;
    let cap = c.cfg.cache_capacity;
    let eval = c.eval_set("dolly")?;
    let sweeps: &[(&str, &str)] = &[
        ("lcs=0.1 (lrm=0.1)", "ft_dolly_lcs0p1"),
        ("lcs=0.5 (default)", "ft_dolly"),
        ("lcs=2.0", "ft_dolly_lcs2p0"),
        ("lcs=10.0", "ft_dolly_lcs10p0"),
        ("lrm=0.01 (lcs=0.5)", "ft_dolly_lrm0p01"),
        ("lrm=1.0", "ft_dolly_lrm1p0"),
    ];
    let mut t = Table::new(&["variant", "Tx/L", "perplexity"]);
    let mut jrows = Vec::new();
    for (label, variant) in sweeps {
        let pol = PolicyConfig::melinoe_no_prefetch(variant, cap).with_quant(QuantMode::Fp16);
        let parts = c.parts(&pol, "dolly")?;
        let engine = parts.engine(&c, GpuSpec::h100()).with_ignore_eos(true);
        let r = run_eval(&engine, &eval, wl, cap)?;
        let ppl = run_perplexity(&engine, &eval, 3, 48)?;
        t.row(vec![label.to_string(), fmt2(r.tx_per_layer), fmt2(ppl)]);
        jrows.push(obj(vec![
            ("variant", s(*variant)),
            ("tx_per_layer", num(r.tx_per_layer)),
            ("ppl", num(ppl)),
        ]));
    }
    print_and_save("fig4", &t, arr(jrows))
}

// ---------------------------------------------------------------- Fig. 5
/// Throughput vs batch size: MELINOE vs base under limited VRAM.
pub fn fig5(args: &Args) -> Result<()> {
    let max_output = args.get_usize("tokens", 24)?;
    let c = ctx(args, "olmoe-micro")?;
    let cap = c.cfg.cache_capacity;
    let eval = c.eval_set("dolly")?;
    let mut t = Table::new(&["batch", "base tok/s", "melinoe tok/s", "speedup"]);
    let mut jrows = Vec::new();
    for bs in [1usize, 2, 4, 8] {
        let prompts: Vec<Vec<usize>> =
            eval.samples.iter().take(bs).map(|s| s.prompt.clone()).collect();
        let mut tps = Vec::new();
        for pol in [
            PolicyConfig::base_offload(cap),
            PolicyConfig::melinoe("ft_dolly", cap).with_quant(QuantMode::Fp16),
        ] {
            let parts = c.parts(&pol, "dolly")?;
            let engine = parts.engine(&c, GpuSpec::h100()).with_ignore_eos(true);
            let (_outs, report) = engine.decode_batch(&prompts, max_output)?;
            // batch makespan: per-request sim_seconds are absolute
            // retirement times within the shared session
            let sim = report.requests.iter().map(|r| r.sim_seconds).fold(0.0f64, f64::max);
            let total: usize = report.requests.iter().map(|r| r.output_tokens).sum();
            tps.push(if sim > 0.0 { total as f64 / sim } else { 0.0 });
        }
        t.row(vec![bs.to_string(), fmt2(tps[0]), fmt2(tps[1]), format!("{:.2}x", tps[1] / tps[0].max(1e-9))]);
        jrows.push(obj(vec![
            ("batch", num(bs as f64)),
            ("base", num(tps[0])),
            ("melinoe", num(tps[1])),
        ]));
    }
    print_and_save("fig5", &t, arr(jrows))
}

// ---------------------------------------------------------------- Table 4
/// Fine-tuned model perplexity across generation lengths.
pub fn table4(args: &Args) -> Result<()> {
    let lengths = [16usize, 32, 64, 128, 256];
    let mut t = Table::new(&["length", "olmoe", "phi", "mixtral"]);
    let mut cols: Vec<Vec<f64>> = Vec::new();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        let Some(c) = try_ctx(args, preset) else {
            cols.push(vec![f64::NAN; lengths.len()]);
            continue;
        };
        let pol = PolicyConfig::melinoe_no_prefetch("ft_dolly", c.cfg.cache_capacity)
            .with_quant(QuantMode::Fp16);
        let parts = c.parts(&pol, "dolly")?;
        let engine = parts.engine(&c, GpuSpec::h100());
        let eval = c.eval_set("dolly")?;
        let mut col = Vec::new();
        for &len in &lengths {
            col.push(run_perplexity(&engine, &eval, 3, len)?);
        }
        cols.push(col);
    }
    let mut jrows = Vec::new();
    for (i, &len) in lengths.iter().enumerate() {
        t.row(vec![len.to_string(), fmt2(cols[0][i]), fmt2(cols[1][i]), fmt2(cols[2][i])]);
        jrows.push(obj(vec![
            ("len", num(len as f64)),
            ("olmoe", num(cols[0][i])),
            ("phi", num(cols[1][i])),
            ("mixtral", num(cols[2][i])),
        ]));
    }
    print_and_save("table4", &t, arr(jrows))
}

// ---------------------------------------------------------------- Table 5
/// Coupling fine-tuning with prior baselines (FLoE, Mixtral-Offloading).
pub fn table5(args: &Args) -> Result<()> {
    let wl = workload(args)?;
    let mut t = Table::new(&["method", "olmoe dolly", "phi dolly", "olmoe gsm", "phi gsm"]);
    let mut rows: Vec<Vec<String>> = vec![
        vec!["floe".into()],
        vec!["floe + fine-tuning".into()],
        vec!["mixtral-offloading".into()],
        vec!["mix-off + fine-tuning".into()],
    ];
    let mut jrows = Vec::new();
    for ds in ["dolly", "gsm"] {
        let ft = if ds == "dolly" { "ft_dolly" } else { "ft_gsm" };
        for preset in ["olmoe-micro", "phi-micro"] {
            let Some(c) = try_ctx(args, preset) else {
                for row in rows.iter_mut() {
                    row.push("n/a".into());
                }
                continue;
            };
            let cap = c.cfg.cache_capacity;
            let pols = [
                PolicyConfig::floe(cap),
                PolicyConfig::floe(cap).with_variant(ft),
                PolicyConfig::mixtral_offloading(cap),
                PolicyConfig::mixtral_offloading(cap).with_variant(ft),
            ];
            for (i, pol) in pols.iter().enumerate() {
                let Some(r) = try_run(&c, pol, ds, GpuSpec::h100(), wl) else {
                    rows[i].push("n/a".into());
                    continue;
                };
                rows[i].push(fmt2(r.tokens_per_sec));
                jrows.push(obj(vec![
                    ("method", s(pol.name.clone())),
                    ("preset", s(preset)),
                    ("dataset", s(ds)),
                    ("tok_s", num(r.tokens_per_sec)),
                ]));
            }
        }
    }
    for row in rows {
        let reordered =
            vec![row[0].clone(), row[1].clone(), row[2].clone(), row[3].clone(), row[4].clone()];
        t.row(reordered);
    }
    print_and_save("table5", &t, arr(jrows))
}

// --------------------------------------------------------------- Table 11
/// Out-of-distribution generalization: fine-tune on A, evaluate on B.
pub fn table11(args: &Args) -> Result<()> {
    let wl = workload(args)?;
    let mut t = Table::new(&["method", "phi dolly", "mixtral dolly", "phi gsm", "mixtral gsm"]);
    let mut rows: Vec<Vec<String>> = vec![
        vec!["melinoe (ft: dolly)".into()],
        vec!["melinoe (ft: gsm)".into()],
        vec!["fiddler".into()],
        vec!["mixtral-offloading".into()],
        vec!["deepspeed-moe".into()],
        vec!["floe".into()],
        vec!["moe-infinity".into()],
    ];
    let mut jrows = Vec::new();
    for ds in ["dolly", "gsm"] {
        for preset in ["phi-micro", "mixtral-micro"] {
            let Some(c) = try_ctx(args, preset) else {
                for row in rows.iter_mut() {
                    row.push("n/a".into());
                }
                continue;
            };
            let cap = c.cfg.cache_capacity;
            let pols = [
                PolicyConfig::melinoe("ft_dolly", cap),
                PolicyConfig::melinoe("ft_gsm", cap),
                PolicyConfig::fiddler(cap),
                PolicyConfig::mixtral_offloading(cap),
                PolicyConfig::deepspeed_moe(c.cfg.top_k),
                PolicyConfig::floe(cap),
                PolicyConfig::moe_infinity(cap),
            ];
            for (i, pol) in pols.iter().enumerate() {
                let Some(r) = try_run(&c, pol, ds, GpuSpec::a100(), wl) else {
                    rows[i].push("n/a".into());
                    continue;
                };
                rows[i].push(fmt2(r.tokens_per_sec));
                jrows.push(obj(vec![
                    ("method", s(format!("{}:{}", pol.name, pol.variant))),
                    ("preset", s(preset)),
                    ("eval", s(ds)),
                    ("tok_s", num(r.tokens_per_sec)),
                ]));
            }
        }
    }
    for row in rows {
        t.row(vec![row[0].clone(), row[1].clone(), row[2].clone(), row[3].clone(), row[4].clone()]);
    }
    print_and_save("table11", &t, arr(jrows))
}

// ---------------------------------------------------------------- Fig. 6
/// Throughput of the baselines at various output lengths (OLMoE, H100).
pub fn fig6(args: &Args) -> Result<()> {
    let c = ctx(args, "olmoe-micro")?;
    let cap = c.cfg.cache_capacity;
    let lengths = [16usize, 32, 64, 128];
    let mut t = Table::new(&["tokens", "melinoe", "fiddler", "mix-off", "deepspeed", "floe", "moe-inf"]);
    let mut jrows = Vec::new();
    for &len in &lengths {
        let wl = Workload { n_prompts: 3, max_output: len, ignore_eos: true };
        let pols = PolicyConfig::all_baselines(cap, c.cfg.top_k, "ft_dolly");
        let mut cells = vec![len.to_string()];
        let mut jc = vec![("tokens", num(len as f64))];
        let labels = ["melinoe", "fiddler", "mixoff", "deepspeed", "floe", "moeinf"];
        for (pol, label) in pols.iter().zip(labels) {
            let r = run_policy(&c, pol, "dolly", GpuSpec::h100(), wl)?;
            cells.push(fmt2(r.tokens_per_sec));
            jc.push((label, num(r.tokens_per_sec)));
        }
        t.row(cells);
        jrows.push(obj(jc));
    }
    print_and_save("fig6", &t, arr(jrows))
}

// ------------------------------------------------------------ Figs. 7–10
/// Expert-activation heatmaps: per-layer expert × step traces (CSV).
pub fn heatmaps(args: &Args) -> Result<()> {
    let tokens = args.get_usize("tokens", 48)?;
    std::fs::create_dir_all("results")?;
    let mut t = Table::new(&["preset", "variant", "distinct experts (L0)", "top-C share"]);
    let mut jrows = Vec::new();
    for preset in ["olmoe-micro", "phi-micro", "mixtral-micro"] {
        let Some(c) = try_ctx(args, preset) else { continue };
        for variant in ["base", "ft_dolly"] {
            let pol =
                PolicyConfig::base_offload(c.cfg.cache_capacity).with_variant(variant);
            let parts = c.parts(&pol, "dolly")?;
            let engine = parts.engine(&c, GpuSpec::h100());
            let eval = c.eval_set("dolly")?;
            let out = engine.decode(&eval.samples[0].prompt, tokens)?;
            // CSV: rows = steps, cols = experts, cell = 1 if selected
            for l in 0..c.cfg.n_layers.min(4) {
                let mut csv = String::new();
                for step in &out.trace.steps {
                    let mut row = vec!["0"; c.cfg.n_experts];
                    for &e in &step[l] {
                        row[e] = "1";
                    }
                    csv.push_str(&row.join(","));
                    csv.push('\n');
                }
                std::fs::write(
                    format!("results/heatmap_{preset}_{variant}_l{l}.csv"),
                    csv,
                )?;
            }
            let distinct =
                out.trace.counts[0].iter().filter(|&&n| n > 0).count();
            let share = out.trace.mean_topc_share(c.cfg.cache_capacity);
            t.row(vec![
                preset.into(),
                variant.into(),
                distinct.to_string(),
                fmt4(share),
            ]);
            jrows.push(obj(vec![
                ("preset", s(preset)),
                ("variant", s(variant)),
                ("distinct_l0", num(distinct as f64)),
                ("topc_share", num(share)),
            ]));
        }
    }
    println!("(per-layer CSVs written to results/heatmap_*.csv)");
    print_and_save("heatmaps", &t, arr(jrows))
}

// ---------------------------------------------------------------- Fig. 11
/// Throughput under different GPU VRAM budgets (H100).
pub fn fig11(args: &Args) -> Result<()> {
    let wl = workload(args)?;
    let budgets: &[(&str, &[f64])] = &[
        ("olmoe-micro", &[2.0, 3.0, 4.0, 6.0]),
        ("phi-micro", &[8.0, 16.0, 24.0]),
        ("mixtral-micro", &[16.0, 24.0, 32.0]),
    ];
    let mut t = Table::new(&["preset", "VRAM GB", "cap/layer", "melinoe", "floe", "deepspeed"]);
    let mut jrows = Vec::new();
    for (preset, gbs) in budgets {
        let Some(c) = try_ctx(args, preset) else { continue };
        for &gb in *gbs {
            let budget = VramBudget::gb(gb, c.cfg.cost);
            let cap = budget.capacity_per_layer(QuantMode::Int4).max(1);
            let cap_fp16 = budget.capacity_per_layer(QuantMode::Fp16).max(1);
            let ft = "ft_dolly";
            // melinoe counts its capacity in int4-resident slots already
            let pols = [
                PolicyConfig::melinoe(ft, cap).with_quant(QuantMode::Int4),
                PolicyConfig::floe(cap),
                PolicyConfig::deepspeed_moe(c.cfg.top_k),
            ];
            // avoid double-applying the quant multiplier for the derived caps
            let mut cells = vec![preset.to_string(), format!("{gb}"), cap.to_string()];
            let mut jc = vec![("preset", s(*preset)), ("gb", num(gb)), ("cap", num(cap as f64))];
            for (pol, label) in pols.iter().zip(["melinoe", "floe", "deepspeed"]) {
                let mut pol = pol.clone();
                if pol.quant != QuantMode::Fp16 {
                    // capacity already derived in quantized units
                    pol.capacity = cap;
                    pol.quant = QuantMode::Int4;
                }
                if pol.name.starts_with("deepspeed") {
                    pol.capacity = c.cfg.top_k.min(cap_fp16.max(1));
                }
                // neutralize effective_capacity's multiplier by feeding
                // fp16-equivalent capacity
                let eff = pol.effective_capacity(c.cfg.n_experts);
                let _ = eff;
                let r = run_policy(&c, &pol, "dolly", GpuSpec::h100(), wl)?;
                cells.push(fmt2(r.tokens_per_sec));
                jc.push((label, num(r.tokens_per_sec)));
            }
            t.row(cells);
            jrows.push(obj(jc));
        }
    }
    print_and_save("fig11", &t, arr(jrows))
}

// --------------------------------------------------------------- Table 12
/// Quantized-expert ablation: fp16 vs INT4 residency at equal VRAM.
pub fn table12(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 4)?,
        max_output: args.get_usize("tokens", 48)?,
        ignore_eos: true,
    };
    let c = ctx(args, "olmoe-micro")?;
    let base_cap = 8usize; // fp16 slots; int4 fits ~3.5× more in the same bytes
    let mut t = Table::new(&["setting", "resident/layer", "dolly tok/s", "gsm tok/s"]);
    let mut jrows = Vec::new();
    let configs: Vec<(&str, PolicyConfig)> = vec![
        ("base fp16", PolicyConfig::base_offload(base_cap)),
        ("base + int4 experts", PolicyConfig::base_offload(base_cap).with_quant(QuantMode::Int4)),
        (
            "fine-tuned fp16",
            PolicyConfig::melinoe_no_prefetch("ft_dolly", base_cap).with_quant(QuantMode::Fp16),
        ),
        (
            "fine-tuned + int4 experts",
            PolicyConfig::melinoe_no_prefetch("ft_dolly", base_cap).with_quant(QuantMode::Int4),
        ),
    ];
    for (label, pol) in configs {
        let eff = pol.effective_capacity(c.cfg.n_experts);
        let rd = run_policy(&c, &pol, "dolly", GpuSpec::h100(), wl)?;
        let pol_gsm = if pol.variant == "base" { pol.clone() } else { pol.clone().with_variant("ft_gsm") };
        let rg = run_policy(&c, &pol_gsm, "gsm", GpuSpec::h100(), wl)?;
        t.row(vec![
            label.to_string(),
            eff.to_string(),
            fmt2(rd.tokens_per_sec),
            fmt2(rg.tokens_per_sec),
        ]);
        jrows.push(obj(vec![
            ("setting", s(label)),
            ("resident", num(eff as f64)),
            ("dolly", num(rd.tokens_per_sec)),
            ("gsm", num(rg.tokens_per_sec)),
        ]));
    }
    print_and_save("table12", &t, arr(jrows))
}

// ---------------------------------------------------------------- Fig. 12
/// Soft-cache capacity in the loss vs eval-time transfers.
pub fn fig12(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 3)?,
        max_output: args.get_usize("tokens", 48)?,
        ignore_eos: true,
    };
    let c = ctx(args, "olmoe-micro")?;
    let variants = [("C_loss=8", "ft_dolly_c8"), ("C_loss=16", "ft_dolly"), ("C_loss=32", "ft_dolly_c32")];
    let eval_caps = [16usize, 32, 48];
    let mut t = Table::new(&["variant", "C=16 Tx/L", "C=32 Tx/L", "C=48 Tx/L"]);
    let mut jrows = Vec::new();
    for (label, variant) in variants {
        let mut cells = vec![label.to_string()];
        let mut jc = vec![("variant", s(variant))];
        for cap in eval_caps {
            let pol = PolicyConfig::melinoe_no_prefetch(variant, cap).with_quant(QuantMode::Fp16);
            let r = run_policy(&c, &pol, "dolly", GpuSpec::h100(), wl)?;
            cells.push(fmt2(r.tx_per_layer));
            jc.push(("c", num(r.tx_per_layer)));
        }
        t.row(cells);
        jrows.push(obj(jc));
    }
    print_and_save("fig12", &t, arr(jrows))
}

// ---------------------------------------------------------------- Fig. 13
/// Decay factor γ in the loss vs eval-time transfers.
pub fn fig13(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 3)?,
        max_output: args.get_usize("tokens", 48)?,
        ignore_eos: true,
    };
    let c = ctx(args, "olmoe-micro")?;
    let variants = [
        ("g=0.1", "ft_dolly_g01"),
        ("g=0.3", "ft_dolly_g03"),
        ("g=0.5", "ft_dolly_g05"),
        ("g=0.7", "ft_dolly_g07"),
        ("g=0.9", "ft_dolly"),
    ];
    let eval_caps = [8usize, 16, 32];
    let mut t = Table::new(&["gamma", "C=8 Tx/L", "C=16 Tx/L", "C=32 Tx/L"]);
    let mut jrows = Vec::new();
    for (label, variant) in variants {
        let mut cells = vec![label.to_string()];
        let mut jc = vec![("variant", s(variant))];
        for cap in eval_caps {
            let pol = PolicyConfig::melinoe_no_prefetch(variant, cap).with_quant(QuantMode::Fp16);
            let r = run_policy(&c, &pol, "dolly", GpuSpec::h100(), wl)?;
            cells.push(fmt2(r.tx_per_layer));
            jc.push(("c", num(r.tx_per_layer)));
        }
        t.row(cells);
        jrows.push(obj(jc));
    }
    print_and_save("fig13", &t, arr(jrows))
}

// --------------------------------------------------------------- Table 13
/// Eviction policy (LRU vs LFU) × fine-tuning γ.
pub fn table13(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 3)?,
        max_output: args.get_usize("tokens", 48)?,
        ignore_eos: true,
    };
    let c = ctx(args, "olmoe-micro")?;
    let cap = c.cfg.cache_capacity;
    let variants = [
        ("g=0.1", "ft_dolly_g01"),
        ("g=0.3", "ft_dolly_g03"),
        ("g=0.5", "ft_dolly_g05"),
        ("g=0.7", "ft_dolly_g07"),
        ("g=0.9", "ft_dolly"),
    ];
    let mut t = Table::new(&["fine-tuned with", "LRU Tx/L", "LFU Tx/L"]);
    let mut jrows = Vec::new();
    for (label, variant) in variants {
        let mut cells = vec![label.to_string()];
        let mut jc = vec![("variant", s(variant))];
        for kind in [EvictionKind::Lru, EvictionKind::Lfu] {
            let pol = PolicyConfig::melinoe_no_prefetch(variant, cap)
                .with_quant(QuantMode::Fp16)
                .with_eviction(kind);
            let r = run_policy(&c, &pol, "dolly", GpuSpec::h100(), wl)?;
            cells.push(fmt2(r.tx_per_layer));
            jc.push(("tx", num(r.tx_per_layer)));
        }
        t.row(cells);
        jrows.push(obj(jc));
    }
    print_and_save("table13", &t, arr(jrows))
}

// ------------------------------------------------- §5 extension (ours)
/// Layer-wise cache budgets (the paper's §5 future-work item): allocate
/// the same *total* number of resident slots non-uniformly, proportional
/// to each layer's routing diversity (effective expert count e^H from the
/// base activation profile), and compare against the uniform schedule.
pub fn ext_layerwise(args: &Args) -> Result<()> {
    let wl = Workload {
        n_prompts: args.get_usize("prompts", 4)?,
        max_output: args.get_usize("tokens", 48)?,
        ignore_eos: true,
    };
    let Some(c) = try_ctx(args, args.get_or("preset", "olmoe-micro")) else { return Ok(()) };
    let profile = crate::moe::RoutingProfile::load(&c.dir, "base", "dolly")?;
    let l_n = c.cfg.n_layers;
    // effective number of experts per layer: exp(entropy of freq row)
    let diversity: Vec<f64> = (0..l_n)
        .map(|l| {
            let row = profile.freq.row(l);
            let total: f32 = row.iter().sum();
            let h: f64 = row
                .iter()
                .filter(|&&p| p > 0.0)
                .map(|&p| {
                    let q = (p / total.max(1e-9)) as f64;
                    -q * q.ln()
                })
                .sum();
            h.exp()
        })
        .collect();
    let total_slots = c.cfg.cache_capacity * l_n;
    let dsum: f64 = diversity.iter().sum();
    let mut caps: Vec<usize> = diversity
        .iter()
        .map(|d| ((d / dsum) * total_slots as f64).round().max(2.0) as usize)
        .collect();
    // exact-budget correction
    while caps.iter().sum::<usize>() > total_slots {
        let i = caps.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap();
        caps[i] -= 1;
    }
    while caps.iter().sum::<usize>() < total_slots {
        let i = caps.iter().enumerate().min_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap();
        caps[i] += 1;
    }

    let mut t = Table::new(&["schedule", "slots/layer", "Tx/L", "tok/s"]);
    let mut jrows = Vec::new();
    for (label, pol) in [
        (
            "uniform (paper)",
            PolicyConfig::melinoe_no_prefetch("ft_dolly", c.cfg.cache_capacity)
                .with_quant(QuantMode::Fp16),
        ),
        (
            "layer-wise (ext)",
            PolicyConfig::melinoe_no_prefetch("ft_dolly", c.cfg.cache_capacity)
                .with_quant(QuantMode::Fp16)
                .with_layer_capacities(caps.clone()),
        ),
    ] {
        let r = run_policy(&c, &pol, "dolly", GpuSpec::h100(), wl)?;
        let desc = match &pol.layer_capacities {
            Some(v) => format!("{v:?}"),
            None => format!("{}×{}", c.cfg.cache_capacity, l_n),
        };
        t.row(vec![label.into(), desc, fmt2(r.tx_per_layer), fmt2(r.tokens_per_sec)]);
        jrows.push(obj(vec![
            ("schedule", s(label)),
            ("tx_per_layer", num(r.tx_per_layer)),
            ("tok_s", num(r.tokens_per_sec)),
        ]));
    }
    print_and_save("ext_layerwise", &t, arr(jrows))
}

/// Extension — cluster serving: RoundRobin vs LeastLoaded vs
/// ExpertAffinity dispatch across 2/4/8 replicas on heterogeneous
/// per-task traffic.  Pure simulation over the cost model and synthetic
/// routing profiles (no artifacts required): the expected shape is
/// ExpertAffinity strictly ahead on fleet cache hit-rate and tokens/s,
/// with the gap widening as replicas (and therefore cache diversity)
/// grow — the fleet-level analogue of the paper's top-C concentration.
pub fn ext_cluster(args: &Args) -> Result<()> {
    use crate::cluster::{self, ClusterConfig};
    use crate::coordinator::workload::Arrival;

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 64)?;
    let n_tasks = args.get_usize("tasks", 4)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let burst = args.has_flag("burst");

    let mut t = Table::new(&[
        "replicas", "balancer", "tok/s", "hit rate", "PCIe GB", "queue p50/p95/p99 (s)",
        "latency p50/p95/p99 (s)",
    ]);
    let mut jrows = Vec::new();
    for replicas in [2usize, 4, 8] {
        let mut bld = ClusterConfig::builder(replicas, n_requests, n_tasks, gpu.clone(), seed)
            .trace(true);
        if burst {
            bld = bld.arrival(Arrival::Burst);
        }
        let cfg = bld.build()?;
        for rep in cluster::compare(&cfg, cluster::BALANCERS)? {
            t.row(vec![
                replicas.to_string(),
                rep.balancer.clone(),
                fmt2(rep.tokens_per_sec),
                fmt4(rep.hit_rate),
                fmt2(rep.pcie_gb),
                rep.queue_wait.cell(1.0),
                rep.latency.cell(1.0),
            ]);
            jrows.push(obj(vec![
                ("replicas", num(replicas as f64)),
                ("balancer", s(rep.balancer.clone())),
                ("tok_s", num(rep.tokens_per_sec)),
                ("hit_rate", num(rep.hit_rate)),
                ("pcie_gb", num(rep.pcie_gb)),
                ("queue_p99_s", num(rep.queue_wait.p99)),
                ("latency_p99_s", num(rep.latency.p99)),
                ("makespan_s", num(rep.makespan)),
                ("metrics", trace_metrics(&rep)),
            ]));
        }
    }
    print_and_save("ext_cluster", &t, arr(jrows))
}

/// Extension — continuous batching: static (run-to-completion batches)
/// vs continuous (step-level admission, the tentpole refactor) on the
/// same fleet, under open-loop Poisson arrivals with bimodal output
/// lengths.  Expected shape: continuous strictly ahead on p95 latency
/// and tokens/s — freed slots re-admit queued requests instead of
/// idling behind the longest batch member — with fleet cache hit-rate
/// no worse, because expert-affinity dispatch keeps each replica
/// task-pure, so mid-flight admissions reuse the experts the in-flight
/// batch already pinned (the deployment-side batching dynamics of
/// *Towards MoE Deployment* and eMoE's task-aware admission).
pub fn ext_continuous(args: &Args) -> Result<()> {
    use crate::cluster::workload::OutputLen;
    use crate::cluster::{self, ClusterConfig};
    use crate::coordinator::workload::Arrival;
    use crate::coordinator::SchedulerMode;

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 64)?;
    let replicas = args.get_usize("replicas", 2)?;
    let n_tasks = args.get_usize("tasks", 2)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let long = args.get_usize("tokens", 48)?;
    let short = args.get_usize("short", 6)?.min(long);
    let long_frac = args.get_f64("long-frac", 0.25)?.clamp(0.0, 1.0);

    let output = OutputLen::Bimodal { short, long, long_frac };
    let bld = ClusterConfig::builder(replicas, n_requests, n_tasks, gpu, seed)
        .output(output)
        .trace(true);
    // saturate: offered load ≈ 2.5× the fleet's single-stream capacity,
    // so scheduling efficiency — not offered load — bounds throughput
    let est = bld
        .draft()
        .spec
        .est_service_seconds(bld.draft().workload.prompt_tokens, output.mean().ceil() as usize)
        .max(1e-9);
    let base = bld.arrival(Arrival::Poisson(2.5 * replicas.max(1) as f64 / est)).build()?;
    println!(
        "{} replicas, {} requests, outputs {}/{} tokens ({}% long), poisson 2.5x capacity",
        replicas,
        n_requests,
        short,
        long,
        (long_frac * 100.0) as u32
    );

    let mut t = Table::new(&[
        "scheduler", "tok/s", "hit rate", "ttft p95 (s)", "latency p50/p95/p99 (s)", "PCIe GB",
    ]);
    let mut jrows = Vec::new();
    for mode in [SchedulerMode::Static, SchedulerMode::Continuous] {
        let cfg = base.clone().with_scheduler(mode);
        let mut b = cluster::balancer::by_name("expert-affinity")?;
        let rep = cluster::run_cluster(&cfg, b.as_mut())?;
        let name = match mode {
            SchedulerMode::Static => "static",
            SchedulerMode::Continuous => "continuous",
        };
        t.row(vec![
            name.into(),
            fmt2(rep.tokens_per_sec),
            fmt4(rep.hit_rate),
            fmt2(rep.ttft.p95),
            rep.latency.cell(1.0),
            fmt2(rep.pcie_gb),
        ]);
        jrows.push(obj(vec![
            ("scheduler", s(name)),
            ("tok_s", num(rep.tokens_per_sec)),
            ("hit_rate", num(rep.hit_rate)),
            ("ttft_p95_s", num(rep.ttft.p95)),
            ("tpot_p50_s", num(rep.tpot.p50)),
            ("latency_p95_s", num(rep.latency.p95)),
            ("pcie_gb", num(rep.pcie_gb)),
            ("makespan_s", num(rep.makespan)),
            ("metrics", trace_metrics(&rep)),
        ]));
    }
    print_and_save("ext_continuous", &t, arr(jrows))
}

/// Extension — chunked prefill: the same long-prompt Poisson workload
/// served at prefill chunk 1 (token-at-a-time, the pre-chunking
/// behaviour) vs 8 vs 32, on an expert-affinity fleet with continuous
/// batching.  Expected shape: chunk ≥ 8 cuts p95 TTFT hard — a P-token
/// prompt needs ⌈P/chunk⌉ steps instead of P, and each chunk amortizes
/// the per-step dispatch overhead and attention weight reads across its
/// tokens (Sarathi-style piggybacked prefill) — while TPOT and the
/// expert-cache hit rate stay no worse, because decodes still emit
/// exactly one token per step and the chunk replays the identical
/// pre-drawn routing against the same caches.
pub fn ext_prefill(args: &Args) -> Result<()> {
    use crate::cluster::workload::OutputLen;
    use crate::cluster::{self, ClusterConfig};
    use crate::coordinator::workload::Arrival;
    use crate::metrics::fmt_speedup;

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 64)?;
    let replicas = args.get_usize("replicas", 2)?;
    let n_tasks = args.get_usize("tasks", 2)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let prompt = args.get_usize("prompt", 96)?.max(1);
    let tokens = args.get_usize("tokens", 16)?.max(1);

    let bld = ClusterConfig::builder(replicas, n_requests, n_tasks, gpu, seed)
        .trace(true)
        .prompt_tokens(prompt)
        .output(OutputLen::Fixed(tokens));
    // stable queueing: offered load ≈ 0.8× the fleet's compute-only
    // capacity at token-at-a-time service, so p95 TTFT reflects prefill
    // latency rather than unbounded queue growth
    let est = bld.draft().spec.est_service_seconds(prompt, tokens).max(1e-9);
    let base = bld.arrival(Arrival::Poisson(0.8 * replicas.max(1) as f64 / est)).build()?;
    println!(
        "{replicas} replicas, {n_requests} requests, {prompt}-token prompts, \
         {tokens} output tokens, poisson 0.8x capacity"
    );

    let mut t = Table::new(&[
        "chunk", "ttft p50/p95/p99 (s)", "p95 ttft speedup", "tpot p50 (ms)", "tok/s",
        "hit rate", "PCIe GB",
    ]);
    let mut jrows = Vec::new();
    let mut ttft_p95_chunk1 = f64::NAN;
    for chunk in [1usize, 8, 32] {
        let cfg = base.clone().with_prefill_chunk(chunk);
        let mut b = cluster::balancer::by_name("expert-affinity")?;
        let rep = cluster::run_cluster(&cfg, b.as_mut())?;
        if chunk == 1 {
            ttft_p95_chunk1 = rep.ttft.p95;
        }
        t.row(vec![
            chunk.to_string(),
            rep.ttft.cell(1.0),
            fmt_speedup(ttft_p95_chunk1, rep.ttft.p95),
            fmt2(rep.tpot.p50 * 1e3),
            fmt2(rep.tokens_per_sec),
            fmt4(rep.hit_rate),
            fmt2(rep.pcie_gb),
        ]);
        jrows.push(obj(vec![
            ("prefill_chunk", num(chunk as f64)),
            ("ttft_p50_s", num(rep.ttft.p50)),
            ("ttft_p95_s", num(rep.ttft.p95)),
            ("ttft_p99_s", num(rep.ttft.p99)),
            ("tpot_p50_s", num(rep.tpot.p50)),
            ("tok_s", num(rep.tokens_per_sec)),
            ("hit_rate", num(rep.hit_rate)),
            ("pcie_gb", num(rep.pcie_gb)),
            ("makespan_s", num(rep.makespan)),
            ("metrics", trace_metrics(&rep)),
        ]));
    }
    print_and_save("ext_prefill", &t, arr(jrows))
}

/// Extension — layer-ahead overlapped expert transfer: the same workload
/// served at lookahead 0 (admit-time prefetch only, the pre-pipeline
/// behaviour) vs 1 vs 2, across OLMoE-scale and Mixtral-scale dims × two
/// cache-pressure points (capacity below the task hot-set size, the
/// regime where Eq. 3's transfer term dominates).  Expected shape:
/// lookahead ≥ 1 strictly cuts decode stall time and lifts tok/s at
/// equal capacity — misses at layer ℓ+1 become transfers issued during
/// layer ℓ's compute, so the decode pays at most the residual — with
/// hit-rate no worse (prefetched experts commit before use; the
/// reserve/commit path never evicts the step's pin set).  The overlap
/// fraction is the mechanism metric: it rises from "admit traffic only"
/// toward 1 as the pipeline hides more of the link time.
pub fn ext_overlap(args: &Args) -> Result<()> {
    use crate::clock::PaperDims;
    use crate::cluster::replica::ReplicaSpec;
    use crate::cluster::workload::{OutputLen, PriorityMix, StreamMix, TaskProfile, WorkloadSpec};
    use crate::cluster::{self, ClusterConfig};
    use crate::coordinator::workload::Arrival;

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 32)?;
    let replicas = args.get_usize("replicas", 2)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let tokens = args.get_usize("tokens", 16)?.max(1);
    let trace_out = args.get("trace").map(str::to_string);
    let mut last_chrome: Option<String> = None;

    // (name, paper dims, task hot-set size, capacities under pressure)
    let olmoe = PaperDims {
        n_layers: 16,
        n_experts: 64,
        top_k: 8,
        d_model: 2048,
        d_ff: 1024,
        vocab: 50304,
    };
    let mixtral = PaperDims {
        n_layers: 32,
        n_experts: 8,
        top_k: 2,
        d_model: 4096,
        d_ff: 14336,
        vocab: 32000,
    };
    let grids: [(&str, PaperDims, usize, [usize; 2]); 2] =
        [("olmoe", olmoe, 16, [8, 12]), ("mixtral", mixtral, 4, [2, 3])];

    let mut t = Table::new(&[
        "dims", "C", "lookahead", "tok/s", "hit rate", "stall s", "overlap s", "overlap %",
        "PCIe GB",
    ]);
    let mut jrows = Vec::new();
    for (name, dims, hot, caps) in grids {
        for cap in caps {
            let spec = ReplicaSpec {
                n_layers: dims.n_layers,
                n_experts: dims.n_experts,
                top_k: dims.top_k,
                capacity: cap,
                eviction: EvictionKind::Lfu,
                quant: QuantMode::Int4,
                little_tier: None,
                fallback_threshold: 0.0,
                prefetch: true,
                lookahead: 0,
                gpu: gpu.clone(),
                dims,
            };
            let tasks = TaskProfile::synthetic(2, dims.n_layers, dims.n_experts, hot, 0.9);
            let prompt_tokens = 8;
            let est = spec.est_service_seconds(prompt_tokens, tokens).max(1e-9);
            let base = ClusterConfig::builder(replicas, n_requests, 2, gpu.clone(), seed)
                .trace(true)
                .spec(spec)
                .tasks(tasks)
                .workload(WorkloadSpec {
                    n_requests,
                    // saturated: serving efficiency, not offered load,
                    // bounds throughput
                    arrival: Arrival::Poisson(1.5 * replicas.max(1) as f64 / est),
                    prompt_tokens,
                    output: OutputLen::Fixed(tokens),
                    balanced_tasks: true,
                    priorities: PriorityMix::none(),
                    stream: StreamMix::none(),
                    seed,
                })
                .build()?;
            for depth in [0usize, 1, 2] {
                let cfg = base.clone().with_lookahead(depth);
                let mut b = cluster::balancer::by_name("expert-affinity")?;
                let rep = cluster::run_cluster(&cfg, b.as_mut())?;
                t.row(vec![
                    name.into(),
                    cap.to_string(),
                    depth.to_string(),
                    fmt2(rep.tokens_per_sec),
                    fmt4(rep.hit_rate),
                    fmt2(rep.stall_seconds),
                    fmt2(rep.overlapped_seconds),
                    format!("{:.1}", rep.overlap_fraction * 100.0),
                    fmt2(rep.pcie_gb),
                ]);
                jrows.push(obj(vec![
                    ("dims", s(name)),
                    ("capacity", num(cap as f64)),
                    ("lookahead", num(depth as f64)),
                    ("tok_s", num(rep.tokens_per_sec)),
                    ("hit_rate", num(rep.hit_rate)),
                    ("stall_s", num(rep.stall_seconds)),
                    ("overlapped_s", num(rep.overlapped_seconds)),
                    ("overlap_fraction", num(rep.overlap_fraction)),
                    ("pcie_gb", num(rep.pcie_gb)),
                    ("makespan_s", num(rep.makespan)),
                    ("metrics", trace_metrics(&rep)),
                ]));
                if let (Some(_), Some(tr)) = (&trace_out, &rep.trace) {
                    last_chrome = Some(tr.to_chrome_json().to_string());
                }
            }
        }
    }
    if let (Some(path), Some(chrome)) = (&trace_out, &last_chrome) {
        std::fs::write(path, chrome).map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("trace (last run): -> {path}");
    }
    print_and_save("ext_overlap", &t, arr(jrows))
}

/// Extension — priority-aware preemption: a priority-skewed Poisson
/// workload (20% High jumping a mostly-Low mix) served with preemption
/// off vs on at two cache-capacity points, on a continuous-batching
/// expert-affinity fleet.  Off still admits priority-first, but a High
/// arrival that finds every slot occupied waits for a natural
/// retirement; on, it suspends the lowest-priority in-flight sequence at
/// a step boundary once its wait passes the threshold (the suspended
/// sequence resumes later, bit-identically).  Expected shape: preemption
/// on cuts High-priority p95 TTFT and p95 latency hard at equal
/// capacity, with aggregate tok/s and hit-rate within noise — the
/// suspended work is conserved, only reordered — and the preempted-wait
/// percentiles make the cost visible on the Low class instead of
/// laundering it into queue time.
pub fn ext_preempt(args: &Args) -> Result<()> {
    use crate::clock::PaperDims;
    use crate::cluster::replica::ReplicaSpec;
    use crate::cluster::workload::{OutputLen, PriorityMix, StreamMix, TaskProfile, WorkloadSpec};
    use crate::cluster::{self, ClusterConfig};
    use crate::coordinator::workload::Arrival;
    use crate::coordinator::{PreemptPolicy, Priority};

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 48)?;
    let replicas = args.get_usize("replicas", 2)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let tokens = args.get_usize("tokens", 32)?.max(2);
    let high_frac = args.get_f64("high-frac", 0.2)?.clamp(0.0, 1.0);
    let low_frac = args.get_f64("low-frac", 0.8)?.clamp(0.0, 1.0 - high_frac);

    let dims = PaperDims {
        n_layers: 16,
        n_experts: 64,
        top_k: 8,
        d_model: 2048,
        d_ff: 1024,
        vocab: 50304,
    };
    let prompt_tokens = 8;
    let mut t = Table::new(&[
        "C", "preempt", "tok/s", "hit rate", "preemptions", "high ttft p95 (s)",
        "high latency p95 (s)", "low latency p95 (s)", "preempted wait p95 (s)",
    ]);
    let mut jrows = Vec::new();
    for cap in [8usize, 12] {
        let spec = ReplicaSpec {
            n_layers: dims.n_layers,
            n_experts: dims.n_experts,
            top_k: dims.top_k,
            capacity: cap,
            eviction: EvictionKind::Lfu,
            quant: QuantMode::Int4,
            little_tier: None,
            fallback_threshold: 0.0,
            prefetch: true,
            lookahead: 0,
            gpu: gpu.clone(),
            dims,
        };
        let tasks = TaskProfile::synthetic(2, dims.n_layers, dims.n_experts, 16, 0.9);
        let est = spec.est_service_seconds(prompt_tokens, tokens).max(1e-9);
        // default threshold: two solo token-steps of waiting, then preempt
        let thresh = args
            .get_f64("preempt-after", 2.0 * est / (prompt_tokens + tokens) as f64)?
            .max(0.0);
        let base = ClusterConfig::builder(replicas, n_requests, 2, gpu.clone(), seed)
            .trace(true)
            .spec(spec)
            .tasks(tasks)
            .workload(WorkloadSpec {
                n_requests,
                // saturated: a High arrival almost always finds the
                // slots full, so the off/on contrast is pure scheduling
                arrival: Arrival::Poisson(1.5 * replicas.max(1) as f64 / est),
                prompt_tokens,
                output: OutputLen::Fixed(tokens),
                balanced_tasks: true,
                priorities: PriorityMix { high: high_frac, low: low_frac },
                stream: StreamMix::none(),
                seed,
            })
            .build()?;
        for policy in [PreemptPolicy::Off, PreemptPolicy::After(thresh)] {
            let cfg = base.clone().with_preempt(policy);
            let mut b = cluster::balancer::by_name("expert-affinity")?;
            let rep = cluster::run_cluster(&cfg, b.as_mut())?;
            let class = |p: Priority| rep.priorities.iter().find(|c| c.priority == p);
            let high = class(Priority::High);
            let low = class(Priority::Low);
            let label = match policy {
                PreemptPolicy::Off => "off".to_string(),
                PreemptPolicy::After(s) => format!("{s:.4}s"),
            };
            t.row(vec![
                cap.to_string(),
                label.clone(),
                fmt2(rep.tokens_per_sec),
                fmt4(rep.hit_rate),
                rep.preemptions.to_string(),
                format!("{:.3}", high.map_or(0.0, |c| c.ttft.p95)),
                format!("{:.3}", high.map_or(0.0, |c| c.latency.p95)),
                format!("{:.3}", low.map_or(0.0, |c| c.latency.p95)),
                format!("{:.3}", low.map_or(0.0, |c| c.preempted_wait.p95)),
            ]);
            jrows.push(obj(vec![
                ("capacity", num(cap as f64)),
                ("preempt_on", num(if policy == PreemptPolicy::Off { 0.0 } else { 1.0 })),
                ("threshold_s", num(policy.threshold().unwrap_or(0.0))),
                ("tok_s", num(rep.tokens_per_sec)),
                ("hit_rate", num(rep.hit_rate)),
                ("preemptions", num(rep.preemptions as f64)),
                ("high_ttft_p95_s", num(high.map_or(0.0, |c| c.ttft.p95))),
                ("high_latency_p95_s", num(high.map_or(0.0, |c| c.latency.p95))),
                ("low_latency_p95_s", num(low.map_or(0.0, |c| c.latency.p95))),
                ("preempted_wait_p95_s", num(low.map_or(0.0, |c| c.preempted_wait.p95))),
                ("overlap_fraction", num(rep.overlap_fraction)),
                ("makespan_s", num(rep.makespan)),
                ("metrics", trace_metrics(&rep)),
            ]));
        }
    }
    print_and_save("ext_preempt", &t, arr(jrows))
}

/// Extension — quantized expert tiers with big-little fallback: the
/// same saturated workload served at *equal VRAM bytes* under three
/// arms per capacity point — fp16 residency, int4 residency (the byte
/// budget holds ~3.6× the experts), and int4 residency with an int3
/// little store (`LITTLE_BUDGET_FRAC` of the budget) whose hot-expert
/// copies execute at zero stall when a demand miss's expected wait
/// exceeds the fallback threshold.  Expected shape: int4 strictly cuts
/// stall time and lifts tok/s vs fp16 at equal bytes (more of the task
/// hot set fits, and each transfer moves ~3.6× fewer bytes), and the
/// fallback arms cut stall further still, paying with a nonzero
/// `degraded_token_frac` — the quality-for-latency dial.  Every row's
/// `metrics` snapshot carries the fleet's per-tier byte counters, so
/// the equal-bytes claim is auditable from the JSON alone.
pub fn ext_quant(args: &Args) -> Result<()> {
    use crate::cache::LITTLE_BUDGET_FRAC;
    use crate::clock::PaperDims;
    use crate::cluster::replica::ReplicaSpec;
    use crate::cluster::workload::{OutputLen, PriorityMix, StreamMix, TaskProfile, WorkloadSpec};
    use crate::cluster::{self, ClusterConfig};
    use crate::coordinator::workload::Arrival;

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 32)?;
    let replicas = args.get_usize("replicas", 2)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let tokens = args.get_usize("tokens", 16)?.max(1);

    let dims = PaperDims {
        n_layers: 16,
        n_experts: 64,
        top_k: 8,
        d_model: 2048,
        d_ff: 1024,
        vocab: 50304,
    };
    let hot = 16; // synthetic task hot-set size (experts/layer)
    let prompt_tokens = 8;

    let mut t = Table::new(&[
        "fp16-eq C", "arm", "slots/layer", "tok/s", "hit rate", "stall s", "degraded",
        "PCIe GB",
    ]);
    let mut jrows = Vec::new();
    // fp16 capacities well under the hot set: the regime where residency
    // bytes are the binding constraint (Eq. 3's transfer term dominates)
    for fp16_cap in [4usize, 6] {
        let budget_units = fp16_cap as f64 * QuantMode::Fp16.cost_units();
        let int4_cap = ((budget_units / QuantMode::Int4.cost_units()) as usize)
            .min(dims.n_experts)
            .max(1);
        let mk_spec = |capacity: usize, quant, little_tier, fallback_threshold| ReplicaSpec {
            n_layers: dims.n_layers,
            n_experts: dims.n_experts,
            top_k: dims.top_k,
            capacity,
            eviction: EvictionKind::Lfu,
            quant,
            little_tier,
            fallback_threshold,
            prefetch: true,
            lookahead: 0,
            gpu: gpu.clone(),
            dims,
        };
        let probe = mk_spec(fp16_cap, QuantMode::Fp16, None, 0.0);
        let est = probe.est_service_seconds(prompt_tokens, tokens).max(1e-9);
        // threshold sweep: 0 (any wait falls back) and one solo
        // token-step of waiting (only step-dominating waits fall back)
        let step_s = est / (prompt_tokens + tokens) as f64;
        let arms: Vec<(String, ReplicaSpec)> = vec![
            ("fp16".into(), probe.clone()),
            ("int4".into(), mk_spec(int4_cap, QuantMode::Int4, None, 0.0)),
            (
                "int4+int3 @0s".into(),
                mk_spec(int4_cap, QuantMode::Int4, Some(QuantMode::Int3), 0.0),
            ),
            (
                format!("int4+int3 @{step_s:.4}s"),
                mk_spec(int4_cap, QuantMode::Int4, Some(QuantMode::Int3), step_s),
            ),
        ];
        for (arm, spec) in arms {
            let tasks = TaskProfile::synthetic(2, dims.n_layers, dims.n_experts, hot, 0.9);
            let cfg = ClusterConfig::builder(replicas, n_requests, 2, gpu.clone(), seed)
                .trace(true)
                .spec(spec.clone())
                .tasks(tasks)
                .workload(WorkloadSpec {
                    n_requests,
                    // saturated: serving efficiency, not offered load,
                    // bounds throughput
                    arrival: Arrival::Poisson(1.5 * replicas.max(1) as f64 / est),
                    prompt_tokens,
                    output: OutputLen::Fixed(tokens),
                    balanced_tasks: true,
                    priorities: PriorityMix::none(),
                    stream: StreamMix::none(),
                    seed,
                })
                .build()?;
            let mut b = cluster::balancer::by_name("expert-affinity")?;
            let rep = cluster::run_cluster(&cfg, b.as_mut())?;
            let little = spec.little_tier.map_or("none", |lt| lt.name());
            // what the byte budget actually funds per layer (the little
            // carve shrinks the big store; LITTLE_BUDGET_FRAC of bytes)
            let slots = match spec.little_tier {
                Some(lt) => {
                    let budget = spec.capacity as f64 * spec.quant.cost_units();
                    let lc = (budget * LITTLE_BUDGET_FRAC / lt.cost_units()) as usize;
                    let bc = ((budget - lc as f64 * lt.cost_units()) / spec.quant.cost_units())
                        as usize;
                    format!("{bc}+{lc}L")
                }
                None => spec.capacity.to_string(),
            };
            t.row(vec![
                fp16_cap.to_string(),
                arm.clone(),
                slots,
                fmt2(rep.tokens_per_sec),
                fmt4(rep.hit_rate),
                fmt2(rep.stall_seconds),
                format!("{:.4}", rep.degraded_token_frac),
                fmt2(rep.pcie_gb),
            ]);
            jrows.push(obj(vec![
                ("fp16_eq_capacity", num(fp16_cap as f64)),
                ("arm", s(arm)),
                ("quant", s(spec.quant.name())),
                ("little_tier", s(little)),
                ("fallback_threshold_s", num(spec.fallback_threshold)),
                ("budget_units", num(budget_units)),
                ("tok_s", num(rep.tokens_per_sec)),
                ("hit_rate", num(rep.hit_rate)),
                ("stall_s", num(rep.stall_seconds)),
                ("degraded_token_frac", num(rep.degraded_token_frac)),
                ("pcie_gb", num(rep.pcie_gb)),
                ("makespan_s", num(rep.makespan)),
                ("metrics", trace_metrics(&rep)),
            ]));
        }
    }
    print_and_save("ext_quant", &t, arr(jrows))
}

/// Extension — streaming front-end under deadline overload and cancel
/// storms.  Two arms over the same saturated fleet.  **deadline**: a
/// burst workload where 80% of requests carry a TTFT deadline of
/// 3× the solo service estimate, served with SLO-aware admission off vs
/// on.  Off, hopeless requests are decoded anyway and crowd out the
/// servable ones; on, the replica rejects a queued request at pop time
/// once even an optimistic prefill estimate cannot make its deadline.
/// Expected shape: admission strictly lifts goodput (deadline-attained
/// tokens per second) while raw tok/s stays within noise — the fleet is
/// saturated either way, admission only changes *which* requests it
/// burns the capacity on.  **cancel-storm**: 35% of requests hang up
/// after their first streamed token and 10% disconnect while still
/// queued.  The gate is conservation, not speed: every cancelled
/// sequence must release its slot and pins at the next step boundary,
/// so the trace's `pins_set` / `pins_released` counters balance
/// exactly (the in-run audits already hard-fail on leaks; the JSON row
/// makes the balance auditable offline).
pub fn ext_stream(args: &Args) -> Result<()> {
    use crate::clock::PaperDims;
    use crate::cluster::replica::ReplicaSpec;
    use crate::cluster::workload::{OutputLen, PriorityMix, StreamMix, TaskProfile, WorkloadSpec};
    use crate::cluster::{self, ClusterConfig};
    use crate::coordinator::workload::Arrival;

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 48)?;
    let replicas = args.get_usize("replicas", 2)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let tokens = args.get_usize("tokens", 32)?.max(2);

    let dims = PaperDims {
        n_layers: 16,
        n_experts: 64,
        top_k: 8,
        d_model: 2048,
        d_ff: 1024,
        vocab: 50304,
    };
    let prompt_tokens = 8;
    let spec = ReplicaSpec {
        n_layers: dims.n_layers,
        n_experts: dims.n_experts,
        top_k: dims.top_k,
        capacity: 8,
        eviction: EvictionKind::Lfu,
        quant: QuantMode::Int4,
        little_tier: None,
        fallback_threshold: 0.0,
        prefetch: true,
        lookahead: 0,
        gpu: gpu.clone(),
        dims,
    };
    let est = spec.est_service_seconds(prompt_tokens, tokens).max(1e-9);
    // a burst fills the queue instantly, so a 3×-service slack strands
    // roughly the back half of the deadline requests — the regime where
    // admission has something to save
    let deadline_mix = StreamMix {
        deadline_frac: 0.8,
        deadline_slack: 3.0 * est,
        cancel_frac: 0.0,
        cancel_after: 0,
        disconnect_frac: 0.0,
    };
    let cancel_mix = StreamMix {
        deadline_frac: 0.0,
        deadline_slack: 0.0,
        cancel_frac: 0.35,
        cancel_after: 1,
        disconnect_frac: 0.1,
    };
    let mk_cfg = |stream: StreamMix, arrival: Arrival, admission: bool| -> Result<ClusterConfig> {
        ClusterConfig::builder(replicas, n_requests, 2, gpu.clone(), seed)
            .admission(admission)
            .trace(true)
            .spec(spec.clone())
            .tasks(TaskProfile::synthetic(2, dims.n_layers, dims.n_experts, 16, 0.9))
            .workload(WorkloadSpec {
                n_requests,
                arrival,
                prompt_tokens,
                output: OutputLen::Fixed(tokens),
                balanced_tasks: true,
                priorities: PriorityMix::none(),
                stream,
                seed,
            })
            .build()
    };
    let arms: Vec<(&str, &str, ClusterConfig)> = vec![
        ("deadline", "least-loaded", mk_cfg(deadline_mix, Arrival::Burst, false)?),
        ("deadline", "least-loaded", mk_cfg(deadline_mix, Arrival::Burst, true)?),
        (
            "cancel-storm",
            "expert-affinity",
            mk_cfg(
                cancel_mix,
                Arrival::Poisson(1.5 * replicas.max(1) as f64 / est),
                false,
            )?,
        ),
    ];

    let mut t = Table::new(&[
        "arm", "admission", "tok/s", "goodput tok/s", "completed", "cancelled", "rejected",
        "makespan s",
    ]);
    let mut jrows = Vec::new();
    for (arm, balancer, cfg) in arms {
        let mut b = cluster::balancer::by_name(balancer)?;
        let rep = cluster::run_cluster(&cfg, b.as_mut())?;
        t.row(vec![
            arm.into(),
            if cfg.admission { "slo-aware".into() } else { "off".to_string() },
            fmt2(rep.tokens_per_sec),
            fmt2(rep.goodput_per_sec),
            rep.completed.to_string(),
            rep.cancelled.to_string(),
            rep.rejected.to_string(),
            fmt2(rep.makespan),
        ]);
        jrows.push(obj(vec![
            ("arm", s(arm)),
            ("admission", num(if cfg.admission { 1.0 } else { 0.0 })),
            ("tok_s", num(rep.tokens_per_sec)),
            ("hit_rate", num(rep.hit_rate)),
            ("goodput_tok_s", num(rep.goodput_per_sec)),
            ("goodput_tokens", num(rep.goodput_tokens as f64)),
            ("output_tokens", num(rep.output_tokens as f64)),
            ("n_requests", num(n_requests as f64)),
            ("completed", num(rep.completed as f64)),
            ("cancelled", num(rep.cancelled as f64)),
            ("rejected", num(rep.rejected as f64)),
            ("makespan_s", num(rep.makespan)),
            ("metrics", trace_metrics(&rep)),
        ]));
    }
    print_and_save("ext_stream", &t, arr(jrows))
}

/// Extension — fault-tolerant fleet.  Four arms over the same burst
/// workload on an expert-affinity fleet: **fault-free** (baseline, and
/// the byte-identity reference), a **crash-storm** served with retries
/// off vs on, and a **brownout-mix** (crashes + brownouts + link flaps
/// + transfer corruption) with retries on.  The fault-free arm runs
/// first and its makespan sizes the storm horizon, so injected faults
/// land inside the active window at any simulated model scale; the
/// crash mtbf then walks a deterministic ladder until the *realized*
/// plan lands a handful of early crashes — disruptive enough that
/// retry-off visibly fails requests, bounded enough that retry-on stays
/// within the check_repro tok/s envelope.  Expected shape: retry-off
/// terminates every reclaimed request `Failed`; retry-on re-decodes
/// them to completion (strictly higher completed fraction) at tok/s
/// near fault-free, with Completed token counts bit-identical to the
/// fault-free arm (asserted here, gated again offline).  Conservation
/// (`injected == recovered + failed`) is hard-checked inside
/// `run_cluster` on every faulty arm.
pub fn ext_fault(args: &Args) -> Result<()> {
    use crate::clock::PaperDims;
    use crate::cluster::replica::ReplicaSpec;
    use crate::cluster::workload::{OutputLen, PriorityMix, StreamMix, TaskProfile, WorkloadSpec};
    use crate::cluster::{self, ClusterConfig};
    use crate::coordinator::workload::Arrival;
    use crate::coordinator::Outcome;
    use crate::fault::{FaultPlan, FaultSpec, RetryPolicy};

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let n_requests = args.get_usize("requests", 64)?;
    let replicas = args.get_usize("replicas", 4)?.max(2);
    let seed = args.get_usize("seed", 42)? as u64;
    let tokens = args.get_usize("tokens", 32)?.max(2);

    let dims = PaperDims {
        n_layers: 16,
        n_experts: 64,
        top_k: 8,
        d_model: 2048,
        d_ff: 1024,
        vocab: 50304,
    };
    let prompt_tokens = 8;
    let spec = ReplicaSpec {
        n_layers: dims.n_layers,
        n_experts: dims.n_experts,
        top_k: dims.top_k,
        capacity: 8,
        eviction: EvictionKind::Lfu,
        quant: QuantMode::Int4,
        little_tier: None,
        fallback_threshold: 0.0,
        prefetch: true,
        lookahead: 0,
        gpu: gpu.clone(),
        dims,
    };
    let est = spec.est_service_seconds(prompt_tokens, tokens).max(1e-9);
    let mk_cfg = |faults: FaultSpec, retry: RetryPolicy| -> Result<ClusterConfig> {
        ClusterConfig::builder(replicas, n_requests, 2, gpu.clone(), seed)
            .trace(true)
            .faults(faults)
            .retry(retry)
            .spec(spec.clone())
            .tasks(TaskProfile::synthetic(2, dims.n_layers, dims.n_experts, 16, 0.9))
            .workload(WorkloadSpec {
                n_requests,
                // burst: the queues are full from t=0, so any crash inside
                // the horizon reclaims work and the retry-off arm has
                // something to fail
                arrival: Arrival::Burst,
                prompt_tokens,
                output: OutputLen::Fixed(tokens),
                balanced_tasks: true,
                priorities: PriorityMix::none(),
                stream: StreamMix::none(),
                seed,
            })
            .build()
    };

    let clean_cfg = mk_cfg(FaultSpec::none(), RetryPolicy::off())?;
    let mut b = cluster::balancer::by_name("expert-affinity")?;
    let clean = cluster::run_cluster(&clean_cfg, b.as_mut())?;
    let horizon = clean.makespan.max(est);
    let fault_seed = clean_cfg.workload.fault_seed();
    let mut storm = FaultSpec::crash_storm(horizon / 2.5, horizon, est / 4.0);
    for div in [2.5, 3.5, 5.0, 7.0, 10.0] {
        let cand = FaultSpec::crash_storm(horizon / div, horizon, est / 4.0);
        let plan = FaultPlan::generate(&cand, replicas, fault_seed);
        let early = plan.events.iter().filter(|e| e.at <= 0.7 * horizon).count();
        if (2..=4).contains(&early) && plan.events.len() <= 5 {
            storm = cand;
            break;
        }
    }
    let mixed = FaultSpec::mixed(horizon / 3.0, horizon, est);
    let retry_on = RetryPolicy::retries(5, est / 8.0);

    let mut reports: Vec<(&str, &str, cluster::ClusterReport)> =
        vec![("fault-free", "off", clean)];
    for (arm, retry_name, cfg) in [
        ("crash-storm", "off", mk_cfg(storm.clone(), RetryPolicy::off())?),
        ("crash-storm", "on", mk_cfg(storm, retry_on)?),
        ("brownout-mix", "on", mk_cfg(mixed, retry_on)?),
    ] {
        let mut b = cluster::balancer::by_name("expert-affinity")?;
        let rep = cluster::run_cluster(&cfg, b.as_mut())?;
        reports.push((arm, retry_name, rep));
    }

    // bit-identity oracle: every request a faulty arm completes must
    // carry exactly the token count the fault-free arm produced for the
    // same request id (re-decode replays the pre-drawn routing trace)
    let clean_tokens: std::collections::HashMap<u64, usize> = reports[0]
        .2
        .outcomes
        .iter()
        .filter(|(_, o, _)| *o == Outcome::Completed)
        .map(|(id, _, n)| (*id, *n))
        .collect();
    for (arm, _, rep) in &reports[1..] {
        for (id, o, n) in &rep.outcomes {
            if *o == Outcome::Completed {
                anyhow::ensure!(
                    clean_tokens.get(id) == Some(n),
                    "{arm}: request {id} completed {n} tokens, != fault-free"
                );
            }
        }
    }

    let mut t = Table::new(&[
        "arm", "retry", "tok/s", "hit rate", "completed", "failed", "retries", "migr",
        "injected", "recovery p95 (s)", "makespan s",
    ]);
    let mut jrows = Vec::new();
    for (arm, retry_name, rep) in &reports {
        t.row(vec![
            (*arm).into(),
            (*retry_name).into(),
            fmt2(rep.tokens_per_sec),
            fmt4(rep.hit_rate),
            rep.completed.to_string(),
            rep.failed.to_string(),
            rep.retries.to_string(),
            rep.migrations.to_string(),
            rep.injected.to_string(),
            format!("{:.3}", rep.recovery_wait.p95),
            fmt2(rep.makespan),
        ]);
        jrows.push(obj(vec![
            ("arm", s(*arm)),
            ("retry", s(*retry_name)),
            ("tok_s", num(rep.tokens_per_sec)),
            ("hit_rate", num(rep.hit_rate)),
            ("n_requests", num(n_requests as f64)),
            ("completed", num(rep.completed as f64)),
            ("cancelled", num(rep.cancelled as f64)),
            ("rejected", num(rep.rejected as f64)),
            ("failed", num(rep.failed as f64)),
            ("retries", num(rep.retries as f64)),
            ("migrations", num(rep.migrations as f64)),
            ("injected", num(rep.injected as f64)),
            ("recovered", num(rep.recovered as f64)),
            ("recovery_wait_p95", num(rep.recovery_wait.p95)),
            ("output_tokens", num(rep.output_tokens as f64)),
            ("makespan_s", num(rep.makespan)),
            ("bit_identical", num(1.0)),
            ("metrics", trace_metrics(rep)),
        ]));
    }
    print_and_save("ext_fault", &t, arr(jrows))
}

/// Extension — fleet-scale work stealing: a Zipf-imbalanced traffic mix
/// (task `i` draws arrivals ∝ `1/(i+1)^1.2`) dispatched by
/// expert-affinity across 8 and 64 replicas, served with stealing off
/// vs on.  Affinity dispatch deliberately concentrates each task on its
/// warm replicas, so under Zipf weights the head task's replicas run
/// deep queues while tail replicas sit idle — exactly the imbalance an
/// idle replica's steal scan can flatten, at the price of colder caches
/// for the stolen work (queued steals) or a KV migration charge over
/// PCIe (live steals).  The model is shrunk to unit-test scale so the
/// fleet sees ~10⁵ requests in CI smoke time.  Expected shape: stealing
/// strictly cuts p95 latency (queue wait dominates it) at tok/s within
/// noise and hit-rate within a couple of points — the affinity-priced
/// gain check refuses steals whose cache penalty outweighs the queue
/// win — with `steals > 0` proving the path exercised.
pub fn ext_steal(args: &Args) -> Result<()> {
    use crate::cluster::replica::ReplicaSpec;
    use crate::cluster::workload::{OutputLen, TaskProfile};
    use crate::cluster::{self, ClusterConfig, StealPolicy};
    use crate::coordinator::workload::Arrival;

    let gpu = GpuSpec::by_name(args.get_or("gpu", "h100"))?;
    let per_replica = args.get_usize("requests", 64)?.max(1);
    let seed = args.get_usize("seed", 42)? as u64;

    // shrink the model to unit-test scale (the steal dynamics live in
    // the queues, not the model dims) so 64 replicas × ~10⁵ requests
    // stay inside CI smoke time
    let mut spec = ReplicaSpec::olmoe(gpu.clone());
    spec.n_layers = 4;
    spec.n_experts = 32;
    spec.top_k = 8;
    spec.capacity = 8;
    let (prompt_tokens, tokens) = (2usize, 8usize);
    let est = spec.est_service_seconds(prompt_tokens, tokens).max(1e-9);

    let mut t = Table::new(&[
        "replicas", "steal", "requests", "tok/s", "hit rate", "queue p95 (s)",
        "latency p50/p95/p99 (s)", "steals", "live",
    ]);
    let mut jrows = Vec::new();
    for replicas in [8usize, 64] {
        let n_requests = per_replica * replicas * 25;
        let mk_cfg = |steal: Option<StealPolicy>| -> Result<ClusterConfig> {
            ClusterConfig::builder(replicas, n_requests, 4, gpu.clone(), seed)
                .spec(spec.clone())
                .tasks(TaskProfile::synthetic(4, 4, 32, 8, 0.92))
                .prompt_tokens(prompt_tokens)
                .output(OutputLen::Fixed(tokens))
                // just under fleet capacity: on average the fleet keeps
                // up, so every queue is imbalance, not offered load
                .arrival(Arrival::Poisson(0.9 * replicas as f64 / est))
                .zipf(1.2)
                .steal(steal)
                .build()
        };
        for steal_on in [false, true] {
            let steal = steal_on.then(|| StealPolicy::every(est / 4.0));
            let cfg = mk_cfg(steal)?;
            let mut b = cluster::balancer::by_name("expert-affinity")?;
            let rep = cluster::run_cluster(&cfg, b.as_mut())?;
            t.row(vec![
                replicas.to_string(),
                if steal_on { "on".into() } else { "off".to_string() },
                n_requests.to_string(),
                fmt2(rep.tokens_per_sec),
                fmt4(rep.hit_rate),
                format!("{:.3}", rep.queue_wait.p95),
                rep.latency.cell(1.0),
                rep.steals.to_string(),
                rep.live_steals.to_string(),
            ]);
            jrows.push(obj(vec![
                ("replicas", num(replicas as f64)),
                ("steal", num(if steal_on { 1.0 } else { 0.0 })),
                ("n_requests", num(n_requests as f64)),
                ("tok_s", num(rep.tokens_per_sec)),
                ("hit_rate", num(rep.hit_rate)),
                ("queue_p95_s", num(rep.queue_wait.p95)),
                ("latency_p95_s", num(rep.latency.p95)),
                ("latency_p99_s", num(rep.latency.p99)),
                ("steals", num(rep.steals as f64)),
                ("live_steals", num(rep.live_steals as f64)),
                ("promotions", num(rep.promotions as f64)),
                ("makespan_s", num(rep.makespan)),
            ]));
        }
    }
    print_and_save("ext_steal", &t, arr(jrows))
}
