//! Experiment harnesses: one per paper table/figure (DESIGN.md §4).
//!
//! Every harness regenerates its table/figure from the live system — the
//! engine decodes real prompts through the PJRT artifacts, transfers are
//! counted by the PCIe engine, and throughput comes from the simulated
//! clock at paper scale.  Results print as aligned tables and are also
//! written to `results/<id>.{txt,json}`.

pub mod experiments;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::clock::GpuSpec;
use crate::engine::{DecodeOutput, Engine};
use crate::eval::{answer_correct, rouge_l};
use crate::moe::{
    preset_dir, EvalSet, MoeConfig, PredictorWeights, RoutingProfile, WeightStore,
};
use crate::policies::{PolicyConfig, Prefetch};
use crate::runtime::Runtime;

/// Everything loadable once per preset.
pub struct Ctx {
    pub preset: String,
    pub dir: PathBuf,
    pub cfg: MoeConfig,
    pub rt: Runtime,
}

impl Ctx {
    pub fn load(artifacts: &Path, preset: &str) -> Result<Ctx> {
        let dir = preset_dir(artifacts, preset)?;
        let cfg = MoeConfig::load(&dir)?;
        let rt = Runtime::load(&dir)?;
        Ok(Ctx { preset: preset.to_string(), dir, cfg, rt })
    }

    /// Which (variant, dataset) predictor artifact a checkpoint uses:
    /// fine-tuned checkpoints carry the predictor trained on their own
    /// fine-tuning dataset (the pre-deployment artifact, §3.1.2).
    fn predictor_key(variant: &str, ds_short: &str) -> (String, String) {
        if variant.starts_with("ft_gsm") {
            ("ft_gsm".into(), "gsm".into())
        } else if variant.starts_with("ft_dolly") {
            ("ft_dolly".into(), "dolly".into())
        } else {
            ("base".into(), ds_short.into())
        }
    }

    /// Load the parts an engine needs for one policy on one dataset.
    /// A lookahead policy's admit-time plan uses whatever source is
    /// available — predictor first, then profile, else nothing (the
    /// per-step pipeline runs off session activation counts regardless).
    pub fn parts(&self, policy: &PolicyConfig, ds_short: &str) -> Result<EngineParts> {
        let store = WeightStore::load(&self.dir, &self.cfg, &policy.variant, policy.quant)?;
        let predictor = match policy.prefetch {
            Prefetch::Predictor => {
                let (v, d) = Self::predictor_key(&policy.variant, ds_short);
                Some(PredictorWeights::load(&self.dir, &v, &d)?)
            }
            Prefetch::Lookahead { .. } => {
                let (v, d) = Self::predictor_key(&policy.variant, ds_short);
                match PredictorWeights::load(&self.dir, &v, &d) {
                    Ok(p) => Some(p),
                    Err(e) => {
                        eprintln!(
                            "[lookahead: no predictor artifact ({e}); \
                             admit-time plan falls back to profile]"
                        );
                        None
                    }
                }
            }
            _ => None,
        };
        let profile = match policy.prefetch {
            Prefetch::Profile => Some(RoutingProfile::load(&self.dir, "base", ds_short)?),
            Prefetch::Lookahead { .. } if predictor.is_none() => {
                match RoutingProfile::load(&self.dir, "base", ds_short) {
                    Ok(p) => Some(p),
                    Err(e) => {
                        eprintln!(
                            "[lookahead: no routing profile either ({e}); \
                             admit-time plan is empty]"
                        );
                        None
                    }
                }
            }
            _ => None,
        };
        Ok(EngineParts { store, predictor, profile, policy: policy.clone() })
    }

    pub fn eval_set(&self, ds_short: &str) -> Result<EvalSet> {
        EvalSet::load(&self.dir, ds_short)
    }
}

pub struct EngineParts {
    pub store: WeightStore,
    pub predictor: Option<PredictorWeights>,
    pub profile: Option<RoutingProfile>,
    pub policy: PolicyConfig,
}

impl EngineParts {
    pub fn engine<'a>(&'a self, ctx: &'a Ctx, gpu: GpuSpec) -> Engine<'a> {
        let mut e = Engine::new(&ctx.rt, &ctx.cfg, &self.store, self.policy.clone(), gpu);
        if let Some(p) = &self.predictor {
            e = e.with_predictor(p);
        }
        if let Some(p) = &self.profile {
            e = e.with_profile(p);
        }
        e
    }
}

/// Aggregate measurements over an eval workload.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub policy: String,
    pub tokens_per_sec: f64,
    pub tx_per_layer: f64,
    pub h2d: u64,
    pub d2h: u64,
    pub hit_rate: f64,
    pub rouge_l: f64,
    pub accuracy: f64,
    pub topc_share: f64,
    pub cpu_execs: u64,
    pub sparsity_skips: u64,
    pub wall_seconds: f64,
    pub mean_ttft: f64,
    pub n_requests: usize,
    pub output_tokens: usize,
    pub sim_seconds: f64,
}

/// Workload knobs shared by the harnesses.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub n_prompts: usize,
    pub max_output: usize,
    /// Fixed-length decoding (ignore EOS): throughput comparisons are
    /// per-token-fair across checkpoints with different natural output
    /// lengths.  Quality harnesses set this false.
    pub ignore_eos: bool,
}

impl Default for Workload {
    fn default() -> Self {
        // scaled from the paper's 64-token / full-eval-split protocol to
        // the single-core testbed; override via --prompts/--tokens.
        Workload { n_prompts: 6, max_output: 32, ignore_eos: true }
    }
}

/// Run one engine over `workload` prompts of an eval set; aggregate.
pub fn run_eval(
    engine: &Engine,
    eval: &EvalSet,
    workload: Workload,
    topc: usize,
) -> Result<RunSummary> {
    let mut s = RunSummary { policy: engine.policy.name.clone(), ..Default::default() };
    let mut hits = 0u64;
    let mut reqs = 0u64;
    let n = workload.n_prompts.min(eval.samples.len());
    let mut shares = Vec::new();
    for sample in eval.samples.iter().take(n) {
        let out: DecodeOutput = engine.decode(&sample.prompt, workload.max_output)?;
        // quality scoring always stops at the first EOS
        let gen_for_quality: Vec<usize> = match out.tokens.iter().position(|&t| t == crate::engine::EOS) {
            Some(i) => out.tokens[..=i].to_vec(),
            None => out.tokens.clone(),
        };
        s.n_requests += 1;
        s.output_tokens += out.metrics.output_tokens;
        s.sim_seconds += out.metrics.sim_seconds;
        s.wall_seconds += out.metrics.wall_seconds;
        s.mean_ttft += out.metrics.sim_ttft;
        s.tx_per_layer += out.report.misses_per_layer;
        s.h2d += out.report.transfers.h2d_count;
        s.d2h += out.report.transfers.d2h_count;
        hits += out.report.cache.hits;
        reqs += out.report.cache.requests();
        s.cpu_execs += out.cpu_execs;
        s.sparsity_skips += out.sparsity_skips;
        shares.push(out.trace.mean_topc_share(topc));
        // quality
        if eval.dataset.starts_with("dolly") {
            s.rouge_l += rouge_l(&gen_for_quality, &sample.reference);
        } else if answer_correct(&gen_for_quality, &sample.answer) {
            s.accuracy += 1.0;
        }
    }
    let nf = s.n_requests.max(1) as f64;
    s.tokens_per_sec = if s.sim_seconds > 0.0 { s.output_tokens as f64 / s.sim_seconds } else { 0.0 };
    s.tx_per_layer /= nf;
    s.hit_rate = if reqs > 0 { hits as f64 / reqs as f64 } else { 0.0 };
    s.rouge_l /= nf;
    s.accuracy = s.accuracy / nf * 100.0;
    s.mean_ttft /= nf;
    s.topc_share = shares.iter().sum::<f64>() / shares.len().max(1) as f64;
    Ok(s)
}

/// Mean teacher-forced perplexity over eval samples truncated/extended to
/// `len` tokens (Tables 4, Fig. 4).
pub fn run_perplexity(engine: &Engine, eval: &EvalSet, n: usize, len: usize) -> Result<f64> {
    let mut nlls = Vec::new();
    for sample in eval.samples.iter().take(n) {
        let mut toks = sample.prompt.clone();
        toks.extend_from_slice(&sample.reference);
        toks.truncate(len.max(2));
        nlls.extend(engine.teacher_forced_nll(&toks)?);
    }
    Ok(crate::eval::perplexity(&nlls))
}

/// Write a result artifact under results/.
pub fn save_result(id: &str, text: &str, json: &crate::util::json::Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{id}.txt"), text)?;
    std::fs::write(format!("results/{id}.json"), json.to_string())?;
    Ok(())
}

/// Dispatch an experiment by id.
pub fn run(id: &str, args: &crate::util::cli::Args) -> Result<()> {
    use experiments as ex;
    match id {
        "table1" => ex::table1(args),
        "fig1a" => ex::fig1a(args),
        "fig1b" => ex::fig1b(args),
        "fig3" => ex::fig3(args),
        "table2" => ex::table2(args),
        "table3" => ex::table3(args),
        "fig4" => ex::fig4(args),
        "fig5" => ex::fig5(args),
        "table4" => ex::table4(args),
        "table5" => ex::table5(args),
        "table11" => ex::table11(args),
        "fig6" => ex::fig6(args),
        "heatmaps" | "fig7_10" => ex::heatmaps(args),
        "fig11" => ex::fig11(args),
        "table12" => ex::table12(args),
        "fig12" => ex::fig12(args),
        "fig13" => ex::fig13(args),
        "table13" => ex::table13(args),
        "ext_layerwise" => ex::ext_layerwise(args),
        "ext_cluster" => ex::ext_cluster(args),
        "ext_continuous" => ex::ext_continuous(args),
        "ext_prefill" => ex::ext_prefill(args),
        "ext_overlap" => ex::ext_overlap(args),
        "ext_preempt" => ex::ext_preempt(args),
        "ext_quant" => ex::ext_quant(args),
        "ext_stream" => ex::ext_stream(args),
        "ext_fault" => ex::ext_fault(args),
        "ext_steal" => ex::ext_steal(args),
        "all" => {
            for id in ex::ALL {
                println!("\n================ {id} ================");
                run(id, args)?;
            }
            Ok(())
        }
        _ => Err(anyhow!("unknown experiment {id:?}; see `melinoe repro --help`")),
    }
}
