//! Offload policies: MELINOE and the five baselines of §4.2.
//!
//! Every system the paper compares against is expressed as a
//! [`PolicyConfig`] over the shared engine: which checkpoint variant to
//! serve, the eviction policy, the prefetch source, expert residency
//! quantization, whether non-resident experts may execute on the CPU
//! (Fiddler), and an optional gate-probability sparsity threshold (FLoE).
//! This mirrors the paper's observation that the fine-tuning procedure is
//! orthogonal to the baselines and composes with them (Table 5):
//! `with_variant` swaps the checkpoint under any policy.

use crate::cache::EvictionKind;
use crate::quant::QuantMode;

/// Where the start-of-request prefetch set comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Prefetch {
    /// No proactive loading (cold cache).
    None,
    /// MELINOE's prompt-conditioned activation predictor (§3.1.2).
    Predictor,
    /// MoE-Infinity-style historical activation-frequency profile.
    Profile,
    /// Layer-ahead transfer pipeline: the admit-time plan comes from
    /// whatever source the engine carries (predictor, else profile, else
    /// nothing), and during every step the engine additionally issues
    /// non-blocking prefetches for the next `depth` layers' predicted
    /// experts (`predictor::predict_next_layer`), overlapped with the
    /// current layer's compute and tracked in-flight so a decode that
    /// catches a transfer on the link pays only the residual wait
    /// (`--lookahead`, docs/SERVING.md).
    Lookahead { depth: usize },
}

impl Prefetch {
    /// Per-step layer-ahead prefetch depth (0 for every non-lookahead
    /// policy).
    pub fn lookahead_depth(&self) -> usize {
        match self {
            Prefetch::Lookahead { depth } => *depth,
            _ => 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PolicyConfig {
    pub name: String,
    /// Checkpoint to serve: "base" or a fine-tuned variant.
    pub variant: String,
    pub eviction: EvictionKind,
    pub prefetch: Prefetch,
    /// Residency + transfer quantization of expert weights.
    pub quant: QuantMode,
    /// Fiddler: execute non-resident experts on the CPU when cheaper.
    pub cpu_compute: bool,
    /// FLoE: drop non-resident experts whose gate probability is below
    /// this threshold (0.0 disables).  Gates are renormalized.
    pub sparsity_tau: f32,
    /// GPU-resident experts per layer.  The quantized capacity boost is
    /// applied by the caller via `effective_capacity`.
    pub capacity: usize,
    /// Paper §5 future-work extension: non-uniform per-layer budgets.
    /// When set, layer ℓ gets `layer_capacities[ℓ]` slots (before the
    /// quantization multiplier) instead of the uniform `capacity`.
    pub layer_capacities: Option<Vec<usize>>,
    /// Big-little fallback (MoBiLE-style): keep low-bit copies of the
    /// hottest experts resident in a carve-out of the byte budget, and
    /// on a demand miss execute the little copy at zero stall instead of
    /// waiting out the transfer.  `None` disables the fallback entirely
    /// (decode numerics are then bit-identical to the seed).  Must be a
    /// strictly smaller tier than `quant` (`validate_little_tier`).
    pub little_tier: Option<QuantMode>,
    /// Only fall back when the residual wait for the full-tier copy
    /// exceeds this many seconds (`--fallback-threshold`).  0.0 falls
    /// back on every miss with a little copy available.
    pub fallback_threshold: f64,
}

impl PolicyConfig {
    /// MELINOE (§3): fine-tuned checkpoint + predictor prefetch + LFU
    /// cache + INT4 residency.
    pub fn melinoe(variant: &str, capacity: usize) -> PolicyConfig {
        PolicyConfig {
            name: "melinoe".into(),
            variant: variant.into(),
            eviction: EvictionKind::Lfu,
            prefetch: Prefetch::Predictor,
            quant: QuantMode::Int4,
            cpu_compute: false,
            sparsity_tau: 0.0,
            capacity,
            layer_capacities: None,
            little_tier: None,
            fallback_threshold: 0.0,
        }
    }

    /// MELINOE without the predictor (Table 3's "Fine-Tuned Model" row).
    pub fn melinoe_no_prefetch(variant: &str, capacity: usize) -> PolicyConfig {
        PolicyConfig {
            name: "melinoe-np".into(),
            prefetch: Prefetch::None,
            ..PolicyConfig::melinoe(variant, capacity)
        }
    }

    /// Fiddler: CPU-GPU orchestration — non-resident experts execute on
    /// the CPU instead of being transferred; base weights, no quantization.
    pub fn fiddler(capacity: usize) -> PolicyConfig {
        PolicyConfig {
            name: "fiddler".into(),
            variant: "base".into(),
            eviction: EvictionKind::Lfu,
            prefetch: Prefetch::None,
            quant: QuantMode::Fp16,
            cpu_compute: true,
            sparsity_tau: 0.0,
            capacity,
            layer_capacities: None,
            little_tier: None,
            fallback_threshold: 0.0,
        }
    }

    /// Mixtral-Offloading: LRU expert cache + aggressive (3-bit) expert
    /// quantization; quality trades for memory (paper Table 2).
    pub fn mixtral_offloading(capacity: usize) -> PolicyConfig {
        PolicyConfig {
            name: "mixtral-offloading".into(),
            variant: "base".into(),
            eviction: EvictionKind::Lru,
            prefetch: Prefetch::None,
            quant: QuantMode::Int3,
            cpu_compute: false,
            sparsity_tau: 0.0,
            capacity,
            layer_capacities: None,
            little_tier: None,
            fallback_threshold: 0.0,
        }
    }

    /// DeepSpeed-MoE-style fetch-on-demand: only the working set (top-K)
    /// is ever resident, so nearly every routing decision transfers —
    /// the paper's transfer-heavy reference point (14.7× gap).
    pub fn deepspeed_moe(top_k: usize) -> PolicyConfig {
        PolicyConfig {
            name: "deepspeed-moe".into(),
            variant: "base".into(),
            eviction: EvictionKind::Lru,
            prefetch: Prefetch::None,
            quant: QuantMode::Fp16,
            cpu_compute: false,
            sparsity_tau: 0.0,
            capacity: top_k,
            layer_capacities: None,
            little_tier: None,
            fallback_threshold: 0.0,
        }
    }

    /// FLoE: INT4 quantization + activation-sparsity skipping of weak
    /// non-resident experts.
    pub fn floe(capacity: usize) -> PolicyConfig {
        PolicyConfig {
            name: "floe".into(),
            variant: "base".into(),
            eviction: EvictionKind::Lfu,
            prefetch: Prefetch::None,
            quant: QuantMode::Int4,
            cpu_compute: false,
            sparsity_tau: 0.04,
            capacity,
            layer_capacities: None,
            little_tier: None,
            fallback_threshold: 0.0,
        }
    }

    /// MoE-Infinity: sparsity-aware profiling prefetch + LFU cache.
    pub fn moe_infinity(capacity: usize) -> PolicyConfig {
        PolicyConfig {
            name: "moe-infinity".into(),
            variant: "base".into(),
            eviction: EvictionKind::Lfu,
            prefetch: Prefetch::Profile,
            quant: QuantMode::Fp16,
            cpu_compute: false,
            sparsity_tau: 0.0,
            capacity,
            layer_capacities: None,
            little_tier: None,
            fallback_threshold: 0.0,
        }
    }

    /// Plain offloaded serving of the base checkpoint (Table 3 baseline).
    pub fn base_offload(capacity: usize) -> PolicyConfig {
        PolicyConfig {
            name: "base".into(),
            variant: "base".into(),
            eviction: EvictionKind::Lfu,
            prefetch: Prefetch::None,
            quant: QuantMode::Fp16,
            cpu_compute: false,
            sparsity_tau: 0.0,
            capacity,
            layer_capacities: None,
            little_tier: None,
            fallback_threshold: 0.0,
        }
    }

    /// Swap the checkpoint variant (Table 5: "+ Fine-Tuning" rows).
    pub fn with_variant(mut self, variant: &str) -> PolicyConfig {
        self.variant = variant.into();
        if self.variant != "base" {
            self.name = format!("{}+ft", self.name);
        }
        self
    }

    pub fn with_capacity(mut self, capacity: usize) -> PolicyConfig {
        self.capacity = capacity;
        self
    }

    pub fn with_eviction(mut self, kind: EvictionKind) -> PolicyConfig {
        self.eviction = kind;
        self
    }

    pub fn with_quant(mut self, q: QuantMode) -> PolicyConfig {
        self.quant = q;
        self
    }

    /// Enable the big-little fallback: keep `little`-tier copies of the
    /// hottest experts resident and serve demand misses from them when
    /// the residual wait exceeds `threshold` seconds (`None` leaves the
    /// fallback off).  The caller validates `little` against `quant`
    /// (`validate_little_tier`).
    pub fn with_fallback(mut self, little: Option<QuantMode>, threshold: f64) -> PolicyConfig {
        self.little_tier = little;
        self.fallback_threshold = threshold;
        self
    }

    pub fn with_prefetch(mut self, p: Prefetch) -> PolicyConfig {
        self.prefetch = p;
        self
    }

    /// Enable the layer-ahead transfer pipeline at the given depth
    /// (`--lookahead`); the admit-time plan source falls back to the
    /// engine's predictor/profile, see [`Prefetch::Lookahead`].
    pub fn with_lookahead(mut self, depth: usize) -> PolicyConfig {
        self.prefetch = Prefetch::Lookahead { depth };
        self
    }

    pub fn with_layer_capacities(mut self, caps: Vec<usize>) -> PolicyConfig {
        self.layer_capacities = Some(caps);
        self
    }

    /// Per-layer effective capacities (layer-wise schedule if set,
    /// otherwise uniform), after the quantization multiplier.
    pub fn effective_layer_capacities(&self, n_layers: usize, n_experts: usize) -> Vec<usize> {
        let mult = self.quant.capacity_multiplier();
        let eff = |c: usize| {
            (((c as f64) * mult).floor() as usize).min(n_experts).max(c.min(n_experts))
        };
        match &self.layer_capacities {
            Some(v) => (0..n_layers).map(|l| eff(v[l.min(v.len() - 1)])).collect(),
            None => vec![eff(self.capacity); n_layers],
        }
    }

    /// Residency capacity after the quantization multiplier: a fixed VRAM
    /// slice holds `multiplier×` more quantized experts (Table 12).
    pub fn effective_capacity(&self, n_experts: usize) -> usize {
        let mult = self.quant.capacity_multiplier();
        (((self.capacity as f64) * mult).floor() as usize).min(n_experts).max(self.capacity.min(n_experts))
    }

    /// All six systems at the paper's evaluation capacity (Fig. 3 grid).
    pub fn all_baselines(capacity: usize, top_k: usize, ft_variant: &str) -> Vec<PolicyConfig> {
        vec![
            PolicyConfig::melinoe(ft_variant, capacity),
            PolicyConfig::fiddler(capacity),
            PolicyConfig::mixtral_offloading(capacity),
            PolicyConfig::deepspeed_moe(top_k),
            PolicyConfig::floe(capacity),
            PolicyConfig::moe_infinity(capacity),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_have_expected_shape() {
        let m = PolicyConfig::melinoe("ft_dolly", 16);
        assert_eq!(m.prefetch, Prefetch::Predictor);
        assert_eq!(m.quant, QuantMode::Int4);
        assert_eq!(m.variant, "ft_dolly");
        let f = PolicyConfig::fiddler(16);
        assert!(f.cpu_compute);
        let d = PolicyConfig::deepspeed_moe(8);
        assert_eq!(d.capacity, 8);
        let fl = PolicyConfig::floe(16);
        assert!(fl.sparsity_tau > 0.0);
    }

    #[test]
    fn effective_capacity_quant_boost() {
        let m = PolicyConfig::melinoe("ft_dolly", 8);
        // int4 fits ~3.5× more experts, capped at n_experts
        assert!(m.effective_capacity(64) >= 24);
        assert_eq!(m.effective_capacity(16), 16);
        let b = PolicyConfig::base_offload(8);
        assert_eq!(b.effective_capacity(64), 8);
    }

    #[test]
    fn with_variant_renames() {
        let f = PolicyConfig::floe(8).with_variant("ft_dolly");
        assert_eq!(f.name, "floe+ft");
        assert_eq!(f.variant, "ft_dolly");
        let b = PolicyConfig::floe(8).with_variant("base");
        assert_eq!(b.name, "floe");
    }

    #[test]
    fn lookahead_depth_accessor() {
        assert_eq!(Prefetch::None.lookahead_depth(), 0);
        assert_eq!(Prefetch::Predictor.lookahead_depth(), 0);
        assert_eq!(Prefetch::Lookahead { depth: 2 }.lookahead_depth(), 2);
        let p = PolicyConfig::base_offload(8).with_lookahead(1);
        assert_eq!(p.prefetch, Prefetch::Lookahead { depth: 1 });
        assert_eq!(p.prefetch.lookahead_depth(), 1);
    }

    #[test]
    fn fallback_defaults_off_and_builder_sets_it() {
        let m = PolicyConfig::melinoe("ft_dolly", 16);
        assert_eq!(m.little_tier, None, "fallback must default off (bit-identical decode)");
        assert_eq!(m.fallback_threshold, 0.0);
        let f = m.with_fallback(Some(QuantMode::Int3), 2.5e-3);
        assert_eq!(f.little_tier, Some(QuantMode::Int3));
        assert_eq!(f.fallback_threshold, 2.5e-3);
        assert!(crate::quant::validate_little_tier(f.quant, QuantMode::Int3).is_ok());
    }

    #[test]
    fn all_baselines_unique_names() {
        let v = PolicyConfig::all_baselines(16, 8, "ft_dolly");
        let names: std::collections::HashSet<_> = v.iter().map(|p| p.name.clone()).collect();
        assert_eq!(names.len(), v.len());
    }
}
