//! Serving workload generation: request arrival processes.
//!
//! The paper's serving measurements are closed-loop (decode one sequence
//! at a time); the coordinator also supports open-loop evaluation with
//! Poisson arrivals, the standard serving-benchmark shape (vLLM/Orca).
//! This module synthesizes those arrival schedules deterministically.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// All requests available at t=0 (throughput measurement).
    Burst,
    /// Poisson process with the given rate (requests/second).
    Poisson(f64),
    /// Fixed inter-arrival gap in seconds.
    Uniform(f64),
}

/// A scheduled request: (arrival time seconds, eval-sample index).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledRequest {
    pub at: f64,
    pub sample: usize,
}

/// Build a deterministic arrival schedule over `n` requests drawn
/// round-robin from `n_samples` eval prompts.
pub fn schedule(n: usize, n_samples: usize, arrival: Arrival, seed: u64) -> Vec<ScheduledRequest> {
    assert!(n_samples > 0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            let at = match arrival {
                Arrival::Burst => 0.0,
                Arrival::Poisson(rate) => {
                    t += rng.exp(rate);
                    t
                }
                Arrival::Uniform(gap) => {
                    t += gap;
                    t
                }
            };
            ScheduledRequest { at, sample: i % n_samples }
        })
        .collect()
}

/// Offered load of a schedule (requests/second over its span).
pub fn offered_load(sched: &[ScheduledRequest]) -> f64 {
    if sched.len() < 2 {
        return 0.0;
    }
    let span = sched.last().unwrap().at - sched[0].at;
    if span <= 0.0 {
        return f64::INFINITY;
    }
    (sched.len() - 1) as f64 / span
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_all_at_zero() {
        let s = schedule(10, 4, Arrival::Burst, 1);
        assert!(s.iter().all(|r| r.at == 0.0));
        assert_eq!(s[5].sample, 1); // round robin over 4 samples
    }

    #[test]
    fn poisson_monotone_and_rate_roughly_matches() {
        let s = schedule(4000, 8, Arrival::Poisson(50.0), 7);
        assert!(s.windows(2).all(|w| w[0].at <= w[1].at));
        let rate = offered_load(&s);
        assert!((rate - 50.0).abs() < 5.0, "offered {rate}");
    }

    #[test]
    fn uniform_fixed_gap() {
        let s = schedule(5, 2, Arrival::Uniform(0.5), 3);
        for (i, r) in s.iter().enumerate() {
            assert!((r.at - 0.5 * (i + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn deterministic_across_calls() {
        let a = schedule(64, 4, Arrival::Poisson(10.0), 42);
        let b = schedule(64, 4, Arrival::Poisson(10.0), 42);
        assert_eq!(a, b);
    }

    #[test]
    fn offered_load_degenerate() {
        assert_eq!(offered_load(&[]), 0.0);
        let s = schedule(10, 2, Arrival::Burst, 1);
        assert!(offered_load(&s).is_infinite());
    }
}
