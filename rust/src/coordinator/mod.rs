//! Serving coordinator: request queue → dynamic batcher → engine loop.
//!
//! The PJRT handles inside the engine are not `Send`, so the coordinator
//! follows the single-runner design (as in vLLM's engine loop): client
//! threads submit requests over an mpsc channel; one runner thread owns
//! the model (constructed *inside* the thread by a `Send` factory), drains
//! the queue into dynamic batches (up to `max_batch`, waiting at most
//! `batch_wait` for stragglers), lockstep-decodes each batch, and answers
//! each request on its own response channel.

pub mod workload;

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::{Percentiles, Report};

/// Anything that can decode a batch of prompts (the real engine, or a mock
/// in the scheduler tests).
pub trait Decoder {
    fn decode_batch(
        &mut self,
        prompts: &[Vec<usize>],
        max_output: usize,
    ) -> Result<(Vec<Vec<usize>>, Report)>;
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_output: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Seconds spent waiting in the queue (wallclock).
    pub queue_wait: f64,
    /// Simulated decode seconds of the batch this request rode in.
    pub sim_seconds: f64,
    /// Simulated decoding throughput of that batch (output tok/s).
    pub batch_tokens_per_sec: f64,
    pub batch_size: usize,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_wait: Duration,
    pub max_output: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 4, batch_wait: Duration::from_millis(2), max_output: 32 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub total_output_tokens: u64,
    pub total_sim_seconds: f64,
    pub mean_batch_size: f64,
    /// p50/p95/p99 of per-request wallclock queue wait (seconds).
    pub queue_wait: Percentiles,
    /// p50/p95/p99 of per-request simulated batch decode time (seconds).
    pub sim_latency: Percentiles,
}

enum Msg {
    Job(Request, Sender<Response>, Instant),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    handle: JoinHandle<Result<ServerStats>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the runner thread.  `factory` constructs the decoder inside
    /// the thread (PJRT handles never cross threads).
    pub fn start<D, F>(factory: F, cfg: ServerConfig) -> Server
    where
        D: Decoder,
        F: FnOnce() -> Result<D> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::spawn(move || runner(factory()?, rx, cfg));
        Server { tx, handle, next_id: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, prompt: Vec<usize>, max_output: usize) -> Receiver<Response> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = channel();
        let _ = self.tx.send(Msg::Job(Request { id, prompt, max_output }, rtx, Instant::now()));
        rrx
    }

    /// Drain outstanding work and stop the runner.
    pub fn shutdown(self) -> Result<ServerStats> {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.join().map_err(|_| anyhow::anyhow!("runner thread panicked"))?
    }
}

/// Per-request samples the runner accumulates for the shutdown report.
#[derive(Default)]
struct RunnerSamples {
    batch_sizes: Vec<usize>,
    queue_waits: Vec<f64>,
    sim_latencies: Vec<f64>,
}

fn runner<D: Decoder>(mut dec: D, rx: Receiver<Msg>, cfg: ServerConfig) -> Result<ServerStats> {
    let mut stats = ServerStats::default();
    let mut samples = RunnerSamples::default();
    'outer: loop {
        // block for the first job
        let first = match rx.recv() {
            Ok(Msg::Job(r, tx, t)) => (r, tx, t),
            Ok(Msg::Shutdown) | Err(_) => break 'outer,
        };
        let mut jobs = vec![first];
        // give stragglers a short window to join the batch
        let deadline = Instant::now() + cfg.batch_wait;
        while jobs.len() < cfg.max_batch {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(Msg::Job(r, tx, t)) => jobs.push((r, tx, t)),
                Ok(Msg::Shutdown) => {
                    process_batch(&mut dec, &mut jobs, &cfg, &mut stats, &mut samples)?;
                    break 'outer;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        process_batch(&mut dec, &mut jobs, &cfg, &mut stats, &mut samples)?;
    }
    if !samples.batch_sizes.is_empty() {
        stats.mean_batch_size =
            samples.batch_sizes.iter().sum::<usize>() as f64 / samples.batch_sizes.len() as f64;
    }
    stats.queue_wait = Percentiles::of(&samples.queue_waits);
    stats.sim_latency = Percentiles::of(&samples.sim_latencies);
    Ok(stats)
}

fn process_batch<D: Decoder>(
    dec: &mut D,
    jobs: &mut Vec<(Request, Sender<Response>, Instant)>,
    cfg: &ServerConfig,
    stats: &mut ServerStats,
    samples: &mut RunnerSamples,
) -> Result<()> {
    if jobs.is_empty() {
        return Ok(());
    }
    let prompts: Vec<Vec<usize>> = jobs.iter().map(|(r, _, _)| r.prompt.clone()).collect();
    let max_output = jobs.iter().map(|(r, _, _)| r.max_output).max().unwrap_or(cfg.max_output);
    let (outputs, report) = dec.decode_batch(&prompts, max_output)?;
    let sim = report.requests.first().map(|r| r.sim_seconds).unwrap_or(0.0);
    let tps = report.tokens_per_sec() * report.requests.len().max(1) as f64;
    stats.batches += 1;
    samples.batch_sizes.push(jobs.len());
    for ((req, tx, t0), tokens) in jobs.drain(..).zip(outputs) {
        stats.requests += 1;
        stats.total_output_tokens += tokens.len() as u64;
        let queue_wait = t0.elapsed().as_secs_f64();
        samples.queue_waits.push(queue_wait);
        samples.sim_latencies.push(sim);
        let _ = tx.send(Response {
            id: req.id,
            tokens,
            queue_wait,
            sim_seconds: sim,
            batch_tokens_per_sec: tps,
            batch_size: prompts.len(),
        });
    }
    stats.total_sim_seconds += sim;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RequestMetrics;

    /// Echo decoder: returns the prompt reversed, constant sim time.
    struct Mock {
        calls: u64,
    }

    impl Decoder for Mock {
        fn decode_batch(
            &mut self,
            prompts: &[Vec<usize>],
            _max_output: usize,
        ) -> Result<(Vec<Vec<usize>>, Report)> {
            self.calls += 1;
            let outs: Vec<Vec<usize>> =
                prompts.iter().map(|p| p.iter().rev().copied().collect()).collect();
            let mut report = Report::default();
            for p in prompts {
                report.requests.push(RequestMetrics {
                    prompt_tokens: p.len(),
                    output_tokens: p.len(),
                    sim_seconds: 0.5,
                    sim_ttft: 0.1,
                    wall_seconds: 0.0,
                });
            }
            Ok((outs, report))
        }
    }

    #[test]
    fn responses_match_requests() {
        let server = Server::start(|| Ok(Mock { calls: 0 }), ServerConfig::default());
        let rx1 = server.submit(vec![1, 2, 3], 8);
        let rx2 = server.submit(vec![9, 8], 8);
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert_eq!(r1.tokens, vec![3, 2, 1]);
        assert_eq!(r2.tokens, vec![8, 9]);
        assert_ne!(r1.id, r2.id);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn batching_groups_concurrent_requests() {
        let cfg = ServerConfig {
            max_batch: 8,
            batch_wait: Duration::from_millis(50),
            max_output: 8,
        };
        let server = Server::start(|| Ok(Mock { calls: 0 }), cfg);
        let rxs: Vec<_> = (0..6).map(|i| server.submit(vec![i], 4)).collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // all six landed; at least one batch had >1 members
        assert!(responses.iter().any(|r| r.batch_size > 1));
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches < 6, "requests should have been batched");
    }

    #[test]
    fn max_batch_respected() {
        let cfg =
            ServerConfig { max_batch: 2, batch_wait: Duration::from_millis(50), max_output: 8 };
        let server = Server::start(|| Ok(Mock { calls: 0 }), cfg);
        let rxs: Vec<_> = (0..5).map(|i| server.submit(vec![i], 4)).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.batch_size <= 2);
        }
        let stats = server.shutdown().unwrap();
        assert!(stats.batches >= 3);
    }

    #[test]
    fn stats_report_latency_percentiles() {
        let server = Server::start(|| Ok(Mock { calls: 0 }), ServerConfig::default());
        let rxs: Vec<_> = (0..8).map(|i| server.submit(vec![i, i + 1], 4)).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.shutdown().unwrap();
        // the mock decoder reports 0.5 simulated seconds per batch
        assert!((stats.sim_latency.p50 - 0.5).abs() < 1e-9);
        assert!((stats.sim_latency.p99 - 0.5).abs() < 1e-9);
        assert!(stats.queue_wait.p50 >= 0.0);
        assert!(stats.queue_wait.p99 >= stats.queue_wait.p50);
    }

    #[test]
    fn shutdown_drains_pending() {
        let cfg = ServerConfig {
            max_batch: 64,
            batch_wait: Duration::from_millis(200),
            max_output: 8,
        };
        let server = Server::start(|| Ok(Mock { calls: 0 }), cfg);
        let rx = server.submit(vec![7], 4);
        let stats = server.shutdown().unwrap();
        assert_eq!(rx.recv().unwrap().tokens, vec![7]);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn no_starvation_under_load() {
        let cfg =
            ServerConfig { max_batch: 3, batch_wait: Duration::from_millis(1), max_output: 8 };
        let server = Server::start(|| Ok(Mock { calls: 0 }), cfg);
        let rxs: Vec<_> = (0..30).map(|i| server.submit(vec![i], 4)).collect();
        let mut got = 0;
        for rx in rxs {
            if rx.recv_timeout(Duration::from_secs(5)).is_ok() {
                got += 1;
            }
        }
        assert_eq!(got, 30);
        server.shutdown().unwrap();
    }
}
