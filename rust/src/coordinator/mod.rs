//! Serving coordinator: request queue → step-level continuous scheduler.
//!
//! The PJRT handles inside the engine are not `Send`, so the coordinator
//! follows the single-runner design (as in vLLM's engine loop): client
//! threads submit requests over an mpsc channel; one runner thread owns
//! the model (constructed *inside* the thread by a `Send` factory) and
//! drives a [`Scheduler`].  At every token step the scheduler admits
//! queued requests into free decode slots (up to `max_batch`), advances
//! all in-flight sequences through the step-level [`Decoder`] — decodes
//! by exactly one token, prompts still in prefill by up to
//! [`ServerConfig::prefill_chunk`] prompt tokens piggybacked on the same
//! step (Sarathi-style chunked prefill, so a long prompt can never stall
//! a live decode's next token) — and retires sequences the moment they
//! hit EOS, so a long sequence never holds finished slots hostage and
//! freed slots re-admit immediately.  [`SchedulerMode::Static`] recovers
//! the legacy drain-batch-then-decode-to-completion behaviour for
//! comparison (`--scheduler static|continuous` on the CLI).

pub mod workload;

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::Percentiles;
use crate::pcie::TransferStats;

/// One retired sequence, in the decoder's simulated timeline.
#[derive(Debug, Clone)]
pub struct SeqFinish {
    pub seq: u64,
    pub tokens: Vec<usize>,
    /// Simulated time the sequence was admitted into a decode slot.
    pub sim_admitted: f64,
    /// Simulated time its first output token landed.
    pub sim_first_token: f64,
    /// Simulated time it retired (EOS or token budget).
    pub sim_finished: f64,
}

impl SeqFinish {
    /// Time-to-first-token from admission (simulated seconds).
    pub fn ttft(&self) -> f64 {
        (self.sim_first_token - self.sim_admitted).max(0.0)
    }

    /// Time per output token after the first (simulated seconds).
    pub fn tpot(&self) -> f64 {
        if self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.sim_finished - self.sim_first_token).max(0.0) / (self.tokens.len() - 1) as f64
    }

    /// Admission-to-retirement latency (simulated seconds).
    pub fn latency(&self) -> f64 {
        (self.sim_finished - self.sim_admitted).max(0.0)
    }
}

/// A resumable, step-granular decoder.  Sequences are admitted into
/// decode slots (possibly mid-flight, while others are decoding) and all
/// in-flight sequences advance one token per [`Decoder::step`] call.
/// Implementors: the engine's `DecodeSession` wrappers, the cluster's
/// analytic replicas, and the mocks in the scheduler tests.
pub trait Decoder {
    /// Admit a sequence into the in-flight set; returns its handle.
    fn admit(&mut self, prompt: &[usize], max_output: usize) -> Result<u64>;
    /// Advance every in-flight sequence one step: decodes emit exactly
    /// one token, prefilling sequences consume up to the configured
    /// prefill chunk of prompt tokens.  Sequences hitting EOS or their
    /// budget retire immediately and are returned — their slots are free
    /// before the next step.
    fn step(&mut self) -> Result<Vec<SeqFinish>>;
    /// Number of in-flight sequences.
    fn active(&self) -> usize;
    /// Current simulated time (seconds).
    fn now(&self) -> f64;
    /// Per-step prompt-token budget for prefilling sequences (chunked
    /// prefill).  The scheduler sets this once from
    /// [`ServerConfig::prefill_chunk`]; decoders without a prefill
    /// concept may ignore it (the default does).
    fn set_prefill_chunk(&mut self, _chunk: usize) {}
    /// PCIe transfer accounting snapshot (stall vs overlapped split, see
    /// `pcie`).  Decoders without a transfer model return the default
    /// zeros.
    fn transfer_stats(&self) -> TransferStats {
        TransferStats::default()
    }
}

/// How the scheduler fills decode slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// Drain a batch from the queue, decode it to completion, repeat.
    /// Finished slots idle until the whole batch retires (the legacy
    /// run-to-completion loop; the Fig. 5 batching convention).
    Static,
    /// Admit from the queue into free slots at *every* token step and
    /// retire sequences at EOS immediately (vLLM-style continuous
    /// batching).  Under MELINOE's fine-tuned routing this also keeps the
    /// LFU cache warm: admitted same-task requests reuse the experts the
    /// in-flight batch already pinned.
    Continuous,
}

impl SchedulerMode {
    pub fn parse(s: &str) -> Result<SchedulerMode> {
        Ok(match s {
            "static" => SchedulerMode::Static,
            "continuous" => SchedulerMode::Continuous,
            _ => anyhow::bail!("unknown scheduler {s:?} (static|continuous)"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_output: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Wallclock seconds between submission and slot admission.
    pub queue_wait: f64,
    /// Simulated seconds from admission to retirement.
    pub sim_latency: f64,
    /// Simulated time-to-first-token (from admission).
    pub sim_ttft: f64,
    /// Simulated time per output token after the first.
    pub sim_tpot: f64,
    /// In-flight sequences (this one included) when it was admitted.
    pub batch_size: usize,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// Straggler window: when the scheduler is idle and the first request
    /// arrives, wait this long for near-simultaneous submitters before
    /// the first token step.
    pub batch_wait: Duration,
    /// Default output budget (callers may override per request).
    pub max_output: usize,
    pub scheduler: SchedulerMode,
    /// Per-step token budget for prompt prefill (`--prefill-chunk`): a
    /// sequence still in prefill consumes up to this many prompt tokens
    /// per scheduler tick, piggybacked on the same step that advances
    /// every in-flight decode by exactly one token — so a long prompt
    /// shortens its own TTFT by `~chunk×` without ever stalling live
    /// decodes.  1 (the default) recovers token-at-a-time prefill.
    pub prefill_chunk: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 4,
            batch_wait: Duration::from_millis(2),
            max_output: 32,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: u64,
    /// Token steps the scheduler executed.
    pub steps: u64,
    /// Prefill chunk the scheduler ran with (1 = token-at-a-time).
    pub prefill_chunk: usize,
    pub total_output_tokens: u64,
    /// Decoder simulated clock at shutdown.
    pub total_sim_seconds: f64,
    /// Mean in-flight sequences per executed step (slot occupancy).
    pub mean_batch_size: f64,
    /// p50/p95/p99 of per-request wallclock queue wait (seconds).
    pub queue_wait: Percentiles,
    /// p50/p95/p99 of per-request simulated admission→finish latency.
    pub sim_latency: Percentiles,
    /// p50/p95/p99 of simulated time-to-first-token.
    pub ttft: Percentiles,
    /// p50/p95/p99 of simulated time-per-output-token.
    pub tpot: Percentiles,
    /// Decode time lost stalled on expert transfers (demand stalls plus
    /// residual waits on caught in-flight prefetches).
    pub pcie_stall_seconds: f64,
    /// Transfer time hidden behind compute (admit + lookahead prefetch).
    pub pcie_overlapped_seconds: f64,
    /// `overlapped / (overlapped + stalled)` — the overlap fraction.
    pub pcie_overlap_fraction: f64,
}

struct Job {
    req: Request,
    tx: Sender<Response>,
    submitted: Instant,
    /// Set at admission: wallclock queue wait and slot occupancy.
    queue_wait: f64,
    batch_at_admit: usize,
}

/// The step-level scheduling core, independent of threads and channels:
/// the runner thread drives it from the mpsc queue; unit tests drive it
/// synchronously against a mock decoder.
pub struct Scheduler<D: Decoder> {
    dec: D,
    cfg: ServerConfig,
    pending: VecDeque<Job>,
    inflight: HashMap<u64, Job>,
    stats: ServerStats,
    batch_sizes: Vec<usize>,
    queue_waits: Vec<f64>,
    sim_latencies: Vec<f64>,
    ttfts: Vec<f64>,
    tpots: Vec<f64>,
}

impl<D: Decoder> Scheduler<D> {
    pub fn new(mut dec: D, cfg: ServerConfig) -> Scheduler<D> {
        dec.set_prefill_chunk(cfg.prefill_chunk.max(1));
        Scheduler {
            dec,
            cfg,
            pending: VecDeque::new(),
            inflight: HashMap::new(),
            stats: ServerStats::default(),
            batch_sizes: Vec::new(),
            queue_waits: Vec::new(),
            sim_latencies: Vec::new(),
            ttfts: Vec::new(),
            tpots: Vec::new(),
        }
    }

    pub fn enqueue(&mut self, req: Request, tx: Sender<Response>, submitted: Instant) {
        self.pending.push_back(Job { req, tx, submitted, queue_wait: 0.0, batch_at_admit: 0 });
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.dec.active() > 0
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn decoder(&self) -> &D {
        &self.dec
    }

    /// Admit what the mode allows, then advance one token step.
    pub fn tick(&mut self) -> Result<()> {
        self.admit()?;
        if self.dec.active() == 0 {
            return Ok(());
        }
        self.batch_sizes.push(self.dec.active());
        self.stats.steps += 1;
        for fin in self.dec.step()? {
            self.retire(fin);
        }
        Ok(())
    }

    fn admit(&mut self) -> Result<()> {
        let open = match self.cfg.scheduler {
            SchedulerMode::Continuous => true,
            SchedulerMode::Static => self.dec.active() == 0,
        };
        if !open {
            return Ok(());
        }
        while self.dec.active() < self.cfg.max_batch.max(1) {
            let Some(mut job) = self.pending.pop_front() else { break };
            let id = self.dec.admit(&job.req.prompt, job.req.max_output)?;
            job.queue_wait = job.submitted.elapsed().as_secs_f64();
            job.batch_at_admit = self.dec.active();
            self.queue_waits.push(job.queue_wait);
            self.inflight.insert(id, job);
        }
        Ok(())
    }

    fn retire(&mut self, fin: SeqFinish) {
        let Some(job) = self.inflight.remove(&fin.seq) else { return };
        let (latency, ttft, tpot) = (fin.latency(), fin.ttft(), fin.tpot());
        self.stats.requests += 1;
        self.stats.total_output_tokens += fin.tokens.len() as u64;
        self.sim_latencies.push(latency);
        self.ttfts.push(ttft);
        self.tpots.push(tpot);
        let _ = job.tx.send(Response {
            id: job.req.id,
            tokens: fin.tokens,
            queue_wait: job.queue_wait,
            sim_latency: latency,
            sim_ttft: ttft,
            sim_tpot: tpot,
            batch_size: job.batch_at_admit,
        });
    }

    pub fn into_stats(mut self) -> ServerStats {
        self.stats.prefill_chunk = self.cfg.prefill_chunk.max(1);
        self.stats.total_sim_seconds = self.dec.now();
        let ts = self.dec.transfer_stats();
        self.stats.pcie_stall_seconds = ts.stall_time;
        self.stats.pcie_overlapped_seconds = ts.overlapped_time;
        self.stats.pcie_overlap_fraction = ts.overlap_fraction();
        if !self.batch_sizes.is_empty() {
            self.stats.mean_batch_size =
                self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64;
        }
        self.stats.queue_wait = Percentiles::of(&self.queue_waits);
        self.stats.sim_latency = Percentiles::of(&self.sim_latencies);
        self.stats.ttft = Percentiles::of(&self.ttfts);
        self.stats.tpot = Percentiles::of(&self.tpots);
        self.stats
    }
}

enum Msg {
    Job(Request, Sender<Response>, Instant),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    handle: JoinHandle<Result<ServerStats>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the runner thread.  `factory` constructs the decoder inside
    /// the thread (PJRT handles never cross threads).
    pub fn start<D, F>(factory: F, cfg: ServerConfig) -> Server
    where
        D: Decoder,
        F: FnOnce() -> Result<D> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::spawn(move || runner(factory()?, rx, cfg));
        Server { tx, handle, next_id: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, prompt: Vec<usize>, max_output: usize) -> Receiver<Response> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = channel();
        let _ = self.tx.send(Msg::Job(Request { id, prompt, max_output }, rtx, Instant::now()));
        rrx
    }

    /// Drain outstanding work and stop the runner.
    pub fn shutdown(self) -> Result<ServerStats> {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.join().map_err(|_| anyhow::anyhow!("runner thread panicked"))?
    }
}

fn runner<D: Decoder>(dec: D, rx: Receiver<Msg>, cfg: ServerConfig) -> Result<ServerStats> {
    let batch_wait = cfg.batch_wait;
    let max_batch = cfg.max_batch.max(1);
    let mut sched = Scheduler::new(dec, cfg);
    let mut shutdown = false;
    loop {
        if !sched.has_work() {
            if shutdown {
                break;
            }
            // block for the first job, then give near-simultaneous
            // submitters a short window to join before the first step
            match rx.recv() {
                Ok(Msg::Job(r, tx, t)) => sched.enqueue(r, tx, t),
                Ok(Msg::Shutdown) | Err(_) => break,
            }
            let deadline = Instant::now() + batch_wait;
            while sched.pending_len() < max_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(left) {
                    Ok(Msg::Job(r, tx, t)) => sched.enqueue(r, tx, t),
                    Ok(Msg::Shutdown) => {
                        shutdown = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
        } else {
            // pick up whatever arrived since the last step, non-blocking
            loop {
                match rx.try_recv() {
                    Ok(Msg::Job(r, tx, t)) => sched.enqueue(r, tx, t),
                    Ok(Msg::Shutdown) => shutdown = true,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
            sched.tick()?;
        }
    }
    Ok(sched.into_stats())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Step-level mock: one output token per step (the prompt reversed),
    /// a fixed simulated `dt` per step, retiring when the echo completes.
    struct Mock {
        dt: f64,
        clock: f64,
        next: u64,
        seqs: Vec<MockSeq>,
        peak_active: usize,
    }

    struct MockSeq {
        id: u64,
        out: Vec<usize>,
        produced: usize,
        admitted: f64,
        first: f64,
    }

    impl Mock {
        fn new(dt: f64) -> Mock {
            Mock { dt, clock: 0.0, next: 0, seqs: Vec::new(), peak_active: 0 }
        }
    }

    impl Decoder for Mock {
        fn admit(&mut self, prompt: &[usize], max_output: usize) -> Result<u64> {
            let id = self.next;
            self.next += 1;
            let out: Vec<usize> = prompt.iter().rev().copied().take(max_output.max(1)).collect();
            self.seqs.push(MockSeq { id, out, produced: 0, admitted: self.clock, first: 0.0 });
            self.peak_active = self.peak_active.max(self.seqs.len());
            Ok(id)
        }

        fn step(&mut self) -> Result<Vec<SeqFinish>> {
            self.clock += self.dt;
            let now = self.clock;
            let mut done = Vec::new();
            let mut keep = Vec::new();
            for mut s in self.seqs.drain(..) {
                if s.produced == 0 {
                    s.first = now;
                }
                s.produced += 1;
                if s.produced >= s.out.len() {
                    done.push(SeqFinish {
                        seq: s.id,
                        tokens: s.out,
                        sim_admitted: s.admitted,
                        sim_first_token: s.first,
                        sim_finished: now,
                    });
                } else {
                    keep.push(s);
                }
            }
            self.seqs = keep;
            Ok(done)
        }

        fn active(&self) -> usize {
            self.seqs.len()
        }

        fn now(&self) -> f64 {
            self.clock
        }
    }

    fn cfg(max_batch: usize, scheduler: SchedulerMode) -> ServerConfig {
        ServerConfig {
            max_batch,
            batch_wait: Duration::from_millis(50),
            max_output: 32,
            scheduler,
            prefill_chunk: 1,
        }
    }

    fn submit(
        s: &mut Scheduler<Mock>,
        id: u64,
        prompt: Vec<usize>,
        max_output: usize,
    ) -> Receiver<Response> {
        let (tx, rx) = channel();
        s.enqueue(Request { id, prompt, max_output }, tx, Instant::now());
        rx
    }

    fn drain(s: &mut Scheduler<Mock>) {
        let mut guard = 0;
        while s.has_work() {
            s.tick().unwrap();
            guard += 1;
            assert!(guard < 10_000, "scheduler failed to drain");
        }
    }

    /// Three requests, two slots: A is long (8 tokens), B and C short
    /// (2 each).  Continuous batching re-admits C into the slot B frees
    /// at its early retirement, so the whole set drains in A's 8 steps.
    #[test]
    fn continuous_readmits_into_slots_freed_by_early_retirement() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
        let ra = submit(&mut s, 0, (0..8).collect(), 8);
        let rb = submit(&mut s, 1, vec![1, 2], 2);
        let rc = submit(&mut s, 2, vec![3, 4], 2);
        drain(&mut s);
        let (a, b, c) = (ra.recv().unwrap(), rb.recv().unwrap(), rc.recv().unwrap());
        assert_eq!(a.tokens.len(), 8);
        assert_eq!(b.tokens, vec![2, 1]);
        assert_eq!(c.tokens, vec![4, 3]);
        // C joined while A was still in flight
        assert_eq!(c.batch_size, 2);
        assert_eq!(s.decoder().peak_active, 2);
        let stats = s.into_stats();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.steps, 8, "C must ride inside A's window, not after it");
        assert!(stats.mean_batch_size > 1.0);
    }

    /// Same workload under the static scheduler: the {A, B} batch runs to
    /// completion before C is admitted, costing 8 + 2 steps.
    #[test]
    fn static_runs_batches_to_completion() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Static));
        let _ra = submit(&mut s, 0, (0..8).collect(), 8);
        let _rb = submit(&mut s, 1, vec![1, 2], 2);
        let rc = submit(&mut s, 2, vec![3, 4], 2);
        drain(&mut s);
        let c = rc.recv().unwrap();
        assert_eq!(c.batch_size, 1, "static mode admits C into a fresh batch");
        let stats = s.into_stats();
        assert_eq!(stats.steps, 10);
    }

    #[test]
    fn ttft_and_tpot_surface_in_stats() {
        let dt = 0.25;
        let mut s = Scheduler::new(Mock::new(dt), cfg(4, SchedulerMode::Continuous));
        let rxs: Vec<_> = (0..4).map(|i| submit(&mut s, i, vec![1, 2, 3, 4], 4)).collect();
        drain(&mut s);
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!((r.sim_ttft - dt).abs() < 1e-12);
            assert!((r.sim_tpot - dt).abs() < 1e-12);
            assert!((r.sim_latency - 4.0 * dt).abs() < 1e-12);
        }
        let stats = s.into_stats();
        assert!((stats.ttft.p50 - dt).abs() < 1e-12);
        assert!((stats.tpot.p99 - dt).abs() < 1e-12);
        assert!((stats.total_sim_seconds - 4.0 * dt).abs() < 1e-12);
    }

    #[test]
    fn max_batch_bounds_slot_occupancy() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
        let rxs: Vec<_> = (0..5).map(|i| submit(&mut s, i, vec![i as usize, 9], 2)).collect();
        drain(&mut s);
        for rx in rxs {
            assert!(rx.recv().unwrap().batch_size <= 2);
        }
        assert_eq!(s.decoder().peak_active, 2);
    }

    #[test]
    fn responses_match_requests_threaded() {
        let server = Server::start(|| Ok(Mock::new(0.5)), ServerConfig::default());
        let rx1 = server.submit(vec![1, 2, 3], 8);
        let rx2 = server.submit(vec![9, 8], 8);
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert_eq!(r1.tokens, vec![3, 2, 1]);
        assert_eq!(r2.tokens, vec![8, 9]);
        assert_ne!(r1.id, r2.id);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 2);
        assert!(stats.queue_wait.p99 >= stats.queue_wait.p50);
    }

    #[test]
    fn batching_groups_concurrent_requests() {
        let cfg = ServerConfig {
            max_batch: 8,
            batch_wait: Duration::from_millis(50),
            max_output: 8,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
        };
        let server = Server::start(|| Ok(Mock::new(0.5)), cfg);
        let rxs: Vec<_> = (0..6).map(|i| server.submit(vec![i, i + 1], 4)).collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert!(responses.iter().any(|r| r.batch_size > 1));
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.mean_batch_size > 1.0, "requests should have shared steps");
    }

    #[test]
    fn shutdown_drains_pending() {
        let cfg = ServerConfig {
            max_batch: 64,
            batch_wait: Duration::from_millis(200),
            max_output: 8,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
        };
        let server = Server::start(|| Ok(Mock::new(0.5)), cfg);
        let rx = server.submit(vec![7], 4);
        let stats = server.shutdown().unwrap();
        assert_eq!(rx.recv().unwrap().tokens, vec![7]);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn no_starvation_under_load() {
        for mode in [SchedulerMode::Static, SchedulerMode::Continuous] {
            let cfg = ServerConfig {
                max_batch: 3,
                batch_wait: Duration::from_millis(1),
                max_output: 8,
                scheduler: mode,
                prefill_chunk: 1,
            };
            let server = Server::start(|| Ok(Mock::new(0.01)), cfg);
            let rxs: Vec<_> = (0..30).map(|i| server.submit(vec![i], 4)).collect();
            let mut got = 0;
            for rx in rxs {
                if rx.recv_timeout(Duration::from_secs(5)).is_ok() {
                    got += 1;
                }
            }
            assert_eq!(got, 30, "{mode:?}");
            server.shutdown().unwrap();
        }
    }
}
