//! Serving coordinator: request queue → step-level continuous scheduler.
//!
//! The PJRT handles inside the engine are not `Send`, so the coordinator
//! follows the single-runner design (as in vLLM's engine loop): client
//! threads submit requests over an mpsc channel; one runner thread owns
//! the model (constructed *inside* the thread by a `Send` factory) and
//! drives a [`Scheduler`].  At every token step the scheduler admits
//! queued requests into free decode slots (up to `max_batch`), advances
//! all in-flight sequences through the step-level [`Decoder`] — decodes
//! by exactly one token, prompts still in prefill by up to
//! [`ServerConfig::prefill_chunk`] prompt tokens piggybacked on the same
//! step (Sarathi-style chunked prefill, so a long prompt can never stall
//! a live decode's next token) — and retires sequences the moment they
//! hit EOS, so a long sequence never holds finished slots hostage and
//! freed slots re-admit immediately.  [`SchedulerMode::Static`] recovers
//! the legacy drain-batch-then-decode-to-completion behaviour for
//! comparison (`--scheduler static|continuous` on the CLI).
//!
//! Scheduling is *priority-aware* end to end: every [`Request`] carries a
//! [`Priority`] (Low/Normal/High), pending requests queue per class and
//! admit highest-class-first, and under a [`PreemptPolicy`] a request
//! that has waited longer than the policy threshold may *preempt* the
//! lowest-priority in-flight sequence at a step boundary — the decoder
//! detaches its state ([`Decoder::suspend`]), the slot re-admits the
//! waiter, and the victim reattaches later ([`Decoder::resume`]) with
//! bit-identical continuation.  Time a sequence spends suspended is
//! reported separately from initial queueing
//! ([`ServerStats::preempted_wait`] vs [`ServerStats::queue_wait`]), so
//! preemption cost is visible rather than laundered into queue time.
//!
//! The front-end is *streaming*: [`Server::submit`] takes a
//! [`RequestSpec`] and returns a [`TokenStream`] — tokens arrive
//! per-step over a per-request channel, and the terminal [`Response`]
//! carries an explicit [`Outcome`].  Three [`StreamPolicy`] behaviours
//! ride on the same suspend machinery preemption introduced:
//! *backpressure* (a bounded stream channel running full suspends the
//! sequence at a step boundary instead of buffering unboundedly),
//! *disconnect/cancel* (dropping the [`TokenStream`] or calling
//! [`TokenStream::cancel`] reclaims the slot and pin ledger immediately
//! — the one-way version of suspend — with a `Cancelled` terminal), and
//! *SLO-aware admission* (deadline-tagged requests whose estimated TTFT
//! under current occupancy cannot meet the deadline are `Rejected` up
//! front instead of missing at p99).  Goodput — SLO-attaining tokens —
//! is reported beside raw throughput.  With every streaming knob off
//! the decode path is bit-identical to the pre-streaming coordinator.

pub mod workload;

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
    TrySendError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::Percentiles;
use crate::pcie::TransferStats;
use crate::trace::{Trace, TraceEvent};

/// Request priority class.  Ordered: `Low < Normal < High` — the
/// scheduler admits pending requests highest class first, and under a
/// [`PreemptPolicy`] a waiter may suspend an in-flight sequence of a
/// *strictly lower* class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    Normal,
    High,
}

impl Priority {
    /// All classes, lowest first (`ALL.iter().rev()` is admission order).
    pub const ALL: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];

    pub fn parse(s: &str) -> Result<Priority> {
        Ok(match s {
            "low" => Priority::Low,
            "normal" => Priority::Normal,
            "high" => Priority::High,
            _ => anyhow::bail!("unknown priority {s:?} (low|normal|high)"),
        })
    }

    /// Dense index for per-class storage (`Low = 0 … High = 2`).
    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// When a waiting request may preempt an in-flight sequence.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PreemptPolicy {
    /// Never preempt: priority only reorders admission.
    #[default]
    Off,
    /// Preempt once a strictly-higher-priority request has waited more
    /// than this many *simulated* seconds for a slot.  `0.0` preempts as
    /// soon as a higher-priority request finds every slot occupied.
    After(f64),
}

impl PreemptPolicy {
    /// `--preempt off` or `--preempt <seconds>`.
    pub fn parse(s: &str) -> Result<PreemptPolicy> {
        if s == "off" {
            return Ok(PreemptPolicy::Off);
        }
        let t: f64 = s.parse().map_err(|e| anyhow::anyhow!("--preempt {s:?}: {e}"))?;
        if !t.is_finite() || t < 0.0 {
            anyhow::bail!("preempt threshold must be a finite non-negative number, got {s:?}");
        }
        Ok(PreemptPolicy::After(t))
    }

    /// The wait threshold, or `None` when preemption is off.
    pub fn threshold(self) -> Option<f64> {
        match self {
            PreemptPolicy::Off => None,
            PreemptPolicy::After(t) => Some(t),
        }
    }
}

/// One retired sequence, in the decoder's simulated timeline.
#[derive(Debug, Clone)]
pub struct SeqFinish {
    pub seq: u64,
    pub tokens: Vec<usize>,
    /// Simulated time the sequence was admitted into a decode slot.
    pub sim_admitted: f64,
    /// Simulated time its first output token landed.
    pub sim_first_token: f64,
    /// Simulated time it retired (EOS or token budget).
    pub sim_finished: f64,
}

impl SeqFinish {
    /// Time-to-first-token from admission (simulated seconds).
    pub fn ttft(&self) -> f64 {
        (self.sim_first_token - self.sim_admitted).max(0.0)
    }

    /// Time per output token after the first (simulated seconds).
    pub fn tpot(&self) -> f64 {
        if self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.sim_finished - self.sim_first_token).max(0.0) / (self.tokens.len() - 1) as f64
    }

    /// Admission-to-retirement latency (simulated seconds).
    pub fn latency(&self) -> f64 {
        (self.sim_finished - self.sim_admitted).max(0.0)
    }
}

/// A resumable, step-granular decoder.  Sequences are admitted into
/// decode slots (possibly mid-flight, while others are decoding) and all
/// in-flight sequences advance one token per [`Decoder::step`] call.
/// Implementors: the engine's `DecodeSession` wrappers, the cluster's
/// analytic replicas, and the mocks in the scheduler tests.
pub trait Decoder {
    /// Admit a sequence into the in-flight set; returns its handle.
    fn admit(&mut self, prompt: &[usize], max_output: usize) -> Result<u64>;
    /// Advance every in-flight sequence one step: decodes emit exactly
    /// one token, prefilling sequences consume up to the configured
    /// prefill chunk of prompt tokens.  Sequences hitting EOS or their
    /// budget retire immediately and are returned — their slots are free
    /// before the next step.
    fn step(&mut self) -> Result<Vec<SeqFinish>>;
    /// Number of in-flight sequences.
    fn active(&self) -> usize;
    /// Current simulated time (seconds).
    fn now(&self) -> f64;
    /// Per-step prompt-token budget for prefilling sequences (chunked
    /// prefill).  The scheduler sets this once from
    /// [`ServerConfig::prefill_chunk`]; decoders without a prefill
    /// concept may ignore it (the default does).
    fn set_prefill_chunk(&mut self, _chunk: usize) {}
    /// PCIe transfer accounting snapshot (stall vs overlapped split, see
    /// `pcie`).  Decoders without a transfer model return the default
    /// zeros.
    fn transfer_stats(&self) -> TransferStats {
        TransferStats::default()
    }
    /// Detach an in-flight sequence's state at a step boundary so its
    /// slot frees (priority preemption).  The returned opaque state is
    /// handed back verbatim to [`Decoder::resume`]; the sequence must
    /// continue bit-identically from where it stopped.  Decoders without
    /// suspension support refuse (the scheduler only calls this under an
    /// active [`PreemptPolicy`]).
    fn suspend(&mut self, _seq: u64) -> Result<Box<dyn Any>> {
        anyhow::bail!("this decoder does not support preemption")
    }
    /// Reattach a sequence detached by [`Decoder::suspend`] into a free
    /// slot, returning its original handle.
    fn resume(&mut self, _state: Box<dyn Any>) -> Result<u64> {
        anyhow::bail!("this decoder does not support preemption")
    }
    /// Enable or disable structured event tracing (see `trace`).  The
    /// scheduler sets this once from [`ServerConfig::trace`]; decoders
    /// without a recorder ignore it (the default does).
    fn set_tracing(&mut self, _on: bool) {}
    /// Drain the recorded event stream at shutdown, or `None` when the
    /// decoder never traced.
    fn take_trace(&mut self) -> Option<Trace> {
        None
    }
    /// Fraction of routed (token, expert) assignments the big-little
    /// fallback served from a degraded low-bit little copy (quality
    /// proxy; see `quant`).  Decoders without the fallback report 0.0.
    fn degraded_token_frac(&self) -> f64 {
        0.0
    }
    /// Cancel an in-flight sequence: detach-and-drop with immediate
    /// slot + pin-ledger reclaim — the one-way version of
    /// [`Decoder::suspend`].  Returns the output tokens produced so far
    /// (they travel on the `Cancelled` terminal [`Response`]).  The
    /// default reuses the suspend path and drops the detached state,
    /// which reclaims correctly for any suspension-capable decoder but
    /// loses the partial tokens; decoders that track per-sequence
    /// output should override (the engine wrapper does, emitting
    /// [`TraceEvent::Cancel`] instead of `Suspend`).
    fn cancel(&mut self, seq: u64) -> Result<Vec<usize>> {
        self.suspend(seq).map(|_| Vec::new())
    }
    /// Output tokens an in-flight sequence has produced so far (the
    /// streaming front-end polls this after every step to forward newly
    /// decoded tokens).  Decoders without per-token visibility return
    /// empty — streaming then degrades to terminal-only delivery.
    fn peek_tokens(&self, _seq: u64) -> Vec<usize> {
        Vec::new()
    }
    /// Record a scheduler-originated event (queue-side cancellation,
    /// admission rejection, stream stall) onto the decoder's trace lane
    /// at its current simulated time.  No-op for untraced decoders.
    fn note(&mut self, _ev: TraceEvent) {}
}

/// How the scheduler fills decode slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// Drain a batch from the queue, decode it to completion, repeat.
    /// Finished slots idle until the whole batch retires (the legacy
    /// run-to-completion loop; the Fig. 5 batching convention).
    Static,
    /// Admit from the queue into free slots at *every* token step and
    /// retire sequences at EOS immediately (vLLM-style continuous
    /// batching).  Under MELINOE's fine-tuned routing this also keeps the
    /// LFU cache warm: admitted same-task requests reuse the experts the
    /// in-flight batch already pinned.
    Continuous,
}

impl SchedulerMode {
    pub fn parse(s: &str) -> Result<SchedulerMode> {
        Ok(match s {
            "static" => SchedulerMode::Static,
            "continuous" => SchedulerMode::Continuous,
            _ => anyhow::bail!("unknown scheduler {s:?} (static|continuous)"),
        })
    }
}

/// How a request left the system.  Every submission resolves with
/// exactly one terminal [`Response`] carrying one of these — rejected
/// and cancelled requests never silently drop their receiver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Decoded to EOS or its token budget.
    Completed,
    /// Client disconnect, explicit [`TokenStream::cancel`], or a
    /// `cancel_after` knob fired; partial tokens ride on the terminal.
    Cancelled,
    /// Refused at admission: the estimated TTFT under current occupancy
    /// could not meet the request's deadline.
    Rejected,
    /// Exhausted its retry budget after repeated replica failures
    /// (fleet-level: single-node serving never produces this — see
    /// [`crate::cluster::run_cluster`] and [`crate::fault`]).
    Failed,
}

impl Outcome {
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Cancelled => "cancelled",
            Outcome::Rejected => "rejected",
            Outcome::Failed => "failed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_output: usize,
    pub priority: Priority,
    /// TTFT SLO in simulated seconds from submission; `None` = no SLO.
    /// Under [`StreamPolicy::admission`] a deadline the scheduler
    /// estimates it cannot meet is `Rejected` up front; completed
    /// requests count toward goodput only when the deadline was met.
    pub deadline: Option<f64>,
    /// Client walks away after this many output tokens (workload
    /// modeling: "cancel after the first token").  The sequence cancels
    /// at the next step boundary once the threshold is reached.
    pub cancel_after: Option<usize>,
}

/// Builder for a submission: `RequestSpec::new(prompt)` then chain
/// `.max_output(n)`, `.priority(p)`, `.deadline(d)`, `.cancel_after(k)`.
/// Consumed by [`Server::submit`], the single submission entry point.
#[derive(Debug, Clone)]
pub struct RequestSpec {
    prompt: Vec<usize>,
    max_output: usize,
    priority: Priority,
    deadline: Option<f64>,
    cancel_after: Option<usize>,
}

impl RequestSpec {
    /// A Normal-priority spec with the default 32-token output budget
    /// and no deadline or cancel knobs.
    pub fn new(prompt: Vec<usize>) -> RequestSpec {
        RequestSpec {
            prompt,
            max_output: 32,
            priority: Priority::Normal,
            deadline: None,
            cancel_after: None,
        }
    }

    /// Output token budget.
    pub fn max_output(mut self, n: usize) -> RequestSpec {
        self.max_output = n;
        self
    }

    /// Scheduling class (see [`Priority`]).
    pub fn priority(mut self, p: Priority) -> RequestSpec {
        self.priority = p;
        self
    }

    /// TTFT SLO in simulated seconds from submission.
    pub fn deadline(mut self, d: f64) -> RequestSpec {
        self.deadline = Some(d);
        self
    }

    /// Client disconnects after this many output tokens.
    pub fn cancel_after(mut self, n: usize) -> RequestSpec {
        self.cancel_after = Some(n);
        self
    }

    /// Materialize the [`Request`] under a server-assigned id.
    pub fn into_request(self, id: u64) -> Request {
        Request {
            id,
            prompt: self.prompt,
            max_output: self.max_output,
            priority: self.priority,
            deadline: self.deadline,
            cancel_after: self.cancel_after,
        }
    }
}

/// Streaming knobs, all off by default — and with all of them off the
/// scheduler's decode path is bit-identical to the pre-streaming
/// coordinator (tokens simply also mirror onto an unbounded channel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamPolicy {
    /// Per-request token channel bound; `0` = unbounded (no
    /// backpressure).  When bounded, a consumer that falls behind
    /// suspends the sequence at a step boundary (the PR 5 suspend path)
    /// instead of buffering unboundedly; it resumes once the backlog
    /// drains.
    pub buffer: usize,
    /// SLO-aware admission: reject deadline-tagged requests up front
    /// when the estimated TTFT from current occupancy cannot meet the
    /// deadline, producing [`Outcome::Rejected`] rather than a p99 miss.
    pub admission: bool,
}

impl Default for StreamPolicy {
    fn default() -> Self {
        StreamPolicy { buffer: 0, admission: false }
    }
}

impl StreamPolicy {
    /// Bounded per-request token channel (`0` = unbounded).
    pub fn with_buffer(mut self, n: usize) -> StreamPolicy {
        self.buffer = n;
        self
    }

    /// Toggle SLO-aware admission.
    pub fn with_admission(mut self, on: bool) -> StreamPolicy {
        self.admission = on;
        self
    }
}

/// Scheduler-side half of a per-request token channel: unbounded when
/// [`StreamPolicy::buffer`] is 0, bounded (backpressure) otherwise.
pub struct StreamTx(StreamTxInner);

enum StreamTxInner {
    Loose(Sender<usize>),
    Tight(SyncSender<usize>),
}

/// Result of a non-blocking token push.
enum StreamPush {
    Sent,
    /// Bounded channel full: the consumer is behind (backpressure).
    Full,
    /// Receiver dropped: the client is gone (disconnect).
    Gone,
}

impl StreamTx {
    fn pair(buffer: usize) -> (StreamTx, Receiver<usize>) {
        if buffer == 0 {
            let (tx, rx) = channel();
            (StreamTx(StreamTxInner::Loose(tx)), rx)
        } else {
            let (tx, rx) = sync_channel(buffer);
            (StreamTx(StreamTxInner::Tight(tx)), rx)
        }
    }

    fn push(&self, t: usize) -> StreamPush {
        match &self.0 {
            StreamTxInner::Loose(tx) => {
                if tx.send(t).is_ok() {
                    StreamPush::Sent
                } else {
                    StreamPush::Gone
                }
            }
            StreamTxInner::Tight(tx) => match tx.try_send(t) {
                Ok(()) => StreamPush::Sent,
                Err(TrySendError::Full(_)) => StreamPush::Full,
                Err(TrySendError::Disconnected(_)) => StreamPush::Gone,
            },
        }
    }
}

/// Client-side handle returned by [`Server::submit`]: tokens arrive
/// per-step on a channel, the terminal [`Response`] (with its
/// [`Outcome`]) arrives once.  Dropping the handle without waiting is a
/// *disconnect* — the scheduler cancels the sequence and reclaims its
/// slot and pins at the next step boundary; [`TokenStream::cancel`]
/// does the same explicitly.
pub struct TokenStream {
    id: u64,
    tokens: Receiver<usize>,
    done: Option<Receiver<Response>>,
    alive: Arc<AtomicBool>,
    /// Cleared by `wait`/`wait_timeout`: consuming the stream to its
    /// terminal is not a disconnect.
    armed: bool,
}

impl TokenStream {
    /// Server-assigned request id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Blocking next token; `None` once the stream closed (terminal
    /// reached — poll [`TokenStream::poll_response`] or call
    /// [`TokenStream::wait`] for the outcome).
    pub fn next_token(&self) -> Option<usize> {
        self.tokens.recv().ok()
    }

    /// Non-blocking token poll.
    pub fn poll_token(&self) -> Option<usize> {
        self.tokens.try_recv().ok()
    }

    /// Non-blocking terminal poll (does not consume the handle).
    pub fn poll_response(&self) -> Option<Response> {
        self.done.as_ref().and_then(|d| d.try_recv().ok())
    }

    /// Explicitly cancel: the scheduler reclaims the slot and pin
    /// ledger at the next step boundary and resolves the terminal with
    /// [`Outcome::Cancelled`] (partial tokens attached).
    pub fn cancel(&self) {
        self.alive.store(false, Ordering::Relaxed);
    }

    /// Block until the terminal [`Response`], draining the token
    /// channel along the way so a bounded stream can never stall the
    /// sequence it is waiting on.  The terminal carries the complete
    /// token list, so unconsumed streamed tokens are not lost.
    pub fn wait(mut self) -> Result<Response> {
        self.armed = false;
        let done = self.done.take().expect("terminal already consumed");
        loop {
            while self.tokens.try_recv().is_ok() {}
            match done.recv_timeout(Duration::from_millis(5)) {
                Ok(r) => return Ok(r),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("runner dropped the request without a terminal response")
                }
            }
        }
    }

    /// [`TokenStream::wait`] with an overall timeout.
    pub fn wait_timeout(mut self, timeout: Duration) -> Result<Response> {
        self.armed = false;
        let done = self.done.take().expect("terminal already consumed");
        let deadline = Instant::now() + timeout;
        loop {
            while self.tokens.try_recv().is_ok() {}
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                anyhow::bail!("timed out waiting for a terminal response");
            }
            match done.recv_timeout(left.min(Duration::from_millis(5))) {
                Ok(r) => return Ok(r),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("runner dropped the request without a terminal response")
                }
            }
        }
    }
}

impl Drop for TokenStream {
    fn drop(&mut self) {
        if self.armed {
            self.alive.store(false, Ordering::Relaxed);
        }
    }
}

/// A request plus its client-side channel endpoints, as handed to
/// [`Scheduler::enqueue`].
pub struct Submission {
    req: Request,
    done: Sender<Response>,
    stream: Option<StreamTx>,
    alive: Arc<AtomicBool>,
    submitted: Instant,
}

impl Submission {
    /// Terminal-only submission (the pre-streaming shape): no token
    /// channel, the client observes exactly one [`Response`].
    pub fn terminal(req: Request) -> (Submission, Receiver<Response>) {
        let (dtx, drx) = channel();
        let sub = Submission {
            req,
            done: dtx,
            stream: None,
            alive: Arc::new(AtomicBool::new(true)),
            submitted: Instant::now(),
        };
        (sub, drx)
    }

    /// Streaming submission under `policy`: the submission plus the
    /// client-side [`TokenStream`] handle.
    pub fn streaming(req: Request, policy: StreamPolicy) -> (Submission, TokenStream) {
        let (dtx, drx) = channel();
        let (stx, srx) = StreamTx::pair(policy.buffer);
        let alive = Arc::new(AtomicBool::new(true));
        let id = req.id;
        let sub = Submission {
            req,
            done: dtx,
            stream: Some(stx),
            alive: alive.clone(),
            submitted: Instant::now(),
        };
        (sub, TokenStream { id, tokens: srx, done: Some(drx), alive, armed: true })
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Complete output tokens (partial for `Cancelled`, empty for
    /// `Rejected`) — authoritative even when streamed tokens went
    /// unconsumed.
    pub tokens: Vec<usize>,
    /// How the request left the system.
    pub outcome: Outcome,
    /// Wallclock seconds between submission and *first* slot admission
    /// (initial queueing only — time spent suspended after a preemption
    /// is reported separately in `preempted_wait`).
    pub queue_wait: f64,
    /// Simulated seconds spent suspended after preemptions (0.0 for a
    /// request that was never preempted).
    pub preempted_wait: f64,
    /// Simulated seconds from admission to retirement.
    pub sim_latency: f64,
    /// Simulated time-to-first-token (from admission).
    pub sim_ttft: f64,
    /// Simulated time per output token after the first.
    pub sim_tpot: f64,
    /// In-flight sequences (this one included) when it was admitted.
    pub batch_size: usize,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// Straggler window: when the scheduler is idle and the first request
    /// arrives, wait this long for near-simultaneous submitters before
    /// the first token step.
    pub batch_wait: Duration,
    /// Default output budget (callers may override per request).
    pub max_output: usize,
    pub scheduler: SchedulerMode,
    /// Per-step token budget for prompt prefill (`--prefill-chunk`): a
    /// sequence still in prefill consumes up to this many prompt tokens
    /// per scheduler tick, piggybacked on the same step that advances
    /// every in-flight decode by exactly one token — so a long prompt
    /// shortens its own TTFT by `~chunk×` without ever stalling live
    /// decodes.  1 (the default) recovers token-at-a-time prefill.
    pub prefill_chunk: usize,
    /// When a waiting higher-priority request may preempt an in-flight
    /// sequence (`--preempt`).  Only meaningful under
    /// [`SchedulerMode::Continuous`] — static batches cannot re-admit a
    /// freed slot mid-batch, so preemption is gated off there.
    pub preempt: PreemptPolicy,
    /// Record the structured sim-time event stream (`--trace`): the
    /// scheduler enables the decoder's recorder at construction and
    /// surfaces the drained [`Trace`] in [`ServerStats::trace`].
    pub trace: bool,
    /// Streaming knobs: token-channel bound (backpressure) and
    /// SLO-aware admission.  All off by default.
    pub stream: StreamPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 4,
            batch_wait: Duration::from_millis(2),
            max_output: 32,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
            preempt: PreemptPolicy::Off,
            trace: false,
            stream: StreamPolicy::default(),
        }
    }
}

impl ServerConfig {
    pub fn with_max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn with_batch_wait(mut self, d: Duration) -> Self {
        self.batch_wait = d;
        self
    }

    pub fn with_max_output(mut self, n: usize) -> Self {
        self.max_output = n;
        self
    }

    pub fn with_scheduler(mut self, m: SchedulerMode) -> Self {
        self.scheduler = m;
        self
    }

    pub fn with_prefill_chunk(mut self, c: usize) -> Self {
        self.prefill_chunk = c;
        self
    }

    pub fn with_preempt(mut self, p: PreemptPolicy) -> Self {
        self.preempt = p;
        self
    }

    pub fn with_trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    pub fn with_stream(mut self, s: StreamPolicy) -> Self {
        self.stream = s;
        self
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// Requests that reached a terminal outcome
    /// (`completed + cancelled + rejected`).
    pub requests: u64,
    /// Terminal [`Outcome::Completed`] count.
    pub completed: u64,
    /// Terminal [`Outcome::Cancelled`] count (queue-side disconnects
    /// included).
    pub cancelled: u64,
    /// Subset of `cancelled`: disconnects detected while still queued —
    /// the request was never admitted into a slot.
    pub cancelled_in_queue: u64,
    /// Terminal [`Outcome::Rejected`] count (SLO-aware admission).
    pub rejected: u64,
    /// Terminal [`Outcome::Failed`] count (retry budget exhausted after
    /// replica failures).  Fleet-level: always zero in single-node
    /// serving, where no fault plan runs.
    pub failed: u64,
    /// Backpressure suspensions: a bounded stream channel ran full and
    /// the sequence was parked at a step boundary.
    pub stream_stalls: u64,
    /// Output tokens of completed requests that met their TTFT deadline
    /// (deadline-free requests always attain).  `goodput()` divides by
    /// the simulated clock.
    pub goodput_tokens: u64,
    /// Token steps the scheduler executed.
    pub steps: u64,
    /// Prefill chunk the scheduler ran with (1 = token-at-a-time).
    pub prefill_chunk: usize,
    pub total_output_tokens: u64,
    /// Decoder simulated clock at shutdown.
    pub total_sim_seconds: f64,
    /// Mean in-flight sequences per executed step (slot occupancy).
    pub mean_batch_size: f64,
    /// Sequences suspended out of their slot by a higher-priority waiter.
    pub preemptions: u64,
    /// p50/p95/p99 of per-request wallclock *initial* queue wait
    /// (seconds) — submission to first admission only.
    pub queue_wait: Percentiles,
    /// p50/p95/p99 of per-request simulated seconds spent suspended
    /// after preemptions (0 everywhere when preemption never fired).
    /// Split out from `queue_wait` so preemption cost is visible.
    pub preempted_wait: Percentiles,
    /// p50/p95/p99 of per-request simulated admission→finish latency.
    pub sim_latency: Percentiles,
    /// p50/p95/p99 of simulated time-to-first-token.
    pub ttft: Percentiles,
    /// p50/p95/p99 of simulated time-per-output-token.
    pub tpot: Percentiles,
    /// Decode time lost stalled on expert transfers (demand stalls plus
    /// residual waits on caught in-flight prefetches).
    pub pcie_stall_seconds: f64,
    /// Transfer time hidden behind compute (admit + lookahead prefetch).
    pub pcie_overlapped_seconds: f64,
    /// `overlapped / (overlapped + stalled)` — the overlap fraction.
    pub pcie_overlap_fraction: f64,
    /// Fraction of routed (token, expert) assignments served degraded by
    /// the big-little fallback (0.0 when the fallback is off; in [0, 1]).
    pub degraded_token_frac: f64,
    /// The decoder's drained event stream when [`ServerConfig::trace`]
    /// was set (and the decoder supports recording), else `None`.
    pub trace: Option<Trace>,
}

impl ServerStats {
    /// Goodput: SLO-attaining simulated throughput (tokens of completed
    /// requests that met their TTFT deadline, per simulated second;
    /// deadline-free requests always attain).
    pub fn goodput(&self) -> f64 {
        if self.total_sim_seconds > 0.0 {
            self.goodput_tokens as f64 / self.total_sim_seconds
        } else {
            0.0
        }
    }
}

struct Job {
    req: Request,
    done: Sender<Response>,
    /// Per-request token channel (None for terminal-only submissions).
    stream: Option<StreamTx>,
    /// Cleared by the client on disconnect/cancel; checked while queued
    /// (cancelled-in-queue) and after every step (cancel mid-decode).
    alive: Arc<AtomicBool>,
    /// Output tokens already forwarded onto the stream channel.
    streamed: usize,
    submitted: Instant,
    /// Decoder sim time at enqueue (preemption thresholds are measured
    /// on the simulated clock, so tests stay deterministic).
    enqueued_sim: f64,
    /// Set at admission: wallclock queue wait and slot occupancy.
    queue_wait: f64,
    batch_at_admit: usize,
    /// Total simulated seconds spent suspended after preemptions.
    preempted_wait: f64,
    /// Sim time of the latest suspension (while in the suspended store).
    suspended_at: f64,
    /// Sim time of the *first* admission — preemption victims are the
    /// most recently (first-)admitted among the lowest class, i.e. the
    /// least-progressed sequence; resume does not reset it, so a
    /// just-resumed sequence cannot become the permanent victim.
    admitted_sim: f64,
}

/// A backpressured sequence: suspended out of its slot with a token
/// backlog its consumer has yet to drain.
struct Stalled {
    seq: u64,
    job: Job,
    state: Box<dyn Any>,
    /// Tokens produced before the stall (`job.streamed` of them already
    /// delivered).
    produced: Vec<usize>,
}

/// The step-level scheduling core, independent of threads and channels:
/// the runner thread drives it from the mpsc queue; unit tests drive it
/// synchronously against a mock decoder.
pub struct Scheduler<D: Decoder> {
    dec: D,
    cfg: ServerConfig,
    /// Pending jobs, one FIFO queue per [`Priority`] class.
    pending: [VecDeque<Job>; 3],
    inflight: HashMap<u64, Job>,
    /// Preempted sequences waiting to reattach: (decoder handle, job,
    /// opaque suspended state), in suspension order.
    suspended: Vec<(u64, Job, Box<dyn Any>)>,
    /// Backpressure-suspended sequences waiting for their consumers to
    /// drain the backlog; they re-enter `suspended` once drained.
    stalled: Vec<Stalled>,
    stats: ServerStats,
    batch_sizes: Vec<usize>,
    queue_waits: Vec<f64>,
    preempted_waits: Vec<f64>,
    sim_latencies: Vec<f64>,
    ttfts: Vec<f64>,
    tpots: Vec<f64>,
}

impl<D: Decoder> Scheduler<D> {
    pub fn new(mut dec: D, cfg: ServerConfig) -> Scheduler<D> {
        dec.set_prefill_chunk(cfg.prefill_chunk.max(1));
        if cfg.trace {
            dec.set_tracing(true);
        }
        Scheduler {
            dec,
            cfg,
            pending: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            inflight: HashMap::new(),
            suspended: Vec::new(),
            stalled: Vec::new(),
            stats: ServerStats::default(),
            batch_sizes: Vec::new(),
            queue_waits: Vec::new(),
            preempted_waits: Vec::new(),
            sim_latencies: Vec::new(),
            ttfts: Vec::new(),
            tpots: Vec::new(),
        }
    }

    /// Accept (or reject) a submission.  Under
    /// [`StreamPolicy::admission`], a deadline-tagged request whose
    /// estimated TTFT from current occupancy cannot meet its deadline
    /// resolves immediately with [`Outcome::Rejected`].
    pub fn enqueue(&mut self, sub: Submission) {
        let Submission { req, done, stream, alive, submitted } = sub;
        if self.cfg.stream.admission {
            if let Some(d) = req.deadline {
                if self.estimated_ttft(&req) > d {
                    self.dec.note(TraceEvent::Reject { seq: req.id });
                    let resp = Response {
                        id: req.id,
                        tokens: Vec::new(),
                        outcome: Outcome::Rejected,
                        queue_wait: 0.0,
                        preempted_wait: 0.0,
                        sim_latency: 0.0,
                        sim_ttft: 0.0,
                        sim_tpot: 0.0,
                        batch_size: 0,
                    };
                    self.resolve(done, resp);
                    return;
                }
            }
        }
        let enqueued_sim = self.dec.now();
        self.pending[req.priority.idx()].push_back(Job {
            req,
            done,
            stream,
            alive,
            streamed: 0,
            submitted,
            enqueued_sim,
            queue_wait: 0.0,
            batch_at_admit: 0,
            preempted_wait: 0.0,
            suspended_at: 0.0,
            admitted_sim: 0.0,
        });
    }

    /// TTFT estimate for an incoming request, from current occupancy:
    /// each "wave" of work ahead of it (pending + suspended + stalled +
    /// in flight, in units of `max_batch`) must produce up to the
    /// configured output budget before a slot frees, then the request's
    /// own chunked prefill runs.  Per-step cost is the observed mean;
    /// with no steps observed yet there is no signal, so the estimate
    /// is 0.0 (accept).
    fn estimated_ttft(&self, req: &Request) -> f64 {
        if self.stats.steps == 0 {
            return 0.0;
        }
        let mean_step = self.dec.now() / self.stats.steps as f64;
        let ahead = self.pending_len() + self.suspended.len() + self.stalled.len()
            + self.dec.active();
        let waves = ahead as f64 / self.cfg.max_batch.max(1) as f64;
        let service_steps = self.cfg.max_output.max(1) as f64;
        let prefill_steps =
            (req.prompt.len() as f64 / self.cfg.prefill_chunk.max(1) as f64).ceil();
        (waves * service_steps + prefill_steps) * mean_step
    }

    pub fn has_work(&self) -> bool {
        self.pending.iter().any(|q| !q.is_empty())
            || !self.suspended.is_empty()
            || !self.stalled.is_empty()
            || self.dec.active() > 0
    }

    /// Only backpressured sequences remain: nothing can progress until
    /// their consumers drain (or disconnect).  The runner idles briefly
    /// instead of spinning, and force-cancels them at shutdown.
    pub fn only_stalled(&self) -> bool {
        !self.stalled.is_empty()
            && self.pending_len() == 0
            && self.suspended.is_empty()
            && self.dec.active() == 0
    }

    pub fn pending_len(&self) -> usize {
        self.pending.iter().map(|q| q.len()).sum()
    }

    pub fn decoder(&self) -> &D {
        &self.dec
    }

    /// One scheduler round: reap queue-side disconnects, retry stalled
    /// stream backlogs, preempt if allowed, admit what the mode allows,
    /// advance one token step, then pump freshly decoded tokens out to
    /// their streams (cancelling / stalling as the consumers dictate).
    pub fn tick(&mut self) -> Result<()> {
        self.reap_queue_disconnects();
        self.flush_stalled();
        self.maybe_preempt()?;
        self.admit()?;
        if self.dec.active() == 0 {
            return Ok(());
        }
        self.batch_sizes.push(self.dec.active());
        self.stats.steps += 1;
        for fin in self.dec.step()? {
            self.retire(fin);
        }
        self.pump_streams()?;
        Ok(())
    }

    /// Drop pending jobs whose client disconnected before admission:
    /// they were never admitted, so there is nothing to reclaim — they
    /// resolve as `Cancelled` and count as cancelled-in-queue.
    fn reap_queue_disconnects(&mut self) {
        let mut reaped: Vec<Job> = Vec::new();
        for q in &mut self.pending {
            if q.iter().all(|j| j.alive.load(Ordering::Relaxed)) {
                continue;
            }
            let mut keep = VecDeque::with_capacity(q.len());
            while let Some(job) = q.pop_front() {
                if job.alive.load(Ordering::Relaxed) {
                    keep.push_back(job);
                } else {
                    reaped.push(job);
                }
            }
            *q = keep;
        }
        for job in reaped {
            self.stats.cancelled_in_queue += 1;
            self.dec.note(TraceEvent::Cancel { seq: job.req.id });
            let resp = Response {
                id: job.req.id,
                tokens: Vec::new(),
                outcome: Outcome::Cancelled,
                queue_wait: 0.0,
                preempted_wait: 0.0,
                sim_latency: 0.0,
                sim_ttft: 0.0,
                sim_tpot: 0.0,
                batch_size: 0,
            };
            self.resolve(job.done, resp);
        }
    }

    /// Retry delivery of stalled backlogs.  A sequence whose backlog
    /// drains re-enters the `suspended` store and reattaches through
    /// the normal admission path; one whose consumer disconnected is
    /// cancelled on the spot (its pins were already released when the
    /// stall suspended it, so only the terminal remains).
    fn flush_stalled(&mut self) {
        if self.stalled.is_empty() {
            return;
        }
        let now = self.dec.now();
        let mut keep = Vec::new();
        let mut cancels = Vec::new();
        let mut resumes = Vec::new();
        for mut st in std::mem::take(&mut self.stalled) {
            let want = st.job.req.cancel_after.unwrap_or(usize::MAX);
            let cap = want.min(st.produced.len());
            let mut gone = !st.job.alive.load(Ordering::Relaxed);
            if !gone {
                let stream = st.job.stream.as_ref().expect("stalled jobs are streaming");
                while st.job.streamed < cap {
                    match stream.push(st.produced[st.job.streamed]) {
                        StreamPush::Sent => st.job.streamed += 1,
                        StreamPush::Full => break,
                        StreamPush::Gone => {
                            gone = true;
                            break;
                        }
                    }
                }
            }
            if gone || st.produced.len() >= want {
                cancels.push(st);
            } else if st.job.streamed >= st.produced.len() {
                resumes.push(st);
            } else {
                keep.push(st);
            }
        }
        self.stalled = keep;
        for st in cancels {
            // the stall's suspend already released the pins; drop the
            // detached state and resolve the terminal
            let Stalled { seq, job, state, produced } = st;
            drop(state);
            self.dec.note(TraceEvent::Cancel { seq });
            self.resolve_cancelled(job, produced, now);
        }
        for st in resumes {
            self.suspended.push((st.seq, st.job, st.state));
        }
    }

    /// After a step: forward freshly decoded tokens to each in-flight
    /// stream, then act on consumer state — a full bounded channel
    /// stalls the sequence (suspend + backlog), a dropped receiver or
    /// cleared alive-flag or reached `cancel_after` cancels it
    /// (detach-and-drop with immediate pin release).
    fn pump_streams(&mut self) -> Result<()> {
        enum Fate {
            Stall(Vec<usize>),
            Cancel,
        }
        let now = self.dec.now();
        let mut ids: Vec<u64> = self.inflight.keys().copied().collect();
        ids.sort_unstable();
        let mut fates: Vec<(u64, Fate)> = Vec::new();
        for id in ids {
            let job = self.inflight.get_mut(&id).expect("id came from the in-flight set");
            if !job.alive.load(Ordering::Relaxed) {
                fates.push((id, Fate::Cancel));
                continue;
            }
            if job.stream.is_none() && job.req.cancel_after.is_none() {
                continue;
            }
            let produced = self.dec.peek_tokens(id);
            let want = job.req.cancel_after.unwrap_or(usize::MAX);
            let cap = want.min(produced.len());
            let mut fate = None;
            if let Some(stream) = &job.stream {
                while job.streamed < cap {
                    match stream.push(produced[job.streamed]) {
                        StreamPush::Sent => job.streamed += 1,
                        StreamPush::Full => {
                            fate = Some(Fate::Stall(produced.clone()));
                            break;
                        }
                        StreamPush::Gone => {
                            fate = Some(Fate::Cancel);
                            break;
                        }
                    }
                }
            } else {
                job.streamed = cap;
            }
            if produced.len() >= want {
                // the client walks away after `want` tokens
                fate = Some(Fate::Cancel);
            }
            if let Some(f) = fate {
                fates.push((id, f));
            }
        }
        for (id, fate) in fates {
            match fate {
                Fate::Cancel => {
                    let tokens = self.dec.cancel(id)?;
                    let job = self.inflight.remove(&id).expect("cancelled job is in flight");
                    self.resolve_cancelled(job, tokens, now);
                }
                Fate::Stall(produced) => {
                    self.stats.stream_stalls += 1;
                    self.dec.note(TraceEvent::StreamStall { seq: id });
                    let state = self.dec.suspend(id)?;
                    let mut job = self.inflight.remove(&id).expect("stalled job is in flight");
                    job.suspended_at = now;
                    self.stalled.push(Stalled { seq: id, job, state, produced });
                }
            }
        }
        Ok(())
    }

    /// Force-cancel every stalled stream (shutdown): a consumer that
    /// never drains must not hold the runner open forever.
    pub fn abort_stalled(&mut self) {
        let now = self.dec.now();
        for st in std::mem::take(&mut self.stalled) {
            let Stalled { seq, job, state, produced } = st;
            drop(state);
            self.dec.note(TraceEvent::Cancel { seq });
            self.resolve_cancelled(job, produced, now);
        }
    }

    /// Resolve a cancelled request: terminal `Cancelled` with whatever
    /// tokens it produced.  Latency percentiles track completed
    /// requests only, so nothing is sampled here.
    fn resolve_cancelled(&mut self, job: Job, tokens: Vec<usize>, now: f64) {
        let resp = Response {
            id: job.req.id,
            tokens,
            outcome: Outcome::Cancelled,
            queue_wait: job.queue_wait,
            preempted_wait: job.preempted_wait,
            sim_latency: (now - job.admitted_sim).max(0.0),
            sim_ttft: 0.0,
            sim_tpot: 0.0,
            batch_size: job.batch_at_admit,
        };
        self.resolve(job.done, resp);
    }

    /// The single terminal-send site: every submission resolves exactly
    /// once through here, whatever its outcome.
    fn resolve(&mut self, done: Sender<Response>, resp: Response) {
        self.stats.requests += 1;
        match resp.outcome {
            Outcome::Completed => self.stats.completed += 1,
            Outcome::Cancelled => self.stats.cancelled += 1,
            Outcome::Rejected => self.stats.rejected += 1,
            Outcome::Failed => self.stats.failed += 1,
        }
        let _ = done.send(resp);
    }

    /// Under [`PreemptPolicy::After`], suspend the lowest-priority (most
    /// recently admitted) in-flight sequence for every pending request of
    /// a strictly higher class that has out-waited the threshold on the
    /// simulated clock.  Continuous mode only: a static batch cannot
    /// re-admit the freed slot until it drains, so suspension would only
    /// idle it.
    fn maybe_preempt(&mut self) -> Result<()> {
        let Some(thresh) = self.cfg.preempt.threshold() else { return Ok(()) };
        if self.cfg.scheduler != SchedulerMode::Continuous {
            return Ok(());
        }
        let max_batch = self.cfg.max_batch.max(1);
        let now = self.dec.now();
        for p in [Priority::High, Priority::Normal] {
            loop {
                if self.dec.active() < max_batch {
                    // a slot is already free: admission handles the waiter
                    return Ok(());
                }
                let waited = match self.pending[p.idx()].front() {
                    Some(job) => now - job.enqueued_sim,
                    None => break,
                };
                if waited <= thresh {
                    break;
                }
                // lowest class first, then latest first admission, then
                // highest handle — the id tiebreak keeps victim choice
                // deterministic across runs (HashMap iteration is not)
                let victim = self
                    .inflight
                    .iter()
                    .filter(|(_, j)| j.req.priority < p)
                    .min_by(|a, b| {
                        a.1.req
                            .priority
                            .cmp(&b.1.req.priority)
                            .then(b.1.admitted_sim.total_cmp(&a.1.admitted_sim))
                            .then(b.0.cmp(a.0))
                    })
                    .map(|(id, _)| *id);
                let Some(vid) = victim else { break };
                let state = self.dec.suspend(vid)?;
                let mut job = self.inflight.remove(&vid).expect("victim is in flight");
                job.suspended_at = now;
                self.stats.preemptions += 1;
                self.suspended.push((vid, job, state));
            }
        }
        Ok(())
    }

    /// Admission order: highest priority class first; within a class,
    /// preempted sequences reattach (in suspension order) before new
    /// requests admit — they have already made progress and hold KV state.
    fn admit(&mut self) -> Result<()> {
        let open = match self.cfg.scheduler {
            SchedulerMode::Continuous => true,
            SchedulerMode::Static => self.dec.active() == 0,
        };
        if !open {
            return Ok(());
        }
        let max_batch = self.cfg.max_batch.max(1);
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            while self.dec.active() < max_batch {
                let pos = self.suspended.iter().position(|(_, j, _)| j.req.priority == p);
                let Some(i) = pos else { break };
                let (seq, mut job, state) = self.suspended.remove(i);
                let id = self.dec.resume(state)?;
                debug_assert_eq!(id, seq, "resume must keep the sequence handle");
                job.preempted_wait += self.dec.now() - job.suspended_at;
                // admitted_sim keeps the *first* admission time: victim
                // selection targets the least-progressed sequence, and a
                // just-resumed one must not become the permanent victim
                // (this also matches the replica's `started` semantics)
                self.inflight.insert(id, job);
            }
            while self.dec.active() < max_batch {
                let Some(mut job) = self.pending[p.idx()].pop_front() else { break };
                let id = self.dec.admit(&job.req.prompt, job.req.max_output)?;
                job.queue_wait = job.submitted.elapsed().as_secs_f64();
                job.batch_at_admit = self.dec.active();
                job.admitted_sim = self.dec.now();
                self.queue_waits.push(job.queue_wait);
                self.inflight.insert(id, job);
            }
        }
        Ok(())
    }

    fn retire(&mut self, fin: SeqFinish) {
        let Some(mut job) = self.inflight.remove(&fin.seq) else { return };
        let (latency, ttft, tpot) = (fin.latency(), fin.ttft(), fin.tpot());
        self.stats.total_output_tokens += fin.tokens.len() as u64;
        // goodput: SLO-attaining tokens — the TTFT deadline is measured
        // from submission on the simulated clock; deadline-free
        // requests always attain
        let attained = match job.req.deadline {
            Some(d) => fin.sim_first_token - job.enqueued_sim <= d,
            None => true,
        };
        if attained {
            self.stats.goodput_tokens += fin.tokens.len() as u64;
        }
        self.sim_latencies.push(latency);
        self.ttfts.push(ttft);
        self.tpots.push(tpot);
        self.preempted_waits.push(job.preempted_wait);
        // best-effort tail flush: the terminal Response carries the
        // complete token list regardless, so a full bounded channel
        // never blocks retirement
        if let Some(stream) = &job.stream {
            while job.streamed < fin.tokens.len() {
                if !matches!(stream.push(fin.tokens[job.streamed]), StreamPush::Sent) {
                    break;
                }
                job.streamed += 1;
            }
        }
        let resp = Response {
            id: job.req.id,
            tokens: fin.tokens,
            outcome: Outcome::Completed,
            queue_wait: job.queue_wait,
            preempted_wait: job.preempted_wait,
            sim_latency: latency,
            sim_ttft: ttft,
            sim_tpot: tpot,
            batch_size: job.batch_at_admit,
        };
        self.resolve(job.done, resp);
    }

    pub fn into_stats(mut self) -> ServerStats {
        self.stats.prefill_chunk = self.cfg.prefill_chunk.max(1);
        self.stats.total_sim_seconds = self.dec.now();
        let ts = self.dec.transfer_stats();
        self.stats.pcie_stall_seconds = ts.stall_time;
        self.stats.pcie_overlapped_seconds = ts.overlapped_time;
        self.stats.pcie_overlap_fraction = ts.overlap_fraction();
        self.stats.degraded_token_frac = self.dec.degraded_token_frac();
        self.stats.trace = self.dec.take_trace();
        if !self.batch_sizes.is_empty() {
            self.stats.mean_batch_size =
                self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64;
        }
        self.stats.queue_wait = Percentiles::of(&self.queue_waits);
        self.stats.preempted_wait = Percentiles::of(&self.preempted_waits);
        self.stats.sim_latency = Percentiles::of(&self.sim_latencies);
        self.stats.ttft = Percentiles::of(&self.ttfts);
        self.stats.tpot = Percentiles::of(&self.tpots);
        self.stats
    }
}

enum Msg {
    Job(Submission),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    handle: JoinHandle<Result<ServerStats>>,
    next_id: AtomicU64,
    stream: StreamPolicy,
}

impl Server {
    /// Start the runner thread.  `factory` constructs the decoder inside
    /// the thread (PJRT handles never cross threads).
    pub fn start<D, F>(factory: F, cfg: ServerConfig) -> Server
    where
        D: Decoder,
        F: FnOnce() -> Result<D> + Send + 'static,
    {
        let stream = cfg.stream;
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::spawn(move || runner(factory()?, rx, cfg));
        Server { tx, handle, next_id: AtomicU64::new(0), stream }
    }

    /// Submit a request; returns its [`TokenStream`] handle.  Tokens
    /// arrive per-step under the server's [`StreamPolicy`]; the
    /// terminal [`Response`] carries the [`Outcome`] and the complete
    /// token list.  Dropping the handle is a disconnect (the sequence
    /// cancels); call [`TokenStream::wait`] to consume to completion.
    pub fn submit(&self, spec: RequestSpec) -> TokenStream {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (sub, stream) = Submission::streaming(spec.into_request(id), self.stream);
        let _ = self.tx.send(Msg::Job(sub));
        stream
    }

    /// Drain outstanding work and stop the runner.  Every submission
    /// still in the system resolves with a terminal [`Response`] —
    /// pending and in-flight work completes; streams still stalled on
    /// an absent consumer are force-cancelled rather than holding the
    /// runner open forever.
    pub fn shutdown(self) -> Result<ServerStats> {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.join().map_err(|_| anyhow::anyhow!("runner thread panicked"))?
    }
}

fn runner<D: Decoder>(dec: D, rx: Receiver<Msg>, cfg: ServerConfig) -> Result<ServerStats> {
    let batch_wait = cfg.batch_wait;
    let max_batch = cfg.max_batch.max(1);
    let mut sched = Scheduler::new(dec, cfg);
    let mut shutdown = false;
    loop {
        if !sched.has_work() {
            if shutdown {
                break;
            }
            // block for the first job, then give near-simultaneous
            // submitters a short window to join before the first step
            match rx.recv() {
                Ok(Msg::Job(sub)) => sched.enqueue(sub),
                Ok(Msg::Shutdown) | Err(_) => break,
            }
            let deadline = Instant::now() + batch_wait;
            while sched.pending_len() < max_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(left) {
                    Ok(Msg::Job(sub)) => sched.enqueue(sub),
                    Ok(Msg::Shutdown) => {
                        shutdown = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
        } else {
            // pick up whatever arrived since the last step, non-blocking
            loop {
                match rx.try_recv() {
                    Ok(Msg::Job(sub)) => sched.enqueue(sub),
                    Ok(Msg::Shutdown) => shutdown = true,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
            if sched.only_stalled() {
                if shutdown {
                    // no consumer is coming to drain these
                    sched.abort_stalled();
                    continue;
                }
                // nothing can progress until a consumer drains; don't spin
                std::thread::sleep(Duration::from_micros(200));
            }
            sched.tick()?;
        }
    }
    Ok(sched.into_stats())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Recorder;

    /// Step-level mock: one output token per step (the prompt reversed),
    /// a fixed simulated `dt` per step, retiring when the echo completes.
    /// Carries an optional recorder mirroring the engine's pin-ledger
    /// emission idiom (PinSet at admit/resume, PinRelease at
    /// retire/suspend/cancel) so the trace conservation audits are
    /// meaningful at the scheduler level.
    struct Mock {
        dt: f64,
        clock: f64,
        next: u64,
        seqs: Vec<MockSeq>,
        peak_active: usize,
        rec: Recorder,
    }

    struct MockSeq {
        id: u64,
        out: Vec<usize>,
        produced: usize,
        admitted: f64,
        first: f64,
    }

    impl Mock {
        fn new(dt: f64) -> Mock {
            Mock { dt, clock: 0.0, next: 0, seqs: Vec::new(), peak_active: 0, rec: Recorder::off() }
        }
    }

    impl Decoder for Mock {
        fn admit(&mut self, prompt: &[usize], max_output: usize) -> Result<u64> {
            let id = self.next;
            self.next += 1;
            let out: Vec<usize> = prompt.iter().rev().copied().take(max_output.max(1)).collect();
            self.seqs.push(MockSeq { id, out, produced: 0, admitted: self.clock, first: 0.0 });
            self.peak_active = self.peak_active.max(self.seqs.len());
            self.rec.emit(self.clock, TraceEvent::RequestAdmit { seq: id });
            self.rec.emit(self.clock, TraceEvent::PinSet { owner: id });
            Ok(id)
        }

        fn step(&mut self) -> Result<Vec<SeqFinish>> {
            self.clock += self.dt;
            let now = self.clock;
            let mut done = Vec::new();
            let mut keep = Vec::new();
            for mut s in self.seqs.drain(..) {
                if s.produced == 0 {
                    s.first = now;
                }
                s.produced += 1;
                if s.produced >= s.out.len() {
                    self.rec.emit(
                        now,
                        TraceEvent::RequestRetire { seq: s.id, output_tokens: s.out.len() as u32 },
                    );
                    self.rec.emit(now, TraceEvent::PinRelease { owner: s.id });
                    done.push(SeqFinish {
                        seq: s.id,
                        tokens: s.out,
                        sim_admitted: s.admitted,
                        sim_first_token: s.first,
                        sim_finished: now,
                    });
                } else {
                    keep.push(s);
                }
            }
            self.seqs = keep;
            Ok(done)
        }

        fn active(&self) -> usize {
            self.seqs.len()
        }

        fn now(&self) -> f64 {
            self.clock
        }

        fn suspend(&mut self, seq: u64) -> Result<Box<dyn Any>> {
            let i = self
                .seqs
                .iter()
                .position(|s| s.id == seq)
                .ok_or_else(|| anyhow::anyhow!("unknown sequence {seq}"))?;
            self.rec.emit(self.clock, TraceEvent::Suspend { seq });
            self.rec.emit(self.clock, TraceEvent::PinRelease { owner: seq });
            Ok(Box::new(self.seqs.remove(i)))
        }

        fn resume(&mut self, state: Box<dyn Any>) -> Result<u64> {
            let s = state
                .downcast::<MockSeq>()
                .map_err(|_| anyhow::anyhow!("foreign suspended state"))?;
            let id = s.id;
            self.rec.emit(self.clock, TraceEvent::Resume { seq: id });
            self.rec.emit(self.clock, TraceEvent::PinSet { owner: id });
            self.seqs.push(*s);
            self.peak_active = self.peak_active.max(self.seqs.len());
            Ok(id)
        }

        fn cancel(&mut self, seq: u64) -> Result<Vec<usize>> {
            let i = self
                .seqs
                .iter()
                .position(|s| s.id == seq)
                .ok_or_else(|| anyhow::anyhow!("unknown sequence {seq}"))?;
            self.rec.emit(self.clock, TraceEvent::Cancel { seq });
            self.rec.emit(self.clock, TraceEvent::PinRelease { owner: seq });
            let s = self.seqs.remove(i);
            Ok(s.out[..s.produced.min(s.out.len())].to_vec())
        }

        fn peek_tokens(&self, seq: u64) -> Vec<usize> {
            self.seqs
                .iter()
                .find(|s| s.id == seq)
                .map(|s| s.out[..s.produced.min(s.out.len())].to_vec())
                .unwrap_or_default()
        }

        fn note(&mut self, ev: TraceEvent) {
            self.rec.emit(self.clock, ev);
        }

        fn set_tracing(&mut self, on: bool) {
            if on {
                if !self.rec.enabled() {
                    self.rec = Recorder::on(0, "mock");
                }
            } else {
                self.rec = Recorder::off();
            }
        }

        fn take_trace(&mut self) -> Option<Trace> {
            self.rec.take()
        }
    }

    fn cfg(max_batch: usize, scheduler: SchedulerMode) -> ServerConfig {
        ServerConfig::default()
            .with_max_batch(max_batch)
            .with_batch_wait(Duration::from_millis(50))
            .with_scheduler(scheduler)
    }

    /// The single submission helper (the old `submit`/`submit_prio`
    /// pair collapsed into one `RequestSpec` path).
    fn submit(s: &mut Scheduler<Mock>, id: u64, spec: RequestSpec) -> Receiver<Response> {
        let (sub, rx) = Submission::terminal(spec.into_request(id));
        s.enqueue(sub);
        rx
    }

    /// Streaming submission under `policy`.
    fn submit_stream(
        s: &mut Scheduler<Mock>,
        id: u64,
        spec: RequestSpec,
        policy: StreamPolicy,
    ) -> TokenStream {
        let (sub, stream) = Submission::streaming(spec.into_request(id), policy);
        s.enqueue(sub);
        stream
    }

    fn drain(s: &mut Scheduler<Mock>) {
        let mut guard = 0;
        while s.has_work() {
            s.tick().unwrap();
            guard += 1;
            assert!(guard < 10_000, "scheduler failed to drain");
        }
    }

    /// Three requests, two slots: A is long (8 tokens), B and C short
    /// (2 each).  Continuous batching re-admits C into the slot B frees
    /// at its early retirement, so the whole set drains in A's 8 steps.
    #[test]
    fn continuous_readmits_into_slots_freed_by_early_retirement() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
        let ra = submit(&mut s, 0, RequestSpec::new((0..8).collect()).max_output(8));
        let rb = submit(&mut s, 1, RequestSpec::new(vec![1, 2]).max_output(2));
        let rc = submit(&mut s, 2, RequestSpec::new(vec![3, 4]).max_output(2));
        drain(&mut s);
        let (a, b, c) = (ra.recv().unwrap(), rb.recv().unwrap(), rc.recv().unwrap());
        assert_eq!(a.outcome, Outcome::Completed);
        assert_eq!(a.tokens.len(), 8);
        assert_eq!(b.tokens, vec![2, 1]);
        assert_eq!(c.tokens, vec![4, 3]);
        // C joined while A was still in flight
        assert_eq!(c.batch_size, 2);
        assert_eq!(s.decoder().peak_active, 2);
        let stats = s.into_stats();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.steps, 8, "C must ride inside A's window, not after it");
        assert!(stats.mean_batch_size > 1.0);
    }

    /// Same workload under the static scheduler: the {A, B} batch runs to
    /// completion before C is admitted, costing 8 + 2 steps.
    #[test]
    fn static_runs_batches_to_completion() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Static));
        let _ra = submit(&mut s, 0, RequestSpec::new((0..8).collect()).max_output(8));
        let _rb = submit(&mut s, 1, RequestSpec::new(vec![1, 2]).max_output(2));
        let rc = submit(&mut s, 2, RequestSpec::new(vec![3, 4]).max_output(2));
        drain(&mut s);
        let c = rc.recv().unwrap();
        assert_eq!(c.batch_size, 1, "static mode admits C into a fresh batch");
        let stats = s.into_stats();
        assert_eq!(stats.steps, 10);
    }

    #[test]
    fn ttft_and_tpot_surface_in_stats() {
        let dt = 0.25;
        let mut s = Scheduler::new(Mock::new(dt), cfg(4, SchedulerMode::Continuous));
        let rxs: Vec<_> =
            (0..4).map(|i| submit(&mut s, i, RequestSpec::new(vec![1, 2, 3, 4]).max_output(4))).collect();
        drain(&mut s);
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!((r.sim_ttft - dt).abs() < 1e-12);
            assert!((r.sim_tpot - dt).abs() < 1e-12);
            assert!((r.sim_latency - 4.0 * dt).abs() < 1e-12);
        }
        let stats = s.into_stats();
        assert!((stats.ttft.p50 - dt).abs() < 1e-12);
        assert!((stats.tpot.p99 - dt).abs() < 1e-12);
        assert!((stats.total_sim_seconds - 4.0 * dt).abs() < 1e-12);
    }

    #[test]
    fn max_batch_bounds_slot_occupancy() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
        let rxs: Vec<_> = (0..5)
            .map(|i| submit(&mut s, i, RequestSpec::new(vec![i as usize, 9]).max_output(2)))
            .collect();
        drain(&mut s);
        for rx in rxs {
            assert!(rx.recv().unwrap().batch_size <= 2);
        }
        assert_eq!(s.decoder().peak_active, 2);
    }

    #[test]
    fn responses_match_requests_threaded() {
        let server = Server::start(|| Ok(Mock::new(0.5)), ServerConfig::default());
        let s1 = server.submit(RequestSpec::new(vec![1, 2, 3]).max_output(8));
        let s2 = server.submit(RequestSpec::new(vec![9, 8]).max_output(8));
        let (id1, id2) = (s1.id(), s2.id());
        let r1 = s1.wait().unwrap();
        let r2 = s2.wait().unwrap();
        assert_eq!(r1.tokens, vec![3, 2, 1]);
        assert_eq!(r2.tokens, vec![8, 9]);
        assert_eq!(r1.outcome, Outcome::Completed);
        assert_eq!((r1.id, r2.id), (id1, id2));
        assert_ne!(r1.id, r2.id);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.completed, 2);
        assert!(stats.queue_wait.p99 >= stats.queue_wait.p50);
    }

    #[test]
    fn batching_groups_concurrent_requests() {
        let cfg = ServerConfig::default()
            .with_max_batch(8)
            .with_batch_wait(Duration::from_millis(50))
            .with_max_output(8);
        let server = Server::start(|| Ok(Mock::new(0.5)), cfg);
        let streams: Vec<_> = (0..6)
            .map(|i| server.submit(RequestSpec::new(vec![i, i + 1]).max_output(4)))
            .collect();
        let responses: Vec<Response> =
            streams.into_iter().map(|st| st.wait().unwrap()).collect();
        assert!(responses.iter().any(|r| r.batch_size > 1));
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.mean_batch_size > 1.0, "requests should have shared steps");
    }

    #[test]
    fn shutdown_drains_pending() {
        let cfg = ServerConfig::default()
            .with_max_batch(64)
            .with_batch_wait(Duration::from_millis(200))
            .with_max_output(8);
        let server = Server::start(|| Ok(Mock::new(0.5)), cfg);
        let stream = server.submit(RequestSpec::new(vec![7]).max_output(4));
        let stats = server.shutdown().unwrap();
        let r = stream.wait().unwrap();
        assert_eq!(r.tokens, vec![7]);
        assert_eq!(r.outcome, Outcome::Completed);
        assert_eq!(stats.requests, 1);
        // decoders without the big-little fallback report a zero quality
        // proxy through the defaulted trait accessor
        assert_eq!(stats.degraded_token_frac, 0.0);
    }

    #[test]
    fn no_starvation_under_load() {
        for mode in [SchedulerMode::Static, SchedulerMode::Continuous] {
            let cfg = ServerConfig::default()
                .with_max_batch(3)
                .with_batch_wait(Duration::from_millis(1))
                .with_max_output(8)
                .with_scheduler(mode);
            let server = Server::start(|| Ok(Mock::new(0.01)), cfg);
            let streams: Vec<_> =
                (0..30).map(|i| server.submit(RequestSpec::new(vec![i]).max_output(4))).collect();
            let mut got = 0;
            for st in streams {
                if st.wait_timeout(Duration::from_secs(5)).is_ok() {
                    got += 1;
                }
            }
            assert_eq!(got, 30, "{mode:?}");
            server.shutdown().unwrap();
        }
    }

    // ------------------------------------------------- priority/preemption

    #[test]
    fn priority_parse_and_order() {
        assert_eq!(Priority::parse("high").unwrap(), Priority::High);
        assert_eq!(Priority::parse("normal").unwrap(), Priority::Normal);
        assert_eq!(Priority::parse("low").unwrap(), Priority::Low);
        assert!(Priority::parse("urgent").is_err());
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!(PreemptPolicy::parse("off").unwrap(), PreemptPolicy::Off);
        assert_eq!(PreemptPolicy::parse("0.5").unwrap(), PreemptPolicy::After(0.5));
        assert_eq!(PreemptPolicy::parse("0").unwrap().threshold(), Some(0.0));
        assert!(PreemptPolicy::parse("-1").is_err());
        assert!(PreemptPolicy::parse("NaN").is_err());
        assert!(PreemptPolicy::parse("soon").is_err());
    }

    /// With one slot and both requests queued before the first step, the
    /// High request is admitted first even though Low enqueued earlier.
    #[test]
    fn high_priority_admits_before_earlier_low() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(1, SchedulerMode::Continuous));
        let _rl = submit(&mut s, 0, RequestSpec::new(vec![1, 2]).max_output(2).priority(Priority::Low));
        let rh =
            submit(&mut s, 1, RequestSpec::new(vec![8, 9]).max_output(2).priority(Priority::High));
        s.tick().unwrap();
        assert_eq!(s.decoder().seqs.len(), 1);
        assert_eq!(s.decoder().seqs[0].out, vec![9, 8], "High must take the only slot");
        drain(&mut s);
        assert_eq!(rh.recv().unwrap().tokens, vec![9, 8]);
    }

    /// Full slots of long Low decodes: under `--preempt 2`, a High
    /// arrival's time to first token is bounded by the threshold plus a
    /// couple of steps; the preempted Low still completes bit-identically
    /// (its echo output is untouched) and reports its suspended time.
    #[test]
    fn preemption_bounds_high_wait_and_resumes_bit_identical() {
        let mut config = cfg(2, SchedulerMode::Continuous);
        config.preempt = PreemptPolicy::After(2.0);
        let mut s = Scheduler::new(Mock::new(1.0), config);
        let low_prompt: Vec<usize> = (0..50).collect();
        let low = |p: Vec<usize>| RequestSpec::new(p).max_output(50).priority(Priority::Low);
        let rl0 = submit(&mut s, 0, low(low_prompt.clone()));
        let rl1 = submit(&mut s, 1, low(low_prompt.clone()));
        s.tick().unwrap();
        s.tick().unwrap();
        let enqueued_at = s.decoder().now();
        let rh = submit(
            &mut s,
            2,
            RequestSpec::new(vec![5, 6, 7]).max_output(3).priority(Priority::High),
        );
        // drive until the High response lands; record the sim time
        let mut high_done_at = f64::NAN;
        let mut guard = 0;
        while s.has_work() {
            s.tick().unwrap();
            if high_done_at.is_nan() && rh.try_recv().is_ok() {
                high_done_at = s.decoder().now();
            }
            guard += 1;
            assert!(guard < 1000, "scheduler failed to drain");
        }
        // wait ≤ threshold + one step to detect + the 3 decode steps
        assert!(
            high_done_at <= enqueued_at + 2.0 + 1.0 + 3.0 + 1e-9,
            "high finished at {high_done_at}, enqueued at {enqueued_at}"
        );
        // the victim resumed and completed its full echo, bit-identical
        let (l0, l1) = (rl0.recv().unwrap(), rl1.recv().unwrap());
        let echo: Vec<usize> = low_prompt.iter().rev().copied().collect();
        assert_eq!(l0.tokens, echo);
        assert_eq!(l1.tokens, echo);
        let preempted: Vec<&Response> =
            [&l0, &l1].into_iter().filter(|r| r.preempted_wait > 0.0).collect();
        assert_eq!(preempted.len(), 1, "exactly one Low was suspended");
        let stats = s.into_stats();
        assert_eq!(stats.preemptions, 1);
        assert!(stats.preempted_wait.p99 > 0.0);
        // queue_wait (initial queueing, wallclock) stays split from the
        // suspended time — the preempted request's suspension shows up in
        // preempted_wait, not in queue_wait percentiles
        assert!(stats.queue_wait.p50 < 1.0, "wallclock queue wait is sub-second in tests");
    }

    /// The same scenario with preemption off: the High request cannot
    /// start until one of the 50-token Lows retires.
    #[test]
    fn preempt_off_high_waits_for_a_free_slot() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
        let low_prompt: Vec<usize> = (0..50).collect();
        let low = |p: Vec<usize>| RequestSpec::new(p).max_output(50).priority(Priority::Low);
        let _rl0 = submit(&mut s, 0, low(low_prompt.clone()));
        let _rl1 = submit(&mut s, 1, low(low_prompt));
        s.tick().unwrap();
        s.tick().unwrap();
        let rh = submit(
            &mut s,
            2,
            RequestSpec::new(vec![5, 6, 7]).max_output(3).priority(Priority::High),
        );
        let mut high_done_at = f64::NAN;
        let mut guard = 0;
        while s.has_work() {
            s.tick().unwrap();
            if high_done_at.is_nan() && rh.try_recv().is_ok() {
                high_done_at = s.decoder().now();
            }
            guard += 1;
            assert!(guard < 1000, "scheduler failed to drain");
        }
        assert!(
            high_done_at >= 50.0,
            "without preemption the High must wait out a Low: finished at {high_done_at}"
        );
        let stats = s.into_stats();
        assert_eq!(stats.preemptions, 0);
        assert_eq!(stats.preempted_wait.p99, 0.0);
    }

    /// Preemption suspends the *lowest* class first and never a peer of
    /// the waiter's own class.
    #[test]
    fn preemption_never_touches_equal_or_higher_class() {
        let mut config = cfg(1, SchedulerMode::Continuous);
        config.preempt = PreemptPolicy::After(0.0);
        let mut s = Scheduler::new(Mock::new(1.0), config);
        let rn = submit(&mut s, 0, RequestSpec::new((0..20).collect()).max_output(20));
        s.tick().unwrap();
        // a Normal waiter must NOT preempt the in-flight Normal sequence
        let _rn2 = submit(&mut s, 1, RequestSpec::new(vec![1, 2]).max_output(2));
        for _ in 0..5 {
            s.tick().unwrap();
        }
        assert_eq!(s.decoder().seqs.len(), 1);
        assert_eq!(s.decoder().seqs[0].out.len(), 20, "the long Normal kept its slot");
        drain(&mut s);
        assert_eq!(rn.recv().unwrap().tokens.len(), 20);
        assert_eq!(s.into_stats().preemptions, 0);
    }

    // ---------------------------------------------------------- streaming

    /// Cancel mid-decode: the slot frees and the pin ledger is empty
    /// within one step, the terminal is `Cancelled` with the partial
    /// tokens, and the trace replay proves zero leaked pins.
    #[test]
    fn cancel_mid_decode_frees_slot_and_pin_ledger() {
        let config = cfg(2, SchedulerMode::Continuous).with_trace(true);
        let mut s = Scheduler::new(Mock::new(1.0), config);
        let stream = submit_stream(
            &mut s,
            0,
            RequestSpec::new((0..20).collect()).max_output(20),
            StreamPolicy::default(),
        );
        let rb = submit(&mut s, 1, RequestSpec::new(vec![1, 2]).max_output(2));
        s.tick().unwrap();
        s.tick().unwrap();
        stream.cancel();
        s.tick().unwrap();
        assert_eq!(s.decoder().active(), 0, "cancel must free the slot within one step");
        let r = stream.wait().unwrap();
        assert_eq!(r.outcome, Outcome::Cancelled);
        assert!(!r.tokens.is_empty() && r.tokens.len() < 20, "partial tokens ride the terminal");
        assert_eq!(rb.recv().unwrap().outcome, Outcome::Completed);
        let stats = s.into_stats();
        assert_eq!((stats.completed, stats.cancelled, stats.requests), (1, 1, 2));
        let trace = stats.trace.expect("tracing was on");
        trace.audit_pins(0).expect("a cancelled sequence must leak zero pins");
    }

    /// Disconnect while queued: the request is never admitted, counts
    /// as cancelled-in-queue, and still resolves with a terminal.
    #[test]
    fn disconnect_while_queued_counts_cancelled_in_queue() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(1, SchedulerMode::Continuous));
        let ra = submit(&mut s, 0, RequestSpec::new((0..10).collect()).max_output(10));
        let stream = submit_stream(
            &mut s,
            1,
            RequestSpec::new(vec![1, 2, 3]).max_output(3),
            StreamPolicy::default(),
        );
        s.tick().unwrap();
        stream.cancel();
        s.tick().unwrap();
        drain(&mut s);
        assert_eq!(s.decoder().peak_active, 1, "the disconnected request was never admitted");
        assert_eq!(stream.wait().unwrap().outcome, Outcome::Cancelled);
        assert_eq!(ra.recv().unwrap().outcome, Outcome::Completed);
        let stats = s.into_stats();
        assert_eq!((stats.completed, stats.cancelled, stats.cancelled_in_queue), (1, 1, 1));
    }

    /// SLO-aware admission under synthetic overload: hopeless deadlines
    /// are rejected up front, so goodput (SLO-attaining tok/s) is
    /// strictly better than letting them complete late — and no fewer
    /// SLO-attaining tokens are produced.
    #[test]
    fn admission_rejects_hopeless_deadlines_and_protects_goodput() {
        let run = |admission: bool| {
            let config = cfg(1, SchedulerMode::Continuous)
                .with_max_output(5)
                .with_stream(StreamPolicy::default().with_admission(admission));
            let mut s = Scheduler::new(Mock::new(1.0), config);
            let warm = submit(&mut s, 0, RequestSpec::new((0..5).collect()).max_output(5));
            s.tick().unwrap();
            let rxs: Vec<_> = (1..=5)
                .map(|i| {
                    submit(
                        &mut s,
                        i,
                        RequestSpec::new((0..5).collect()).max_output(5).deadline(3.0),
                    )
                })
                .collect();
            drain(&mut s);
            assert_eq!(warm.recv().unwrap().outcome, Outcome::Completed);
            let expect = if admission { Outcome::Rejected } else { Outcome::Completed };
            for rx in rxs {
                assert_eq!(rx.recv().unwrap().outcome, expect);
            }
            s.into_stats()
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.rejected, 0);
        assert_eq!(on.rejected, 5);
        assert!(on.goodput_tokens >= off.goodput_tokens);
        assert!(
            on.goodput() > off.goodput(),
            "admission on {} must beat off {}",
            on.goodput(),
            off.goodput()
        );
    }

    /// Every submission resolves with exactly one terminal outcome —
    /// completed, cancelled (mid-decode and in-queue), and rejected all
    /// at once; no receiver is silently dropped.
    #[test]
    fn every_submission_resolves_with_a_terminal_outcome() {
        let config = cfg(1, SchedulerMode::Continuous)
            .with_max_output(4)
            .with_stream(StreamPolicy::default().with_admission(true));
        let mut s = Scheduler::new(Mock::new(1.0), config);
        let completed = submit(&mut s, 0, RequestSpec::new(vec![1, 2, 3, 4]).max_output(4));
        s.tick().unwrap();
        let rejected =
            submit(&mut s, 1, RequestSpec::new(vec![1, 2, 3]).max_output(4).deadline(1e-6));
        let cancelled = submit_stream(
            &mut s,
            2,
            RequestSpec::new((0..8).collect()).max_output(8),
            StreamPolicy::default(),
        );
        let queue_dropped = submit_stream(
            &mut s,
            3,
            RequestSpec::new(vec![5]).max_output(2),
            StreamPolicy::default(),
        );
        queue_dropped.cancel();
        for _ in 0..6 {
            s.tick().unwrap();
        }
        cancelled.cancel();
        drain(&mut s);
        assert_eq!(completed.recv().unwrap().outcome, Outcome::Completed);
        assert_eq!(rejected.recv().unwrap().outcome, Outcome::Rejected);
        assert_eq!(cancelled.wait().unwrap().outcome, Outcome::Cancelled);
        assert_eq!(queue_dropped.wait().unwrap().outcome, Outcome::Cancelled);
        let stats = s.into_stats();
        assert_eq!(stats.requests, 4);
        assert_eq!(
            (stats.completed, stats.cancelled, stats.cancelled_in_queue, stats.rejected),
            (1, 2, 1, 1)
        );
    }

    /// Backpressure: a bounded channel whose consumer stops reading
    /// suspends the sequence at a step boundary; draining the channel
    /// flushes the backlog, resumes the sequence, and it completes with
    /// its full token list.  Pins balance throughout.
    #[test]
    fn bounded_stream_backpressures_then_resumes() {
        let policy = StreamPolicy::default().with_buffer(2);
        let config = cfg(2, SchedulerMode::Continuous).with_trace(true).with_stream(policy);
        let mut s = Scheduler::new(Mock::new(1.0), config);
        let stream =
            submit_stream(&mut s, 0, RequestSpec::new((0..10).collect()).max_output(10), policy);
        // nobody consumes: two tokens fill the channel, the third stalls
        for _ in 0..5 {
            s.tick().unwrap();
        }
        assert_eq!(s.decoder().active(), 0, "the stalled sequence left its slot");
        // now consume: backlog flushes and the sequence resumes
        let mut got = Vec::new();
        let mut guard = 0;
        let resp = loop {
            while let Some(t) = stream.poll_token() {
                got.push(t);
            }
            if let Some(r) = stream.poll_response() {
                while let Some(t) = stream.poll_token() {
                    got.push(t);
                }
                break r;
            }
            s.tick().unwrap();
            guard += 1;
            assert!(guard < 100, "stalled stream never completed");
        };
        assert_eq!(resp.outcome, Outcome::Completed);
        assert_eq!(resp.tokens.len(), 10);
        assert_eq!(&resp.tokens[..got.len()], &got[..], "streamed tokens are an in-order prefix");
        let stats = s.into_stats();
        assert!(stats.stream_stalls >= 1);
        assert_eq!(stats.completed, 1);
        let trace = stats.trace.expect("tracing was on");
        trace.audit_pins(0).expect("stall/resume cycles must leak zero pins");
    }

    /// With every streaming knob off, attaching stream handles does not
    /// perturb the decode: tokens, step count, and the simulated clock
    /// are bit-identical to terminal-only submissions.
    #[test]
    fn streaming_handles_do_not_perturb_decode() {
        let run = |streaming: bool| {
            let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
            let mut terminals = Vec::new();
            let mut streams = Vec::new();
            for i in 0..6u64 {
                let spec = RequestSpec::new(vec![i as usize, 9, 7]).max_output(3);
                if streaming {
                    streams.push(submit_stream(&mut s, i, spec, StreamPolicy::default()));
                } else {
                    terminals.push(submit(&mut s, i, spec));
                }
            }
            drain(&mut s);
            let toks: Vec<Vec<usize>> = if streaming {
                streams.into_iter().map(|st| st.wait().unwrap().tokens).collect()
            } else {
                terminals.into_iter().map(|rx| rx.recv().unwrap().tokens).collect()
            };
            let stats = s.into_stats();
            (toks, stats.steps, stats.total_sim_seconds.to_bits())
        };
        assert_eq!(run(false), run(true));
    }
}
