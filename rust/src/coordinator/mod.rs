//! Serving coordinator: request queue → step-level continuous scheduler.
//!
//! The PJRT handles inside the engine are not `Send`, so the coordinator
//! follows the single-runner design (as in vLLM's engine loop): client
//! threads submit requests over an mpsc channel; one runner thread owns
//! the model (constructed *inside* the thread by a `Send` factory) and
//! drives a [`Scheduler`].  At every token step the scheduler admits
//! queued requests into free decode slots (up to `max_batch`), advances
//! all in-flight sequences through the step-level [`Decoder`] — decodes
//! by exactly one token, prompts still in prefill by up to
//! [`ServerConfig::prefill_chunk`] prompt tokens piggybacked on the same
//! step (Sarathi-style chunked prefill, so a long prompt can never stall
//! a live decode's next token) — and retires sequences the moment they
//! hit EOS, so a long sequence never holds finished slots hostage and
//! freed slots re-admit immediately.  [`SchedulerMode::Static`] recovers
//! the legacy drain-batch-then-decode-to-completion behaviour for
//! comparison (`--scheduler static|continuous` on the CLI).
//!
//! Scheduling is *priority-aware* end to end: every [`Request`] carries a
//! [`Priority`] (Low/Normal/High), pending requests queue per class and
//! admit highest-class-first, and under a [`PreemptPolicy`] a request
//! that has waited longer than the policy threshold may *preempt* the
//! lowest-priority in-flight sequence at a step boundary — the decoder
//! detaches its state ([`Decoder::suspend`]), the slot re-admits the
//! waiter, and the victim reattaches later ([`Decoder::resume`]) with
//! bit-identical continuation.  Time a sequence spends suspended is
//! reported separately from initial queueing
//! ([`ServerStats::preempted_wait`] vs [`ServerStats::queue_wait`]), so
//! preemption cost is visible rather than laundered into queue time.

pub mod workload;

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::Percentiles;
use crate::pcie::TransferStats;
use crate::trace::Trace;

/// Request priority class.  Ordered: `Low < Normal < High` — the
/// scheduler admits pending requests highest class first, and under a
/// [`PreemptPolicy`] a waiter may suspend an in-flight sequence of a
/// *strictly lower* class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    Normal,
    High,
}

impl Priority {
    /// All classes, lowest first (`ALL.iter().rev()` is admission order).
    pub const ALL: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];

    pub fn parse(s: &str) -> Result<Priority> {
        Ok(match s {
            "low" => Priority::Low,
            "normal" => Priority::Normal,
            "high" => Priority::High,
            _ => anyhow::bail!("unknown priority {s:?} (low|normal|high)"),
        })
    }

    /// Dense index for per-class storage (`Low = 0 … High = 2`).
    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// When a waiting request may preempt an in-flight sequence.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PreemptPolicy {
    /// Never preempt: priority only reorders admission.
    #[default]
    Off,
    /// Preempt once a strictly-higher-priority request has waited more
    /// than this many *simulated* seconds for a slot.  `0.0` preempts as
    /// soon as a higher-priority request finds every slot occupied.
    After(f64),
}

impl PreemptPolicy {
    /// `--preempt off` or `--preempt <seconds>`.
    pub fn parse(s: &str) -> Result<PreemptPolicy> {
        if s == "off" {
            return Ok(PreemptPolicy::Off);
        }
        let t: f64 = s.parse().map_err(|e| anyhow::anyhow!("--preempt {s:?}: {e}"))?;
        if !t.is_finite() || t < 0.0 {
            anyhow::bail!("preempt threshold must be a finite non-negative number, got {s:?}");
        }
        Ok(PreemptPolicy::After(t))
    }

    /// The wait threshold, or `None` when preemption is off.
    pub fn threshold(self) -> Option<f64> {
        match self {
            PreemptPolicy::Off => None,
            PreemptPolicy::After(t) => Some(t),
        }
    }
}

/// One retired sequence, in the decoder's simulated timeline.
#[derive(Debug, Clone)]
pub struct SeqFinish {
    pub seq: u64,
    pub tokens: Vec<usize>,
    /// Simulated time the sequence was admitted into a decode slot.
    pub sim_admitted: f64,
    /// Simulated time its first output token landed.
    pub sim_first_token: f64,
    /// Simulated time it retired (EOS or token budget).
    pub sim_finished: f64,
}

impl SeqFinish {
    /// Time-to-first-token from admission (simulated seconds).
    pub fn ttft(&self) -> f64 {
        (self.sim_first_token - self.sim_admitted).max(0.0)
    }

    /// Time per output token after the first (simulated seconds).
    pub fn tpot(&self) -> f64 {
        if self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.sim_finished - self.sim_first_token).max(0.0) / (self.tokens.len() - 1) as f64
    }

    /// Admission-to-retirement latency (simulated seconds).
    pub fn latency(&self) -> f64 {
        (self.sim_finished - self.sim_admitted).max(0.0)
    }
}

/// A resumable, step-granular decoder.  Sequences are admitted into
/// decode slots (possibly mid-flight, while others are decoding) and all
/// in-flight sequences advance one token per [`Decoder::step`] call.
/// Implementors: the engine's `DecodeSession` wrappers, the cluster's
/// analytic replicas, and the mocks in the scheduler tests.
pub trait Decoder {
    /// Admit a sequence into the in-flight set; returns its handle.
    fn admit(&mut self, prompt: &[usize], max_output: usize) -> Result<u64>;
    /// Advance every in-flight sequence one step: decodes emit exactly
    /// one token, prefilling sequences consume up to the configured
    /// prefill chunk of prompt tokens.  Sequences hitting EOS or their
    /// budget retire immediately and are returned — their slots are free
    /// before the next step.
    fn step(&mut self) -> Result<Vec<SeqFinish>>;
    /// Number of in-flight sequences.
    fn active(&self) -> usize;
    /// Current simulated time (seconds).
    fn now(&self) -> f64;
    /// Per-step prompt-token budget for prefilling sequences (chunked
    /// prefill).  The scheduler sets this once from
    /// [`ServerConfig::prefill_chunk`]; decoders without a prefill
    /// concept may ignore it (the default does).
    fn set_prefill_chunk(&mut self, _chunk: usize) {}
    /// PCIe transfer accounting snapshot (stall vs overlapped split, see
    /// `pcie`).  Decoders without a transfer model return the default
    /// zeros.
    fn transfer_stats(&self) -> TransferStats {
        TransferStats::default()
    }
    /// Detach an in-flight sequence's state at a step boundary so its
    /// slot frees (priority preemption).  The returned opaque state is
    /// handed back verbatim to [`Decoder::resume`]; the sequence must
    /// continue bit-identically from where it stopped.  Decoders without
    /// suspension support refuse (the scheduler only calls this under an
    /// active [`PreemptPolicy`]).
    fn suspend(&mut self, _seq: u64) -> Result<Box<dyn Any>> {
        anyhow::bail!("this decoder does not support preemption")
    }
    /// Reattach a sequence detached by [`Decoder::suspend`] into a free
    /// slot, returning its original handle.
    fn resume(&mut self, _state: Box<dyn Any>) -> Result<u64> {
        anyhow::bail!("this decoder does not support preemption")
    }
    /// Enable or disable structured event tracing (see `trace`).  The
    /// scheduler sets this once from [`ServerConfig::trace`]; decoders
    /// without a recorder ignore it (the default does).
    fn set_tracing(&mut self, _on: bool) {}
    /// Drain the recorded event stream at shutdown, or `None` when the
    /// decoder never traced.
    fn take_trace(&mut self) -> Option<Trace> {
        None
    }
    /// Fraction of routed (token, expert) assignments the big-little
    /// fallback served from a degraded low-bit little copy (quality
    /// proxy; see `quant`).  Decoders without the fallback report 0.0.
    fn degraded_token_frac(&self) -> f64 {
        0.0
    }
}

/// How the scheduler fills decode slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// Drain a batch from the queue, decode it to completion, repeat.
    /// Finished slots idle until the whole batch retires (the legacy
    /// run-to-completion loop; the Fig. 5 batching convention).
    Static,
    /// Admit from the queue into free slots at *every* token step and
    /// retire sequences at EOS immediately (vLLM-style continuous
    /// batching).  Under MELINOE's fine-tuned routing this also keeps the
    /// LFU cache warm: admitted same-task requests reuse the experts the
    /// in-flight batch already pinned.
    Continuous,
}

impl SchedulerMode {
    pub fn parse(s: &str) -> Result<SchedulerMode> {
        Ok(match s {
            "static" => SchedulerMode::Static,
            "continuous" => SchedulerMode::Continuous,
            _ => anyhow::bail!("unknown scheduler {s:?} (static|continuous)"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_output: usize,
    pub priority: Priority,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Wallclock seconds between submission and *first* slot admission
    /// (initial queueing only — time spent suspended after a preemption
    /// is reported separately in `preempted_wait`).
    pub queue_wait: f64,
    /// Simulated seconds spent suspended after preemptions (0.0 for a
    /// request that was never preempted).
    pub preempted_wait: f64,
    /// Simulated seconds from admission to retirement.
    pub sim_latency: f64,
    /// Simulated time-to-first-token (from admission).
    pub sim_ttft: f64,
    /// Simulated time per output token after the first.
    pub sim_tpot: f64,
    /// In-flight sequences (this one included) when it was admitted.
    pub batch_size: usize,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// Straggler window: when the scheduler is idle and the first request
    /// arrives, wait this long for near-simultaneous submitters before
    /// the first token step.
    pub batch_wait: Duration,
    /// Default output budget (callers may override per request).
    pub max_output: usize,
    pub scheduler: SchedulerMode,
    /// Per-step token budget for prompt prefill (`--prefill-chunk`): a
    /// sequence still in prefill consumes up to this many prompt tokens
    /// per scheduler tick, piggybacked on the same step that advances
    /// every in-flight decode by exactly one token — so a long prompt
    /// shortens its own TTFT by `~chunk×` without ever stalling live
    /// decodes.  1 (the default) recovers token-at-a-time prefill.
    pub prefill_chunk: usize,
    /// When a waiting higher-priority request may preempt an in-flight
    /// sequence (`--preempt`).  Only meaningful under
    /// [`SchedulerMode::Continuous`] — static batches cannot re-admit a
    /// freed slot mid-batch, so preemption is gated off there.
    pub preempt: PreemptPolicy,
    /// Record the structured sim-time event stream (`--trace`): the
    /// scheduler enables the decoder's recorder at construction and
    /// surfaces the drained [`Trace`] in [`ServerStats::trace`].
    pub trace: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 4,
            batch_wait: Duration::from_millis(2),
            max_output: 32,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
            preempt: PreemptPolicy::Off,
            trace: false,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: u64,
    /// Token steps the scheduler executed.
    pub steps: u64,
    /// Prefill chunk the scheduler ran with (1 = token-at-a-time).
    pub prefill_chunk: usize,
    pub total_output_tokens: u64,
    /// Decoder simulated clock at shutdown.
    pub total_sim_seconds: f64,
    /// Mean in-flight sequences per executed step (slot occupancy).
    pub mean_batch_size: f64,
    /// Sequences suspended out of their slot by a higher-priority waiter.
    pub preemptions: u64,
    /// p50/p95/p99 of per-request wallclock *initial* queue wait
    /// (seconds) — submission to first admission only.
    pub queue_wait: Percentiles,
    /// p50/p95/p99 of per-request simulated seconds spent suspended
    /// after preemptions (0 everywhere when preemption never fired).
    /// Split out from `queue_wait` so preemption cost is visible.
    pub preempted_wait: Percentiles,
    /// p50/p95/p99 of per-request simulated admission→finish latency.
    pub sim_latency: Percentiles,
    /// p50/p95/p99 of simulated time-to-first-token.
    pub ttft: Percentiles,
    /// p50/p95/p99 of simulated time-per-output-token.
    pub tpot: Percentiles,
    /// Decode time lost stalled on expert transfers (demand stalls plus
    /// residual waits on caught in-flight prefetches).
    pub pcie_stall_seconds: f64,
    /// Transfer time hidden behind compute (admit + lookahead prefetch).
    pub pcie_overlapped_seconds: f64,
    /// `overlapped / (overlapped + stalled)` — the overlap fraction.
    pub pcie_overlap_fraction: f64,
    /// Fraction of routed (token, expert) assignments served degraded by
    /// the big-little fallback (0.0 when the fallback is off; in [0, 1]).
    pub degraded_token_frac: f64,
    /// The decoder's drained event stream when [`ServerConfig::trace`]
    /// was set (and the decoder supports recording), else `None`.
    pub trace: Option<Trace>,
}

struct Job {
    req: Request,
    tx: Sender<Response>,
    submitted: Instant,
    /// Decoder sim time at enqueue (preemption thresholds are measured
    /// on the simulated clock, so tests stay deterministic).
    enqueued_sim: f64,
    /// Set at admission: wallclock queue wait and slot occupancy.
    queue_wait: f64,
    batch_at_admit: usize,
    /// Total simulated seconds spent suspended after preemptions.
    preempted_wait: f64,
    /// Sim time of the latest suspension (while in the suspended store).
    suspended_at: f64,
    /// Sim time of the *first* admission — preemption victims are the
    /// most recently (first-)admitted among the lowest class, i.e. the
    /// least-progressed sequence; resume does not reset it, so a
    /// just-resumed sequence cannot become the permanent victim.
    admitted_sim: f64,
}

/// The step-level scheduling core, independent of threads and channels:
/// the runner thread drives it from the mpsc queue; unit tests drive it
/// synchronously against a mock decoder.
pub struct Scheduler<D: Decoder> {
    dec: D,
    cfg: ServerConfig,
    /// Pending jobs, one FIFO queue per [`Priority`] class.
    pending: [VecDeque<Job>; 3],
    inflight: HashMap<u64, Job>,
    /// Preempted sequences waiting to reattach: (decoder handle, job,
    /// opaque suspended state), in suspension order.
    suspended: Vec<(u64, Job, Box<dyn Any>)>,
    stats: ServerStats,
    batch_sizes: Vec<usize>,
    queue_waits: Vec<f64>,
    preempted_waits: Vec<f64>,
    sim_latencies: Vec<f64>,
    ttfts: Vec<f64>,
    tpots: Vec<f64>,
}

impl<D: Decoder> Scheduler<D> {
    pub fn new(mut dec: D, cfg: ServerConfig) -> Scheduler<D> {
        dec.set_prefill_chunk(cfg.prefill_chunk.max(1));
        if cfg.trace {
            dec.set_tracing(true);
        }
        Scheduler {
            dec,
            cfg,
            pending: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            inflight: HashMap::new(),
            suspended: Vec::new(),
            stats: ServerStats::default(),
            batch_sizes: Vec::new(),
            queue_waits: Vec::new(),
            preempted_waits: Vec::new(),
            sim_latencies: Vec::new(),
            ttfts: Vec::new(),
            tpots: Vec::new(),
        }
    }

    pub fn enqueue(&mut self, req: Request, tx: Sender<Response>, submitted: Instant) {
        let enqueued_sim = self.dec.now();
        self.pending[req.priority.idx()].push_back(Job {
            req,
            tx,
            submitted,
            enqueued_sim,
            queue_wait: 0.0,
            batch_at_admit: 0,
            preempted_wait: 0.0,
            suspended_at: 0.0,
            admitted_sim: 0.0,
        });
    }

    pub fn has_work(&self) -> bool {
        self.pending.iter().any(|q| !q.is_empty())
            || !self.suspended.is_empty()
            || self.dec.active() > 0
    }

    pub fn pending_len(&self) -> usize {
        self.pending.iter().map(|q| q.len()).sum()
    }

    pub fn decoder(&self) -> &D {
        &self.dec
    }

    /// Preempt if allowed, admit what the mode allows, then advance one
    /// token step.
    pub fn tick(&mut self) -> Result<()> {
        self.maybe_preempt()?;
        self.admit()?;
        if self.dec.active() == 0 {
            return Ok(());
        }
        self.batch_sizes.push(self.dec.active());
        self.stats.steps += 1;
        for fin in self.dec.step()? {
            self.retire(fin);
        }
        Ok(())
    }

    /// Under [`PreemptPolicy::After`], suspend the lowest-priority (most
    /// recently admitted) in-flight sequence for every pending request of
    /// a strictly higher class that has out-waited the threshold on the
    /// simulated clock.  Continuous mode only: a static batch cannot
    /// re-admit the freed slot until it drains, so suspension would only
    /// idle it.
    fn maybe_preempt(&mut self) -> Result<()> {
        let Some(thresh) = self.cfg.preempt.threshold() else { return Ok(()) };
        if self.cfg.scheduler != SchedulerMode::Continuous {
            return Ok(());
        }
        let max_batch = self.cfg.max_batch.max(1);
        let now = self.dec.now();
        for p in [Priority::High, Priority::Normal] {
            loop {
                if self.dec.active() < max_batch {
                    // a slot is already free: admission handles the waiter
                    return Ok(());
                }
                let waited = match self.pending[p.idx()].front() {
                    Some(job) => now - job.enqueued_sim,
                    None => break,
                };
                if waited <= thresh {
                    break;
                }
                // lowest class first, then latest first admission, then
                // highest handle — the id tiebreak keeps victim choice
                // deterministic across runs (HashMap iteration is not)
                let victim = self
                    .inflight
                    .iter()
                    .filter(|(_, j)| j.req.priority < p)
                    .min_by(|a, b| {
                        a.1.req
                            .priority
                            .cmp(&b.1.req.priority)
                            .then(b.1.admitted_sim.total_cmp(&a.1.admitted_sim))
                            .then(b.0.cmp(a.0))
                    })
                    .map(|(id, _)| *id);
                let Some(vid) = victim else { break };
                let state = self.dec.suspend(vid)?;
                let mut job = self.inflight.remove(&vid).expect("victim is in flight");
                job.suspended_at = now;
                self.stats.preemptions += 1;
                self.suspended.push((vid, job, state));
            }
        }
        Ok(())
    }

    /// Admission order: highest priority class first; within a class,
    /// preempted sequences reattach (in suspension order) before new
    /// requests admit — they have already made progress and hold KV state.
    fn admit(&mut self) -> Result<()> {
        let open = match self.cfg.scheduler {
            SchedulerMode::Continuous => true,
            SchedulerMode::Static => self.dec.active() == 0,
        };
        if !open {
            return Ok(());
        }
        let max_batch = self.cfg.max_batch.max(1);
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            while self.dec.active() < max_batch {
                let pos = self.suspended.iter().position(|(_, j, _)| j.req.priority == p);
                let Some(i) = pos else { break };
                let (seq, mut job, state) = self.suspended.remove(i);
                let id = self.dec.resume(state)?;
                debug_assert_eq!(id, seq, "resume must keep the sequence handle");
                job.preempted_wait += self.dec.now() - job.suspended_at;
                // admitted_sim keeps the *first* admission time: victim
                // selection targets the least-progressed sequence, and a
                // just-resumed one must not become the permanent victim
                // (this also matches the replica's `started` semantics)
                self.inflight.insert(id, job);
            }
            while self.dec.active() < max_batch {
                let Some(mut job) = self.pending[p.idx()].pop_front() else { break };
                let id = self.dec.admit(&job.req.prompt, job.req.max_output)?;
                job.queue_wait = job.submitted.elapsed().as_secs_f64();
                job.batch_at_admit = self.dec.active();
                job.admitted_sim = self.dec.now();
                self.queue_waits.push(job.queue_wait);
                self.inflight.insert(id, job);
            }
        }
        Ok(())
    }

    fn retire(&mut self, fin: SeqFinish) {
        let Some(job) = self.inflight.remove(&fin.seq) else { return };
        let (latency, ttft, tpot) = (fin.latency(), fin.ttft(), fin.tpot());
        self.stats.requests += 1;
        self.stats.total_output_tokens += fin.tokens.len() as u64;
        self.sim_latencies.push(latency);
        self.ttfts.push(ttft);
        self.tpots.push(tpot);
        self.preempted_waits.push(job.preempted_wait);
        let _ = job.tx.send(Response {
            id: job.req.id,
            tokens: fin.tokens,
            queue_wait: job.queue_wait,
            preempted_wait: job.preempted_wait,
            sim_latency: latency,
            sim_ttft: ttft,
            sim_tpot: tpot,
            batch_size: job.batch_at_admit,
        });
    }

    pub fn into_stats(mut self) -> ServerStats {
        self.stats.prefill_chunk = self.cfg.prefill_chunk.max(1);
        self.stats.total_sim_seconds = self.dec.now();
        let ts = self.dec.transfer_stats();
        self.stats.pcie_stall_seconds = ts.stall_time;
        self.stats.pcie_overlapped_seconds = ts.overlapped_time;
        self.stats.pcie_overlap_fraction = ts.overlap_fraction();
        self.stats.degraded_token_frac = self.dec.degraded_token_frac();
        self.stats.trace = self.dec.take_trace();
        if !self.batch_sizes.is_empty() {
            self.stats.mean_batch_size =
                self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64;
        }
        self.stats.queue_wait = Percentiles::of(&self.queue_waits);
        self.stats.preempted_wait = Percentiles::of(&self.preempted_waits);
        self.stats.sim_latency = Percentiles::of(&self.sim_latencies);
        self.stats.ttft = Percentiles::of(&self.ttfts);
        self.stats.tpot = Percentiles::of(&self.tpots);
        self.stats
    }
}

enum Msg {
    Job(Request, Sender<Response>, Instant),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    handle: JoinHandle<Result<ServerStats>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the runner thread.  `factory` constructs the decoder inside
    /// the thread (PJRT handles never cross threads).
    pub fn start<D, F>(factory: F, cfg: ServerConfig) -> Server
    where
        D: Decoder,
        F: FnOnce() -> Result<D> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::spawn(move || runner(factory()?, rx, cfg));
        Server { tx, handle, next_id: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Submit a Normal-priority request; returns the response channel.
    pub fn submit(&self, prompt: Vec<usize>, max_output: usize) -> Receiver<Response> {
        self.submit_prio(prompt, max_output, Priority::Normal)
    }

    /// Submit a request with an explicit [`Priority`].
    pub fn submit_prio(
        &self,
        prompt: Vec<usize>,
        max_output: usize,
        priority: Priority,
    ) -> Receiver<Response> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = channel();
        let req = Request { id, prompt, max_output, priority };
        let _ = self.tx.send(Msg::Job(req, rtx, Instant::now()));
        rrx
    }

    /// Drain outstanding work and stop the runner.
    pub fn shutdown(self) -> Result<ServerStats> {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.join().map_err(|_| anyhow::anyhow!("runner thread panicked"))?
    }
}

fn runner<D: Decoder>(dec: D, rx: Receiver<Msg>, cfg: ServerConfig) -> Result<ServerStats> {
    let batch_wait = cfg.batch_wait;
    let max_batch = cfg.max_batch.max(1);
    let mut sched = Scheduler::new(dec, cfg);
    let mut shutdown = false;
    loop {
        if !sched.has_work() {
            if shutdown {
                break;
            }
            // block for the first job, then give near-simultaneous
            // submitters a short window to join before the first step
            match rx.recv() {
                Ok(Msg::Job(r, tx, t)) => sched.enqueue(r, tx, t),
                Ok(Msg::Shutdown) | Err(_) => break,
            }
            let deadline = Instant::now() + batch_wait;
            while sched.pending_len() < max_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(left) {
                    Ok(Msg::Job(r, tx, t)) => sched.enqueue(r, tx, t),
                    Ok(Msg::Shutdown) => {
                        shutdown = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
        } else {
            // pick up whatever arrived since the last step, non-blocking
            loop {
                match rx.try_recv() {
                    Ok(Msg::Job(r, tx, t)) => sched.enqueue(r, tx, t),
                    Ok(Msg::Shutdown) => shutdown = true,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
            sched.tick()?;
        }
    }
    Ok(sched.into_stats())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Step-level mock: one output token per step (the prompt reversed),
    /// a fixed simulated `dt` per step, retiring when the echo completes.
    struct Mock {
        dt: f64,
        clock: f64,
        next: u64,
        seqs: Vec<MockSeq>,
        peak_active: usize,
    }

    struct MockSeq {
        id: u64,
        out: Vec<usize>,
        produced: usize,
        admitted: f64,
        first: f64,
    }

    impl Mock {
        fn new(dt: f64) -> Mock {
            Mock { dt, clock: 0.0, next: 0, seqs: Vec::new(), peak_active: 0 }
        }
    }

    impl Decoder for Mock {
        fn admit(&mut self, prompt: &[usize], max_output: usize) -> Result<u64> {
            let id = self.next;
            self.next += 1;
            let out: Vec<usize> = prompt.iter().rev().copied().take(max_output.max(1)).collect();
            self.seqs.push(MockSeq { id, out, produced: 0, admitted: self.clock, first: 0.0 });
            self.peak_active = self.peak_active.max(self.seqs.len());
            Ok(id)
        }

        fn step(&mut self) -> Result<Vec<SeqFinish>> {
            self.clock += self.dt;
            let now = self.clock;
            let mut done = Vec::new();
            let mut keep = Vec::new();
            for mut s in self.seqs.drain(..) {
                if s.produced == 0 {
                    s.first = now;
                }
                s.produced += 1;
                if s.produced >= s.out.len() {
                    done.push(SeqFinish {
                        seq: s.id,
                        tokens: s.out,
                        sim_admitted: s.admitted,
                        sim_first_token: s.first,
                        sim_finished: now,
                    });
                } else {
                    keep.push(s);
                }
            }
            self.seqs = keep;
            Ok(done)
        }

        fn active(&self) -> usize {
            self.seqs.len()
        }

        fn now(&self) -> f64 {
            self.clock
        }

        fn suspend(&mut self, seq: u64) -> Result<Box<dyn Any>> {
            let i = self
                .seqs
                .iter()
                .position(|s| s.id == seq)
                .ok_or_else(|| anyhow::anyhow!("unknown sequence {seq}"))?;
            Ok(Box::new(self.seqs.remove(i)))
        }

        fn resume(&mut self, state: Box<dyn Any>) -> Result<u64> {
            let s = state
                .downcast::<MockSeq>()
                .map_err(|_| anyhow::anyhow!("foreign suspended state"))?;
            let id = s.id;
            self.seqs.push(*s);
            self.peak_active = self.peak_active.max(self.seqs.len());
            Ok(id)
        }
    }

    fn cfg(max_batch: usize, scheduler: SchedulerMode) -> ServerConfig {
        ServerConfig {
            max_batch,
            batch_wait: Duration::from_millis(50),
            max_output: 32,
            scheduler,
            prefill_chunk: 1,
            preempt: PreemptPolicy::Off,
            trace: false,
        }
    }

    fn submit(
        s: &mut Scheduler<Mock>,
        id: u64,
        prompt: Vec<usize>,
        max_output: usize,
    ) -> Receiver<Response> {
        submit_prio(s, id, prompt, max_output, Priority::Normal)
    }

    fn submit_prio(
        s: &mut Scheduler<Mock>,
        id: u64,
        prompt: Vec<usize>,
        max_output: usize,
        priority: Priority,
    ) -> Receiver<Response> {
        let (tx, rx) = channel();
        s.enqueue(Request { id, prompt, max_output, priority }, tx, Instant::now());
        rx
    }

    fn drain(s: &mut Scheduler<Mock>) {
        let mut guard = 0;
        while s.has_work() {
            s.tick().unwrap();
            guard += 1;
            assert!(guard < 10_000, "scheduler failed to drain");
        }
    }

    /// Three requests, two slots: A is long (8 tokens), B and C short
    /// (2 each).  Continuous batching re-admits C into the slot B frees
    /// at its early retirement, so the whole set drains in A's 8 steps.
    #[test]
    fn continuous_readmits_into_slots_freed_by_early_retirement() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
        let ra = submit(&mut s, 0, (0..8).collect(), 8);
        let rb = submit(&mut s, 1, vec![1, 2], 2);
        let rc = submit(&mut s, 2, vec![3, 4], 2);
        drain(&mut s);
        let (a, b, c) = (ra.recv().unwrap(), rb.recv().unwrap(), rc.recv().unwrap());
        assert_eq!(a.tokens.len(), 8);
        assert_eq!(b.tokens, vec![2, 1]);
        assert_eq!(c.tokens, vec![4, 3]);
        // C joined while A was still in flight
        assert_eq!(c.batch_size, 2);
        assert_eq!(s.decoder().peak_active, 2);
        let stats = s.into_stats();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.steps, 8, "C must ride inside A's window, not after it");
        assert!(stats.mean_batch_size > 1.0);
    }

    /// Same workload under the static scheduler: the {A, B} batch runs to
    /// completion before C is admitted, costing 8 + 2 steps.
    #[test]
    fn static_runs_batches_to_completion() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Static));
        let _ra = submit(&mut s, 0, (0..8).collect(), 8);
        let _rb = submit(&mut s, 1, vec![1, 2], 2);
        let rc = submit(&mut s, 2, vec![3, 4], 2);
        drain(&mut s);
        let c = rc.recv().unwrap();
        assert_eq!(c.batch_size, 1, "static mode admits C into a fresh batch");
        let stats = s.into_stats();
        assert_eq!(stats.steps, 10);
    }

    #[test]
    fn ttft_and_tpot_surface_in_stats() {
        let dt = 0.25;
        let mut s = Scheduler::new(Mock::new(dt), cfg(4, SchedulerMode::Continuous));
        let rxs: Vec<_> = (0..4).map(|i| submit(&mut s, i, vec![1, 2, 3, 4], 4)).collect();
        drain(&mut s);
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!((r.sim_ttft - dt).abs() < 1e-12);
            assert!((r.sim_tpot - dt).abs() < 1e-12);
            assert!((r.sim_latency - 4.0 * dt).abs() < 1e-12);
        }
        let stats = s.into_stats();
        assert!((stats.ttft.p50 - dt).abs() < 1e-12);
        assert!((stats.tpot.p99 - dt).abs() < 1e-12);
        assert!((stats.total_sim_seconds - 4.0 * dt).abs() < 1e-12);
    }

    #[test]
    fn max_batch_bounds_slot_occupancy() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
        let rxs: Vec<_> = (0..5).map(|i| submit(&mut s, i, vec![i as usize, 9], 2)).collect();
        drain(&mut s);
        for rx in rxs {
            assert!(rx.recv().unwrap().batch_size <= 2);
        }
        assert_eq!(s.decoder().peak_active, 2);
    }

    #[test]
    fn responses_match_requests_threaded() {
        let server = Server::start(|| Ok(Mock::new(0.5)), ServerConfig::default());
        let rx1 = server.submit(vec![1, 2, 3], 8);
        let rx2 = server.submit(vec![9, 8], 8);
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert_eq!(r1.tokens, vec![3, 2, 1]);
        assert_eq!(r2.tokens, vec![8, 9]);
        assert_ne!(r1.id, r2.id);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 2);
        assert!(stats.queue_wait.p99 >= stats.queue_wait.p50);
    }

    #[test]
    fn batching_groups_concurrent_requests() {
        let cfg = ServerConfig {
            max_batch: 8,
            batch_wait: Duration::from_millis(50),
            max_output: 8,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
            preempt: PreemptPolicy::Off,
            trace: false,
        };
        let server = Server::start(|| Ok(Mock::new(0.5)), cfg);
        let rxs: Vec<_> = (0..6).map(|i| server.submit(vec![i, i + 1], 4)).collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert!(responses.iter().any(|r| r.batch_size > 1));
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.mean_batch_size > 1.0, "requests should have shared steps");
    }

    #[test]
    fn shutdown_drains_pending() {
        let cfg = ServerConfig {
            max_batch: 64,
            batch_wait: Duration::from_millis(200),
            max_output: 8,
            scheduler: SchedulerMode::Continuous,
            prefill_chunk: 1,
            preempt: PreemptPolicy::Off,
            trace: false,
        };
        let server = Server::start(|| Ok(Mock::new(0.5)), cfg);
        let rx = server.submit(vec![7], 4);
        let stats = server.shutdown().unwrap();
        assert_eq!(rx.recv().unwrap().tokens, vec![7]);
        assert_eq!(stats.requests, 1);
        // decoders without the big-little fallback report a zero quality
        // proxy through the defaulted trait accessor
        assert_eq!(stats.degraded_token_frac, 0.0);
    }

    #[test]
    fn no_starvation_under_load() {
        for mode in [SchedulerMode::Static, SchedulerMode::Continuous] {
            let cfg = ServerConfig {
                max_batch: 3,
                batch_wait: Duration::from_millis(1),
                max_output: 8,
                scheduler: mode,
                prefill_chunk: 1,
                preempt: PreemptPolicy::Off,
                trace: false,
            };
            let server = Server::start(|| Ok(Mock::new(0.01)), cfg);
            let rxs: Vec<_> = (0..30).map(|i| server.submit(vec![i], 4)).collect();
            let mut got = 0;
            for rx in rxs {
                if rx.recv_timeout(Duration::from_secs(5)).is_ok() {
                    got += 1;
                }
            }
            assert_eq!(got, 30, "{mode:?}");
            server.shutdown().unwrap();
        }
    }

    // ------------------------------------------------- priority/preemption

    #[test]
    fn priority_parse_and_order() {
        assert_eq!(Priority::parse("high").unwrap(), Priority::High);
        assert_eq!(Priority::parse("normal").unwrap(), Priority::Normal);
        assert_eq!(Priority::parse("low").unwrap(), Priority::Low);
        assert!(Priority::parse("urgent").is_err());
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!(PreemptPolicy::parse("off").unwrap(), PreemptPolicy::Off);
        assert_eq!(PreemptPolicy::parse("0.5").unwrap(), PreemptPolicy::After(0.5));
        assert_eq!(PreemptPolicy::parse("0").unwrap().threshold(), Some(0.0));
        assert!(PreemptPolicy::parse("-1").is_err());
        assert!(PreemptPolicy::parse("NaN").is_err());
        assert!(PreemptPolicy::parse("soon").is_err());
    }

    /// With one slot and both requests queued before the first step, the
    /// High request is admitted first even though Low enqueued earlier.
    #[test]
    fn high_priority_admits_before_earlier_low() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(1, SchedulerMode::Continuous));
        let _rl = submit_prio(&mut s, 0, vec![1, 2], 2, Priority::Low);
        let rh = submit_prio(&mut s, 1, vec![8, 9], 2, Priority::High);
        s.tick().unwrap();
        assert_eq!(s.decoder().seqs.len(), 1);
        assert_eq!(s.decoder().seqs[0].out, vec![9, 8], "High must take the only slot");
        drain(&mut s);
        assert_eq!(rh.recv().unwrap().tokens, vec![9, 8]);
    }

    /// Full slots of long Low decodes: under `--preempt 2`, a High
    /// arrival's time to first token is bounded by the threshold plus a
    /// couple of steps; the preempted Low still completes bit-identically
    /// (its echo output is untouched) and reports its suspended time.
    #[test]
    fn preemption_bounds_high_wait_and_resumes_bit_identical() {
        let mut config = cfg(2, SchedulerMode::Continuous);
        config.preempt = PreemptPolicy::After(2.0);
        let mut s = Scheduler::new(Mock::new(1.0), config);
        let low_prompt: Vec<usize> = (0..50).collect();
        let rl0 = submit_prio(&mut s, 0, low_prompt.clone(), 50, Priority::Low);
        let rl1 = submit_prio(&mut s, 1, low_prompt.clone(), 50, Priority::Low);
        s.tick().unwrap();
        s.tick().unwrap();
        let enqueued_at = s.decoder().now();
        let rh = submit_prio(&mut s, 2, vec![5, 6, 7], 3, Priority::High);
        // drive until the High response lands; record the sim time
        let mut high_done_at = f64::NAN;
        let mut guard = 0;
        while s.has_work() {
            s.tick().unwrap();
            if high_done_at.is_nan() && rh.try_recv().is_ok() {
                high_done_at = s.decoder().now();
            }
            guard += 1;
            assert!(guard < 1000, "scheduler failed to drain");
        }
        // wait ≤ threshold + one step to detect + the 3 decode steps
        assert!(
            high_done_at <= enqueued_at + 2.0 + 1.0 + 3.0 + 1e-9,
            "high finished at {high_done_at}, enqueued at {enqueued_at}"
        );
        // the victim resumed and completed its full echo, bit-identical
        let (l0, l1) = (rl0.recv().unwrap(), rl1.recv().unwrap());
        let echo: Vec<usize> = low_prompt.iter().rev().copied().collect();
        assert_eq!(l0.tokens, echo);
        assert_eq!(l1.tokens, echo);
        let preempted: Vec<&Response> =
            [&l0, &l1].into_iter().filter(|r| r.preempted_wait > 0.0).collect();
        assert_eq!(preempted.len(), 1, "exactly one Low was suspended");
        let stats = s.into_stats();
        assert_eq!(stats.preemptions, 1);
        assert!(stats.preempted_wait.p99 > 0.0);
        // queue_wait (initial queueing, wallclock) stays split from the
        // suspended time — the preempted request's suspension shows up in
        // preempted_wait, not in queue_wait percentiles
        assert!(stats.queue_wait.p50 < 1.0, "wallclock queue wait is sub-second in tests");
    }

    /// The same scenario with preemption off: the High request cannot
    /// start until one of the 50-token Lows retires.
    #[test]
    fn preempt_off_high_waits_for_a_free_slot() {
        let mut s = Scheduler::new(Mock::new(1.0), cfg(2, SchedulerMode::Continuous));
        let low_prompt: Vec<usize> = (0..50).collect();
        let _rl0 = submit_prio(&mut s, 0, low_prompt.clone(), 50, Priority::Low);
        let _rl1 = submit_prio(&mut s, 1, low_prompt, 50, Priority::Low);
        s.tick().unwrap();
        s.tick().unwrap();
        let rh = submit_prio(&mut s, 2, vec![5, 6, 7], 3, Priority::High);
        let mut high_done_at = f64::NAN;
        let mut guard = 0;
        while s.has_work() {
            s.tick().unwrap();
            if high_done_at.is_nan() && rh.try_recv().is_ok() {
                high_done_at = s.decoder().now();
            }
            guard += 1;
            assert!(guard < 1000, "scheduler failed to drain");
        }
        assert!(
            high_done_at >= 50.0,
            "without preemption the High must wait out a Low: finished at {high_done_at}"
        );
        let stats = s.into_stats();
        assert_eq!(stats.preemptions, 0);
        assert_eq!(stats.preempted_wait.p99, 0.0);
    }

    /// Preemption suspends the *lowest* class first and never a peer of
    /// the waiter's own class.
    #[test]
    fn preemption_never_touches_equal_or_higher_class() {
        let mut config = cfg(1, SchedulerMode::Continuous);
        config.preempt = PreemptPolicy::After(0.0);
        let mut s = Scheduler::new(Mock::new(1.0), config);
        let rn = submit_prio(&mut s, 0, (0..20).collect(), 20, Priority::Normal);
        s.tick().unwrap();
        // a Normal waiter must NOT preempt the in-flight Normal sequence
        let _rn2 = submit_prio(&mut s, 1, vec![1, 2], 2, Priority::Normal);
        for _ in 0..5 {
            s.tick().unwrap();
        }
        assert_eq!(s.decoder().seqs.len(), 1);
        assert_eq!(s.decoder().seqs[0].out.len(), 20, "the long Normal kept its slot");
        drain(&mut s);
        assert_eq!(rn.recv().unwrap().tokens.len(), 20);
        assert_eq!(s.into_stats().preemptions, 0);
    }
}
