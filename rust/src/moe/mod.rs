//! Model configuration and weight storage.
//!
//! [`MoeConfig`] mirrors `artifacts/<preset>/config.json` (micro dims that
//! actually execute + the paper-scale cost dims).  [`WeightStore`] holds
//! one checkpoint variant (base or a MELINOE fine-tune) split by residency
//! class:
//!
//! * **always-resident** — embeddings, norms, attention, router, LM head.
//!   Pre-converted to [`xla::Literal`]s once at load.
//! * **experts** — the offloadable unit.  Stored host-side per (layer,
//!   expert); under a quantized residency mode the tensors are passed
//!   through quantize→dequantize at load so the engine's numerics carry
//!   the real quantization error (paper §3.2, Table 12).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::clock::PaperDims;
use crate::quant::{dequantize, quantize, QuantMode};
use crate::tensor::{HostTensor, NpzFile};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct MoeConfig {
    pub name: String,
    pub mirrors: String,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    /// Default evaluation cache capacity (paper Table 10).
    pub cache_capacity: usize,
    pub predictor_hidden: usize,
    pub variants: Vec<String>,
    pub cost: PaperDims,
}

impl MoeConfig {
    pub fn load(preset_dir: &Path) -> Result<MoeConfig> {
        let j = Json::from_file(preset_dir.join("config.json"))?;
        let cost = j.get("cost")?;
        let name = j.get("name")?.as_str()?.to_string();
        let vocab = if name.contains("olmoe") { 50304 } else { 32000 };
        Ok(MoeConfig {
            mirrors: j.get("mirrors")?.as_str()?.to_string(),
            n_layers: j.get("n_layers")?.as_usize()?,
            n_experts: j.get("n_experts")?.as_usize()?,
            top_k: j.get("top_k")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            vocab_size: j.get("vocab_size")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            cache_capacity: j.get("cache_capacity")?.as_usize()?,
            predictor_hidden: j
                .opt("predictor_hidden")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(128),
            variants: j
                .get("variants")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            cost: PaperDims {
                n_layers: cost.get("n_layers")?.as_usize()?,
                n_experts: cost.get("n_experts")?.as_usize()?,
                top_k: cost.get("top_k")?.as_usize()?,
                d_model: cost.get("d_model")?.as_usize()?,
                d_ff: cost.get("d_ff")?.as_usize()?,
                vocab,
            },
            name,
        })
    }
}

/// Always-resident weights of one layer, as PJRT-ready literals in
/// `layer_step` argument order (after x): ln1, wq, wk, wv, wo, ln2, router.
pub struct LayerWeights {
    pub lits: Vec<xla::Literal>,
}

/// One expert's offloadable weights (host-side f32).
pub struct ExpertWeights {
    pub wg: HostTensor, // [dff, d]
    pub wu: HostTensor, // [dff, d]
    pub wd: HostTensor, // [d, dff]
}

/// Stacked `expert_group` argument literals for one routed set.
pub struct StackedExperts {
    pub wg: xla::Literal,
    pub wu: xla::Literal,
    pub wd: xla::Literal,
}

/// One checkpoint variant, ready for the engine.
pub struct WeightStore {
    pub variant: String,
    pub quant: QuantMode,
    pub embed: HostTensor, // [V, d] host (token gather is a host op)
    pub embed_lit: xla::Literal,
    pub lnf_lit: xla::Literal,
    pub layers: Vec<LayerWeights>,
    /// experts[layer][expert]
    pub experts: Vec<Vec<ExpertWeights>>,
    /// Memo of stacked expert literals keyed by (layer, routed set).
    /// MELINOE's whole point is that the routed set repeats within a
    /// sequence — after fine-tuning this cache hits most steps, removing
    /// the dominant host-side cost of `expert_group` dispatch (§Perf).
    stack_cache: std::cell::RefCell<std::collections::HashMap<(usize, Vec<usize>), std::rc::Rc<StackedExperts>>>,
    pub stack_hits: std::cell::Cell<u64>,
    pub stack_misses: std::cell::Cell<u64>,
}

/// Bound on memoized stacked sets (64 sets ≈ a few MB at micro scale).
const STACK_CACHE_CAP: usize = 512;

fn maybe_quantize(t: &HostTensor, mode: QuantMode) -> HostTensor {
    match mode {
        QuantMode::Fp16 => t.clone(),
        m => HostTensor { dims: t.dims.clone(), data: dequantize(&quantize(&t.data, m)) },
    }
}

impl WeightStore {
    /// Load `<preset_dir>/weights/<variant>.npz` with the given expert
    /// residency quantization.
    pub fn load(
        preset_dir: &Path,
        cfg: &MoeConfig,
        variant: &str,
        quant: QuantMode,
    ) -> Result<WeightStore> {
        let path = preset_dir.join("weights").join(format!("{variant}.npz"));
        let npz = NpzFile::load(&path)?;
        let embed = npz.get("embed")?.clone();
        let embed_lit = embed.to_literal()?;
        let lnf_lit = npz.get("lnf")?.to_literal()?;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        let mut experts = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let g = |n: &str| -> Result<&HostTensor> { npz.get(&format!("l{l}.{n}")) };
            let lits = ["ln1", "wq", "wk", "wv", "wo", "ln2", "router"]
                .iter()
                .map(|n| g(n)?.to_literal())
                .collect::<Result<Vec<_>>>()?;
            layers.push(LayerWeights { lits });
            let wg = g("wg")?;
            let wu = g("wu")?;
            let wd = g("wd")?;
            let mut row = Vec::with_capacity(cfg.n_experts);
            for e in 0..cfg.n_experts {
                row.push(ExpertWeights {
                    wg: maybe_quantize(&wg.slice0(e), quant),
                    wu: maybe_quantize(&wu.slice0(e), quant),
                    wd: maybe_quantize(&wd.slice0(e), quant),
                });
            }
            experts.push(row);
        }
        Ok(WeightStore {
            variant: variant.to_string(),
            quant,
            embed,
            embed_lit,
            lnf_lit,
            layers,
            experts,
            stack_cache: std::cell::RefCell::new(std::collections::HashMap::new()),
            stack_hits: std::cell::Cell::new(0),
            stack_misses: std::cell::Cell::new(0),
        })
    }

    /// Stack the selected experts' weights into the `expert_group`
    /// argument literals: wg/wu [K', dff, d], wd [K', d, dff].
    /// Memoized per routed set — see `stack_cache`.
    pub fn stack_experts(
        &self,
        layer: usize,
        selected: &[usize],
        d: usize,
        dff: usize,
    ) -> Result<std::rc::Rc<StackedExperts>> {
        let key = (layer, selected.to_vec());
        if let Some(hit) = self.stack_cache.borrow().get(&key) {
            self.stack_hits.set(self.stack_hits.get() + 1);
            return Ok(hit.clone());
        }
        self.stack_misses.set(self.stack_misses.get() + 1);
        let k = selected.len();
        let mut wg = Vec::with_capacity(k * dff * d);
        let mut wu = Vec::with_capacity(k * dff * d);
        let mut wd = Vec::with_capacity(k * d * dff);
        for &e in selected {
            let ex = &self.experts[layer][e];
            wg.extend_from_slice(&ex.wg.data);
            wu.extend_from_slice(&ex.wu.data);
            wd.extend_from_slice(&ex.wd.data);
        }
        let k = k as i64;
        let stacked = std::rc::Rc::new(StackedExperts {
            wg: xla::Literal::vec1(&wg).reshape(&[k, dff as i64, d as i64])?,
            wu: xla::Literal::vec1(&wu).reshape(&[k, dff as i64, d as i64])?,
            wd: xla::Literal::vec1(&wd).reshape(&[k, d as i64, dff as i64])?,
        });
        let mut cache = self.stack_cache.borrow_mut();
        if cache.len() >= STACK_CACHE_CAP {
            cache.clear(); // simple epoch reset; sets are cheap to rebuild
        }
        cache.insert(key, stacked.clone());
        Ok(stacked)
    }
}

/// Activation predictor weights (w1, b1, w2, b2 literals).
pub struct PredictorWeights {
    pub lits: Vec<xla::Literal>,
}

impl PredictorWeights {
    pub fn load(preset_dir: &Path, variant: &str, dataset_short: &str) -> Result<PredictorWeights> {
        let path = preset_dir
            .join("weights")
            .join(format!("predictor_{variant}_{dataset_short}.npz"));
        let npz = NpzFile::load(&path)?;
        let lits = ["w1", "b1", "w2", "b2"]
            .iter()
            .map(|n| npz.get(n)?.to_literal())
            .collect::<Result<Vec<_>>>()?;
        Ok(PredictorWeights { lits })
    }
}

/// MoE-Infinity-style activation frequency profile [L, E].
pub struct RoutingProfile {
    pub freq: HostTensor,
}

impl RoutingProfile {
    pub fn load(preset_dir: &Path, variant: &str, dataset_short: &str) -> Result<RoutingProfile> {
        let path =
            preset_dir.join("weights").join(format!("profile_{variant}_{dataset_short}.npz"));
        let npz = NpzFile::load(&path)?;
        Ok(RoutingProfile { freq: npz.get("freq")?.clone() })
    }

    /// Top-C most frequently activated experts for a layer.
    pub fn topc(&self, layer: usize, c: usize) -> Vec<usize> {
        let row = HostTensor::new(vec![self.freq.dims[1]], self.freq.row(layer).to_vec()).unwrap();
        row.topk(c)
    }
}

/// One evaluation sample exported by `data.export_eval_set`.
#[derive(Debug, Clone)]
pub struct EvalSample {
    pub prompt: Vec<usize>,
    pub reference: Vec<usize>,
    pub domain: usize,
    pub answer: String,
}

/// Held-out evaluation set for one dataset.
pub struct EvalSet {
    pub dataset: String,
    pub samples: Vec<EvalSample>,
}

impl EvalSet {
    pub fn load(preset_dir: &Path, dataset_short: &str) -> Result<EvalSet> {
        let j =
            Json::from_file(preset_dir.join("eval").join(format!("eval_{dataset_short}.json")))?;
        let samples = j
            .get("samples")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(EvalSample {
                    prompt: s.get("prompt")?.as_usize_vec()?,
                    reference: s.get("reference")?.as_usize_vec()?,
                    domain: s.get("domain")?.as_usize()?,
                    answer: s.get("answer")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(EvalSet { dataset: j.get("dataset")?.as_str()?.to_string(), samples })
    }
}

/// A golden decode trace (python reference output, integration tests).
#[derive(Debug, Clone)]
pub struct Golden {
    pub variant: String,
    pub dataset: String,
    pub prompt: Vec<usize>,
    pub expected: Vec<usize>,
}

pub fn load_goldens(preset_dir: &Path) -> Result<Vec<Golden>> {
    let j = Json::from_file(preset_dir.join("eval").join("goldens.json"))?;
    let mut out = Vec::new();
    for (variant, recs) in j.as_obj()? {
        for r in recs.as_arr()? {
            out.push(Golden {
                variant: variant.clone(),
                dataset: r.get("dataset")?.as_str()?.to_string(),
                prompt: r.get("prompt")?.as_usize_vec()?,
                expected: r.get("expected")?.as_usize_vec()?,
            });
        }
    }
    Ok(out)
}

/// Locate a preset directory under the artifacts root.
pub fn preset_dir(artifacts: &Path, preset: &str) -> Result<PathBuf> {
    let dir = artifacts.join(preset);
    if !dir.join("config.json").exists() {
        return Err(anyhow!(
            "no artifacts for preset {preset:?} under {artifacts:?} — run `make artifacts`"
        ));
    }
    Ok(dir)
}
