//! Cluster serving simulator: a fleet of replicas behind a pluggable
//! request dispatcher, driven by one sim-time event queue.
//!
//! This is the first layer above the single-engine stack.  MELINOE makes
//! each sequence's routing concentrate on a small, predictable expert set
//! (PAPER.md §3); once a fleet serves heterogeneous traffic, replicas
//! whose caches hold *different* task's experts are not interchangeable —
//! a dispatcher that routes each request to the replica whose resident
//! experts best match the request's `predict_plan` prefetch set
//! ([`balancer::ExpertAffinity`]) multiplies the single-GPU cache-hit
//! advantage cluster-wide.
//!
//! Structure:
//! * [`workload`] — open-loop Poisson arrivals over per-task routing
//!   profiles (pre-drawn traces: all balancers see identical traffic),
//!   with per-request output lengths (skew is continuous batching's win
//!   case) and streaming-client behaviour — TTFT deadlines, cancel-after-N
//!   hang-ups, queue-time disconnects ([`workload::StreamMix`]).
//! * [`replica`]  — one GPU's cache/PCIe/VRAM/clock stack with a
//!   step-granular decode loop: slots admit mid-flight, sequences retire
//!   at trace end (see [`crate::coordinator::SchedulerMode`]), and
//!   prompts prefill in chunks piggybacked on live decode steps
//!   (`--prefill-chunk`).
//! * [`balancer`] — RoundRobin / LeastLoaded / ExpertAffinity /
//!   PriorityAffinity dispatch against *live* slot occupancy and replica
//!   [`Health`] (never a Down replica, de-weighted Degraded ones).
//! * `config` — [`ClusterConfig`] plus the validating [`ClusterBuilder`]
//!   (the one construction path) and the work-stealing knobs
//!   ([`StealPolicy`]).
//! * `events` — the fleet's sim-time event queue: arrivals, retry
//!   wake-ups, the deterministic fault plan, and the periodic steal scan
//!   pop in one ordered timeline (step boundaries and transfer landings
//!   replay inside each replica's own clock).
//! * [`run_cluster`] — pops events one at a time: crashes reclaim every
//!   affected sequence for re-dispatch under the
//!   [`crate::fault::RetryPolicy`], brownouts migrate live sequences to
//!   affinity-priced healthy peers, link flaps and checksum corruption
//!   exercise the transfer pipeline, steal ticks let idle replicas take
//!   queued or suspended work from loaded peers (priced warm-cache
//!   advantage vs queue delay vs KV transfer), and age-based promotion
//!   (`--age-promote`) bounds low-class starvation — plus fleet metrics
//!   (throughput, hit-rate, queue/TTFT/latency percentiles, recovery
//!   accounting, PCIe per replica).

pub mod balancer;
mod config;
mod events;
pub mod replica;
pub mod workload;

use std::collections::{HashMap, HashSet};

use anyhow::{ensure, Result};

use crate::coordinator::{Outcome, Priority, SchedulerMode};
use crate::fault::{FaultKind, FaultPlan, Health, PhiDetector};
use crate::metrics::{fmt2, Percentiles, Table};
use crate::trace::{Recorder, Trace, TraceEvent};

use balancer::{Balancer, ReplicaView};
use events::{Event, EventQueue, RetryEntry};
use replica::{Completion, Replica};
use workload::ClusterRequest;

pub use config::{ClusterBuilder, ClusterConfig, StealPolicy};

/// The three stock balancers, in comparison-table order.
pub const BALANCERS: &[&str] = &["round-robin", "least-loaded", "expert-affinity"];

/// Per-replica slice of a cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaSummary {
    pub id: usize,
    pub requests: usize,
    pub output_tokens: usize,
    pub hit_rate: f64,
    pub h2d: u64,
    pub pcie_gb: f64,
    pub stall_seconds: f64,
    /// Transfer time hidden behind compute (prefetch overlap).
    pub overlapped_seconds: f64,
    pub busy_seconds: f64,
    pub peak_queue_depth: usize,
    /// Sequences suspended out of a slot by a higher-priority waiter.
    pub preemptions: u64,
    /// Queued or suspended requests promoted to a higher class by aging
    /// on this replica (`--age-promote`).
    pub promotions: u64,
    /// Fraction of this replica's routed assignments the big-little
    /// fallback served from a degraded little copy.
    pub degraded_token_frac: f64,
}

/// Per-priority-class latency slice of a cluster run (only classes that
/// actually completed requests appear, highest class first).
#[derive(Debug, Clone)]
pub struct PriorityClass {
    pub priority: Priority,
    pub requests: usize,
    /// Arrival → first output token.
    pub ttft: Percentiles,
    /// Arrival → retirement.
    pub latency: Percentiles,
    /// Simulated seconds spent suspended after preemptions.
    pub preempted_wait: Percentiles,
}

/// Fleet-level outcome of one (config, balancer) run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub balancer: String,
    pub scheduler: SchedulerMode,
    /// Per-step prompt-token budget the fleet ran with.
    pub prefill_chunk: usize,
    /// Layer-ahead transfer pipeline depth the fleet ran with.
    pub lookahead: usize,
    pub n_requests: usize,
    /// All decoded output tokens, including the partial outputs of
    /// cancelled requests (they occupied slots and compute).
    pub output_tokens: usize,
    /// Requests that decoded their full output.
    pub completed: usize,
    /// Requests the client hung up on (queue-time disconnects plus
    /// cancel-after-N mid-decode hang-ups).
    pub cancelled: usize,
    /// Requests admission control turned away.
    pub rejected: usize,
    /// Requests that exhausted their retry budget after fault reclaim
    /// ([`Outcome::Failed`]; always 0 without fault injection).
    pub failed: usize,
    /// Re-dispatches of fault-reclaimed requests (`--retry`).
    pub retries: u64,
    /// Live-sequence migrations off browned-out replicas.
    pub migrations: u64,
    /// Work-steal transfers between replicas (`--steal`): queued
    /// requests plus live-stolen suspended sequences.
    pub steals: u64,
    /// The subset of `steals` that migrated a suspended in-flight
    /// sequence (charged its KV transfer over PCIe).
    pub live_steals: u64,
    /// Age-based priority promotions across the fleet (`--age-promote`).
    pub promotions: u64,
    /// Distinct requests ever reclaimed by an injected fault.
    pub injected: usize,
    /// Reclaimed requests that still reached a served terminal outcome
    /// (`injected == recovered + failed`, audited when faults are on).
    pub recovered: usize,
    /// Sim seconds from a recovered request's first reclaim to its
    /// terminal outcome.
    pub recovery_wait: Percentiles,
    /// `(request id, outcome, output tokens)` for every terminal, sorted
    /// by id — the bit-identity oracle for the fault property tests.
    pub outcomes: Vec<(u64, Outcome, usize)>,
    /// Output tokens of completed requests whose first token landed
    /// within their deadline (deadline-free completions always attain).
    pub goodput_tokens: usize,
    /// SLO-attaining throughput: `goodput_tokens` per simulated second of
    /// makespan — the number that matters once requests carry deadlines.
    pub goodput_per_sec: f64,
    /// Last completion time (simulated seconds).
    pub makespan: f64,
    /// Fleet throughput: output tokens per simulated second of makespan.
    pub tokens_per_sec: f64,
    /// Aggregate expert-cache hit rate across all replicas.
    pub hit_rate: f64,
    pub queue_wait: Percentiles,
    /// Arrival → first output token (the serving TTFT).
    pub ttft: Percentiles,
    /// Time per output token after the first.
    pub tpot: Percentiles,
    /// Arrival → retirement.
    pub latency: Percentiles,
    /// Total H2D traffic across the fleet, GB.
    pub pcie_gb: f64,
    /// Decode time lost stalled on expert transfers, fleet total
    /// (demand stalls + residual waits on caught in-flight prefetches).
    pub stall_seconds: f64,
    /// Transfer time hidden behind compute, fleet total.
    pub overlapped_seconds: f64,
    /// Total H2D link occupancy across the fleet (seconds).
    pub h2d_seconds: f64,
    /// `overlapped / (overlapped + stalled)` — the overlap fraction.
    pub overlap_fraction: f64,
    /// Fleet-total preemptions (suspensions of an in-flight sequence).
    pub preemptions: u64,
    /// Fraction of routed (token, expert) assignments the big-little
    /// fallback served from a degraded low-bit little copy, fleet-wide
    /// (0.0 when `--little-tier` is off; a quality proxy, not a speed
    /// metric).
    pub degraded_token_frac: f64,
    /// Fleet-total H2D bytes split by precision tier
    /// (`[fp16, int4, int3]` — [`crate::quant::QuantMode::idx`] order).
    pub h2d_bytes_by_tier: [f64; 3],
    /// Fleet-total D2H (eviction write-back) bytes split by tier.
    pub d2h_bytes_by_tier: [f64; 3],
    /// Per-priority-class TTFT/latency slices (High first; only classes
    /// with completed requests appear).
    pub priorities: Vec<PriorityClass>,
    pub replicas: Vec<ReplicaSummary>,
    /// Merged fleet timeline (one lane per replica + the dispatcher
    /// lane) when [`ClusterConfig::trace`] was set; every replica's
    /// stream has already passed the conservation audits.
    pub trace: Option<Trace>,
}

/// Run one cluster simulation off the sim-time event queue: pop the
/// earliest arrival / retry wake-up / fault / steal-tick event, bring
/// every replica's clock up to the event instant (replicas admit and
/// step continuously along the way), and react — dispatch through `bal`
/// against live slot occupancy and health, reclaim and retry around
/// crashes, migrate live sequences off brownouts, and on steal ticks
/// let idle replicas take affinity-priced work from loaded peers.  No
/// lockstep epochs: a freed slot on one replica re-admits from its
/// queue immediately, regardless of what the rest of the fleet is
/// doing.  The run bails if any request resolves with more (or fewer)
/// than one terminal outcome.
pub fn run_cluster(cfg: &ClusterConfig, bal: &mut dyn Balancer) -> Result<ClusterReport> {
    let requests = cfg.requests();
    let n_expected = requests.len();
    let mut reps: Vec<Replica> = (0..cfg.replicas.max(1))
        .map(|i| {
            Replica::new(i, cfg.spec.clone(), cfg.scheduler)
                .with_prefill_chunk(cfg.prefill_chunk)
                .with_preempt(cfg.preempt)
                .with_admission(cfg.admission)
                .with_age_promote(cfg.age_promote)
                .with_trace(cfg.trace)
        })
        .collect();
    // the dispatcher records on its own lane, one past the replica ids
    let mut drec = if cfg.trace {
        Recorder::on(cfg.replicas.max(1) as u32, "dispatcher")
    } else {
        Recorder::off()
    };
    let max_queue = cfg.max_queue.max(1);
    let n_replicas = reps.len();
    let plan = FaultPlan::generate(&cfg.faults, n_replicas, cfg.workload.fault_seed());
    // phi-style missed-heartbeat detector: every non-Down replica beats
    // at every timeline event, so a silent replica's phi grows until the
    // dispatcher stops believing in it — the dispatcher's health belief,
    // layered over the coordinator's ground truth
    let mut detector = PhiDetector::new(n_replicas, (cfg.faults.mtbf / 8.0).max(1e-9), 2.0);
    let mut queue =
        EventQueue::new(requests, plan.events, cfg.steal.as_ref().map(|s| s.interval));
    let faults_on = queue.faults_armed();
    let mut attempts: HashMap<u64, u32> = HashMap::new();
    let mut first_reclaim: HashMap<u64, f64> = HashMap::new();
    let mut injected_ids: HashSet<u64> = HashSet::new();
    let mut failed_terminals: Vec<Completion> = Vec::new();
    let (mut retries_total, mut migrations_total) = (0u64, 0u64);
    let (mut steals_total, mut live_steals_total) = (0u64, 0u64);
    loop {
        let fleet_busy = reps.iter().any(|r| r.has_work());
        let Some((now, ev)) = queue.pop(fleet_busy) else { break };
        // advance every replica to the event instant so dispatch (and
        // the steal scan) sees live slot occupancy, not an
        // epoch-boundary snapshot
        for r in &mut reps {
            r.run_until(now, cfg.max_batch);
        }
        if faults_on {
            // heartbeat sweep: advance every health machine, read phi
            // before the beat (a Down replica stays silent), and refresh
            // the fleet-degradation fallback escalation
            for r in &mut reps {
                r.refresh_health(now);
            }
            for (i, r) in reps.iter().enumerate() {
                if r.health() != Health::Down {
                    drec.emit(
                        now,
                        TraceEvent::Heartbeat { replica: i as u32, phi: detector.phi(i, now) },
                    );
                    detector.beat(i, now);
                }
            }
            let any_down = reps.iter().any(|r| r.health() == Health::Down);
            for r in &mut reps {
                if r.health() != Health::Down {
                    r.set_fallback_escalation(any_down);
                }
            }
        }
        let (req, attempt) = match ev {
            Event::Arrival(req) => (req, 0),
            Event::Retry(e) => (e.req, e.attempt),
            Event::StealTick => {
                steal_pass(
                    cfg,
                    &mut reps,
                    &mut drec,
                    now,
                    &mut steals_total,
                    &mut live_steals_total,
                );
                continue;
            }
            Event::Fault(f) => {
                let i = f.replica.min(n_replicas - 1);
                match f.kind {
                    FaultKind::Crash => {
                        // lost progress: reclaimed sequences re-decode from
                        // scratch elsewhere (pre-drawn routing keeps their
                        // tokens bit-identical), under the retry budget
                        let back_up = now + cfg.faults.recovery.max(1e-9);
                        for req in reps[i].crash(back_up) {
                            injected_ids.insert(req.id);
                            first_reclaim.entry(req.id).or_insert(now);
                            let a = attempts.entry(req.id).or_insert(0);
                            if *a >= cfg.retry.max_retries {
                                // budget exhausted: the one terminal outcome
                                drec.emit(now, TraceEvent::RequestFailed { request: req.id });
                                failed_terminals.push(Completion {
                                    request_id: req.id,
                                    task: req.task,
                                    priority: req.priority,
                                    arrival: req.at,
                                    started: now,
                                    first_token: now,
                                    finished: now,
                                    output_tokens: 0,
                                    preempted_wait: 0.0,
                                    outcome: Outcome::Failed,
                                    deadline: req.deadline,
                                });
                            } else {
                                *a += 1;
                                let ready_at = now + cfg.retry.delay(*a - 1);
                                queue.push_retry(RetryEntry { ready_at, attempt: *a, req });
                            }
                        }
                    }
                    FaultKind::Brownout { factor, duration } => {
                        // live migration: suspended progress moves whole to
                        // an affinity-priced healthy peer (or rides out the
                        // brownout in place when there is none)
                        reps[i].set_brownout(factor, now + duration);
                        for m in reps[i].extract_live() {
                            let mut best: Option<(usize, f64)> = None;
                            for (j, r) in reps.iter().enumerate() {
                                if j == i || !r.health().dispatchable() {
                                    continue;
                                }
                                let load = (r.queue_depth() + r.slots_in_use()) as f64;
                                let score = r.affinity_overlap(&m.req.plan) - 0.1 * load;
                                if best.map_or(true, |(_, s)| score > s) {
                                    best = Some((j, score));
                                }
                            }
                            match best {
                                Some((j, _)) => {
                                    migrations_total += 1;
                                    drec.emit(
                                        now,
                                        TraceEvent::Migrate {
                                            request: m.req.id,
                                            from: i as u32,
                                            to: j as u32,
                                        },
                                    );
                                    reps[j].adopt(m, now);
                                }
                                None => reps[i].adopt(m, now),
                            }
                        }
                    }
                    FaultKind::LinkFlap { factor, duration } => {
                        reps[i].apply_link_flap(factor, now + duration);
                    }
                    FaultKind::Corrupt => {
                        let _ = reps[i].corrupt_transfer();
                    }
                }
                continue;
            }
        };
        if !reps.iter().any(|r| r.health().dispatchable()) {
            // whole fleet down: defer to the earliest recovery without
            // burning a retry attempt
            let ready_at = reps
                .iter()
                .filter(|r| r.health() == Health::Down)
                .map(|r| r.recover_at())
                .fold(f64::INFINITY, f64::min);
            ensure!(ready_at.is_finite(), "no replica is dispatchable or recovering");
            queue.push_retry(RetryEntry { ready_at: ready_at.max(now), attempt, req });
            continue;
        }
        // lossless back-pressure: when every dispatchable queue is at the
        // admission bound, step the least-advanced replica until one drains
        while reps
            .iter()
            .filter(|r| r.health().dispatchable())
            .all(|r| r.queue_depth() >= max_queue)
        {
            let i = reps
                .iter()
                .enumerate()
                .filter(|(_, r)| r.has_work() && r.health().dispatchable())
                .min_by(|(_, a), (_, b)| a.clock.now().total_cmp(&b.clock.now()))
                .map(|(i, _)| i)
                .expect("full queues imply outstanding dispatchable work");
            reps[i].run_one_step(cfg.max_batch);
        }
        let wants_overlap = bal.wants_overlap();
        let mut views: Vec<ReplicaView> = reps
            .iter()
            .enumerate()
            .map(|(i, r)| {
                // layer the detector's belief over ground truth: a
                // replica that stopped heartbeating is not a dispatch
                // target even before its fault event is processed
                let mut v = r.view();
                if faults_on && v.health != Health::Down && detector.suspect(i, now) {
                    v.health = Health::Down;
                }
                // overlap is the one O(plan) field: fill it only for
                // balancers that price affinity at pick time
                if wants_overlap {
                    v.overlap = r.affinity_overlap(&req.plan);
                }
                v
            })
            .collect();
        let mut choice = bal.pick(&req, &views).min(n_replicas - 1);
        if !views[choice].dispatchable() || reps[choice].queue_depth() >= max_queue {
            // shed to the fewest-queued dispatchable replica with room
            // (ties toward the earliest-free clock)
            choice = views
                .iter()
                .filter(|v| v.dispatchable() && v.queue_depth < max_queue)
                .min_by(|a, b| {
                    a.queue_depth.cmp(&b.queue_depth).then(a.busy_until.total_cmp(&b.busy_until))
                })
                .map(|v| v.id)
                .expect("back-pressure loop freed a dispatchable queue");
        }
        ensure!(
            reps[choice].health().dispatchable(),
            "dispatched request {} to Down replica {}",
            req.id,
            choice
        );
        if attempt > 0 {
            retries_total += 1;
            drec.emit(now, TraceEvent::Retry { request: req.id, attempt, replica: choice as u32 });
            // a re-dispatched request must not decode in the target's
            // past: its loss happened at fleet time `now`
            let lag = now - reps[choice].clock.now();
            if lag > 0.0 {
                reps[choice].clock.advance(lag);
            }
        }
        if drec.enabled() {
            // affinity-free balancers never needed the overlap to pick;
            // fill the chosen view lazily so the recorded dispatch score
            // stays bit-identical to the eager assembly
            if !wants_overlap {
                views[choice].overlap = reps[choice].affinity_overlap(&req.plan);
            }
            drec.emit(
                now,
                TraceEvent::Dispatch {
                    request: req.id,
                    replica: choice as u32,
                    score: bal.score(&views[choice]),
                },
            );
        }
        reps[choice].enqueue(req);
    }
    let outcome = FleetOutcome {
        n_expected,
        failed_terminals,
        retries: retries_total,
        migrations: migrations_total,
        steals: steals_total,
        live_steals: live_steals_total,
        injected_ids,
        first_reclaim,
        faults_on,
    };
    finalize(cfg, bal.name().to_string(), reps, drec, outcome)
}

/// One fleet-wide steal scan (`--steal`): every idle dispatchable
/// replica prices the best queued candidate (back of each loaded peer's
/// lowest-priority queue — tail steals never reorder a class's FIFO)
/// and, with `live` on, the best suspended sequence (lowest class,
/// least sunk wait), and takes the single highest-gain one.  Gain is
/// the brownout-migration score difference — `(thief overlap − c·thief
/// load) − (victim overlap − c·(victim load − 1))` — with a live steal
/// additionally charged its KV/plan transfer over PCIe, normalized by
/// the request's estimated service time.  Thieves scan in id order,
/// one steal per thief per tick.
fn steal_pass(
    cfg: &ClusterConfig,
    reps: &mut [Replica],
    drec: &mut Recorder,
    now: f64,
    steals: &mut u64,
    live_steals: &mut u64,
) {
    let Some(policy) = &cfg.steal else { return };
    for thief in 0..reps.len() {
        if reps[thief].has_work() || !reps[thief].health().dispatchable() {
            continue;
        }
        let thief_load = (reps[thief].queue_depth() + reps[thief].slots_in_use()) as f64;
        // (victim, live?, gain) of the best-priced candidate fleet-wide
        let mut best: Option<(usize, bool, f64)> = None;
        for victim in 0..reps.len() {
            if victim == thief || !reps[victim].health().dispatchable() {
                continue;
            }
            let victim_load =
                (reps[victim].queue_depth() + reps[victim].slots_in_use()) as f64;
            if let Some(req) = reps[victim].steal_candidate_queued() {
                let gain = (reps[thief].affinity_overlap(&req.plan)
                    - policy.load_coeff * thief_load)
                    - (reps[victim].affinity_overlap(&req.plan)
                        - policy.load_coeff * (victim_load - 1.0));
                if gain > policy.threshold && best.map_or(true, |(_, _, g)| gain > g) {
                    best = Some((victim, false, gain));
                }
            }
            if policy.live {
                if let Some((req, step)) = reps[victim].steal_candidate_live() {
                    let kv = kv_transfer_seconds(cfg, req, step);
                    let est = cfg
                        .spec
                        .est_service_seconds(req.prompt_tokens, req.max_output)
                        .max(1e-9);
                    let gain = (reps[thief].affinity_overlap(&req.plan)
                        - policy.load_coeff * thief_load)
                        - (reps[victim].affinity_overlap(&req.plan)
                            - policy.load_coeff * (victim_load - 1.0))
                        - kv / est;
                    if gain > policy.threshold && best.map_or(true, |(_, _, g)| gain > g) {
                        best = Some((victim, true, gain));
                    }
                }
            }
        }
        let Some((victim, live, _)) = best else { continue };
        if live {
            let Some(m) = reps[victim].take_steal_suspended() else { continue };
            let kv = kv_transfer_seconds(cfg, &m.req, m.step);
            *steals += 1;
            *live_steals += 1;
            drec.emit(
                now,
                TraceEvent::Steal {
                    request: m.req.id,
                    from: victim as u32,
                    to: thief as u32,
                    live: true,
                },
            );
            // the adopter cannot resume before the KV transfer lands
            reps[thief].adopt(m, now + kv);
        } else {
            let Some(req) = reps[victim].take_steal_queued() else { continue };
            *steals += 1;
            drec.emit(
                now,
                TraceEvent::Steal {
                    request: req.id,
                    from: victim as u32,
                    to: thief as u32,
                    live: false,
                },
            );
            // an idle thief's clock may lag the fleet: the stolen
            // request changed hands at fleet time `now`, so it must not
            // serve in the thief's past (mirrors the retry lag rule)
            let lag = now - reps[thief].clock.now();
            if lag > 0.0 {
                reps[thief].clock.advance(lag);
            }
            reps[thief].enqueue(req);
        }
    }
}

/// Sim-seconds to move a suspended sequence's KV cache (fp16 K and V
/// per token per layer) plus its plan over PCIe — the live steal's
/// migration charge.
fn kv_transfer_seconds(cfg: &ClusterConfig, req: &ClusterRequest, step: usize) -> f64 {
    let tokens = (req.prompt_tokens + step) as f64;
    let kv_bytes = 2.0 * 2.0 * cfg.spec.dims.d_model as f64 * cfg.spec.n_layers as f64 * tokens;
    cfg.spec.gpu.pcie_lat + kv_bytes / cfg.spec.gpu.pcie_bw
}

/// Everything the cluster loop accumulated outside the replicas,
/// handed to [`finalize`] — shared by the event-driven loop and the
/// frozen polling oracle so both aggregate identically.
struct FleetOutcome {
    n_expected: usize,
    failed_terminals: Vec<Completion>,
    retries: u64,
    migrations: u64,
    steals: u64,
    live_steals: u64,
    injected_ids: HashSet<u64>,
    first_reclaim: HashMap<u64, f64>,
    faults_on: bool,
}

/// Drain the fleet, run the conservation audits, and aggregate the
/// [`ClusterReport`].
fn finalize(
    cfg: &ClusterConfig,
    balancer: String,
    mut reps: Vec<Replica>,
    mut drec: Recorder,
    out: FleetOutcome,
) -> Result<ClusterReport> {
    for r in &mut reps {
        r.run_until(f64::INFINITY, cfg.max_batch);
    }

    // conservation audits: each replica's event stream must reconcile
    // with its own TransferStats, pin ledger, cache occupancy, and the
    // PCIe in-flight set before the lanes merge into the fleet timeline
    let mut trace: Option<Trace> = None;
    for r in &mut reps {
        let Some(t) = r.take_trace() else { continue };
        t.audit_lane_monotonic()?;
        t.reconcile(&r.pcie.stats, 1e-6)?;
        t.audit_prefetch_landed(r.pcie.in_flight_len())?;
        t.audit_pins(r.cache.layers[0].pinned_owners())?;
        // big residents plus little-tier copies: LittleInstall/LittleEvict
        // events balance against the same ledger as CacheInsert/CacheEvict
        let resident: Vec<usize> = r.cache.layers.iter().map(|l| l.occupancy_len()).collect();
        t.audit_occupancy(&resident)?;
        match &mut trace {
            Some(all) => all.merge(t),
            None => trace = Some(t),
        }
    }
    if let Some(dt) = drec.take() {
        match &mut trace {
            Some(all) => all.merge(dt),
            None => trace = Some(dt),
        }
    }

    // aggregate fleet metrics.  Latency percentiles sample *completed*
    // requests only — a rejected request's zero-latency terminal (or a
    // cancelled one's truncated decode) says nothing about served
    // latency; their populations are reported as counts instead.
    let completions: Vec<&Completion> = reps
        .iter()
        .flat_map(|r| r.completions.iter())
        .chain(out.failed_terminals.iter())
        .collect();
    let output_tokens: usize = completions.iter().map(|c| c.output_tokens).sum();
    let completed_set: Vec<&Completion> =
        completions.iter().copied().filter(|c| c.outcome == Outcome::Completed).collect();
    let cancelled = completions.iter().filter(|c| c.outcome == Outcome::Cancelled).count();
    let rejected = completions.iter().filter(|c| c.outcome == Outcome::Rejected).count();
    let failed = completions.iter().filter(|c| c.outcome == Outcome::Failed).count();
    // recovery conservation: every fault-reclaimed request either reached
    // a served terminal or exhausted its retry budget — and nothing
    // resolved twice or leaked
    let injected = out.injected_ids.len();
    let recovered = completions
        .iter()
        .filter(|c| out.injected_ids.contains(&c.request_id) && c.outcome != Outcome::Failed)
        .count();
    if out.faults_on {
        let mut seen: HashSet<u64> = HashSet::with_capacity(completions.len());
        for c in &completions {
            ensure!(
                seen.insert(c.request_id),
                "request {} resolved with more than one terminal outcome",
                c.request_id
            );
        }
        ensure!(
            completions.len() == out.n_expected,
            "recovery leaked requests: {} terminals for {} arrivals",
            completions.len(),
            out.n_expected
        );
        ensure!(
            injected == recovered + failed,
            "recovery conservation broke: {injected} injected != {recovered} recovered \
             + {failed} failed"
        );
    }
    let promotions: u64 = reps.iter().map(|r| r.promotions).sum();
    if let Some(tr) = &trace {
        tr.audit_recovery(injected as u64, recovered as u64, failed as u64)?;
        tr.audit_steal_promote(out.steals, promotions)?;
    }
    let recovery_waits: Vec<f64> = completions
        .iter()
        .filter(|c| c.outcome != Outcome::Failed)
        .filter_map(|c| out.first_reclaim.get(&c.request_id).map(|t0| (c.finished - t0).max(0.0)))
        .collect();
    let mut outcomes: Vec<(u64, Outcome, usize)> =
        completions.iter().map(|c| (c.request_id, c.outcome, c.output_tokens)).collect();
    outcomes.sort_unstable_by_key(|o| o.0);
    let goodput_tokens: usize =
        completed_set.iter().filter(|c| c.attained()).map(|c| c.output_tokens).sum();
    let makespan = completions.iter().map(|c| c.finished).fold(0.0f64, f64::max);
    let queue_waits: Vec<f64> = completed_set.iter().map(|c| c.queue_wait()).collect();
    let ttfts: Vec<f64> = completed_set.iter().map(|c| c.ttft()).collect();
    let tpots: Vec<f64> = completed_set.iter().map(|c| c.tpot()).collect();
    let latencies: Vec<f64> = completed_set.iter().map(|c| c.latency()).collect();
    let (mut hits, mut lookups) = (0u64, 0u64);
    let mut pcie_bytes = 0.0f64;
    let (mut stall_seconds, mut overlapped_seconds) = (0.0f64, 0.0f64);
    let mut h2d_seconds = 0.0f64;
    let mut preemptions = 0u64;
    let (mut degraded, mut assignments) = (0u64, 0u64);
    let mut h2d_bytes_by_tier = [0.0f64; 3];
    let mut d2h_bytes_by_tier = [0.0f64; 3];
    let replicas: Vec<ReplicaSummary> = reps
        .iter()
        .map(|r| {
            let stats = r.cache.total_stats();
            hits += stats.hits;
            lookups += stats.requests();
            pcie_bytes += r.pcie.stats.h2d_bytes;
            stall_seconds += r.pcie.stats.stall_time;
            overlapped_seconds += r.pcie.stats.overlapped_time;
            h2d_seconds += r.pcie.stats.h2d_seconds;
            preemptions += r.preemptions;
            degraded += r.degraded_execs;
            assignments += r.total_assignments;
            for t in 0..3 {
                h2d_bytes_by_tier[t] += r.pcie.stats.h2d_bytes_by_tier[t];
                d2h_bytes_by_tier[t] += r.pcie.stats.d2h_bytes_by_tier[t];
            }
            ReplicaSummary {
                id: r.id,
                requests: r.completions.len(),
                output_tokens: r.completions.iter().map(|c| c.output_tokens).sum(),
                hit_rate: stats.hit_rate(),
                h2d: r.pcie.stats.h2d_count,
                pcie_gb: r.pcie.stats.h2d_bytes / 1e9,
                stall_seconds: r.pcie.stats.stall_time,
                overlapped_seconds: r.pcie.stats.overlapped_time,
                busy_seconds: r.busy_seconds,
                peak_queue_depth: r.peak_queue_depth,
                preemptions: r.preemptions,
                promotions: r.promotions,
                degraded_token_frac: r.degraded_token_frac(),
            }
        })
        .collect();
    let priorities: Vec<PriorityClass> = Priority::ALL
        .iter()
        .rev()
        .copied()
        .filter_map(|p| {
            let of: Vec<&Completion> =
                completed_set.iter().copied().filter(|c| c.priority == p).collect();
            if of.is_empty() {
                return None;
            }
            Some(PriorityClass {
                priority: p,
                requests: of.len(),
                ttft: Percentiles::of(&of.iter().map(|c| c.ttft()).collect::<Vec<f64>>()),
                latency: Percentiles::of(&of.iter().map(|c| c.latency()).collect::<Vec<f64>>()),
                preempted_wait: Percentiles::of(
                    &of.iter().map(|c| c.preempted_wait).collect::<Vec<f64>>(),
                ),
            })
        })
        .collect();
    Ok(ClusterReport {
        balancer,
        scheduler: cfg.scheduler,
        prefill_chunk: cfg.prefill_chunk.max(1),
        lookahead: cfg.spec.lookahead,
        n_requests: completions.len(),
        output_tokens,
        completed: completed_set.len(),
        cancelled,
        rejected,
        failed,
        retries: out.retries,
        migrations: out.migrations,
        steals: out.steals,
        live_steals: out.live_steals,
        promotions,
        injected,
        recovered,
        recovery_wait: Percentiles::of(&recovery_waits),
        outcomes,
        goodput_tokens,
        goodput_per_sec: if makespan > 0.0 { goodput_tokens as f64 / makespan } else { 0.0 },
        makespan,
        tokens_per_sec: if makespan > 0.0 { output_tokens as f64 / makespan } else { 0.0 },
        hit_rate: if lookups > 0 { hits as f64 / lookups as f64 } else { 0.0 },
        queue_wait: Percentiles::of(&queue_waits),
        ttft: Percentiles::of(&ttfts),
        tpot: Percentiles::of(&tpots),
        latency: Percentiles::of(&latencies),
        pcie_gb: pcie_bytes / 1e9,
        stall_seconds,
        overlapped_seconds,
        h2d_seconds,
        overlap_fraction: crate::metrics::overlap_fraction(overlapped_seconds, stall_seconds),
        preemptions,
        degraded_token_frac: crate::metrics::degraded_frac(degraded, assignments),
        h2d_bytes_by_tier,
        d2h_bytes_by_tier,
        priorities,
        replicas,
        trace,
    })
}

/// Run the same config under several balancers (identical traffic).
pub fn compare(cfg: &ClusterConfig, names: &[&str]) -> Result<Vec<ClusterReport>> {
    names
        .iter()
        .map(|n| {
            let mut b = balancer::by_name(n)?;
            run_cluster(cfg, b.as_mut())
        })
        .collect()
}

/// Comparison table over fleet metrics (the repro-harness rendering).
pub fn comparison_table(reports: &[ClusterReport]) -> Table {
    let mut t = Table::new(&[
        "balancer",
        "replicas",
        "tok/s",
        "goodput tok/s",
        "hit rate",
        "PCIe GB",
        "degraded",
        "queue p50/p95/p99 (s)",
        "latency p50/p95/p99 (s)",
    ]);
    for r in reports {
        t.row(vec![
            r.balancer.clone(),
            r.replicas.len().to_string(),
            fmt2(r.tokens_per_sec),
            fmt2(r.goodput_per_sec),
            format!("{:.3}", r.hit_rate),
            fmt2(r.pcie_gb),
            format!("{:.3}", r.degraded_token_frac),
            r.queue_wait.cell(1.0),
            r.latency.cell(1.0),
        ]);
    }
    t
}

/// The pre-event-queue per-step polling loop, frozen verbatim as the
/// determinism oracle: [`run_cluster`]'s event core must reproduce this
/// loop's report bit for bit under the same seeds (with steal and aging
/// off — this loop predates both knobs and ignores them).
#[cfg(test)]
fn run_cluster_polling(cfg: &ClusterConfig, bal: &mut dyn Balancer) -> Result<ClusterReport> {
    use std::collections::VecDeque;

    let requests = cfg.requests();
    let n_expected = requests.len();
    let mut reps: Vec<Replica> = (0..cfg.replicas.max(1))
        .map(|i| {
            Replica::new(i, cfg.spec.clone(), cfg.scheduler)
                .with_prefill_chunk(cfg.prefill_chunk)
                .with_preempt(cfg.preempt)
                .with_admission(cfg.admission)
                .with_trace(cfg.trace)
        })
        .collect();
    let mut drec = if cfg.trace {
        Recorder::on(cfg.replicas.max(1) as u32, "dispatcher")
    } else {
        Recorder::off()
    };
    let max_queue = cfg.max_queue.max(1);
    let n_replicas = reps.len();
    let plan = FaultPlan::generate(&cfg.faults, n_replicas, cfg.workload.fault_seed());
    let faults_on = !plan.is_empty();
    let mut detector = PhiDetector::new(n_replicas, (cfg.faults.mtbf / 8.0).max(1e-9), 2.0);
    let mut arrivals: VecDeque<ClusterRequest> = requests.into();
    let mut fault_events: VecDeque<_> = plan.events.into();
    let mut pending: Vec<RetryEntry> = Vec::new();
    let mut attempts: HashMap<u64, u32> = HashMap::new();
    let mut first_reclaim: HashMap<u64, f64> = HashMap::new();
    let mut injected_ids: HashSet<u64> = HashSet::new();
    let mut failed_terminals: Vec<Completion> = Vec::new();
    let (mut retries_total, mut migrations_total) = (0u64, 0u64);
    loop {
        let t_arr = arrivals.front().map(|r| r.at);
        let t_retry = pending
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.ready_at.total_cmp(&b.1.ready_at))
            .map(|(i, e)| (i, e.ready_at));
        let fleet_busy = reps.iter().any(|r| r.has_work());
        let t_fault = if t_arr.is_none() && t_retry.is_none() && !fleet_busy {
            None
        } else {
            fault_events.front().map(|e| e.at)
        };
        let ta = t_arr.unwrap_or(f64::INFINITY);
        let tr = t_retry.map_or(f64::INFINITY, |(_, t)| t);
        let tf = t_fault.unwrap_or(f64::INFINITY);
        let now = ta.min(tr).min(tf);
        if !now.is_finite() {
            break;
        }
        for r in &mut reps {
            r.run_until(now, cfg.max_batch);
        }
        if faults_on {
            for r in &mut reps {
                r.refresh_health(now);
            }
            for (i, r) in reps.iter().enumerate() {
                if r.health() != Health::Down {
                    drec.emit(
                        now,
                        TraceEvent::Heartbeat { replica: i as u32, phi: detector.phi(i, now) },
                    );
                    detector.beat(i, now);
                }
            }
            let any_down = reps.iter().any(|r| r.health() == Health::Down);
            for r in &mut reps {
                if r.health() != Health::Down {
                    r.set_fallback_escalation(any_down);
                }
            }
        }
        let (req, attempt) = if ta <= tr && ta <= tf {
            (arrivals.pop_front().expect("arrival front exists"), 0)
        } else if tr <= tf {
            let (i, _) = t_retry.expect("retry minimum exists");
            let e = pending.swap_remove(i);
            (e.req, e.attempt)
        } else {
            let f = fault_events.pop_front().expect("fault front exists");
            let i = f.replica.min(n_replicas - 1);
            match f.kind {
                FaultKind::Crash => {
                    let back_up = now + cfg.faults.recovery.max(1e-9);
                    for req in reps[i].crash(back_up) {
                        injected_ids.insert(req.id);
                        first_reclaim.entry(req.id).or_insert(now);
                        let a = attempts.entry(req.id).or_insert(0);
                        if *a >= cfg.retry.max_retries {
                            drec.emit(now, TraceEvent::RequestFailed { request: req.id });
                            failed_terminals.push(Completion {
                                request_id: req.id,
                                task: req.task,
                                priority: req.priority,
                                arrival: req.at,
                                started: now,
                                first_token: now,
                                finished: now,
                                output_tokens: 0,
                                preempted_wait: 0.0,
                                outcome: Outcome::Failed,
                                deadline: req.deadline,
                            });
                        } else {
                            *a += 1;
                            let ready_at = now + cfg.retry.delay(*a - 1);
                            pending.push(RetryEntry { ready_at, attempt: *a, req });
                        }
                    }
                }
                FaultKind::Brownout { factor, duration } => {
                    reps[i].set_brownout(factor, now + duration);
                    for m in reps[i].extract_live() {
                        let mut best: Option<(usize, f64)> = None;
                        for (j, r) in reps.iter().enumerate() {
                            if j == i || !r.health().dispatchable() {
                                continue;
                            }
                            let load = (r.queue_depth() + r.slots_in_use()) as f64;
                            let score = r.affinity_overlap(&m.req.plan) - 0.1 * load;
                            if best.map_or(true, |(_, s)| score > s) {
                                best = Some((j, score));
                            }
                        }
                        match best {
                            Some((j, _)) => {
                                migrations_total += 1;
                                drec.emit(
                                    now,
                                    TraceEvent::Migrate {
                                        request: m.req.id,
                                        from: i as u32,
                                        to: j as u32,
                                    },
                                );
                                reps[j].adopt(m, now);
                            }
                            None => reps[i].adopt(m, now),
                        }
                    }
                }
                FaultKind::LinkFlap { factor, duration } => {
                    reps[i].apply_link_flap(factor, now + duration);
                }
                FaultKind::Corrupt => {
                    let _ = reps[i].corrupt_transfer();
                }
            }
            continue;
        };
        if !reps.iter().any(|r| r.health().dispatchable()) {
            let ready_at = reps
                .iter()
                .filter(|r| r.health() == Health::Down)
                .map(|r| r.recover_at())
                .fold(f64::INFINITY, f64::min);
            ensure!(ready_at.is_finite(), "no replica is dispatchable or recovering");
            pending.push(RetryEntry { ready_at: ready_at.max(now), attempt, req });
            continue;
        }
        while reps
            .iter()
            .filter(|r| r.health().dispatchable())
            .all(|r| r.queue_depth() >= max_queue)
        {
            let i = reps
                .iter()
                .enumerate()
                .filter(|(_, r)| r.has_work() && r.health().dispatchable())
                .min_by(|(_, a), (_, b)| a.clock.now().total_cmp(&b.clock.now()))
                .map(|(i, _)| i)
                .expect("full queues imply outstanding dispatchable work");
            reps[i].run_one_step(cfg.max_batch);
        }
        let views: Vec<ReplicaView> = reps
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut health = r.health();
                if faults_on && health != Health::Down && detector.suspect(i, now) {
                    health = Health::Down;
                }
                ReplicaView {
                    id: r.id,
                    queue_depth: r.queue_depth(),
                    slots_in_use: r.slots_in_use(),
                    busy_until: r.busy_until(),
                    overlap: r.affinity_overlap(&req.plan),
                    low_load: 0,
                    health,
                }
            })
            .collect();
        let mut choice = bal.pick(&req, &views).min(n_replicas - 1);
        if !views[choice].dispatchable() || reps[choice].queue_depth() >= max_queue {
            choice = views
                .iter()
                .filter(|v| v.dispatchable() && v.queue_depth < max_queue)
                .min_by(|a, b| {
                    a.queue_depth.cmp(&b.queue_depth).then(a.busy_until.total_cmp(&b.busy_until))
                })
                .map(|v| v.id)
                .expect("back-pressure loop freed a dispatchable queue");
        }
        ensure!(
            reps[choice].health().dispatchable(),
            "dispatched request {} to Down replica {}",
            req.id,
            choice
        );
        if attempt > 0 {
            retries_total += 1;
            drec.emit(now, TraceEvent::Retry { request: req.id, attempt, replica: choice as u32 });
            let lag = now - reps[choice].clock.now();
            if lag > 0.0 {
                reps[choice].clock.advance(lag);
            }
        }
        if drec.enabled() {
            drec.emit(
                now,
                TraceEvent::Dispatch {
                    request: req.id,
                    replica: choice as u32,
                    score: bal.score(&views[choice]),
                },
            );
        }
        reps[choice].enqueue(req);
    }
    let outcome = FleetOutcome {
        n_expected,
        failed_terminals,
        retries: retries_total,
        migrations: migrations_total,
        steals: 0,
        live_steals: 0,
        injected_ids,
        first_reclaim,
        faults_on,
    };
    finalize(cfg, bal.name().to_string(), reps, drec, outcome)
}

#[cfg(test)]
mod tests {
    use super::workload::{OutputLen, PriorityMix, StreamMix, TaskProfile};
    use super::*;
    use crate::clock::GpuSpec;
    use crate::coordinator::workload::Arrival;
    use crate::coordinator::PreemptPolicy;
    use crate::fault::{FaultSpec, RetryPolicy};
    use crate::quant::QuantMode;

    /// Small-but-real config: heterogeneous tasks, saturated arrivals.
    /// Balanced stream volumes (the synthetic default) make the balancer
    /// comparison deterministic: every dispatcher serves the same number
    /// of requests per replica, so throughput differences come purely
    /// from batch purity (cache behaviour), not task-count luck.
    fn small_cfg(replicas: usize, seed: u64) -> ClusterConfig {
        let mut cfg = ClusterConfig::synthetic(replicas, 48, 4, GpuSpec::h100(), seed);
        // shrink the model so unit tests stay fast
        cfg.spec.n_layers = 4;
        cfg.spec.n_experts = 32;
        cfg.spec.top_k = 8;
        cfg.spec.capacity = 8;
        cfg.tasks = TaskProfile::synthetic(4, 4, 32, 8, 0.92);
        cfg.workload.prompt_tokens = 2;
        cfg.workload.output = OutputLen::Fixed(8);
        cfg
    }

    #[test]
    fn every_arrival_dispatched_exactly_once() {
        let cfg = small_cfg(3, 11);
        for name in BALANCERS {
            let mut b = balancer::by_name(name).unwrap();
            let rep = run_cluster(&cfg, b.as_mut()).unwrap();
            assert_eq!(rep.n_requests, cfg.workload.n_requests, "{name}");
            let total: usize = rep.replicas.iter().map(|r| r.requests).sum();
            assert_eq!(total, cfg.workload.n_requests, "{name}: dispatched exactly once");
        }
    }

    #[test]
    fn admission_bound_respected() {
        let cfg = small_cfg(2, 13)
            .with_arrival(crate::coordinator::workload::Arrival::Burst)
            .with_max_queue(3);
        for name in BALANCERS {
            let mut b = balancer::by_name(name).unwrap();
            let rep = run_cluster(&cfg, b.as_mut()).unwrap();
            assert_eq!(rep.n_requests, cfg.workload.n_requests, "{name}: lossless");
            for rs in &rep.replicas {
                assert!(
                    rs.peak_queue_depth <= 3,
                    "{name}: replica {} peaked at {}",
                    rs.id,
                    rs.peak_queue_depth
                );
            }
        }
    }

    #[test]
    fn affinity_beats_round_robin_on_heterogeneous_traffic() {
        // burst arrivals saturate the fleet, so makespan (and therefore
        // tokens/s) is determined by serving efficiency alone
        let cfg = small_cfg(4, 17).with_arrival(crate::coordinator::workload::Arrival::Burst);
        let reports = compare(&cfg, BALANCERS).unwrap();
        let rr = &reports[0];
        let affinity = &reports[2];
        assert!(
            affinity.hit_rate > rr.hit_rate,
            "affinity hit rate {} <= round-robin {}",
            affinity.hit_rate,
            rr.hit_rate
        );
        assert!(
            affinity.tokens_per_sec > rr.tokens_per_sec,
            "affinity tok/s {} <= round-robin {}",
            affinity.tokens_per_sec,
            rr.tokens_per_sec
        );
        // less PCIe traffic is the mechanism
        assert!(affinity.pcie_gb < rr.pcie_gb);
    }

    /// Property: for random fleet sizes, admission bounds, balancers,
    /// scheduler modes and seeds, the cluster loop dispatches every
    /// arrival exactly once and never lets a replica's queue exceed the
    /// admission bound.
    #[test]
    fn prop_dispatch_once_and_admission_bound() {
        use crate::util::prop::check_no_shrink;
        check_no_shrink(
            30,
            |r| {
                let replicas = r.range(1, 5);
                let bound = r.range(1, 6);
                let balancer_idx = r.below(BALANCERS.len());
                let continuous = r.below(2) == 0;
                let seed = r.next_u64();
                (replicas, bound, balancer_idx, continuous, seed)
            },
            |&(replicas, bound, balancer_idx, continuous, seed)| {
                let mut cfg = small_cfg(replicas, seed);
                cfg.workload.n_requests = 12;
                cfg = cfg
                    .with_arrival(crate::coordinator::workload::Arrival::Burst)
                    .with_max_queue(bound)
                    .with_scheduler(if continuous {
                        SchedulerMode::Continuous
                    } else {
                        SchedulerMode::Static
                    });
                let mut b = balancer::by_name(BALANCERS[balancer_idx]).unwrap();
                let rep = run_cluster(&cfg, b.as_mut()).unwrap();
                let total: usize = rep.replicas.iter().map(|r| r.requests).sum();
                rep.n_requests == 12
                    && total == 12
                    && rep.replicas.iter().all(|r| r.peak_queue_depth <= bound)
            },
        );
    }

    #[test]
    fn identical_traffic_across_balancers_and_schedulers() {
        // comparisons are meaningful only if the workload is identical
        let cfg = small_cfg(2, 19);
        let a = cfg.requests();
        let b = cfg.clone().with_scheduler(SchedulerMode::Static).requests();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.task, y.task);
            assert_eq!(x.max_output, y.max_output);
            assert_eq!(x.routing, y.routing);
        }
    }

    #[test]
    fn report_accounting_consistent() {
        let cfg = small_cfg(2, 23);
        let mut b = balancer::by_name("expert-affinity").unwrap();
        let rep = run_cluster(&cfg, b.as_mut()).unwrap();
        assert_eq!(rep.output_tokens, cfg.workload.n_requests * cfg.workload.output.cap());
        assert!(rep.makespan > 0.0);
        assert!(rep.tokens_per_sec > 0.0);
        // streaming knobs off: every request completes, and goodput is
        // exactly raw throughput (deadline-free requests always attain)
        assert_eq!(rep.completed, rep.n_requests);
        assert_eq!(rep.cancelled, 0);
        assert_eq!(rep.rejected, 0);
        assert_eq!(rep.goodput_tokens, rep.output_tokens);
        assert!((rep.goodput_per_sec - rep.tokens_per_sec).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&rep.hit_rate));
        assert!(rep.latency.p50 <= rep.latency.p99);
        assert!(rep.queue_wait.p50 <= rep.queue_wait.p99);
        assert!(rep.ttft.p50 <= rep.latency.p50, "first token lands before retirement");
        assert!(rep.tpot.p50 > 0.0);
        let per_replica_gb: f64 = rep.replicas.iter().map(|r| r.pcie_gb).sum();
        assert!((per_replica_gb - rep.pcie_gb).abs() < 1e-9);
        // overlap accounting: fleet totals are the per-replica sums and
        // the fraction is a valid ratio
        let per_replica_stall: f64 = rep.replicas.iter().map(|r| r.stall_seconds).sum();
        assert!((per_replica_stall - rep.stall_seconds).abs() < 1e-9);
        let per_replica_ovl: f64 = rep.replicas.iter().map(|r| r.overlapped_seconds).sum();
        assert!((per_replica_ovl - rep.overlapped_seconds).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&rep.overlap_fraction));
        assert_eq!(rep.lookahead, 0, "synthetic default is admit-only prefetch");
        // priority-free default: no preemptions, one all-Normal class
        assert_eq!(rep.preemptions, 0);
        assert_eq!(rep.priorities.len(), 1);
        assert_eq!(rep.priorities[0].priority, Priority::Normal);
        assert_eq!(rep.priorities[0].requests, rep.n_requests);
        assert_eq!(rep.priorities[0].preempted_wait.p99, 0.0);
        assert!(rep.replicas.iter().all(|r| r.preemptions == 0));
        // steal and aging off by default: both stay inert
        assert_eq!(rep.steals, 0);
        assert_eq!(rep.live_steals, 0);
        assert_eq!(rep.promotions, 0);
        // fallback off by default: nothing degraded, and every byte of
        // H2D traffic rode the serving tier (int4 for the synthetic
        // OLMoE spec) — no fp16 or little-tier traffic
        assert_eq!(rep.degraded_token_frac, 0.0);
        assert!(rep.replicas.iter().all(|r| r.degraded_token_frac == 0.0));
        let tier_sum: f64 = rep.h2d_bytes_by_tier.iter().sum();
        assert!((tier_sum / 1e9 - rep.pcie_gb).abs() < 1e-9);
        assert_eq!(rep.h2d_bytes_by_tier[QuantMode::Fp16.idx()], 0.0);
        assert!(rep.h2d_bytes_by_tier[QuantMode::Int4.idx()] > 0.0);
        assert_eq!(rep.h2d_bytes_by_tier[QuantMode::Int3.idx()], 0.0);
        let table = comparison_table(&[rep]);
        assert!(table.render().contains("expert-affinity"));
    }

    /// Big-little fallback fleet-wide: int4 big copies, int3 little
    /// copies, zero-threshold fallback.  The conservation audits inside
    /// `run_cluster` (per-tier byte reconcile, occupancy replay with
    /// mixed tiers) must pass, and the degraded fraction must be a valid
    /// ratio sourced only from the two low-bit tiers.
    #[test]
    fn fallback_cluster_traces_reconcile() {
        let cfg = small_cfg(2, 29)
            .with_quant(QuantMode::Int4)
            .with_fallback(Some(QuantMode::Int3), 0.0)
            .with_trace(true);
        let mut b = balancer::by_name("least-loaded").unwrap();
        let rep = run_cluster(&cfg, b.as_mut()).unwrap();
        assert_eq!(rep.n_requests, cfg.workload.n_requests);
        assert!((0.0..=1.0).contains(&rep.degraded_token_frac));
        assert!(rep.trace.is_some());
        // demand/prefetch traffic is int4; little installs ride int3;
        // nothing moves at fp16
        assert!(rep.h2d_bytes_by_tier[1] > 0.0);
        assert_eq!(rep.h2d_bytes_by_tier[0], 0.0);
        let tier_sum: f64 = rep.h2d_bytes_by_tier.iter().sum();
        assert!((tier_sum / 1e9 - rep.pcie_gb).abs() < 1e-9);
    }

    /// Deadline-heavy burst overload: SLO-aware admission strictly
    /// improves goodput over serving everything — rejecting a deadline
    /// the optimistic estimate already misses frees its slots and
    /// compute for requests that can still attain.
    #[test]
    fn admission_improves_goodput_under_deadline_overload() {
        let base = small_cfg(2, 31);
        let slack = 3.0
            * base
                .spec
                .est_service_seconds(base.workload.prompt_tokens, base.workload.output.cap());
        let run = |admission: bool| {
            let cfg = base
                .clone()
                .with_arrival(Arrival::Burst)
                .with_stream_mix(StreamMix {
                    deadline_frac: 0.8,
                    deadline_slack: slack,
                    cancel_frac: 0.0,
                    cancel_after: 0,
                    disconnect_frac: 0.0,
                })
                .with_admission(admission);
            let mut b = balancer::by_name("least-loaded").unwrap();
            run_cluster(&cfg, b.as_mut()).unwrap()
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.rejected, 0, "no admission control, nothing rejected");
        assert_eq!(off.completed, off.n_requests);
        assert!(
            off.goodput_tokens < off.output_tokens,
            "overload must make some deadline requests miss"
        );
        assert!(on.rejected > 0, "admission must turn the hopeless tail away");
        assert_eq!(on.completed + on.rejected, on.n_requests);
        assert!(
            on.goodput_per_sec > off.goodput_per_sec,
            "admission goodput {} must beat no-admission {}",
            on.goodput_per_sec,
            off.goodput_per_sec
        );
    }

    /// Cancel storm (cancel-after-1 plus queue disconnects) with tracing
    /// on: `run_cluster`'s conservation audits — pin ledger, occupancy,
    /// PCIe reconcile — must balance, proving cancelled sequences leak
    /// zero pins or reservations, and every request still gets exactly
    /// one terminal outcome.
    #[test]
    fn cancel_storm_leaks_nothing_and_audits_balance() {
        let cfg = small_cfg(2, 37)
            .with_stream_mix(StreamMix {
                deadline_frac: 0.0,
                deadline_slack: 0.0,
                cancel_frac: 0.4,
                cancel_after: 1,
                disconnect_frac: 0.15,
            })
            .with_trace(true);
        let mut b = balancer::by_name("expert-affinity").unwrap();
        let rep = run_cluster(&cfg, b.as_mut()).unwrap();
        assert_eq!(rep.n_requests, cfg.workload.n_requests);
        assert!(rep.cancelled > 0, "the storm must actually cancel something");
        assert_eq!(rep.completed + rep.cancelled + rep.rejected, rep.n_requests);
        assert!(
            rep.output_tokens < cfg.workload.n_requests * cfg.workload.output.cap(),
            "cancel-after-1 must truncate decodes"
        );
        assert!(rep.goodput_tokens <= rep.output_tokens);
        assert!(rep.trace.is_some(), "audited lanes merge into the fleet timeline");
    }

    #[test]
    fn with_quant_preserves_byte_budget() {
        let cfg = small_cfg(1, 7); // synthetic spec serves at int4
        assert_eq!(cfg.spec.quant, QuantMode::Int4);
        let bytes = cfg.spec.capacity as f64 * cfg.spec.quant.cost_units();
        // same tier: exact no-op (cost units are exact binary fractions)
        let same = cfg.clone().with_quant(QuantMode::Int4);
        assert_eq!(same.spec.capacity, cfg.spec.capacity);
        // fp16 at the same bytes holds ~3.6× fewer experts, never zero
        let fp16 = cfg.clone().with_quant(QuantMode::Fp16);
        assert_eq!(fp16.spec.quant, QuantMode::Fp16);
        assert!(fp16.spec.capacity >= 1 && fp16.spec.capacity < cfg.spec.capacity);
        let fp16_bytes = fp16.spec.capacity as f64 * QuantMode::Fp16.cost_units();
        assert!(fp16_bytes <= bytes + 1e-12, "rescaling never grows the budget");
        // int3 holds more experts in the same bytes (clamped to n_experts)
        let int3 = cfg.with_quant(QuantMode::Int3);
        assert!(int3.spec.capacity > same.spec.capacity);
        assert!(int3.spec.capacity <= int3.spec.n_experts);
    }

    // ------------------------------------------------------ fault tolerance

    /// Arming the retry policy without a fault plan is fully inert: the
    /// report — makespan bits included — is identical to the default
    /// config, and no fault accounting appears.
    #[test]
    fn fault_free_run_is_bit_identical_with_retry_armed() {
        let base = small_cfg(2, 41);
        let armed =
            base.clone().with_faults(FaultSpec::none()).with_retry(RetryPolicy::retries(3, 0.5));
        let mut b1 = balancer::by_name("expert-affinity").unwrap();
        let mut b2 = balancer::by_name("expert-affinity").unwrap();
        let r1 = run_cluster(&base, b1.as_mut()).unwrap();
        let r2 = run_cluster(&armed, b2.as_mut()).unwrap();
        assert_eq!(r1.makespan.to_bits(), r2.makespan.to_bits());
        assert_eq!(r1.hit_rate.to_bits(), r2.hit_rate.to_bits());
        assert_eq!(r1.outcomes, r2.outcomes);
        assert_eq!(r2.injected, 0);
        assert_eq!(r2.retries, 0);
        assert_eq!(r2.migrations, 0);
        assert_eq!(r2.failed, 0);
        assert_eq!(r2.recovery_wait.p99, 0.0);
    }

    /// Crash storm with a generous retry budget: every reclaimed request
    /// recovers, terminals still partition the workload exactly, the
    /// conservation audits inside `run_cluster` pass with tracing on,
    /// and every Completed request decodes the same tokens as the
    /// fault-free run.
    #[test]
    fn crash_storm_with_retry_recovers_and_stays_bit_identical() {
        let base = small_cfg(2, 43).with_arrival(Arrival::Burst);
        let est = base
            .spec
            .est_service_seconds(base.workload.prompt_tokens, base.workload.output.cap());
        let storm = base
            .clone()
            .with_faults(FaultSpec::crash_storm(est / 2.0, 4.0 * est, est / 2.0))
            .with_retry(RetryPolicy::retries(24, est / 8.0))
            .with_trace(true);
        let mut b1 = balancer::by_name("least-loaded").unwrap();
        let mut b2 = balancer::by_name("least-loaded").unwrap();
        let clean = run_cluster(&base, b1.as_mut()).unwrap();
        let rep = run_cluster(&storm, b2.as_mut()).unwrap();
        assert_eq!(rep.n_requests, storm.workload.n_requests);
        assert_eq!(
            rep.completed + rep.cancelled + rep.rejected + rep.failed,
            rep.n_requests,
            "terminal outcomes must partition the workload"
        );
        assert!(rep.injected > 0, "the storm must reclaim something");
        assert_eq!(rep.injected, rep.recovered + rep.failed);
        assert!(rep.retries >= (rep.injected - rep.failed) as u64);
        assert!(rep.trace.is_some(), "audited lanes merged");
        // Completed requests decode identical output to the clean run
        let clean_tokens: HashMap<u64, usize> = clean
            .outcomes
            .iter()
            .filter(|(_, o, _)| *o == Outcome::Completed)
            .map(|&(id, _, tok)| (id, tok))
            .collect();
        for &(id, o, tok) in &rep.outcomes {
            if o == Outcome::Completed {
                assert_eq!(Some(&tok), clean_tokens.get(&id), "request {id} token drift");
            }
        }
    }

    /// Disconnects and mid-decode hang-ups racing replica crashes: each
    /// request must still resolve with exactly one terminal outcome and
    /// release its pin-ledger entry exactly once — both enforced inside
    /// `run_cluster` (terminal-uniqueness bail + per-lane pin audits).
    #[test]
    fn disconnect_racing_crash_keeps_terminals_unique() {
        let base = small_cfg(2, 47);
        let est = base
            .spec
            .est_service_seconds(base.workload.prompt_tokens, base.workload.output.cap());
        let cfg = base
            .with_arrival(Arrival::Burst)
            .with_stream_mix(StreamMix {
                deadline_frac: 0.0,
                deadline_slack: 0.0,
                cancel_frac: 0.3,
                cancel_after: 1,
                disconnect_frac: 0.25,
            })
            .with_faults(FaultSpec::crash_storm(est / 2.0, 4.0 * est, est / 2.0))
            .with_retry(RetryPolicy::retries(16, est / 8.0))
            .with_trace(true);
        let mut b = balancer::by_name("expert-affinity").unwrap();
        let rep = run_cluster(&cfg, b.as_mut()).unwrap();
        assert_eq!(rep.n_requests, cfg.workload.n_requests);
        assert_eq!(rep.completed + rep.cancelled + rep.rejected + rep.failed, rep.n_requests);
        assert!(rep.cancelled > 0, "the mix must actually cancel something");
        assert_eq!(rep.injected, rep.recovered + rep.failed);
        let mut ids: Vec<u64> = rep.outcomes.iter().map(|o| o.0).collect();
        ids.dedup();
        assert_eq!(ids.len(), rep.n_requests, "one terminal per request");
    }

    /// Retry off under the same storm: reclaimed requests fail terminally
    /// on first reclaim, while retry on strictly lifts the completed
    /// fraction — the degradation the `--retry` knob exists to fix.
    #[test]
    fn retry_budget_strictly_lifts_completion_under_crashes() {
        let base = small_cfg(2, 53).with_arrival(Arrival::Burst);
        let est = base
            .spec
            .est_service_seconds(base.workload.prompt_tokens, base.workload.output.cap());
        let faults = FaultSpec::crash_storm(est / 2.0, 4.0 * est, est / 2.0);
        let run = |retry: RetryPolicy| {
            let cfg = base.clone().with_faults(faults.clone()).with_retry(retry);
            let mut b = balancer::by_name("round-robin").unwrap();
            run_cluster(&cfg, b.as_mut()).unwrap()
        };
        let off = run(RetryPolicy::off());
        let on = run(RetryPolicy::retries(24, est / 8.0));
        assert!(off.failed > 0, "without retries a reclaimed request is lost");
        assert_eq!(off.injected, off.recovered + off.failed);
        assert_eq!(off.retries, 0);
        assert!(
            on.completed > off.completed,
            "retry on ({}) must strictly beat retry off ({})",
            on.completed,
            off.completed
        );
        assert_eq!(on.injected, on.recovered + on.failed);
    }

    // --------------------------------------------------- event-core oracle

    /// The event-driven loop and the frozen polling loop must agree to
    /// the bit on every comparable metric.
    fn assert_matches_polling(cfg: &ClusterConfig, name: &str) {
        let mut b1 = balancer::by_name(name).unwrap();
        let mut b2 = balancer::by_name(name).unwrap();
        let ev = run_cluster(cfg, b1.as_mut()).unwrap();
        let poll = run_cluster_polling(cfg, b2.as_mut()).unwrap();
        assert_eq!(ev.makespan.to_bits(), poll.makespan.to_bits(), "{name}: makespan drift");
        assert_eq!(ev.hit_rate.to_bits(), poll.hit_rate.to_bits(), "{name}: hit-rate drift");
        assert_eq!(
            ev.tokens_per_sec.to_bits(),
            poll.tokens_per_sec.to_bits(),
            "{name}: tok/s drift"
        );
        assert_eq!(
            ev.latency.p99.to_bits(),
            poll.latency.p99.to_bits(),
            "{name}: latency drift"
        );
        assert_eq!(ev.pcie_gb.to_bits(), poll.pcie_gb.to_bits(), "{name}: PCIe drift");
        assert_eq!(ev.outcomes, poll.outcomes, "{name}: outcome drift");
        assert_eq!(ev.retries, poll.retries, "{name}: retry drift");
        assert_eq!(ev.migrations, poll.migrations, "{name}: migration drift");
        assert_eq!(ev.steals, 0, "{name}: steal must stay inert");
        assert_eq!(ev.promotions, 0, "{name}: aging must stay inert");
    }

    /// Determinism oracle, ext_cluster shape: Poisson and burst traffic
    /// across fleet sizes under every stock balancer, plus a traced run
    /// (the recorded timelines pass the same audits on both loops).
    #[test]
    fn event_core_matches_polling_loop_bit_for_bit() {
        for &replicas in &[2usize, 4] {
            for name in BALANCERS {
                assert_matches_polling(&small_cfg(replicas, 61), name);
                assert_matches_polling(
                    &small_cfg(replicas, 62).with_arrival(Arrival::Burst).with_max_queue(5),
                    name,
                );
            }
        }
        assert_matches_polling(&small_cfg(3, 63).with_trace(true), "expert-affinity");
    }

    /// Determinism oracle, ext_fault shape: crash storms and the
    /// all-kinds mixed storm with retries, traced — the merged fault
    /// timeline pops in exactly the order the polling loop processed it.
    #[test]
    fn event_core_matches_polling_loop_under_fault_storms() {
        let base = small_cfg(2, 43).with_arrival(Arrival::Burst);
        let est = base
            .spec
            .est_service_seconds(base.workload.prompt_tokens, base.workload.output.cap());
        let storm = base
            .clone()
            .with_faults(FaultSpec::crash_storm(est / 2.0, 4.0 * est, est / 2.0))
            .with_retry(RetryPolicy::retries(24, est / 8.0))
            .with_trace(true);
        for name in BALANCERS {
            assert_matches_polling(&storm, name);
        }
        let mixed = base
            .with_faults(FaultSpec::mixed(est / 2.0, 4.0 * est, est / 2.0))
            .with_retry(RetryPolicy::retries(16, est / 8.0));
        assert_matches_polling(&mixed, "expert-affinity");
    }

    // ------------------------------------------------------- work stealing

    /// A steal tick that can never fire (interval beyond the horizon)
    /// leaves the run bit-identical to an unarmed config.
    #[test]
    fn never_firing_steal_tick_is_inert() {
        let base = small_cfg(2, 71);
        let armed = base.clone().with_steal(Some(StealPolicy::every(1e9)));
        let mut b1 = balancer::by_name("expert-affinity").unwrap();
        let mut b2 = balancer::by_name("expert-affinity").unwrap();
        let r1 = run_cluster(&base, b1.as_mut()).unwrap();
        let r2 = run_cluster(&armed, b2.as_mut()).unwrap();
        assert_eq!(r1.makespan.to_bits(), r2.makespan.to_bits());
        assert_eq!(r1.outcomes, r2.outcomes);
        assert_eq!(r2.steals, 0);
        assert_eq!(r2.live_steals, 0);
    }

    /// Zipf-imbalanced burst traffic under affinity dispatch piles the
    /// head task's backlog onto the warm replicas; with stealing armed,
    /// drained replicas take from that backlog.  Conservation must hold
    /// (every request still one terminal, audits balance with tracing
    /// on) and the steal/counter ledgers must agree.
    #[test]
    fn idle_replica_steals_queued_backlog_from_loaded_peer() {
        let mut base = small_cfg(2, 73).with_arrival(Arrival::Burst);
        workload::zipf_weights(&mut base.tasks, 1.5);
        base.workload.balanced_tasks = false;
        let est = base
            .spec
            .est_service_seconds(base.workload.prompt_tokens, base.workload.output.cap());
        let armed =
            base.clone().with_steal(Some(StealPolicy::every(est / 4.0))).with_trace(true);
        let mut b1 = balancer::by_name("expert-affinity").unwrap();
        let mut b2 = balancer::by_name("expert-affinity").unwrap();
        let off = run_cluster(&base, b1.as_mut()).unwrap();
        let on = run_cluster(&armed, b2.as_mut()).unwrap();
        assert!(on.steals > 0, "imbalanced backlog must trigger steals");
        assert!(on.live_steals <= on.steals);
        assert_eq!(on.completed, on.n_requests, "stolen requests still complete");
        assert_eq!(off.completed, off.n_requests);
        let total: usize = on.replicas.iter().map(|r| r.requests).sum();
        assert_eq!(total, on.n_requests, "each request exactly one terminal home");
        assert!(on.trace.is_some(), "Steal events passed the counter audit");
        // same decoded tokens per completed request: stealing moves work,
        // never alters the pre-drawn routing
        assert_eq!(
            on.outcomes.iter().map(|o| o.2).sum::<usize>(),
            off.outcomes.iter().map(|o| o.2).sum::<usize>()
        );
    }

    // --------------------------------------------------- age-based promotion

    /// Sustained 80%-High burst flood over a starved Low minority with
    /// zero-threshold preemption: without aging the Low class's
    /// suspended wait grows unboundedly with the flood; with aging on,
    /// promotion caps it.  (A promoted request completes in its
    /// promoted class, so the bound is asserted on the fleet-wide worst
    /// class, which includes every promoted ex-Low completion.)
    #[test]
    fn aging_bounds_starvation_under_high_flood() {
        let base = small_cfg(1, 79)
            .with_arrival(Arrival::Burst)
            .with_max_batch(2)
            .with_preempt(PreemptPolicy::After(0.0))
            .with_priority_mix(PriorityMix { high: 0.8, low: 0.2 });
        let est = base
            .spec
            .est_service_seconds(base.workload.prompt_tokens, base.workload.output.cap());
        let aged = base.clone().with_age_promote(Some(est));
        let mut b1 = balancer::by_name("round-robin").unwrap();
        let mut b2 = balancer::by_name("round-robin").unwrap();
        let off = run_cluster(&base, b1.as_mut()).unwrap();
        let on = run_cluster(&aged, b2.as_mut()).unwrap();
        let worst = |r: &ClusterReport| {
            r.priorities.iter().map(|c| c.preempted_wait.p99).fold(0.0f64, f64::max)
        };
        assert_eq!(off.promotions, 0, "aging off never promotes");
        assert!(on.promotions > 0, "the flood must age someone up");
        let low_off = off
            .priorities
            .iter()
            .find(|c| c.priority == Priority::Low)
            .expect("un-aged run completes Low requests as Low");
        assert!(
            low_off.preempted_wait.p99 > 0.0,
            "the flood must actually starve the Low class"
        );
        assert!(
            worst(&on) < worst(&off),
            "aging must shrink the worst-class suspended wait: {} !< {}",
            worst(&on),
            worst(&off)
        );
        // conservation: promotion re-classes requests, never loses them
        assert_eq!(on.completed + on.cancelled + on.rejected, on.n_requests);
    }
}
