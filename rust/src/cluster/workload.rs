//! Cluster workload generation: open-loop arrivals over heterogeneous
//! per-task routing profiles.
//!
//! MELINOE's core observation is that a fine-tuned checkpoint routes each
//! *task's* traffic onto a small, predictable expert set (PAPER.md §3).
//! At the fleet level this means different request streams prefer
//! different experts — exactly the structure an affinity dispatcher can
//! exploit.  A [`TaskProfile`] captures one stream: a per-layer hot expert
//! set plus a concentration (the top-C share the fine-tune achieves), and
//! every generated [`ClusterRequest`] carries a pre-drawn routing trace so
//! all balancers are compared on *identical* traffic.
//!
//! Arrival shapes reuse [`crate::coordinator::workload::Arrival`] — this
//! module extends the single-replica generator with the per-task routing
//! dimension rather than replacing it.

use crate::coordinator::workload::Arrival;
use crate::coordinator::Priority;
use crate::predictor::PrefetchPlan;
use crate::util::rng::Rng;

/// One traffic stream's routing behaviour after fine-tuning.
#[derive(Debug, Clone)]
pub struct TaskProfile {
    pub id: usize,
    pub name: String,
    /// `hot[layer]` — the experts this task's routing concentrates on
    /// (what MELINOE's activation predictor would prefetch).
    pub hot: Vec<Vec<usize>>,
    /// Probability that a routing draw lands inside the hot set (the
    /// paper's top-C share; ≈0.9 after fine-tuning, Fig. 1b).
    pub concentration: f64,
    /// Relative traffic share in the arrival mix.
    pub weight: f64,
}

impl TaskProfile {
    /// Synthesize `n_tasks` profiles whose hot sets tile the expert space
    /// with minimal overlap (wrapping when `n_tasks · hot_size` exceeds
    /// `n_experts`), with a per-layer rotation so layers differ.
    pub fn synthetic(
        n_tasks: usize,
        n_layers: usize,
        n_experts: usize,
        hot_size: usize,
        concentration: f64,
    ) -> Vec<TaskProfile> {
        let hot_size = hot_size.clamp(1, n_experts);
        (0..n_tasks)
            .map(|t| {
                let hot = (0..n_layers)
                    .map(|l| {
                        let start = (t * hot_size + l * 13) % n_experts;
                        (0..hot_size).map(|i| (start + i) % n_experts).collect()
                    })
                    .collect();
                TaskProfile {
                    id: t,
                    name: format!("task{t}"),
                    hot,
                    concentration: concentration.clamp(0.0, 1.0),
                    weight: 1.0,
                }
            })
            .collect()
    }

    /// The prefetch plan MELINOE's predictor would produce for this task
    /// (per-layer hot sets — paper Eq. 7's Top-C).
    pub fn plan(&self) -> PrefetchPlan {
        PrefetchPlan { per_layer: self.hot.clone() }
    }

    /// Draw one step's top-K distinct experts for `layer`.
    pub fn draw(&self, layer: usize, top_k: usize, n_experts: usize, rng: &mut Rng) -> Vec<usize> {
        let hot = &self.hot[layer];
        let k = top_k.min(n_experts);
        let mut sel: Vec<usize> = Vec::with_capacity(k);
        let mut tries = 0usize;
        while sel.len() < k && tries < 16 * (k + 1) {
            tries += 1;
            let e = if !hot.is_empty() && rng.f64() < self.concentration {
                hot[rng.below(hot.len())]
            } else {
                rng.below(n_experts)
            };
            if !sel.contains(&e) {
                sel.push(e);
            }
        }
        // deterministic fill if the concentrated draw saturated (e.g. a
        // hot set smaller than K at concentration 1.0)
        let mut next = 0usize;
        while sel.len() < k {
            if !sel.contains(&next) {
                sel.push(next);
            }
            next += 1;
        }
        sel
    }
}

/// Reweight a task set to a Zipf traffic mix: task `i` (in id order)
/// gets weight `1 / (i+1)^alpha`.  The head task dominates arrivals —
/// the imbalance regime work stealing exists for (pair with
/// `balanced_tasks: false`, or the per-stream balancing quota undoes the
/// skew).
pub fn zipf_weights(tasks: &mut [TaskProfile], alpha: f64) {
    for (i, t) in tasks.iter_mut().enumerate() {
        t.weight = 1.0 / ((i + 1) as f64).powf(alpha);
    }
}

/// Per-request output-length distribution.  Continuous batching's win
/// case is skew: a few long sequences among many short ones — under
/// run-to-completion batching the long member holds its batch's slots
/// hostage, while step-level admission refills them immediately.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OutputLen {
    /// Every request decodes exactly this many tokens.
    Fixed(usize),
    /// `long_frac` of requests decode `long` tokens, the rest `short`.
    Bimodal { short: usize, long: usize, long_frac: f64 },
}

impl OutputLen {
    /// Upper bound over draws (the per-request token budget).
    pub fn cap(&self) -> usize {
        match *self {
            OutputLen::Fixed(n) => n,
            OutputLen::Bimodal { short, long, .. } => short.max(long),
        }
    }

    /// Expected output length.
    pub fn mean(&self) -> f64 {
        match *self {
            OutputLen::Fixed(n) => n as f64,
            OutputLen::Bimodal { short, long, long_frac } => {
                let f = long_frac.clamp(0.0, 1.0);
                long as f64 * f + short as f64 * (1.0 - f)
            }
        }
    }

    /// Draw one request's output length.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        match *self {
            OutputLen::Fixed(n) => n,
            OutputLen::Bimodal { short, long, long_frac } => {
                if rng.f64() < long_frac.clamp(0.0, 1.0) {
                    long
                } else {
                    short
                }
            }
        }
    }
}

/// Per-request priority distribution: `high` of arrivals are High,
/// `low` are Low, the rest Normal.  [`PriorityMix::none`] (all Normal)
/// consumes no randomness, so priority-free workloads stay byte-identical
/// to the pre-priority generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriorityMix {
    pub high: f64,
    pub low: f64,
}

impl PriorityMix {
    /// Every request Normal (the default; draws no randomness).
    pub fn none() -> PriorityMix {
        PriorityMix { high: 0.0, low: 0.0 }
    }

    pub fn is_none(&self) -> bool {
        self.high <= 0.0 && self.low <= 0.0
    }

    /// Draw one request's priority.
    pub fn draw(&self, rng: &mut Rng) -> Priority {
        if self.is_none() {
            return Priority::Normal;
        }
        let high = self.high.clamp(0.0, 1.0);
        let low = self.low.clamp(0.0, 1.0 - high);
        let x = rng.f64();
        if x < high {
            Priority::High
        } else if x < high + low {
            Priority::Low
        } else {
            Priority::Normal
        }
    }
}

/// Per-request streaming-client behaviour: deadlines, early cancels and
/// queue-time disconnects.  [`StreamMix::none`] (the default) consumes no
/// randomness, so streaming-free workloads stay byte-identical to the
/// pre-streaming generator (same guarantee [`PriorityMix::none`] gives).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamMix {
    /// Fraction of requests carrying a TTFT deadline.
    pub deadline_frac: f64,
    /// Slack granted to deadline-tagged requests: the deadline is
    /// `arrival + deadline_slack` (simulated seconds).
    pub deadline_slack: f64,
    /// Fraction of requests whose client hangs up after consuming
    /// `cancel_after` tokens.
    pub cancel_frac: f64,
    /// Tokens a cancelling client consumes before hanging up.
    pub cancel_after: usize,
    /// Fraction of requests whose client disconnects while still queued
    /// (never admitted; counted as cancelled-in-queue).
    pub disconnect_frac: f64,
}

impl StreamMix {
    /// No deadlines, cancels or disconnects (draws no randomness).
    pub fn none() -> StreamMix {
        StreamMix {
            deadline_frac: 0.0,
            deadline_slack: 0.0,
            cancel_frac: 0.0,
            cancel_after: 0,
            disconnect_frac: 0.0,
        }
    }

    pub fn is_none(&self) -> bool {
        self.deadline_frac <= 0.0 && self.cancel_frac <= 0.0 && self.disconnect_frac <= 0.0
    }

    /// Draw one request's streaming behaviour.  Consumes exactly three
    /// draws whenever any knob is active (so per-request traffic stays
    /// aligned when fractions change), and zero when the mix is off.
    /// Returns `(deadline, cancel_after, disconnect)` with the deadline
    /// absolute (arrival `at` + slack).
    pub fn draw(&self, rng: &mut Rng, at: f64) -> (Option<f64>, Option<usize>, bool) {
        if self.is_none() {
            return (None, None, false);
        }
        let deadline = rng.f64() < self.deadline_frac;
        let cancel = rng.f64() < self.cancel_frac;
        let disconnect = rng.f64() < self.disconnect_frac;
        (
            if deadline { Some(at + self.deadline_slack.max(0.0)) } else { None },
            if cancel { Some(self.cancel_after.max(1)) } else { None },
            disconnect,
        )
    }
}

/// One admitted request, with its routing trace pre-drawn so every
/// balancer sees byte-identical traffic.
#[derive(Debug, Clone)]
pub struct ClusterRequest {
    pub id: u64,
    pub task: usize,
    pub priority: Priority,
    /// Arrival time (simulated seconds).
    pub at: f64,
    pub prompt_tokens: usize,
    pub max_output: usize,
    /// Absolute TTFT deadline (simulated seconds); requests that cannot
    /// meet it are rejected at admission when the replica's admission
    /// control is on, and never count toward goodput when missed.
    pub deadline: Option<f64>,
    /// The client hangs up after consuming this many tokens (the request
    /// finishes `Cancelled` with a partial output).
    pub cancel_after: Option<usize>,
    /// The client disconnects while the request is still queued; it is
    /// dropped before admission as cancelled-in-queue.
    pub disconnect: bool,
    /// `routing[step][layer]` — the top-K experts this request activates
    /// at each forward step (prompt prefill steps + decode steps).
    pub routing: Vec<Vec<Vec<usize>>>,
    /// The activation predictor's prefetch sets for this request.
    pub plan: PrefetchPlan,
}

impl ClusterRequest {
    /// A routing-free probe request (balancer unit tests).
    pub fn probe(task: usize) -> ClusterRequest {
        ClusterRequest {
            id: 0,
            task,
            priority: Priority::Normal,
            at: 0.0,
            prompt_tokens: 0,
            max_output: 0,
            deadline: None,
            cancel_after: None,
            disconnect: false,
            routing: Vec::new(),
            plan: PrefetchPlan::empty(0),
        }
    }
}

/// Knobs for one generated workload.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    pub arrival: Arrival,
    pub prompt_tokens: usize,
    /// Per-request output-length distribution (the pre-drawn routing
    /// trace of each request is sized to its own draw).
    pub output: OutputLen,
    /// `true`: exact per-task proportions in a shuffled arrival order
    /// (aggregated traffic from many users — task *identity* is random
    /// per arrival but stream volumes are stable).  `false`: every
    /// arrival draws its task independently by weight.
    pub balanced_tasks: bool,
    /// Per-request priority distribution ([`PriorityMix::none`] keeps the
    /// generator's random stream byte-identical to priority-free runs).
    pub priorities: PriorityMix,
    /// Per-request streaming-client behaviour ([`StreamMix::none`] keeps
    /// the generator's random stream byte-identical to streaming-free
    /// runs).
    pub stream: StreamMix,
    pub seed: u64,
}

impl WorkloadSpec {
    /// Seed for the fault-injection RNG stream: derived from the
    /// workload seed but salted, so the fault plan is deterministic per
    /// workload yet consumes *zero* draws from the request generator —
    /// fault-free traffic stays byte-identical whether or not a fault
    /// plan was ever sampled.
    pub fn fault_seed(&self) -> u64 {
        self.seed ^ crate::fault::FAULT_SEED_SALT
    }
}

/// Generate the full request schedule: arrival process × task mix ×
/// pre-drawn per-request routing traces.
pub fn generate(
    spec: &WorkloadSpec,
    tasks: &[TaskProfile],
    n_layers: usize,
    n_experts: usize,
    top_k: usize,
) -> Vec<ClusterRequest> {
    assert!(!tasks.is_empty(), "workload needs at least one task profile");
    let mut rng = Rng::new(spec.seed);
    let total_weight: f64 = tasks.iter().map(|t| t.weight).sum();
    // balanced mode: fix each stream's volume exactly, randomize order
    let balanced_seq: Option<Vec<usize>> = if spec.balanced_tasks {
        let mut seq: Vec<usize> = (0..spec.n_requests).map(|i| i % tasks.len()).collect();
        rng.shuffle(&mut seq);
        Some(seq)
    } else {
        None
    };
    let mut t = 0.0f64;
    (0..spec.n_requests)
        .map(|i| {
            let at = match spec.arrival {
                Arrival::Burst => 0.0,
                Arrival::Poisson(rate) => {
                    t += rng.exp(rate);
                    t
                }
                Arrival::Uniform(gap) => {
                    t += gap;
                    t
                }
            };
            let task = match &balanced_seq {
                Some(seq) => seq[i],
                None => {
                    // weighted independent draw
                    let mut x = rng.f64() * total_weight;
                    let mut task = tasks.len() - 1;
                    for (k, tp) in tasks.iter().enumerate() {
                        if x < tp.weight {
                            task = k;
                            break;
                        }
                        x -= tp.weight;
                    }
                    task
                }
            };
            let priority = spec.priorities.draw(&mut rng);
            let (deadline, cancel_after, disconnect) = spec.stream.draw(&mut rng, at);
            let out_len = spec.output.draw(&mut rng);
            let steps = spec.prompt_tokens + out_len;
            let routing = (0..steps)
                .map(|_| {
                    (0..n_layers)
                        .map(|l| tasks[task].draw(l, top_k, n_experts, &mut rng))
                        .collect()
                })
                .collect();
            ClusterRequest {
                id: i as u64,
                task,
                priority,
                at,
                prompt_tokens: spec.prompt_tokens,
                max_output: out_len,
                deadline,
                cancel_after,
                disconnect,
                routing,
                plan: tasks[task].plan(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize, arrival: Arrival) -> WorkloadSpec {
        WorkloadSpec {
            n_requests: n,
            arrival,
            prompt_tokens: 4,
            output: OutputLen::Fixed(8),
            balanced_tasks: false,
            priorities: PriorityMix::none(),
            stream: StreamMix::none(),
            seed: 7,
        }
    }

    #[test]
    fn synthetic_profiles_tile_and_differ() {
        let tasks = TaskProfile::synthetic(4, 8, 64, 16, 0.9);
        assert_eq!(tasks.len(), 4);
        for tp in &tasks {
            assert_eq!(tp.hot.len(), 8);
            for layer in &tp.hot {
                assert_eq!(layer.len(), 16);
                assert!(layer.iter().all(|&e| e < 64));
            }
        }
        // disjoint when the sets tile exactly (4 × 16 = 64)
        let a: std::collections::HashSet<_> = tasks[0].hot[0].iter().collect();
        assert!(tasks[1].hot[0].iter().all(|e| !a.contains(e)));
    }

    #[test]
    fn draw_is_distinct_and_concentrated() {
        let tasks = TaskProfile::synthetic(2, 4, 64, 16, 0.95);
        let mut rng = Rng::new(11);
        let mut hot_hits = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            let sel = tasks[0].draw(0, 8, 64, &mut rng);
            assert_eq!(sel.len(), 8);
            let set: std::collections::HashSet<_> = sel.iter().collect();
            assert_eq!(set.len(), 8, "duplicates in {sel:?}");
            total += sel.len();
            hot_hits += sel.iter().filter(|e| tasks[0].hot[0].contains(*e)).count();
        }
        let share = hot_hits as f64 / total as f64;
        assert!(share > 0.75, "hot share {share}");
    }

    #[test]
    fn draw_saturated_hot_set_terminates() {
        // hot set smaller than K at full concentration: must still return
        // K distinct experts
        let tp = TaskProfile {
            id: 0,
            name: "tiny".into(),
            hot: vec![vec![3, 5]],
            concentration: 1.0,
            weight: 1.0,
        };
        let mut rng = Rng::new(1);
        let sel = tp.draw(0, 6, 64, &mut rng);
        assert_eq!(sel.len(), 6);
        assert!(sel.contains(&3) && sel.contains(&5));
    }

    #[test]
    fn generate_schedules_monotone_poisson() {
        let tasks = TaskProfile::synthetic(3, 4, 64, 16, 0.9);
        let reqs = generate(&spec(64, Arrival::Poisson(10.0)), &tasks, 4, 64, 8);
        assert_eq!(reqs.len(), 64);
        assert!(reqs.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(reqs.iter().all(|r| r.task < 3));
        assert!(reqs.iter().all(|r| r.routing.len() == 12));
        // heterogeneity: more than one task actually appears
        let seen: std::collections::HashSet<_> = reqs.iter().map(|r| r.task).collect();
        assert!(seen.len() > 1);
    }

    #[test]
    fn generate_deterministic_per_seed() {
        let tasks = TaskProfile::synthetic(2, 4, 64, 8, 0.9);
        let a = generate(&spec(16, Arrival::Poisson(5.0)), &tasks, 4, 64, 4);
        let b = generate(&spec(16, Arrival::Poisson(5.0)), &tasks, 4, 64, 4);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.task, y.task);
            assert_eq!(x.routing, y.routing);
        }
    }

    #[test]
    fn balanced_mode_fixes_stream_volumes() {
        let tasks = TaskProfile::synthetic(4, 2, 64, 8, 0.9);
        let mut s = spec(40, Arrival::Burst);
        s.balanced_tasks = true;
        let reqs = generate(&s, &tasks, 2, 64, 4);
        let mut counts = [0usize; 4];
        for r in &reqs {
            counts[r.task] += 1;
        }
        assert_eq!(counts, [10, 10, 10, 10]);
        // order is shuffled, not blocked
        let first_ten: std::collections::HashSet<_> =
            reqs.iter().take(10).map(|r| r.task).collect();
        assert!(first_ten.len() > 1, "balanced sequence must interleave tasks");
    }

    #[test]
    fn bimodal_output_lengths_skew_and_stay_deterministic() {
        let tasks = TaskProfile::synthetic(2, 2, 64, 8, 0.9);
        let mut s = spec(200, Arrival::Burst);
        s.output = OutputLen::Bimodal { short: 4, long: 40, long_frac: 0.25 };
        assert_eq!(s.output.cap(), 40);
        assert!((s.output.mean() - 13.0).abs() < 1e-12);
        let a = generate(&s, &tasks, 2, 64, 4);
        let b = generate(&s, &tasks, 2, 64, 4);
        let longs = a.iter().filter(|r| r.max_output == 40).count();
        let shorts = a.iter().filter(|r| r.max_output == 4).count();
        assert_eq!(longs + shorts, 200, "every draw is one of the two modes");
        assert!((20..=80).contains(&longs), "long fraction ~25%, got {longs}/200");
        // the routing trace is sized to the request's own draw
        assert!(a.iter().all(|r| r.routing.len() == r.prompt_tokens + r.max_output));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.max_output, y.max_output);
            assert_eq!(x.routing, y.routing);
        }
    }

    #[test]
    fn plan_matches_hot_sets() {
        let tasks = TaskProfile::synthetic(2, 4, 64, 8, 0.9);
        let plan = tasks[1].plan();
        assert_eq!(plan.per_layer, tasks[1].hot);
    }

    #[test]
    fn priority_mix_skews_and_stays_deterministic() {
        let tasks = TaskProfile::synthetic(2, 2, 64, 8, 0.9);
        let mut s = spec(200, Arrival::Burst);
        s.priorities = PriorityMix { high: 0.2, low: 0.5 };
        let a = generate(&s, &tasks, 2, 64, 4);
        let b = generate(&s, &tasks, 2, 64, 4);
        let highs = a.iter().filter(|r| r.priority == Priority::High).count();
        let lows = a.iter().filter(|r| r.priority == Priority::Low).count();
        let normals = a.iter().filter(|r| r.priority == Priority::Normal).count();
        assert_eq!(highs + lows + normals, 200);
        assert!((20..=80).contains(&highs), "high fraction ~20%, got {highs}/200");
        assert!((60..=140).contains(&lows), "low fraction ~50%, got {lows}/200");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.routing, y.routing);
        }
    }

    /// `PriorityMix::none` consumes no randomness: the pre-drawn traces
    /// are byte-identical to a generator without the priority dimension
    /// (locked in so priority-free comparisons keep their traffic).
    #[test]
    fn none_mix_is_all_normal_and_draw_free() {
        let tasks = TaskProfile::synthetic(2, 2, 64, 8, 0.9);
        let s = spec(50, Arrival::Poisson(10.0));
        let reqs = generate(&s, &tasks, 2, 64, 4);
        assert!(reqs.iter().all(|r| r.priority == Priority::Normal));
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(PriorityMix::none().draw(&mut rng), Priority::Normal);
        assert_eq!(rng.next_u64(), before, "none mix must not consume the stream");
    }

    /// `StreamMix::none` consumes no randomness: streaming-free workloads
    /// are byte-identical to the pre-streaming generator (locked in so
    /// every existing repro keeps its traffic).
    #[test]
    fn none_stream_mix_is_inert_and_draw_free() {
        let tasks = TaskProfile::synthetic(2, 2, 64, 8, 0.9);
        let s = spec(50, Arrival::Poisson(10.0));
        let reqs = generate(&s, &tasks, 2, 64, 4);
        assert!(reqs.iter().all(|r| {
            r.deadline.is_none() && r.cancel_after.is_none() && !r.disconnect
        }));
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(StreamMix::none().draw(&mut rng, 3.0), (None, None, false));
        assert_eq!(rng.next_u64(), before, "none mix must not consume the stream");
    }

    #[test]
    fn stream_mix_skews_and_stays_deterministic() {
        let tasks = TaskProfile::synthetic(2, 2, 64, 8, 0.9);
        let mut s = spec(200, Arrival::Poisson(20.0));
        s.stream = StreamMix {
            deadline_frac: 0.5,
            deadline_slack: 2.0,
            cancel_frac: 0.3,
            cancel_after: 1,
            disconnect_frac: 0.1,
        };
        let a = generate(&s, &tasks, 2, 64, 4);
        let b = generate(&s, &tasks, 2, 64, 4);
        let deadlines = a.iter().filter(|r| r.deadline.is_some()).count();
        let cancels = a.iter().filter(|r| r.cancel_after.is_some()).count();
        let disconnects = a.iter().filter(|r| r.disconnect).count();
        assert!((60..=140).contains(&deadlines), "deadline ~50%, got {deadlines}/200");
        assert!((30..=90).contains(&cancels), "cancel ~30%, got {cancels}/200");
        assert!((5..=40).contains(&disconnects), "disconnect ~10%, got {disconnects}/200");
        // the deadline is absolute: arrival plus the configured slack
        assert!(a
            .iter()
            .filter_map(|r| r.deadline.map(|d| d - r.at))
            .all(|slack| (slack - 2.0).abs() < 1e-12));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.deadline, y.deadline);
            assert_eq!(x.cancel_after, y.cancel_after);
            assert_eq!(x.disconnect, y.disconnect);
            assert_eq!(x.routing, y.routing);
        }
    }

    #[test]
    fn zipf_weights_skew_head_task_and_shift_traffic() {
        let mut tasks = TaskProfile::synthetic(4, 2, 16, 4, 0.9);
        zipf_weights(&mut tasks, 1.2);
        assert_eq!(tasks[0].weight, 1.0, "the head task anchors the scale");
        for pair in tasks.windows(2) {
            assert!(pair[0].weight > pair[1].weight, "weights strictly decay");
        }
        assert!(tasks.last().unwrap().weight > 0.0);
        // with balancing off the head task actually dominates arrivals
        let s = WorkloadSpec {
            n_requests: 200,
            arrival: Arrival::Burst,
            prompt_tokens: 1,
            output: OutputLen::Fixed(2),
            balanced_tasks: false,
            priorities: PriorityMix::none(),
            stream: StreamMix::none(),
            seed: 5,
        };
        let reqs = generate(&s, &tasks, 2, 16, 2);
        let head = reqs.iter().filter(|r| r.task == 0).count();
        let tail = reqs.iter().filter(|r| r.task == 3).count();
        assert!(head > tail, "Zipf head ({head}) must out-arrive the tail ({tail})");
    }
}
