//! Pluggable request dispatchers for the replica fleet.
//!
//! A [`Balancer`] sees one arriving request plus a snapshot of every
//! replica ([`ReplicaView`]) and picks the destination.  Four policies:
//!
//! * [`RoundRobin`]     — rotate, ignore all state (the fleet baseline).
//! * [`LeastLoaded`]    — shortest queue, earliest-free tiebreak (classic
//!                        join-shortest-queue).
//! * [`ExpertAffinity`] — maximize overlap between the request's predicted
//!   expert set (MELINOE's `predict_plan` output) and the replica's
//!   resident experts, minus a queue-depth penalty.  Same-task traffic
//!   converges onto the same replicas, multiplying the single-GPU cache
//!   hit-rate advantage cluster-wide.
//! * [`PriorityAffinity`] — ExpertAffinity made priority-aware: a High
//!   request discounts a replica's Low-class work from the load penalty,
//!   because preempting a Low on a warm replica beats queueing behind
//!   Highs on a cold one.  Opt-in (`--balancer prio`), never part of the
//!   stock comparison set.
//!
//! Every policy is *health-aware*: a `Down` replica is never picked
//! while any dispatchable one exists, and `Degraded` / `Recovering`
//! replicas carry a virtual-load bias so traffic drains away from them
//! without a hard cutoff.  With an all-`Healthy` fleet the bias is
//! exactly zero and every pick is bit-identical to the pre-fault
//! dispatcher — fault-free runs cannot diverge.

use anyhow::{anyhow, Result};

use super::workload::ClusterRequest;
use crate::coordinator::Priority;
use crate::fault::Health;

/// Scheduler-visible snapshot of one replica at dispatch time.  Under
/// the step-granular serving loop this is *live* state — slot occupancy
/// and queue depth at the arrival instant, not an epoch-boundary echo.
#[derive(Debug, Clone)]
pub struct ReplicaView {
    pub id: usize,
    /// Requests queued behind the decode slots.
    pub queue_depth: usize,
    /// Sequences currently occupying decode slots (in flight).
    pub slots_in_use: usize,
    /// The replica's simulated clock (when it would next be free).
    pub busy_until: f64,
    /// Fraction of the request's predicted expert set resident (or
    /// planned-resident) on this replica, in [0, 1].
    pub overlap: f64,
    /// Queued plus in-flight Low-class requests — the preemption
    /// headroom a priority-aware policy may discount from the load.
    pub low_load: usize,
    /// The dispatcher's health verdict for this replica at the arrival
    /// instant ([`Health::Healthy`] in a fault-free fleet).
    pub health: Health,
}

impl ReplicaView {
    /// Total outstanding work: queued plus in-flight.
    pub fn load(&self) -> usize {
        self.queue_depth + self.slots_in_use
    }

    /// Whether this replica may receive traffic at all.
    pub fn dispatchable(&self) -> bool {
        self.health.dispatchable()
    }

    /// Virtual load added by the health state: zero when `Healthy` (so
    /// fault-free picks are bit-identical to the health-blind
    /// dispatcher), a de-weighting surcharge when `Degraded` or
    /// `Recovering`, and infinite when `Down` — an infinite load loses
    /// every comparison against any live replica.
    pub fn health_bias(&self) -> f64 {
        match self.health {
            Health::Healthy => 0.0,
            Health::Recovering => 1.0,
            Health::Degraded => 2.0,
            Health::Down => f64::INFINITY,
        }
    }

    /// Outstanding work plus the health surcharge — what the load-based
    /// policies actually minimize.
    pub fn effective_load(&self) -> f64 {
        self.load() as f64 + self.health_bias()
    }
}

pub trait Balancer {
    fn name(&self) -> &'static str;
    /// Index into `views` of the replica that receives `req`.
    /// `views` is never empty.
    fn pick(&mut self, req: &ClusterRequest, views: &[ReplicaView]) -> usize;
    /// The policy's scalar preference for `view` — what `pick` maximizes
    /// when the policy is score-based.  State-free policies report the
    /// view's expert overlap so dispatch traces always carry a
    /// comparable affinity number.
    fn score(&self, view: &ReplicaView) -> f64 {
        view.overlap
    }
    /// Whether `pick` actually reads [`ReplicaView::overlap`].  The
    /// cluster loop skips the O(plan) overlap computation for every
    /// replica when the policy doesn't price affinity (it still fills
    /// the chosen view before recording the dispatch score).
    fn wants_overlap(&self) -> bool {
        false
    }
}

/// Rotate through replicas regardless of state.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { next: 0 }
    }
}

impl Balancer for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, _req: &ClusterRequest, views: &[ReplicaView]) -> usize {
        assert!(!views.is_empty());
        let start = self.next % views.len();
        // rotate past Down replicas; with an all-dispatchable fleet the
        // first probe wins and the cursor advances exactly as before
        for k in 0..views.len() {
            let i = (start + k) % views.len();
            if views[i].dispatchable() {
                self.next = (start + k).wrapping_add(1);
                return i;
            }
        }
        self.next = start.wrapping_add(1);
        start
    }
}

/// Join the least outstanding work (queued + in-flight, plus the health
/// surcharge); break ties toward the earliest-free replica.  A `Down`
/// replica's infinite effective load means it can never beat a live
/// one.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Balancer for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&mut self, _req: &ClusterRequest, views: &[ReplicaView]) -> usize {
        assert!(!views.is_empty());
        let mut best = 0usize;
        for i in 1..views.len() {
            let (v, b) = (&views[i], &views[best]);
            let (ve, be) = (v.effective_load(), b.effective_load());
            if ve < be || (ve == be && v.busy_until < b.busy_until) {
                best = i;
            }
        }
        best
    }
}

/// Route to the replica whose resident experts best match the request's
/// predicted expert set, with a per-queued-request score penalty so a hot
/// replica sheds load once its queue grows.
#[derive(Debug)]
pub struct ExpertAffinity {
    /// Score subtracted per queued request (overlap is in [0, 1]; the
    /// default trades a full-overlap replica against one ~10 requests
    /// shorter in queue).
    pub load_penalty: f64,
}

impl Default for ExpertAffinity {
    fn default() -> ExpertAffinity {
        ExpertAffinity { load_penalty: 0.1 }
    }
}

impl Balancer for ExpertAffinity {
    fn name(&self) -> &'static str {
        "expert-affinity"
    }

    fn wants_overlap(&self) -> bool {
        true
    }

    fn score(&self, v: &ReplicaView) -> f64 {
        if !v.dispatchable() {
            return f64::NEG_INFINITY;
        }
        v.overlap - self.load_penalty * v.effective_load()
    }

    fn pick(&mut self, _req: &ClusterRequest, views: &[ReplicaView]) -> usize {
        assert!(!views.is_empty());
        let mut best = 0usize;
        let mut best_score = self.score(&views[0]);
        for i in 1..views.len() {
            let s = self.score(&views[i]);
            // strictly better score wins; near-ties go to the replica
            // that frees up first (then lowest id, by iteration order)
            if s > best_score + 1e-12
                || ((s - best_score).abs() <= 1e-12
                    && views[i].busy_until < views[best].busy_until)
            {
                best = i;
                best_score = s;
            }
        }
        best
    }
}

/// [`ExpertAffinity`] made priority-aware: for a High-class request,
/// a replica's Low-class work is discounted from the load penalty — the
/// preemption machinery will suspend those Lows on admission, so they
/// cost the High nothing.  Preempting a Low on a warm replica can
/// therefore beat queueing behind Highs on a cold one.  Normal and Low
/// requests score exactly like [`ExpertAffinity`].
#[derive(Debug)]
pub struct PriorityAffinity {
    /// Score subtracted per unit of (priority-discounted) load — same
    /// scale as [`ExpertAffinity::load_penalty`].
    pub load_penalty: f64,
}

impl Default for PriorityAffinity {
    fn default() -> PriorityAffinity {
        PriorityAffinity { load_penalty: 0.1 }
    }
}

impl Balancer for PriorityAffinity {
    fn name(&self) -> &'static str {
        "priority-affinity"
    }

    fn wants_overlap(&self) -> bool {
        true
    }

    /// The request-free score (what the dispatch trace records): plain
    /// affinity-minus-load, identical to [`ExpertAffinity`].
    fn score(&self, v: &ReplicaView) -> f64 {
        if !v.dispatchable() {
            return f64::NEG_INFINITY;
        }
        v.overlap - self.load_penalty * v.effective_load()
    }

    fn pick(&mut self, req: &ClusterRequest, views: &[ReplicaView]) -> usize {
        assert!(!views.is_empty());
        // the load as *this* request will experience it: a High request
        // preempts Low work, so Lows don't stand in its way (the health
        // surcharge always does — a Down replica stays uninhabitable)
        let score = |v: &ReplicaView| -> f64 {
            if !v.dispatchable() {
                return f64::NEG_INFINITY;
            }
            let load = if req.priority == Priority::High {
                v.load().saturating_sub(v.low_load) as f64 + v.health_bias()
            } else {
                v.effective_load()
            };
            v.overlap - self.load_penalty * load
        };
        let mut best = 0usize;
        let mut best_score = score(&views[0]);
        for i in 1..views.len() {
            let s = score(&views[i]);
            // same tie policy as ExpertAffinity: strictly better score
            // wins, near-ties go to the replica that frees up first
            if s > best_score + 1e-12
                || ((s - best_score).abs() <= 1e-12
                    && views[i].busy_until < views[best].busy_until)
            {
                best = i;
                best_score = s;
            }
        }
        best
    }
}

/// Balancer registry for CLI / repro use.
pub fn by_name(name: &str) -> Result<Box<dyn Balancer>> {
    Ok(match name {
        "rr" | "round-robin" => Box::new(RoundRobin::new()),
        "least" | "least-loaded" => Box::new(LeastLoaded),
        "affinity" | "expert-affinity" => Box::new(ExpertAffinity::default()),
        "prio" | "priority-affinity" => Box::new(PriorityAffinity::default()),
        _ => {
            return Err(anyhow!(
                "unknown balancer {name:?} \
                 (round-robin|least-loaded|expert-affinity|priority-affinity)"
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, check_no_shrink, shrink_vec};
    use crate::util::rng::Rng;

    fn view(id: usize, depth: usize, busy: f64, overlap: f64) -> ReplicaView {
        ReplicaView {
            id,
            queue_depth: depth,
            slots_in_use: 0,
            busy_until: busy,
            overlap,
            low_load: 0,
            health: Health::Healthy,
        }
    }

    fn random_views(r: &mut Rng) -> Vec<ReplicaView> {
        let n = r.range(1, 9);
        (0..n)
            .map(|i| {
                let (depth, slots) = (r.below(12), r.below(5));
                ReplicaView {
                    id: i,
                    queue_depth: depth,
                    slots_in_use: slots,
                    busy_until: r.f64() * 10.0,
                    overlap: r.f64(),
                    low_load: r.below(depth + slots + 1),
                    health: Health::Healthy,
                }
            })
            .collect()
    }

    /// Random fleet states with random health verdicts (fault regime).
    fn random_mixed_health_views(r: &mut Rng) -> Vec<ReplicaView> {
        let healths =
            [Health::Healthy, Health::Degraded, Health::Down, Health::Recovering];
        let mut views = random_views(r);
        for v in &mut views {
            v.health = healths[r.below(healths.len())];
        }
        views
    }

    #[test]
    fn round_robin_cycles() {
        let mut b = RoundRobin::new();
        let views: Vec<ReplicaView> = (0..3).map(|i| view(i, 0, 0.0, 0.0)).collect();
        let req = ClusterRequest::probe(0);
        let picks: Vec<usize> = (0..6).map(|_| b.pick(&req, &views)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_short_queue_then_earliest_free() {
        let mut b = LeastLoaded;
        let req = ClusterRequest::probe(0);
        let views = vec![view(0, 3, 0.0, 0.0), view(1, 1, 5.0, 0.0), view(2, 1, 2.0, 0.0)];
        assert_eq!(b.pick(&req, &views), 2);
    }

    #[test]
    fn least_loaded_counts_live_slots() {
        let mut b = LeastLoaded;
        let req = ClusterRequest::probe(0);
        // replica 0 has the shorter queue but more sequences in flight
        let views = vec![
            ReplicaView {
                id: 0,
                queue_depth: 1,
                slots_in_use: 4,
                busy_until: 0.0,
                overlap: 0.0,
                low_load: 0,
                health: Health::Healthy,
            },
            ReplicaView {
                id: 1,
                queue_depth: 2,
                slots_in_use: 0,
                busy_until: 9.0,
                overlap: 0.0,
                low_load: 0,
                health: Health::Healthy,
            },
        ];
        assert_eq!(b.pick(&req, &views), 1);
        assert_eq!(views[0].load(), 5);
    }

    #[test]
    fn affinity_prefers_overlap_until_queue_penalty_wins() {
        let mut b = ExpertAffinity { load_penalty: 0.1 };
        let req = ClusterRequest::probe(0);
        let hot_short = vec![view(0, 0, 0.0, 0.9), view(1, 0, 0.0, 0.1)];
        assert_eq!(b.pick(&req, &hot_short), 0);
        // 9 queued requests erase a 0.8 overlap advantage
        let hot_long = vec![view(0, 9, 0.0, 0.9), view(1, 0, 0.0, 0.1)];
        assert_eq!(b.pick(&req, &hot_long), 1);
    }

    #[test]
    fn round_robin_skips_down_replicas() {
        let mut b = RoundRobin::new();
        let req = ClusterRequest::probe(0);
        let mut views: Vec<ReplicaView> = (0..3).map(|i| view(i, 0, 0.0, 0.0)).collect();
        views[1].health = Health::Down;
        let picks: Vec<usize> = (0..4).map(|_| b.pick(&req, &views)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "the Down replica is rotated past");
        // Degraded / Recovering stay in rotation — RR ignores weight
        views[1].health = Health::Degraded;
        assert_eq!(b.pick(&req, &views), 1);
    }

    #[test]
    fn least_loaded_deweights_degraded_and_never_picks_down() {
        let mut b = LeastLoaded;
        let req = ClusterRequest::probe(0);
        // idle but degraded loses to a lightly-loaded healthy replica
        let mut views = vec![view(0, 1, 0.0, 0.0), view(1, 0, 0.0, 0.0)];
        views[1].health = Health::Degraded;
        assert_eq!(b.pick(&req, &views), 0, "degraded surcharge outweighs one queued request");
        // an idle Down replica never beats a busy live one
        views[1].health = Health::Down;
        views[0].queue_depth = 50;
        assert_eq!(b.pick(&req, &views), 0);
    }

    #[test]
    fn affinity_scores_down_as_uninhabitable() {
        let b = ExpertAffinity::default();
        let mut v = view(0, 0, 0.0, 1.0);
        assert!(b.score(&v) > 0.9);
        v.health = Health::Down;
        assert_eq!(b.score(&v), f64::NEG_INFINITY);
        // a full-overlap Down replica loses to a zero-overlap healthy one
        let mut af = ExpertAffinity::default();
        let req = ClusterRequest::probe(0);
        let mut views = vec![view(0, 0, 0.0, 1.0), view(1, 0, 0.0, 0.0)];
        views[0].health = Health::Down;
        assert_eq!(af.pick(&req, &views), 1);
    }

    /// A High request sees Low work as preemptable headroom: the warm
    /// replica buried in Lows still wins it.  Normal requests score like
    /// plain ExpertAffinity, and the Low discount never resurrects a
    /// Down replica.
    #[test]
    fn priority_affinity_discounts_low_work_for_high_requests() {
        let mut b = PriorityAffinity::default();
        let mut high = ClusterRequest::probe(0);
        high.priority = Priority::High;
        let normal = ClusterRequest::probe(0);
        // replica 0: warm but 9 queued — all Low; replica 1: cold, idle
        let mut views = vec![view(0, 9, 0.0, 0.9), view(1, 0, 0.0, 0.1)];
        views[0].low_load = 9;
        assert_eq!(b.pick(&normal, &views), 1, "a Normal request queues behind the Lows");
        assert_eq!(b.pick(&high, &views), 0, "a High request preempts them instead");
        // with nothing to preempt, the High queues like everyone else
        views[0].low_load = 0;
        assert_eq!(b.pick(&high, &views), 1);
        // and it never makes a Down replica inhabitable
        views[0].low_load = 9;
        views[0].health = Health::Down;
        assert_eq!(b.pick(&high, &views), 1);
        // request-free trace score matches ExpertAffinity's
        views[0].health = Health::Healthy;
        let ea = ExpertAffinity::default();
        assert_eq!(b.score(&views[0]).to_bits(), ea.score(&views[0]).to_bits());
    }

    #[test]
    fn by_name_resolves_aliases() {
        for n in [
            "rr",
            "round-robin",
            "least",
            "least-loaded",
            "affinity",
            "expert-affinity",
            "prio",
            "priority-affinity",
        ] {
            assert!(by_name(n).is_ok(), "{n}");
        }
        assert!(by_name("random").is_err());
    }

    // --------------------------------------------------- property tests

    /// Every balancer returns a valid replica index for arbitrary fleet
    /// states — the cluster loop's "dispatched exactly once" invariant
    /// reduces to this plus its own accounting test (see cluster::tests).
    #[test]
    fn prop_pick_always_in_bounds() {
        check_no_shrink(300, random_views, |views| {
            let req = ClusterRequest::probe(0);
            let mut rr = RoundRobin::new();
            let mut ll = LeastLoaded;
            let mut af = ExpertAffinity::default();
            let mut pa = PriorityAffinity::default();
            rr.pick(&req, views) < views.len()
                && ll.pick(&req, views) < views.len()
                && af.pick(&req, views) < views.len()
                && pa.pick(&req, views) < views.len()
        });
    }

    /// With no load penalty, ExpertAffinity's chosen replica never has
    /// less overlap than RoundRobin's *worst possible* choice on the same
    /// views (RR ignores overlap, so its worst case is the fleet minimum).
    #[test]
    fn prop_affinity_at_least_round_robin_worst_case() {
        check(
            300,
            random_views,
            |views| shrink_vec(views, |_| vec![]),
            |views| {
                if views.is_empty() {
                    return true;
                }
                let req = ClusterRequest::probe(0);
                let mut af = ExpertAffinity { load_penalty: 0.0 };
                let chosen = af.pick(&req, views);
                let min = views.iter().map(|v| v.overlap).fold(f64::INFINITY, f64::min);
                views[chosen].overlap >= min - 1e-12
            },
        );
    }

    /// With the penalty active, the chosen replica maximizes the score —
    /// no other replica strictly beats it.
    #[test]
    fn prop_affinity_picks_argmax_score() {
        check_no_shrink(300, random_views, |views| {
            let req = ClusterRequest::probe(0);
            let mut af = ExpertAffinity::default();
            let chosen = af.pick(&req, views);
            let cs = af.score(&views[chosen]);
            views.iter().all(|v| af.score(v) <= cs + 1e-9)
        });
    }

    /// Under arbitrary health mixes, no policy ever picks a `Down`
    /// replica while at least one dispatchable replica exists — the
    /// dispatcher-side half of the "no dispatch to Down" invariant.
    #[test]
    fn prop_no_policy_picks_down_while_alternatives_exist() {
        check_no_shrink(300, random_mixed_health_views, |views| {
            if !views.iter().any(ReplicaView::dispatchable) {
                return true; // run_cluster defers instead of dispatching
            }
            let mut req = ClusterRequest::probe(0);
            req.priority = Priority::High; // exercise the Low discount too
            let mut rr = RoundRobin::new();
            let mut ll = LeastLoaded;
            let mut af = ExpertAffinity::default();
            let mut pa = PriorityAffinity::default();
            views[rr.pick(&req, views)].dispatchable()
                && views[ll.pick(&req, views)].dispatchable()
                && views[af.pick(&req, views)].dispatchable()
                && views[pa.pick(&req, views)].dispatchable()
        });
    }
}
