//! Pluggable request dispatchers for the replica fleet.
//!
//! A [`Balancer`] sees one arriving request plus a snapshot of every
//! replica ([`ReplicaView`]) and picks the destination.  Three policies:
//!
//! * [`RoundRobin`]     — rotate, ignore all state (the fleet baseline).
//! * [`LeastLoaded`]    — shortest queue, earliest-free tiebreak (classic
//!                        join-shortest-queue).
//! * [`ExpertAffinity`] — maximize overlap between the request's predicted
//!   expert set (MELINOE's `predict_plan` output) and the replica's
//!   resident experts, minus a queue-depth penalty.  Same-task traffic
//!   converges onto the same replicas, multiplying the single-GPU cache
//!   hit-rate advantage cluster-wide.

use anyhow::{anyhow, Result};

use super::workload::ClusterRequest;

/// Scheduler-visible snapshot of one replica at dispatch time.  Under
/// the step-granular serving loop this is *live* state — slot occupancy
/// and queue depth at the arrival instant, not an epoch-boundary echo.
#[derive(Debug, Clone)]
pub struct ReplicaView {
    pub id: usize,
    /// Requests queued behind the decode slots.
    pub queue_depth: usize,
    /// Sequences currently occupying decode slots (in flight).
    pub slots_in_use: usize,
    /// The replica's simulated clock (when it would next be free).
    pub busy_until: f64,
    /// Fraction of the request's predicted expert set resident (or
    /// planned-resident) on this replica, in [0, 1].
    pub overlap: f64,
}

impl ReplicaView {
    /// Total outstanding work: queued plus in-flight.
    pub fn load(&self) -> usize {
        self.queue_depth + self.slots_in_use
    }
}

pub trait Balancer {
    fn name(&self) -> &'static str;
    /// Index into `views` of the replica that receives `req`.
    /// `views` is never empty.
    fn pick(&mut self, req: &ClusterRequest, views: &[ReplicaView]) -> usize;
    /// The policy's scalar preference for `view` — what `pick` maximizes
    /// when the policy is score-based.  State-free policies report the
    /// view's expert overlap so dispatch traces always carry a
    /// comparable affinity number.
    fn score(&self, view: &ReplicaView) -> f64 {
        view.overlap
    }
}

/// Rotate through replicas regardless of state.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { next: 0 }
    }
}

impl Balancer for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, _req: &ClusterRequest, views: &[ReplicaView]) -> usize {
        assert!(!views.is_empty());
        let i = self.next % views.len();
        self.next = self.next.wrapping_add(1);
        i
    }
}

/// Join the least outstanding work (queued + in-flight); break ties
/// toward the earliest-free replica.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Balancer for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&mut self, _req: &ClusterRequest, views: &[ReplicaView]) -> usize {
        assert!(!views.is_empty());
        let mut best = 0usize;
        for i in 1..views.len() {
            let (v, b) = (&views[i], &views[best]);
            if v.load() < b.load() || (v.load() == b.load() && v.busy_until < b.busy_until) {
                best = i;
            }
        }
        best
    }
}

/// Route to the replica whose resident experts best match the request's
/// predicted expert set, with a per-queued-request score penalty so a hot
/// replica sheds load once its queue grows.
#[derive(Debug)]
pub struct ExpertAffinity {
    /// Score subtracted per queued request (overlap is in [0, 1]; the
    /// default trades a full-overlap replica against one ~10 requests
    /// shorter in queue).
    pub load_penalty: f64,
}

impl Default for ExpertAffinity {
    fn default() -> ExpertAffinity {
        ExpertAffinity { load_penalty: 0.1 }
    }
}

impl Balancer for ExpertAffinity {
    fn name(&self) -> &'static str {
        "expert-affinity"
    }

    fn score(&self, v: &ReplicaView) -> f64 {
        v.overlap - self.load_penalty * v.load() as f64
    }

    fn pick(&mut self, _req: &ClusterRequest, views: &[ReplicaView]) -> usize {
        assert!(!views.is_empty());
        let mut best = 0usize;
        let mut best_score = self.score(&views[0]);
        for i in 1..views.len() {
            let s = self.score(&views[i]);
            // strictly better score wins; near-ties go to the replica
            // that frees up first (then lowest id, by iteration order)
            if s > best_score + 1e-12
                || ((s - best_score).abs() <= 1e-12
                    && views[i].busy_until < views[best].busy_until)
            {
                best = i;
                best_score = s;
            }
        }
        best
    }
}

/// Balancer registry for CLI / repro use.
pub fn by_name(name: &str) -> Result<Box<dyn Balancer>> {
    Ok(match name {
        "rr" | "round-robin" => Box::new(RoundRobin::new()),
        "least" | "least-loaded" => Box::new(LeastLoaded),
        "affinity" | "expert-affinity" => Box::new(ExpertAffinity::default()),
        _ => {
            return Err(anyhow!(
                "unknown balancer {name:?} (round-robin|least-loaded|expert-affinity)"
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, check_no_shrink, shrink_vec};
    use crate::util::rng::Rng;

    fn view(id: usize, depth: usize, busy: f64, overlap: f64) -> ReplicaView {
        ReplicaView { id, queue_depth: depth, slots_in_use: 0, busy_until: busy, overlap }
    }

    fn random_views(r: &mut Rng) -> Vec<ReplicaView> {
        let n = r.range(1, 9);
        (0..n)
            .map(|i| ReplicaView {
                id: i,
                queue_depth: r.below(12),
                slots_in_use: r.below(5),
                busy_until: r.f64() * 10.0,
                overlap: r.f64(),
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut b = RoundRobin::new();
        let views: Vec<ReplicaView> = (0..3).map(|i| view(i, 0, 0.0, 0.0)).collect();
        let req = ClusterRequest::probe(0);
        let picks: Vec<usize> = (0..6).map(|_| b.pick(&req, &views)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_short_queue_then_earliest_free() {
        let mut b = LeastLoaded;
        let req = ClusterRequest::probe(0);
        let views = vec![view(0, 3, 0.0, 0.0), view(1, 1, 5.0, 0.0), view(2, 1, 2.0, 0.0)];
        assert_eq!(b.pick(&req, &views), 2);
    }

    #[test]
    fn least_loaded_counts_live_slots() {
        let mut b = LeastLoaded;
        let req = ClusterRequest::probe(0);
        // replica 0 has the shorter queue but more sequences in flight
        let views = vec![
            ReplicaView { id: 0, queue_depth: 1, slots_in_use: 4, busy_until: 0.0, overlap: 0.0 },
            ReplicaView { id: 1, queue_depth: 2, slots_in_use: 0, busy_until: 9.0, overlap: 0.0 },
        ];
        assert_eq!(b.pick(&req, &views), 1);
        assert_eq!(views[0].load(), 5);
    }

    #[test]
    fn affinity_prefers_overlap_until_queue_penalty_wins() {
        let mut b = ExpertAffinity { load_penalty: 0.1 };
        let req = ClusterRequest::probe(0);
        let hot_short = vec![view(0, 0, 0.0, 0.9), view(1, 0, 0.0, 0.1)];
        assert_eq!(b.pick(&req, &hot_short), 0);
        // 9 queued requests erase a 0.8 overlap advantage
        let hot_long = vec![view(0, 9, 0.0, 0.9), view(1, 0, 0.0, 0.1)];
        assert_eq!(b.pick(&req, &hot_long), 1);
    }

    #[test]
    fn by_name_resolves_aliases() {
        for n in ["rr", "round-robin", "least", "least-loaded", "affinity", "expert-affinity"] {
            assert!(by_name(n).is_ok(), "{n}");
        }
        assert!(by_name("random").is_err());
    }

    // --------------------------------------------------- property tests

    /// Every balancer returns a valid replica index for arbitrary fleet
    /// states — the cluster loop's "dispatched exactly once" invariant
    /// reduces to this plus its own accounting test (see cluster::tests).
    #[test]
    fn prop_pick_always_in_bounds() {
        check_no_shrink(300, random_views, |views| {
            let req = ClusterRequest::probe(0);
            let mut rr = RoundRobin::new();
            let mut ll = LeastLoaded;
            let mut af = ExpertAffinity::default();
            rr.pick(&req, views) < views.len()
                && ll.pick(&req, views) < views.len()
                && af.pick(&req, views) < views.len()
        });
    }

    /// With no load penalty, ExpertAffinity's chosen replica never has
    /// less overlap than RoundRobin's *worst possible* choice on the same
    /// views (RR ignores overlap, so its worst case is the fleet minimum).
    #[test]
    fn prop_affinity_at_least_round_robin_worst_case() {
        check(
            300,
            random_views,
            |views| shrink_vec(views, |_| vec![]),
            |views| {
                if views.is_empty() {
                    return true;
                }
                let req = ClusterRequest::probe(0);
                let mut af = ExpertAffinity { load_penalty: 0.0 };
                let chosen = af.pick(&req, views);
                let min = views.iter().map(|v| v.overlap).fold(f64::INFINITY, f64::min);
                views[chosen].overlap >= min - 1e-12
            },
        );
    }

    /// With the penalty active, the chosen replica maximizes the score —
    /// no other replica strictly beats it.
    #[test]
    fn prop_affinity_picks_argmax_score() {
        check_no_shrink(300, random_views, |views| {
            let req = ClusterRequest::probe(0);
            let mut af = ExpertAffinity::default();
            let chosen = af.pick(&req, views);
            let cs = af.score(&views[chosen]);
            views.iter().all(|v| af.score(v) <= cs + 1e-9)
        });
    }
}
