//! A serving replica: one GPU's memory hierarchy plus a decoder.
//!
//! Each [`Replica`] owns the full single-GPU simulation stack — per-layer
//! [`ExpertCache`]s, a [`TransferEngine`] for PCIe accounting, a VRAM
//! budget-derived capacity, and its own [`SimClock`] — and is driven
//! through the existing [`Decoder`] trait, so the cluster scheduler is
//! testable with the same mocks the coordinator tests use.
//!
//! Costing follows the engine's Eq. 3 decomposition: the decoder supplies
//! `Time_compute` for a batch, and the replica replays the batch's
//! pre-drawn routing trace against its *persistent* caches to add the
//! `N_miss · Time_transfer` term.  Persistence across requests is the
//! point: a replica that keeps serving the same task's traffic stays
//! hit-bound, which is what affinity routing exploits.

use std::collections::VecDeque;

use anyhow::Result;

use crate::cache::{EvictionKind, ExpertCache};
use crate::clock::{CostModel, GpuSpec, PaperDims, SimClock};
use crate::coordinator::Decoder;
use crate::metrics::{Report, RequestMetrics};
use crate::pcie::TransferEngine;
use crate::predictor::PrefetchPlan;
use crate::quant::QuantMode;
use crate::vram::VramBudget;

use super::workload::ClusterRequest;

/// Static description of one replica's model + memory configuration.
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    /// GPU-resident experts per layer (derived from the VRAM ledger).
    pub capacity: usize,
    pub eviction: EvictionKind,
    pub quant: QuantMode,
    /// Apply the request's predictor prefetch plan at batch start.
    pub prefetch: bool,
    pub gpu: GpuSpec,
    pub dims: PaperDims,
}

impl ReplicaSpec {
    /// OLMoE at paper scale under the paper's 3 GB VRAM budget (§4.1);
    /// per-layer capacity comes from the [`VramBudget`] ledger.
    pub fn olmoe(gpu: GpuSpec) -> ReplicaSpec {
        let dims = PaperDims {
            n_layers: 16,
            n_experts: 64,
            top_k: 8,
            d_model: 2048,
            d_ff: 1024,
            vocab: 50304,
        };
        ReplicaSpec::from_vram_gb(gpu, dims, 3.0)
    }

    /// Derive per-layer expert capacity from a VRAM budget in GB.
    pub fn from_vram_gb(gpu: GpuSpec, dims: PaperDims, vram_gb: f64) -> ReplicaSpec {
        let quant = QuantMode::Int4;
        let capacity = VramBudget::gb(vram_gb, dims).capacity_per_layer(quant).max(1);
        ReplicaSpec {
            n_layers: dims.n_layers,
            n_experts: dims.n_experts,
            top_k: dims.top_k,
            capacity,
            eviction: EvictionKind::Lfu,
            quant,
            prefetch: true,
            gpu,
            dims,
        }
    }

    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.gpu.clone(), self.dims)
    }

    /// Analytic compute-only service time of one request (no transfer
    /// stalls) — used to auto-scale offered load.
    pub fn est_service_seconds(&self, prompt_tokens: usize, max_output: usize) -> f64 {
        let cost = self.cost_model();
        let steps = (prompt_tokens + max_output) as f64;
        let per_step = self.n_layers as f64
            * (cost.attn_time(1) + cost.expert_exec_time(self.top_k, self.top_k, self.quant))
            + cost.head_time(1);
        steps * per_step
    }
}

/// Analytic compute-time decoder for cluster simulation: batch-amortized
/// attention/head plus grouped-expert execution, no PJRT required.
pub struct SimComputeDecoder {
    cost: CostModel,
    n_layers: usize,
    n_experts: usize,
    top_k: usize,
    quant: QuantMode,
}

impl SimComputeDecoder {
    pub fn new(spec: &ReplicaSpec) -> SimComputeDecoder {
        SimComputeDecoder {
            cost: spec.cost_model(),
            n_layers: spec.n_layers,
            n_experts: spec.n_experts,
            top_k: spec.top_k,
            quant: spec.quant,
        }
    }
}

impl Decoder for SimComputeDecoder {
    fn decode_batch(
        &mut self,
        prompts: &[Vec<usize>],
        max_output: usize,
    ) -> Result<(Vec<Vec<usize>>, Report)> {
        let b = prompts.len().max(1);
        let prompt_steps = prompts.iter().map(|p| p.len()).max().unwrap_or(0);
        let steps = prompt_steps + max_output;
        // distinct experts a lockstep batch step touches is capped by E
        let unique = (self.top_k * b).min(self.n_experts);
        let step_time = self.n_layers as f64
            * (self.cost.attn_time(b)
                + self.cost.expert_exec_time(unique, self.top_k * b, self.quant))
            + self.cost.head_time(b);
        let sim = steps as f64 * step_time;
        let ttft = prompt_steps as f64 * step_time;
        let outputs: Vec<Vec<usize>> = prompts.iter().map(|_| vec![1usize; max_output]).collect();
        let mut report = Report::default();
        for p in prompts {
            report.requests.push(RequestMetrics {
                prompt_tokens: p.len(),
                output_tokens: max_output,
                sim_seconds: sim,
                sim_ttft: ttft,
                wall_seconds: 0.0,
            });
        }
        Ok((outputs, report))
    }
}

/// One finished request, in the replica's simulated timeline.
#[derive(Debug, Clone)]
pub struct Completion {
    pub request_id: u64,
    pub task: usize,
    pub arrival: f64,
    pub started: f64,
    pub finished: f64,
    pub output_tokens: usize,
}

impl Completion {
    pub fn queue_wait(&self) -> f64 {
        (self.started - self.arrival).max(0.0)
    }

    pub fn latency(&self) -> f64 {
        (self.finished - self.arrival).max(0.0)
    }
}

/// One serving replica (see module docs).
pub struct Replica<D: Decoder> {
    pub id: usize,
    pub spec: ReplicaSpec,
    decoder: D,
    cost: CostModel,
    pub cache: ExpertCache,
    pub pcie: TransferEngine,
    pub clock: SimClock,
    queue: VecDeque<ClusterRequest>,
    /// Prefetch plan of the most recently enqueued request: the replica's
    /// *planned* residency, which the affinity scorer may consult before
    /// the caches have warmed (burst arrivals dispatch ahead of decode).
    last_plan: Option<PrefetchPlan>,
    pub completions: Vec<Completion>,
    pub busy_seconds: f64,
    pub peak_queue_depth: usize,
}

impl<D: Decoder> Replica<D> {
    pub fn new(id: usize, spec: ReplicaSpec, decoder: D) -> Replica<D> {
        let cache = ExpertCache::new(spec.n_layers, spec.n_experts, spec.capacity, spec.eviction);
        let cost = spec.cost_model();
        Replica {
            id,
            spec,
            decoder,
            cost,
            cache,
            pcie: TransferEngine::new(),
            clock: SimClock::new(),
            queue: VecDeque::new(),
            last_plan: None,
            completions: Vec::new(),
            busy_seconds: 0.0,
            peak_queue_depth: 0,
        }
    }

    pub fn enqueue(&mut self, req: ClusterRequest) {
        self.last_plan = Some(req.plan.clone());
        self.queue.push_back(req);
        self.peak_queue_depth = self.peak_queue_depth.max(self.queue.len());
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn busy_until(&self) -> f64 {
        self.clock.now()
    }

    /// Fraction of `plan`'s experts resident in this replica's caches,
    /// taking the max with the planned residency of the queue tail so
    /// affinity works before the first decode warms anything.
    pub fn affinity_overlap(&self, plan: &PrefetchPlan) -> f64 {
        let resident = self.resident_overlap(plan);
        match &self.last_plan {
            Some(last) => resident.max(plan_overlap(plan, last)),
            None => resident,
        }
    }

    /// Fraction of `plan`'s experts currently resident (mean over layers,
    /// weighted by set size).
    pub fn resident_overlap(&self, plan: &PrefetchPlan) -> f64 {
        let mut num = 0usize;
        let mut den = 0usize;
        for (l, set) in plan.per_layer.iter().enumerate() {
            if l >= self.cache.layers.len() {
                break;
            }
            den += set.len();
            num += set.iter().filter(|&&e| self.cache.layers[l].contains(e)).count();
        }
        if den == 0 {
            0.0
        } else {
            num as f64 / den as f64
        }
    }

    /// Serve queued requests until this replica's clock reaches `horizon`
    /// (a batch started before the horizon runs to completion, so clocks
    /// may overshoot by one batch — the lockstep-epoch convention).
    pub fn run_until(&mut self, horizon: f64, max_batch: usize) -> Result<()> {
        loop {
            let start = match self.queue.front() {
                Some(front) => self.clock.now().max(front.at),
                None => break,
            };
            if start >= horizon {
                break;
            }
            // form a batch from requests that have arrived by `start`
            let mut batch = vec![self.queue.pop_front().unwrap()];
            while batch.len() < max_batch.max(1) {
                let take = matches!(self.queue.front(), Some(r) if r.at <= start);
                if !take {
                    break;
                }
                batch.push(self.queue.pop_front().unwrap());
            }
            if self.clock.now() < start {
                let idle = start - self.clock.now();
                self.clock.advance(idle);
            }
            let t_start = self.clock.now();

            // 1. predictor prefetch: prefill each layer with the union of
            //    the batch's predicted sets (non-blocking transfers that
            //    occupy the PCIe link — later demand misses queue behind
            //    them, as in the engine's overlap model).
            if self.spec.prefetch {
                self.clock.advance(self.cost.predictor_time());
                for l in 0..self.spec.n_layers {
                    let mut target: Vec<usize> = Vec::new();
                    for req in &batch {
                        if let Some(set) = req.plan.per_layer.get(l) {
                            for &e in set {
                                if !target.contains(&e) {
                                    target.push(e);
                                }
                            }
                        }
                    }
                    if target.is_empty() {
                        continue;
                    }
                    let loads = self.cache.layer(l).prefill(&target);
                    for _ in loads {
                        self.pcie.prefetch_h2d(&self.cost, &self.clock, self.spec.quant);
                    }
                }
            }

            // 2. compute time from the decoder (Eq. 3's Time_compute)
            let prompts: Vec<Vec<usize>> =
                batch.iter().map(|r| vec![r.task; r.prompt_tokens.max(1)]).collect();
            let max_output = batch.iter().map(|r| r.max_output).max().unwrap_or(0);
            let (_tokens, report) = self.decoder.decode_batch(&prompts, max_output)?;
            let compute = report.requests.first().map(|r| r.sim_seconds).unwrap_or(0.0);

            // 3. replay the routing traces against the persistent caches:
            //    each miss demand-transfers and stalls (Eq. 3's N_miss ·
            //    Time_transfer)
            let steps = batch.iter().map(|r| r.routing.len()).max().unwrap_or(0);
            for step in 0..steps {
                for req in &batch {
                    let layers = match req.routing.get(step) {
                        Some(l) => l,
                        None => continue,
                    };
                    for (l, experts) in layers.iter().enumerate() {
                        for &e in experts {
                            let hit = self.cache.layer(l).request(e);
                            if !hit {
                                self.pcie.demand_h2d(&self.cost, &mut self.clock, self.spec.quant);
                                if self.cache.layer(l).insert(e, experts).is_some() {
                                    self.pcie.evict_d2h(&self.cost, self.spec.quant);
                                }
                            }
                        }
                    }
                }
                self.cache.token_tick();
            }
            self.clock.advance(compute);

            let t_end = self.clock.now();
            self.busy_seconds += t_end - t_start;
            for req in batch {
                self.completions.push(Completion {
                    request_id: req.id,
                    task: req.task,
                    arrival: req.at,
                    started: t_start,
                    finished: t_end,
                    output_tokens: req.max_output,
                });
            }
        }
        Ok(())
    }
}

/// Mean per-layer overlap between two prefetch plans (size-weighted).
fn plan_overlap(a: &PrefetchPlan, b: &PrefetchPlan) -> f64 {
    let mut num = 0usize;
    let mut den = 0usize;
    for (l, set) in a.per_layer.iter().enumerate() {
        let other = match b.per_layer.get(l) {
            Some(o) => o,
            None => continue,
        };
        den += set.len();
        num += set.iter().filter(|e| other.contains(*e)).count();
    }
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

#[cfg(test)]
mod tests {
    use super::super::workload::{generate, TaskProfile, WorkloadSpec};
    use super::*;
    use crate::coordinator::workload::Arrival;

    fn spec() -> ReplicaSpec {
        let mut s = ReplicaSpec::olmoe(GpuSpec::h100());
        // small model for fast unit tests
        s.n_layers = 4;
        s.n_experts = 16;
        s.top_k = 2;
        s.capacity = 4;
        s
    }

    fn requests(n: usize, tasks: usize, seed: u64, s: &ReplicaSpec) -> Vec<ClusterRequest> {
        let profiles = TaskProfile::synthetic(tasks, s.n_layers, s.n_experts, s.capacity, 0.9);
        let wl = WorkloadSpec {
            n_requests: n,
            arrival: Arrival::Burst,
            prompt_tokens: 2,
            max_output: 4,
            balanced_tasks: false,
            seed,
        };
        generate(&wl, &profiles, s.n_layers, s.n_experts, s.top_k)
    }

    #[test]
    fn replica_serves_all_queued_requests() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SimComputeDecoder::new(&s));
        for req in requests(6, 2, 3, &s) {
            r.enqueue(req);
        }
        assert_eq!(r.queue_depth(), 6);
        assert_eq!(r.peak_queue_depth, 6);
        r.run_until(f64::INFINITY, 2).unwrap();
        assert_eq!(r.queue_depth(), 0);
        assert_eq!(r.completions.len(), 6);
        assert!(r.clock.now() > 0.0);
        assert!(r.busy_seconds > 0.0);
        // every routed expert request was accounted as hit or miss
        let stats = r.cache.total_stats();
        assert_eq!(stats.requests(), stats.hits + stats.misses);
        assert!(stats.requests() > 0);
        // monotone per-request timeline
        for c in &r.completions {
            assert!(c.finished >= c.started);
            assert!(c.queue_wait() >= 0.0);
            assert!(c.latency() > 0.0);
        }
    }

    #[test]
    fn horizon_bounds_batch_starts() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SimComputeDecoder::new(&s));
        for req in requests(8, 2, 4, &s) {
            r.enqueue(req);
        }
        // a tiny horizon admits at most the first batch
        r.run_until(1e-9, 4).unwrap();
        assert!(r.completions.len() <= 4);
        let after_first = r.completions.len();
        assert!(after_first > 0, "a batch starting before the horizon must run");
        r.run_until(f64::INFINITY, 4).unwrap();
        assert_eq!(r.completions.len(), 8);
    }

    #[test]
    fn same_task_traffic_warms_cache() {
        let s = spec();
        // task-pure stream on one replica: later requests should mostly hit
        let mut r = Replica::new(0, s.clone(), SimComputeDecoder::new(&s));
        let reqs: Vec<ClusterRequest> =
            requests(12, 1, 5, &s).into_iter().filter(|q| q.task == 0).collect();
        assert!(reqs.len() >= 8);
        for req in reqs {
            r.enqueue(req);
        }
        r.run_until(f64::INFINITY, 1).unwrap();
        let stats = r.cache.total_stats();
        assert!(
            stats.hit_rate() > 0.5,
            "persistent cache should be hit-bound on task-pure traffic: {}",
            stats.hit_rate()
        );
    }

    #[test]
    fn affinity_overlap_sees_planned_residency_before_decode() {
        let s = spec();
        let mut r = Replica::new(0, s.clone(), SimComputeDecoder::new(&s));
        let profiles = TaskProfile::synthetic(2, s.n_layers, s.n_experts, s.capacity, 0.9);
        // cold: no residency, no queue
        assert_eq!(r.affinity_overlap(&profiles[0].plan()), 0.0);
        let reqs = requests(4, 2, 9, &s);
        let task0 = reqs.iter().find(|q| q.task == 0).cloned();
        if let Some(q) = task0 {
            r.enqueue(q);
            // planned residency: same task scores high, other task low
            let same = r.affinity_overlap(&profiles[0].plan());
            let other = r.affinity_overlap(&profiles[1].plan());
            assert!(same > 0.99, "same-task planned overlap {same}");
            assert!(other < same, "other-task overlap {other} >= {same}");
        }
    }

    #[test]
    fn est_service_positive_and_scales() {
        let s = ReplicaSpec::olmoe(GpuSpec::h100());
        let a = s.est_service_seconds(8, 16);
        let b = s.est_service_seconds(8, 32);
        assert!(a > 0.0);
        assert!(b > a);
        // paper-scale OLMoE decodes tens of ms per token (Table 1 regime)
        let per_tok = a / 24.0;
        assert!((0.001..1.0).contains(&per_tok), "per-token {per_tok}");
    }

    #[test]
    fn vram_budget_derives_capacity() {
        let s = ReplicaSpec::olmoe(GpuSpec::h100());
        assert!((2..=64).contains(&s.capacity), "capacity {}", s.capacity);
        let big = ReplicaSpec::from_vram_gb(GpuSpec::h100(), s.dims, 400.0);
        assert_eq!(big.capacity, s.dims.n_experts);
    }
}
